"""ShapeDtypeStruct input stand-ins for every (arch × shape) dry-run cell —
weak-type-correct, shardable, zero device allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES
from repro.models import model as M
from repro.models import steps as ST
from repro.optim import init_opt_state


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg, shape_cfg) -> dict:
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32),
             "labels": _sds((b, s), jnp.int32)}
    if cfg.frontend:
        batch["embeds"] = _sds((b, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


def decode_inputs_specs(cfg, shape_cfg):
    """(cache, token, index) stand-ins for a serve_step decode cell: one new
    token against a KV/state cache of seq_len."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    enc_len = cfg.frontend_len if cfg.encoder_layers else 0
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, b, s, enc_len=enc_len))
    token = _sds((b, 1), jnp.int32)
    return cache, token


def abstract_train_state(cfg):
    params = M.abstract_params(cfg)
    opt = jax.eval_shape(lambda p: init_opt_state(p), params)
    return params, opt


def abstract_params(cfg):
    return M.abstract_params(cfg)
