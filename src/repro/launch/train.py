"""Training launcher: mesh + logical shardings + fault-tolerant loop.

On real hardware this runs under `python -m repro.launch.train --arch ...`
per host; on this CPU container it drives the reduced smoke configs (the
examples use it for the ~100M-param demonstration run).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from repro.configs import get_config, smoke_config
from repro.data import DataConfig, SyntheticLMStream
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.shardings import ShardingRules
from repro.models import steps as ST
from repro.optim import AdamWConfig
from repro.runtime import FaultTolerantLoop


def build(cfg, *, mesh=None, seq_len=128, global_batch=8, seed=0,
          lr=3e-4, total_steps=1000):
    mesh = mesh or make_local_mesh()
    rules = ShardingRules(mesh)
    params, opt_state = ST.init_train_state(cfg, jax.random.PRNGKey(seed))
    params = jax.device_put(params, rules.tree_param_specs(params))
    opt_state = jax.device_put(opt_state, rules.tree_opt_specs(opt_state))
    opt_cfg = AdamWConfig(lr=lr, total_steps=total_steps,
                          warmup_steps=max(10, total_steps // 20))
    step = jax.jit(ST.make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    data_cfg = DataConfig(seq_len=seq_len, global_batch=global_batch,
                          vocab_size=cfg.vocab_size, seed=seed,
                          frontend_len=cfg.frontend_len if cfg.frontend else 0,
                          d_model=cfg.d_model)
    stream = SyntheticLMStream(data_cfg)
    return params, opt_state, step, stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log", default=None)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params, opt_state, step, stream = build(
        cfg, seq_len=args.seq_len, global_batch=args.global_batch,
        lr=args.lr, total_steps=args.steps)

    loop = FaultTolerantLoop(step, stream, params, opt_state,
                             ckpt_dir=args.ckpt_dir,
                             ckpt_every=args.ckpt_every)
    t0 = time.time()
    loop.run(args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in loop.metrics_log]
    print(f"steps={args.steps} wall={dt:.1f}s "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f} "
          f"median_step={loop.watchdog.median*1e3:.0f}ms "
          f"stragglers={loop.watchdog.flagged}")
    if args.log:
        with open(args.log, "w") as f:
            json.dump({"metrics": loop.metrics_log, "wall_s": dt}, f)


if __name__ == "__main__":
    main()
