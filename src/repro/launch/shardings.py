"""Logical sharding rules: tree-path pattern → PartitionSpec with divisibility
fallback (MaxText-style logical axis rules).

TP over "model" (attention heads / d_ff / vocab / experts), DP over
("pod", "data"), SP (sequence sharding) over "data" for the long-context
decode caches.  Any dim that does not divide its mesh axes falls back to
replication for that dim — e.g. StarCoder2's 36 query heads or Granite's
49,155-entry vocab under model=16 (recorded by `fallbacks`).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


# (path regex, per-dim logical axes measured from the *last* dims of the leaf)
# Leading stacked axes (layer stack) are padded with None automatically.
PARAM_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)embed$",        (("model",), None)),            # (vocab, d)
    (r"pos_embed$",         (None, None)),
    (r"lm_head$",           (None, ("model",))),            # (d, vocab)
    (r"attn/w[qkv]$",       (None, ("model",))),
    (r"attn/wo$",           (("model",), None)),
    (r"cross/w[qkv]$",      (None, ("model",))),
    (r"cross/wo$",          (("model",), None)),
    (r"mlp/wi(_gate|_up)?$", (None, ("model",))),
    (r"mlp/wo$",            (("model",), None)),
    (r"moe/router$",        (None, None)),
    (r"moe/wi(_gate|_up)$", (("model",), None, None)),      # (E, d, ff) — EP
    (r"moe/wo$",            (("model",), None, None)),
    (r"shared/wi(_gate|_up)$", (None, ("model",))),
    (r"shared/wo$",         (("model",), None)),
    (r"ssm/in_proj$",       (None, ("model",))),
    (r"ssm/bc_proj$",       (None, ("model",))),
    (r"ssm/dt_proj$",       (None, None)),
    (r"ssm/out_proj$",      (("model",), None)),
    (r"ssm/(a_log|d_skip)$", (None,)),
    (r"(ln_|norm)",         None),                          # replicate norms
]

# fallback alternatives tried per rule when the primary axis does not divide
MOE_ALT = {r"moe/wi(_gate|_up)$": (None, None, ("model",)),
           r"moe/wo$": (None, ("model",), None)}


class ShardingRules:
    def __init__(self, mesh, *, moe_replicate: bool = False):
        self.mesh = mesh
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.fallbacks: list[str] = []
        # §Perf knob: replicate expert weights instead of EP/d_ff sharding
        # (small-expert models: trades memory for zero MoE collectives)
        self.moe_replicate = moe_replicate

    def _fits(self, dim: int, axes) -> bool:
        if axes is None:
            return True
        size = 1
        for a in axes:
            size *= self.axis_sizes.get(a, 1)
        return dim % size == 0

    def _spec_from_dims(self, shape, dims, path=""):
        """dims: per-dim axes for the LAST len(dims) dims of shape."""
        pad = len(shape) - len(dims)
        spec = [None] * pad
        for dim_size, axes in zip(shape[pad:], dims):
            if axes is None:
                spec.append(None)
            elif self._fits(dim_size, axes):
                spec.append(axes[0] if len(axes) == 1 else tuple(axes))
            else:
                self.fallbacks.append(f"{path}: dim {dim_size} !% {axes}")
                spec.append(None)
        return P(*spec)

    def param_spec(self, path: str, shape) -> P:
        if self.moe_replicate and re.search(r"moe/(wi|wo|router)", path):
            return P()
        for pat, dims in PARAM_RULES:
            if re.search(pat, path):
                if dims is None:
                    return P()
                # MoE expert-axis fallback: try EP first, then d_ff sharding
                if pat in MOE_ALT and not self._fits(
                        shape[len(shape) - len(dims)], dims[0]):
                    alt = MOE_ALT[pat]
                    return self._spec_from_dims(shape, alt, path)
                return self._spec_from_dims(shape, dims, path)
        return P()

    def batch_spec(self, shape, *, seq_axis: int | None = 1) -> P:
        dp = tuple(a for a in ("pod", "data") if a in self.axis_sizes)
        b = shape[0]
        spec = [None] * len(shape)
        if self._fits(b, dp):
            spec[0] = dp if len(dp) > 1 else dp[0]
        elif "data" in self.axis_sizes and self._fits(b, ("data",)):
            spec[0] = "data"
        return P(*spec)

    def cache_spec(self, path: str, shape) -> P:
        """Decode caches: (L, B, S, H, dh) k/v, (L, B, S) pos,
        (L, B, H, P, N) ssm state.  Batch → data(/pod); heads → model;
        B==1 (long-context) → shard the sequence dim over data (SP)."""
        dp = tuple(a for a in ("pod", "data") if a in self.axis_sizes)
        spec = [None] * len(shape)
        b = shape[1]
        batch_sharded = False
        if self._fits(b, dp) and b > 1:
            spec[1] = dp if len(dp) > 1 else dp[0]
            batch_sharded = True
        if path.endswith("state"):                      # (L,B,H,P,N)
            if self._fits(shape[2], ("model",)):
                spec[2] = "model"
            return P(*spec)
        if path.endswith("pos"):                        # (L,B,S)
            if not batch_sharded and self._fits(shape[2], ("data",)):
                spec[2] = "data"
            return P(*spec)
        if len(shape) >= 5:                             # (L,B,S,H,dh) k/v
            if not batch_sharded and self._fits(shape[2], ("data",)):
                spec[2] = "data"                        # sequence parallelism
            if self._fits(shape[3], ("model",)):
                spec[3] = "model"
        return P(*spec)

    # --- tree-level helpers ----------------------------------------------------

    def tree_param_specs(self, tree):
        def by_path(path, leaf):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            return NamedSharding(self.mesh, self.param_spec(key, leaf.shape))
        return jax.tree_util.tree_map_with_path(by_path, tree)

    def tree_opt_specs(self, opt_tree):
        def by_path(path, leaf):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            if key.startswith(("m/", "v/")):
                key = key[2:]
            if leaf.ndim == 0:
                return NamedSharding(self.mesh, P())
            return NamedSharding(self.mesh, self.param_spec(key, leaf.shape))
        return jax.tree_util.tree_map_with_path(by_path, opt_tree)

    def tree_batch_specs(self, batch_tree):
        return jax.tree.map(
            lambda leaf: NamedSharding(self.mesh, self.batch_spec(leaf.shape)),
            batch_tree)

    def tree_cache_specs(self, cache_tree):
        def by_path(path, leaf):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            return NamedSharding(self.mesh, self.cache_spec(key, leaf.shape))
        return jax.tree_util.tree_map_with_path(by_path, cache_tree)
