"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
memory term     = HLO_bytes / (chips × HBM_bw)
collective term = collective_bytes / (chips × link_bw)

cost_analysis() reports per-device (post-SPMD-partition) flops/bytes, so the
terms below use per-chip quantities directly.  collective_bytes is parsed
from the optimized HLO text: the summed result-operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants (TPU v5e-class target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _bytes_of_types(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind byte totals from an optimized HLO module."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.lstrip()
        if not s.startswith("%") and not s.startswith("ROOT"):
            continue
        m = re.match(r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        type_str, opcode = m.groups()
        for kind in _COLLECTIVES:
            if opcode == kind or opcode.startswith(kind + "-"):
                out[kind] += _bytes_of_types(type_str)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(cost: dict, coll_bytes: int, *, n_chips: int) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = (coll_bytes / n_chips) / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)), key=lambda kv: kv[1])[0]
    return {
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "collective_bytes_total": coll_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
    }


def model_flops(n_params: int, n_tokens: int, *, active_params: int | None = None,
                train: bool = True) -> float:
    """6·N·D (dense train) / 2·N·D (inference); MoE uses active params."""
    n = active_params if active_params is not None else n_params
    return (6.0 if train else 2.0) * n * n_tokens
