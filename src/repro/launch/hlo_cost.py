"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
silently undercounts scan-over-layers models by ~n_layers×.  This module
re-derives FLOPs / HBM-bytes / collective-bytes by walking the computation
call graph with ``known_trip_count`` multipliers from the HLO backend_config:

* FLOPs: dots contribute 2·|result|·K (K = contracted extent of the lhs),
  elementwise arithmetic contributes |result|;
* bytes: per top-level op, operand + result buffer sizes (the same HBM-traffic
  model XLA's own metric uses — fusion internals are free, fusion boundaries
  materialize);
* collectives: result sizes of all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute, per kind.

Everything multiplies through while-loop trip counts, so a 126-layer scanned
model reports 126× its layer body — verified against unrolled references in
tests/test_launch.py.
"""
from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*?)\)(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "floor",
    "remainder", "and", "or", "xor", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "compare", "select", "convert", "cosine", "sine",
    "logistic", "exponential-minus-one", "clamp", "round-nearest-even",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shapes_of(type_str: str):
    """All (dtype, [dims]) in a (possibly tuple) HLO type string."""
    return [(dt, [int(x) for x in dims.split(",")] if dims else [])
            for dt, dims in _TYPE_RE.findall(type_str)]


def _bytes_of(type_str: str) -> int:
    return sum(_DTYPE_BYTES.get(dt, 4) * math.prod(d)
               for dt, d in _shapes_of(type_str))


def _elems_of(type_str: str) -> int:
    return sum(math.prod(d) for _, d in _shapes_of(type_str))


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list
    rest: str


def parse_computations(hlo: str) -> dict:
    comps: dict[str, list[Op]] = {}
    cur_name, cur_ops = None, []
    for line in hlo.splitlines():
        s = line.rstrip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$", s)
        if header and " = " not in s:
            cur_name, cur_ops = header.group(1), []
            comps[cur_name] = cur_ops
            continue
        if s.startswith("}"):
            cur_name = None
            continue
        if cur_name is None:
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, type_str, opcode, args, rest = m.groups()
        operands = re.findall(r"%([\w.\-]+)", args)
        cur_ops.append(Op(name, type_str, opcode, operands, rest))
    return comps


def _called(rest: str, key: str):
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self.types: dict[str, dict[str, str]] = {
            cname: {op.name: op.type_str for op in ops}
            for cname, ops in self.comps.items()
        }
        self._memo: dict[str, tuple] = {}
        entry = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M)
        self.entry = entry.group(1) if entry else next(iter(self.comps))

    def _dot_flops(self, cname: str, op: Op) -> float:
        out_elems = _elems_of(op.type_str)
        lhs = op.operands[0] if op.operands else None
        lhs_type = self.types[cname].get(lhs, "")
        shapes = _shapes_of(lhs_type)
        if not shapes:
            return 0.0
        dims = shapes[0][1]
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        k = 1
        if m and m.group(1):
            for idx in m.group(1).split(","):
                i = int(idx)
                if i < len(dims):
                    k *= dims[i]
        return 2.0 * out_elems * k

    def analyze(self, cname: str | None = None) -> dict:
        cname = cname or self.entry
        if cname in self._memo:
            return self._memo[cname]
        flops = bytes_ = 0.0
        coll = {k: 0.0 for k in _COLLECTIVES}
        for op in self.comps.get(cname, []):
            oc = op.opcode
            if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "partition-id"):
                continue
            # bytes: operands + result (fusion internals never reach here
            # because we only recurse for control flow, not fusion bodies).
            # dynamic-(update-)slice is in-place on the big buffer: only the
            # slice region moves (XLA aliases the operand), so counting the
            # full operand would bill a loop-carried KV cache per iteration.
            if oc == "dynamic-slice":
                op_bytes = 2 * _bytes_of(op.type_str)
            elif oc == "dynamic-update-slice":
                upd = (self.types[cname].get(op.operands[1], "")
                       if len(op.operands) > 1 else "")
                op_bytes = 2 * _bytes_of(upd)
            else:
                op_bytes = _bytes_of(op.type_str)
                for o in op.operands:
                    t = self.types[cname].get(o)
                    if t:
                        op_bytes += _bytes_of(t)
            mult = 1.0
            sub = None
            if oc == "while":
                body = _called(op.rest, "body")
                tm = _TRIP_RE.search(op.rest)
                mult = float(tm.group(1)) if tm else 1.0
                sub = body
                op_bytes = 0  # the loop op itself moves no data; body does
            elif oc == "fusion":
                sub_name = _called(op.rest, "calls")
                s = self.analyze(sub_name) if sub_name else {"flops": 0}
                flops += s["flops"]          # fused compute still executes
                for k in _COLLECTIVES:
                    coll[k] += s.get(k, 0.0)
            elif oc in ("call", "custom-call"):
                sub = _called(op.rest, "to_apply")
            elif oc == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      op.rest)
                if branches:
                    names = re.findall(r"%?([\w.\-]+)", branches[0])
                    subs = [self.analyze(n) for n in names if n in self.comps]
                    if subs:
                        best = max(subs, key=lambda s: s["flops"] + s["bytes"])
                        flops += best["flops"]
                        bytes_ += best["bytes"]
            elif oc == "dot":
                flops += self._dot_flops(cname, op)
            elif oc == "convolution":
                flops += 2.0 * _elems_of(op.type_str)  # lower bound
            elif oc in _ELEMENTWISE:
                flops += _elems_of(op.type_str)
            elif oc == "reduce" or oc.startswith("reduce-window"):
                in_elems = sum(_elems_of(self.types[cname].get(o, ""))
                               for o in op.operands[: len(op.operands) // 2])
                flops += in_elems
            for kind in _COLLECTIVES:
                if oc == kind or oc.startswith(kind + "-"):
                    coll[kind] += _bytes_of(op.type_str)
            if sub and sub in self.comps:
                s = self.analyze(sub)
                flops += mult * s["flops"]
                bytes_ += mult * s["bytes"]
                for k in _COLLECTIVES:
                    coll[k] += mult * s[k]
            bytes_ += op_bytes
        out = {"flops": flops, "bytes": bytes_, **coll,
               "collective_bytes": sum(coll.values())}
        self._memo[cname] = out
        return out


def corrected_cost(hlo_text: str) -> dict:
    return HloCost(hlo_text).analyze()


def xla_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict across jax versions (0.4.x
    returns a one-element list)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
