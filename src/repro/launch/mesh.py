"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Degenerate mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n, 1) if n > 1 else (1, 1, 1),
                         ("pod", "data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
