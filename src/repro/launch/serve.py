"""Serving launcher — three modes:

* ``--mode crypto``: offline replay of the Aegis multi-tenant sequencer:
  Poisson ingress → Tier-1 rectangular batching → Tier-2 co-scheduled
  dispatch → per-tenant results, with HLO validation before first dispatch.
* ``--mode crypto-online``: the :mod:`repro.serve` runtime — live submit →
  admission → continuous batcher → dispatch closed loop with telemetry JSON.
* ``--mode lm``: batched LM serving (prefill + greedy decode) for any arch.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.models import steps as ST
from repro.models import model as M


def serve_lm(cfg, *, batch=2, prompt_len=16, decode_steps=8, seed=0):
    rng = np.random.default_rng(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    if cfg.frontend:
        prompts["embeds"] = jnp.asarray(rng.normal(
            size=(batch, max(cfg.frontend_len, 4), cfg.d_model)), jnp.float32)
    prefill = jax.jit(ST.make_prefill(cfg, max_len=prompt_len + decode_steps))
    decode = jax.jit(ST.make_decode_step(cfg))
    t0 = time.time()
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for i in range(decode_steps - 1):
        tok, _, cache = decode(params, cache, tok, jnp.int32(prompt_len + i))
        out.append(tok)
    toks = np.concatenate([np.asarray(t) for t in out], axis=1)
    dt = time.time() - t0
    return toks, dt


def serve_crypto(*, duration_s=0.05, rate_hz=2048, n_c=8, d_uniform=None,
                 seed=0, validate=True, accum="fp32_mantissa",
                 coscheduler=None):
    from repro.core.scheduler import (IngressQueue, PoissonTrace,
                                      RectangularScheduler)
    from repro.core.scheduler.coscheduler import SliceCoScheduler
    from repro.core import validator as V
    from repro.serve.client import attach_payloads

    trace = PoissonTrace(rate_hz=rate_hz, duration_s=duration_s,
                         uniform_degree=d_uniform, seed=seed).generate()
    attach_payloads(trace, seed=seed, accum=accum)
    q = IngressQueue()
    q.push_trace(trace)
    sched = RectangularScheduler(n_c=n_c)
    cos = coscheduler or SliceCoScheduler(accum=accum)
    results, n_ops = [], 0
    t0 = time.time()
    validated = set()
    while q.workloads:
        for w in list(q.workloads):
            reqs = q.pop_batch(w, n_c)
            for batch in sched.plan_batches(reqs):
                if validate and (w, batch.d_bucket) not in validated:
                    eng = cos.engine_for(w, batch.d_bucket)
                    shape = ((batch.n_c, batch.d_bucket) if w == "dilithium"
                             else (batch.n_c, batch.d_bucket, eng.n_channels))
                    if cos.reduction_for(w) == "eager":
                        rep = V.validate_fn(
                            eng.e2e, jnp.zeros(shape, jnp.uint32),
                            expected_passes=eng.n_passes)
                    else:
                        rep = V.validate_fn(
                            eng.e2e, jnp.zeros(shape, jnp.uint32),
                            expect_eager=False,
                            expected_windows=eng.fold_profile["n_folds"],
                            n_diag=eng.n_diag)
                    rep.raise_if_failed()
                    validated.add((w, batch.d_bucket))
                results.append(cos.dispatch(batch))
                n_ops += batch.n_c
    dt = time.time() - t0
    return results, n_ops, dt


def serve_crypto_online(*, duration_s=0.05, rate_hz=2048, n_c=8,
                        max_age_s=0.005, d_uniform=None, seed=0,
                        validate=True, accum="fp32_mantissa",
                        reduction="eager", reduction_by_workload=None,
                        kappa=None, d_tile=None,
                        max_pending=1024, tenant_rate_hz=None,
                        slo_deadline_s=None, occupancy_close=None,
                        merge_dispatch=True, row_ladder_max=None,
                        donate=False, async_pipeline=False, warm_start=None,
                        controller=False, holdback_lambda=0.0,
                        inflight_depth=1, compilation_cache_dir=None,
                        telemetry_out=None, trace_out=None,
                        metrics_out=None, metrics_period_s=0.005,
                        metrics_port=None, deterministic_timing=False,
                        realtime=False, coscheduler=None,
                        arrival_batch=None, columnar_admission=True):
    """Closed loop over the online runtime: load generator → admission →
    continuous batcher → co-scheduled dispatch → per-tenant results.
    ``trace_out`` switches request-lifecycle tracing on and writes the run's
    Chrome-trace JSON there (open in ui.perfetto.dev); ``metrics_out``
    switches the continuous metrics scrape + alert engine on and writes the
    OpenMetrics exposition there (``.gz`` compresses either file);
    ``metrics_port`` additionally serves ``/metrics`` over HTTP for the
    run's duration (wall-clock ``realtime`` mode only — a virtual-clock run
    finishes before any external scraper could connect)."""
    from repro.core.scheduler import PoissonTrace
    from repro.serve import CryptoServer, LoadGenerator, ServeConfig

    if metrics_port is not None and not realtime:
        raise ValueError("--metrics-port needs --realtime: the HTTP "
                         "endpoint only makes sense on the wall clock")

    cfg = ServeConfig(n_c=n_c, max_age_s=max_age_s, validate=validate,
                      accum=accum, max_pending=max_pending,
                      reduction=reduction,
                      reduction_by_workload=reduction_by_workload,
                      kappa=kappa, d_tile=d_tile,
                      tenant_rate_hz=tenant_rate_hz,
                      slo_deadline_s=slo_deadline_s,
                      occupancy_close=occupancy_close,
                      merge_dispatch=merge_dispatch,
                      row_ladder_max=row_ladder_max, donate=donate,
                      async_pipeline=async_pipeline, warm_start=warm_start,
                      controller=controller,
                      holdback_lambda=holdback_lambda,
                      inflight_depth=inflight_depth,
                      compilation_cache_dir=compilation_cache_dir,
                      columnar_admission=columnar_admission,
                      tracing=trace_out is not None,
                      metrics=(metrics_out is not None
                               or metrics_port is not None),
                      metrics_period_s=metrics_period_s,
                      deterministic_timing=deterministic_timing)
    server = CryptoServer(cfg, coscheduler=coscheduler)
    gen = LoadGenerator(PoissonTrace(rate_hz=rate_hz, duration_s=duration_s,
                                     uniform_degree=d_uniform, seed=seed),
                        seed=seed, accum=accum)
    httpd = None
    if metrics_port is not None:
        from repro.obs.metrics import serve_metrics_http
        httpd = serve_metrics_http([server.metrics], metrics_port)
    t0 = time.time()
    try:
        load = gen.run(server, realtime=realtime, arrival_batch=arrival_batch)
    finally:
        if httpd is not None:
            httpd.shutdown()
    dt = time.time() - t0
    snap = (server.telemetry.write_json(telemetry_out) if telemetry_out
            else server.telemetry.snapshot())
    if trace_out:
        server.write_trace(trace_out)
    if metrics_out:
        server.write_metrics(metrics_out)
    return load, snap, dt


def serve_crypto_cluster(*, hosts=2, duration_s=0.05, rate_hz=2048, n_c=8,
                         max_age_s=0.005, d_uniform=None, seed=0,
                         validate=True, accum="fp32_mantissa",
                         reduction="eager", reduction_by_workload=None,
                         kappa=None, d_tile=None, max_pending=1024,
                         tenant_rate_hz=None, slo_deadline_s=None,
                         occupancy_close=None, gossip_period_s=0.002,
                         gossip_staleness_factor=2.0, pinned=None,
                         merge_dispatch=True, row_ladder_max=None,
                         donate=False, async_pipeline=False,
                         warm_start=None, controller=False,
                         holdback_lambda=0.0, inflight_depth=1,
                         compilation_cache_dir=None,
                         telemetry_out=None, trace=None, trace_out=None,
                         metrics_out=None, metrics_period_s=0.005,
                         deterministic_timing=False,
                         realtime=False, coscheduler_factory=None,
                         arrival_batch=None, columnar_admission=True,
                         fault_plan=None, shed_watermark=None,
                         device_parallel=False):
    """Closed loop over an N-host sharded cluster: tenant-hash ingress →
    per-host admission (gossip-informed SLO gate) → per-host continuous
    batcher → co-scheduled dispatch → two-phase drain barrier → merged
    telemetry.  ``trace`` overrides the Poisson trace (benchmarks pass
    skewed tenant distributions); ``trace_out`` switches request-lifecycle
    tracing on and writes the merged fleet Chrome-trace JSON there.

    ``fault_plan`` injects deterministic host failures: a
    ``"kill@T:hN,recover@T:hN,pause@T:hN"`` spec (string times are
    *fractions of the run duration* — ``kill@0.5:h1`` kills host 1 mid-run
    — and are scaled here) or a pre-built :class:`repro.cluster.FaultPlan`
    with absolute virtual-clock times.  ``shed_watermark`` arms
    watermark-gated load shedding during failover redistribution
    transients (fraction of ``max_pending``)."""
    from repro.cluster import ClusterConfig, ClusterServer, FaultPlan
    from repro.core.scheduler import PoissonTrace
    from repro.serve import LoadGenerator, ServeConfig

    if isinstance(fault_plan, str):
        fault_plan = FaultPlan.parse(fault_plan).scaled(duration_s)

    serve_cfg = ServeConfig(
        n_c=n_c, max_age_s=max_age_s, validate=validate, accum=accum,
        max_pending=max_pending, reduction=reduction,
        reduction_by_workload=reduction_by_workload, kappa=kappa,
        d_tile=d_tile, tenant_rate_hz=tenant_rate_hz,
        slo_deadline_s=slo_deadline_s, occupancy_close=occupancy_close,
        merge_dispatch=merge_dispatch, row_ladder_max=row_ladder_max,
        donate=donate, async_pipeline=async_pipeline, warm_start=warm_start,
        controller=controller, holdback_lambda=holdback_lambda,
        inflight_depth=inflight_depth,
        compilation_cache_dir=compilation_cache_dir,
        columnar_admission=columnar_admission,
        tracing=trace_out is not None,
        metrics=metrics_out is not None,
        metrics_period_s=metrics_period_s,
        deterministic_timing=deterministic_timing)
    cluster = ClusterServer(
        ClusterConfig(n_hosts=hosts, gossip_period_s=gossip_period_s,
                      gossip_staleness_factor=gossip_staleness_factor,
                      pinned=pinned, fault_plan=fault_plan,
                      shed_watermark=shed_watermark,
                      device_parallel=device_parallel, serve=serve_cfg),
        coscheduler_factory=coscheduler_factory)
    gen = LoadGenerator(
        trace if trace is not None else
        PoissonTrace(rate_hz=rate_hz, duration_s=duration_s,
                     uniform_degree=d_uniform, seed=seed),
        seed=seed, accum=accum)
    t0 = time.time()
    load = gen.run(cluster, realtime=realtime, arrival_batch=arrival_batch)
    dt = time.time() - t0
    snap = (cluster.write_json(telemetry_out) if telemetry_out
            else cluster.snapshot())
    if trace_out:
        cluster.write_trace(trace_out)
    if metrics_out:
        cluster.write_metrics(metrics_out)
    return load, snap, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["crypto", "crypto-online", "lm"],
                    default="crypto")
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--duration", type=float, default=0.05)
    ap.add_argument("--rate", type=float, default=2048)
    ap.add_argument("--n-c", type=int, default=8)
    ap.add_argument("--max-age-ms", type=float, default=5.0)
    ap.add_argument("--hosts", type=int, default=1,
                    help="shard crypto-online serving across N simulated "
                         "host slices (tenant-hash ingress + gossip + "
                         "distributed drain barrier)")
    ap.add_argument("--gossip-period-ms", type=float, default=2.0,
                    help="queue-depth digest exchange period (cluster mode)")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic host-failure injection (cluster "
                         "mode): comma-separated kill@T:hN / pause@T:hN / "
                         "recover@T:hN events, T a fraction of the run "
                         "duration — e.g. 'kill@0.5:h1,recover@0.9:h1'")
    ap.add_argument("--shed-watermark", type=float, default=None,
                    help="arm watermark load shedding during failover "
                         "transients: fraction of max-pending above which "
                         "non-sticky tenants divert (power-of-two) and "
                         "sticky ones shed")
    ap.add_argument("--device-parallel", action="store_true",
                    help="partition the process's JAX devices across the "
                         "host slices and pin each host's programs/operands/"
                         "twiddle planes to its own slice (cluster mode; on "
                         "CPU, widen the slice with XLA_FLAGS "
                         "--xla_force_host_platform_device_count=N first)")
    ap.add_argument("--tenant-rate", type=float, default=None,
                    help="per-tenant token-bucket rate (req/s)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="reject requests predicted to queue past this deadline")
    ap.add_argument("--telemetry-out", default=None,
                    help="write the telemetry snapshot JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="record request-lifecycle tracing and write the "
                         "Chrome-trace/Perfetto JSON here (crypto-online "
                         "and cluster modes; open in ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None,
                    help="scrape continuous metrics + run the alert engine "
                         "and write the OpenMetrics exposition here "
                         "(crypto-online and cluster modes; .gz compresses)")
    ap.add_argument("--metrics-period-ms", type=float, default=5.0,
                    help="serving-clock scrape cadence for --metrics-out")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="also serve GET /metrics on this localhost port for "
                         "the run's duration (requires --realtime)")
    ap.add_argument("--deterministic-timing", action="store_true",
                    help="replace measured dispatch wall time with the "
                         "modeled device-cycle time so latencies, EWMAs, "
                         "metrics series, and alert logs are bit-identical "
                         "across reruns of the same trace")
    ap.add_argument("--realtime", action="store_true",
                    help="pace submissions in wall time (default: virtual clock)")
    ap.add_argument("--accum", default="fp32_mantissa",
                    choices=["fp32_mantissa", "int32_native"])
    ap.add_argument("--reduction", default="eager", choices=["eager", "lazy"],
                    help="default fold discipline for every workload class")
    ap.add_argument("--reduction-by-workload", default=None,
                    help="per-class overrides, e.g. 'dilithium=lazy,bn254=eager'")
    ap.add_argument("--kappa", type=int, default=None,
                    help="lazy deferral window depth (None = whole transform)")
    ap.add_argument("--d-tile", type=int, default=None,
                    help="staging-pass tile width override (e.g. 171 keeps the "
                         "fp32-era pass structure under --accum int32_native)")
    ap.add_argument("--no-merge", action="store_true",
                    help="disable M-axis super-batching of same-class batches")
    ap.add_argument("--row-ladder-max", type=int, default=None,
                    help="enable the row-ladder compile cache with rungs "
                         "8→16→…→MAX (bounds XLA retraces per program class)")
    ap.add_argument("--donate", action="store_true",
                    help="donate operand buffers to the e2e programs")
    ap.add_argument("--async-pipeline", action="store_true",
                    help="zero-sync dispatch: launch now, gather at the next "
                         "serving event")
    ap.add_argument("--controller", action="store_true",
                    help="closed-loop close policy: adapt per-class target "
                         "rung / max-age / occupancy from dispatch telemetry "
                         "(static config values become the loop's bounds)")
    ap.add_argument("--holdback-lambda", type=float, default=0.0,
                    help="cross-event merge holdback aggressiveness (0 "
                         "disables; requires --controller; SLO-priced)")
    ap.add_argument("--inflight-depth", type=int, default=1,
                    help="depth-k multi-flight launch ring per workload "
                         "class (k>1 requires --async-pipeline)")
    ap.add_argument("--compilation-cache-dir", default=None,
                    help="persist compiled programs here across process "
                         "restarts (JAX compilation cache)")
    ap.add_argument("--arrival-batch", type=int, default=None,
                    help="feed the trace through the vectorised submit_many "
                         "ingress edge in chunks of this many arrivals "
                         "(virtual clock only)")
    ap.add_argument("--scalar-admission", action="store_true",
                    help="per-tenant TokenBucket dict instead of the "
                         "columnar (structured-array) admission state — the "
                         "bit-identical oracle path")
    args = ap.parse_args()

    reduction_by_workload = None
    if args.reduction_by_workload:
        try:
            reduction_by_workload = dict(
                kv.split("=", 1) for kv in args.reduction_by_workload.split(","))
        except ValueError:
            ap.error("--reduction-by-workload expects 'class=mode[,class=mode]'"
                     f", e.g. 'dilithium=lazy' (got "
                     f"{args.reduction_by_workload!r})")

    if args.mode == "lm":
        cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
        toks, dt = serve_lm(cfg, decode_steps=args.decode_steps)
        print(f"decoded {toks.shape} tokens in {dt:.2f}s")
    elif args.mode == "crypto-online" and args.hosts > 1:
        load, snap, dt = serve_crypto_cluster(
            hosts=args.hosts, duration_s=args.duration, rate_hz=args.rate,
            n_c=args.n_c, max_age_s=args.max_age_ms / 1e3,
            tenant_rate_hz=args.tenant_rate,
            slo_deadline_s=None if args.slo_ms is None else args.slo_ms / 1e3,
            accum=args.accum, reduction=args.reduction,
            reduction_by_workload=reduction_by_workload,
            kappa=args.kappa, d_tile=args.d_tile,
            gossip_period_s=args.gossip_period_ms / 1e3,
            merge_dispatch=not args.no_merge,
            row_ladder_max=args.row_ladder_max, donate=args.donate,
            async_pipeline=args.async_pipeline,
            controller=args.controller,
            holdback_lambda=args.holdback_lambda,
            inflight_depth=args.inflight_depth,
            compilation_cache_dir=args.compilation_cache_dir,
            telemetry_out=args.telemetry_out, trace_out=args.trace_out,
            metrics_out=args.metrics_out,
            metrics_period_s=args.metrics_period_ms / 1e3,
            deterministic_timing=args.deterministic_timing,
            realtime=args.realtime, arrival_batch=args.arrival_batch,
            columnar_admission=not args.scalar_admission,
            fault_plan=args.fault_plan, shed_watermark=args.shed_watermark,
            device_parallel=args.device_parallel)
        m = snap["merged"]
        served = sum(1 for h in load.handles if h.done() and not h.rejected)
        print(f"cluster[{args.hosts} hosts]: served {served}/"
              f"{len(load.handles)} requests ({len(load.rejected)} rejected) "
              f"in {dt:.2f}s wall, {m['batches']} batches "
              f"[{', '.join(f'{k}:{v}' for k, v in m['close_reasons'].items())}]")
        imb = m["load_imbalance"]
        print(f"per-host requests {imb['per_host_requests']} "
              f"(max/mean {imb['max_over_mean']:.2f}, cv {imb['cv']:.2f}); "
              f"occupancy K={m['k_occupancy_mean']:.3f} "
              f"M={m['m_occupancy_mean']:.3f}")
        g = snap["gossip"]
        print(f"gossip: {g['publishes']} publishes, {g['views']} views, "
              f"{g['stale_drops']} stale drops, "
              f"used staleness max {g['used_staleness_max_s']*1e3:.2f}ms "
              f"(bound {g['staleness_bound_s']*1e3:.2f}ms)")
        lat = m["latency"]
        print(f"latency (merged, exact={lat['merged_exact']}): "
              f"p50={lat['p50_s']*1e3:.2f}ms p95={lat['p95_s']*1e3:.2f}ms "
              f"p99={lat['p99_s']*1e3:.2f}ms")
        bar = snap["drain_barrier"]
        print(f"drain barrier: {bar['hosts']} hosts quiesced → "
              f"{bar['batches_flushed']} batches flushed, "
              f"complete={bar['complete']}, "
              f"in-flight={bar['inflight_groups']}")
        if args.device_parallel:
            dv, ov = snap["devices"], snap["dispatch_overlap"]
            print(f"devices: per-host {dv['per_host']} "
                  f"({dv['distinct']} distinct); overlap: "
                  f"{ov['launches']} launches, concurrency "
                  f"mean {ov['launch_concurrency_mean']:.2f} / "
                  f"max {ov['launch_concurrency_max']}, cross-host queue "
                  f"share {ov['cross_host_queue_share']:.3f}")
        if args.fault_plan or args.shed_watermark is not None:
            fo = snap["failover"]
            s = fo["summary"]
            print(f"failover: {s['kills']} kills / {s['pauses']} pauses / "
                  f"{s['recovers']} recovers → {s['cordons']} cordons; "
                  f"requests replayed={fo['replayed']} "
                  f"recovered={fo['recovered']} deduped={fo['deduped']} "
                  f"shed={fo['sheds']} diverted={fo['diverted']} "
                  f"lost={fo['lost']} (must be 0)")
        if args.controller:
            ctl, hb = m["controller"], m["holdback"]
            print(f"controller[{ctl['hosts']} hosts]: {ctl['updates']} "
                  f"updates, m-occ EWMA mean "
                  f"{ctl['m_occupancy_ewma_mean']:.3f}, top rung "
                  f"{ctl['target_rows_max']}, age max "
                  f"{ctl['max_age_s_max']*1e3:.1f}ms; holdback "
                  f"{hb['held']} held → {hb['wins']} wins / "
                  f"{hb['losses']} losses / {hb['flushed']} flushed")
        if args.metrics_out:
            met, al = m.get("metrics", {}), m.get("alerts", {})
            fired = sum(r["fired"] for r in al.get("rules", {}).values())
            print(f"metrics: {met.get('scrapes', 0)} scrapes / "
                  f"{met.get('series', 0)} series across "
                  f"{met.get('hosts', 0)} hosts; alerts: "
                  f"{al.get('events_total', 0)} transitions, {fired} firings "
                  f"→ {args.metrics_out}")
        if args.telemetry_out:
            print(f"cluster telemetry JSON → {args.telemetry_out}")
        if args.trace_out:
            print(f"fleet trace → {args.trace_out} (open in ui.perfetto.dev)")
    elif args.mode == "crypto-online":
        load, snap, dt = serve_crypto_online(
            duration_s=args.duration, rate_hz=args.rate, n_c=args.n_c,
            max_age_s=args.max_age_ms / 1e3, tenant_rate_hz=args.tenant_rate,
            slo_deadline_s=None if args.slo_ms is None else args.slo_ms / 1e3,
            accum=args.accum, reduction=args.reduction,
            reduction_by_workload=reduction_by_workload,
            kappa=args.kappa, d_tile=args.d_tile,
            merge_dispatch=not args.no_merge,
            row_ladder_max=args.row_ladder_max, donate=args.donate,
            async_pipeline=args.async_pipeline,
            controller=args.controller,
            holdback_lambda=args.holdback_lambda,
            inflight_depth=args.inflight_depth,
            compilation_cache_dir=args.compilation_cache_dir,
            telemetry_out=args.telemetry_out, trace_out=args.trace_out,
            metrics_out=args.metrics_out,
            metrics_period_s=args.metrics_period_ms / 1e3,
            metrics_port=args.metrics_port,
            deterministic_timing=args.deterministic_timing,
            realtime=args.realtime, arrival_batch=args.arrival_batch,
            columnar_admission=not args.scalar_admission)
        lat = snap["latency"]
        print(f"online: served {load.n_served}/{len(load.handles)} requests "
              f"({len(load.rejected)} rejected) in {dt:.2f}s wall, "
              f"{snap['batches']} batches "
              f"[{', '.join(f'{k}:{v}' for k, v in snap['close_reasons'].items())}]")
        print(f"occupancy: K={snap['k_occupancy_mean']:.3f} "
              f"M={snap['m_occupancy_mean']:.3f}, "
              f"queue depth mean={snap['queue_depth_mean']:.1f} "
              f"max={snap['queue_depth_max']}")
        print(f"latency: p50={lat['p50_s']*1e3:.2f}ms "
              f"p95={lat['p95_s']*1e3:.2f}ms p99={lat['p99_s']*1e3:.2f}ms")
        stalls = snap["reduction_stalls"]
        print(f"reduction stalls: eager={stalls['eager_folds']} "
              f"deferred={stalls['deferred_folds']}")
        disp = snap["dispatch"]
        print(f"dispatch: {disp['dispatches']} launches "
              f"({disp['merged_dispatches']} merged, "
              f"{disp['batches_per_dispatch_mean']:.2f} batches/launch), "
              f"M-occ {disp['m_occupancy_mean']:.3f} "
              f"M-fill {disp['m_fill_mean']:.3f}")
        if args.controller:
            ctl, hb = snap["controller"], snap["holdback"]
            classes = ", ".join(
                f"{name}: rung {c['target_rows']} "
                f"age {c['max_age_s']*1e3:.1f}ms "
                f"m-occ {c['m_occupancy_ewma']:.3f}"
                for name, c in ctl["classes"].items())
            print(f"controller: {ctl['updates']} updates [{classes}]; "
                  f"holdback {hb['held']} held → {hb['wins']} wins / "
                  f"{hb['losses']} losses / {hb['flushed']} flushed")
        if args.metrics_out or args.metrics_port:
            met, al = snap.get("metrics", {}), snap.get("alerts", {})
            states = {name: r["state"] for name, r in
                      al.get("rules", {}).items() if r["state"] != "inactive"}
            fired = sum(r["fired"] for r in al.get("rules", {}).values())
            print(f"metrics: {met.get('scrapes', 0)} scrapes / "
                  f"{met.get('series', 0)} series; alerts: "
                  f"{al.get('events_total', 0)} transitions, {fired} firings"
                  + (f", non-inactive {states}" if states else "")
                  + (f" → {args.metrics_out}" if args.metrics_out else ""))
        if args.telemetry_out:
            print(f"telemetry JSON → {args.telemetry_out}")
        if args.trace_out:
            print(f"trace → {args.trace_out} (open in ui.perfetto.dev)")
    else:
        results, n_ops, dt = serve_crypto(duration_s=args.duration,
                                          rate_hz=args.rate, n_c=args.n_c)
        print(f"sequencer: {n_ops} tenant ops in {dt:.2f}s "
              f"({n_ops/dt:.0f} ops/s this-hardware), "
              f"{len(results)} stacked batches dispatched, HLO-validated")


if __name__ == "__main__":
    main()
