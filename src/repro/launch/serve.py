"""Serving launcher — two modes:

* ``--mode crypto``: the Aegis multi-tenant sequencer (the paper's system):
  Poisson ingress → Tier-1 rectangular batching → Tier-2 co-scheduled
  dispatch → per-tenant results, with HLO validation before first dispatch.
* ``--mode lm``: batched LM serving (prefill + greedy decode) for any arch.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.models import steps as ST
from repro.models import model as M


def serve_lm(cfg, *, batch=2, prompt_len=16, decode_steps=8, seed=0):
    rng = np.random.default_rng(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    if cfg.frontend:
        prompts["embeds"] = jnp.asarray(rng.normal(
            size=(batch, max(cfg.frontend_len, 4), cfg.d_model)), jnp.float32)
    prefill = jax.jit(ST.make_prefill(cfg, max_len=prompt_len + decode_steps))
    decode = jax.jit(ST.make_decode_step(cfg))
    t0 = time.time()
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for i in range(decode_steps - 1):
        tok, _, cache = decode(params, cache, tok, jnp.int32(prompt_len + i))
        out.append(tok)
    toks = np.concatenate([np.asarray(t) for t in out], axis=1)
    dt = time.time() - t0
    return toks, dt


def serve_crypto(*, duration_s=0.05, rate_hz=2048, n_c=8, d_uniform=None,
                 seed=0, validate=True, accum="fp32_mantissa"):
    from repro.core.scheduler import (IngressQueue, PoissonTrace,
                                      RectangularScheduler)
    from repro.core.scheduler.coscheduler import SliceCoScheduler
    from repro.core import validator as V
    from repro.core import workloads as WK

    trace = PoissonTrace(rate_hz=rate_hz, duration_s=duration_s,
                         uniform_degree=d_uniform, seed=seed).generate()
    rng = np.random.default_rng(seed)
    for r in trace:  # attach payloads
        if r.workload == "dilithium":
            r.coeffs = np.asarray(rng.integers(
                0, 8380417, r.degree, dtype=np.uint64), np.uint32)
        else:
            eng = WK.make_engine("bn254", 64, accum=accum)
            r.degree = min(r.degree, 64)  # CPU-budget BN254 rows
            vals = np.array([int(x) for x in
                             rng.integers(0, 2**31, r.degree)], object)
            r.coeffs = np.asarray(eng.ingest(vals))
    q = IngressQueue()
    q.push_trace(trace)
    sched = RectangularScheduler(n_c=n_c)
    cos = SliceCoScheduler(accum=accum)
    results, n_ops = [], 0
    t0 = time.time()
    validated = set()
    while q.workloads:
        for w in list(q.workloads):
            reqs = q.pop_batch(w, n_c)
            for batch in sched.plan_batches(reqs):
                if validate and (w, batch.d_bucket) not in validated:
                    eng = cos.engine_for(w, batch.d_bucket)
                    shape = ((batch.n_c, batch.d_bucket) if w == "dilithium"
                             else (batch.n_c, batch.d_bucket, eng.n_channels))
                    rep = V.validate_fn(
                        eng.e2e, jnp.zeros(shape, jnp.uint32),
                        expected_passes=eng.n_passes)
                    rep.raise_if_failed()
                    validated.add((w, batch.d_bucket))
                results.append(cos.dispatch(batch))
                n_ops += batch.n_c
    dt = time.time() - t0
    return results, n_ops, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["crypto", "lm"], default="crypto")
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--duration", type=float, default=0.05)
    args = ap.parse_args()

    if args.mode == "lm":
        cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
        toks, dt = serve_lm(cfg, decode_steps=args.decode_steps)
        print(f"decoded {toks.shape} tokens in {dt:.2f}s")
    else:
        results, n_ops, dt = serve_crypto(duration_s=args.duration)
        print(f"sequencer: {n_ops} tenant ops in {dt:.2f}s "
              f"({n_ops/dt:.0f} ops/s this-hardware), "
              f"{len(results)} stacked batches dispatched, HLO-validated")


if __name__ == "__main__":
    main()
