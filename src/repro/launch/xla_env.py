"""Process-environment bootstrap for forced host (CPU) device counts.

JAX reads ``XLA_FLAGS`` exactly once, when its first backend initialises,
and locks the device count for the life of the process.  Anything that
wants N CPU devices (the device-parallel cluster tests, ``bench_cluster
--device-parallel``, the dry-run topology planner) therefore has to edit
the environment *before* that first init — and has to **append** to any
user-set ``XLA_FLAGS`` rather than clobbering it, or it silently throws
away flags the operator passed in (the historical ``dryrun.py`` bug).

This module must stay importable without importing jax: callers import it
at the very top of their entrypoint, mutate ``os.environ``, and only then
touch jax.  The jax-initialisation probe below inspects already-imported
module state and never triggers an init itself.
"""
from __future__ import annotations

import os
import sys

HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def with_host_device_count(flags: str | None, n: int) -> str:
    """Pure string edit: return ``flags`` with any existing
    ``--xla_force_host_platform_device_count`` token replaced by ``=n``,
    appending one if absent.  Every other token is preserved verbatim."""
    if n < 1:
        raise ValueError(f"host device count must be >= 1, got {n}")
    kept = [tok for tok in (flags or "").split()
            if not tok.startswith(HOST_DEVICE_FLAG)]
    kept.append(f"{HOST_DEVICE_FLAG}={n}")
    return " ".join(kept)


def jax_initialised() -> bool:
    """True iff a JAX backend is already live in this process (at which
    point ``XLA_FLAGS`` edits are inert).  Importing jax alone does not
    initialise a backend; the first ``jax.devices()`` / jit does."""
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        bridge = sys.modules.get("jax._src.xla_bridge")
        return bool(getattr(bridge, "_backends", None))
    except Exception:  # pragma: no cover - defensive against jax internals
        return False


def force_host_device_count(n: int, *, env=None) -> str:
    """Set ``XLA_FLAGS`` so the host platform exposes ``n`` devices,
    preserving all other flags.  Raises ``RuntimeError`` if a JAX backend
    already initialised with a different device count — the edit would be
    silently ignored, which is worse than failing loudly."""
    if env is None:
        env = os.environ
    if jax_initialised():
        import jax
        have = jax.device_count()
        if have != n:
            raise RuntimeError(
                f"cannot force {n} host devices: a JAX backend is already "
                f"initialised with {have} device(s); set XLA_FLAGS "
                f"{HOST_DEVICE_FLAG}={n} before the first jax use")
        return env.get("XLA_FLAGS", "")
    flags = with_host_device_count(env.get("XLA_FLAGS"), n)
    env["XLA_FLAGS"] = flags
    return flags


def maybe_force_host_device_count(n: int, *, env=None) -> bool:
    """Best-effort variant for test modules: like
    :func:`force_host_device_count` but returns ``False`` instead of
    raising when jax already initialised (the caller is expected to skip
    or degrade, e.g. via ``pytest.mark.skipif`` on ``jax.device_count()``).
    Returns ``True`` when the environment was (re)written."""
    if jax_initialised():
        return False
    if env is None:
        env = os.environ
    env["XLA_FLAGS"] = with_host_device_count(env.get("XLA_FLAGS"), n)
    return True
