from repro.launch.xla_env import force_host_device_count
force_host_device_count(512)
# ^ MUST run before ANY jax-importing line (jax locks the device count on
# first init).  xla_env appends to a user-set XLA_FLAGS instead of
# clobbering it, so operator-passed flags survive.
#
# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
# ShapeDtypeStruct inputs (no allocation), print memory/cost analysis, parse
# the collective schedule, and persist JSON artifacts for EXPERIMENTS.md.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch olmo_1b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
#   PYTHONPATH=src python -m repro.launch.dryrun --arch aegis_bn254 --shape serve_8k

import argparse
import json
import os
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config, SHAPES, shape_applicable
from repro.launch import hlo_analysis as HA
from repro.launch import hlo_cost as HC
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import ShardingRules
from repro.launch import specs as SP
from repro.models import steps as ST

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

CRYPTO_SHAPES = {
    # stacked-batch crypto serving cells (rows × degree)
    "serve_256": dict(rows_per_core=8, d=256),
    "serve_8k": dict(rows_per_core=8, d=8192),
}


def _crypto_cell(arch: str, shape: str, mesh, *, accum="fp32_mantissa",
                 reduction="eager", kappa=None, scan_staging=False):
    """Lower the Aegis sequencer op for a pod-slice stacked batch.

    Twiddle limb planes enter as *traced operands* (sharded over "model" on
    the output-column dim — twiddle TP), so even d=8192 cells lower from
    ShapeDtypeStructs with no host constant materialisation.
    """
    from repro.core import field as FLD
    from repro.core import limb_gemm as G
    from repro.core import rns as R

    spec = CRYPTO_SHAPES[shape]
    n_cores = int(np.prod(mesh.devices.shape))
    rows = spec["rows_per_core"] * n_cores
    d = spec["d"]
    name = {"aegis_bn254": "bn254", "aegis_dilithium": "dilithium"}[arch]

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else dp[0]
    row_sharding = NamedSharding(mesh, P(dp_spec))

    def transform(a, w, modulus):
        if scan_staging:
            return G.staged_transform_scan(a, w, modulus=modulus,
                                           data_limbs=4 if name == "bn254"
                                           else 3, accum=accum,
                                           reduction=reduction, kappa=kappa)
        return G.staged_transform_traced(a, w, modulus=modulus,
                                         data_limbs=4 if name == "bn254"
                                         else 3, accum=accum,
                                         reduction=reduction, kappa=kappa)

    if name == "dilithium":
        a_sds = jax.ShapeDtypeStruct((rows, d), jnp.uint32)
        w_sds = jax.ShapeDtypeStruct((d, d, 3), jnp.int8)

        def step(a, w):
            with jax.named_scope("wzone_dilithium"), \
                    jax.named_scope("pzone_3limb"):
                return transform(a, w, FLD.DILITHIUM_Q)

        in_shardings = (row_sharding,
                        NamedSharding(mesh, P(None, "model", None)))
        lowered = jax.jit(step, in_shardings=in_shardings).lower(a_sds, w_sds)
    else:
        chain = R.make_chain(9)
        c = len(chain.moduli)
        a_sds = jax.ShapeDtypeStruct((rows, d, c), jnp.uint32)
        w_sds = jax.ShapeDtypeStruct((c, d, d, 4), jnp.int8)

        def step(a, w):
            with jax.named_scope("wzone_bn254"), jax.named_scope("pzone_4limb"):
                outs = []
                for ci, m in enumerate(chain.moduli):
                    with jax.named_scope(f"channel_{ci}"):
                        outs.append(transform(a[..., ci], w[ci], m))
                y = jnp.stack(outs, axis=-1)
                with jax.named_scope("vpu_montgomery"):
                    return R.rns_to_field(y, chain)

        in_shardings = (NamedSharding(mesh, P(dp_spec, None, None)),
                        NamedSharding(mesh, P(None, None, "model", None)))
        lowered = jax.jit(step, in_shardings=in_shardings).lower(a_sds, w_sds)
    return lowered, {"rows": rows, "d": d, "workload": name,
                     "accum": accum, "reduction": reduction, "kappa": kappa,
                     "scan_staging": scan_staging}


def _lm_cell(arch: str, shape: str, mesh, rules: ShardingRules,
             overrides: dict | None = None):
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(
            cfg, **{k: v for k, v in overrides.items() if not k.startswith("_")})
    shape_cfg = SHAPES[shape]
    if shape_cfg.kind == "train":
        params, opt = SP.abstract_train_state(cfg)
        batch = SP.train_batch_specs(cfg, shape_cfg)
        in_sh = (rules.tree_param_specs(params), rules.tree_opt_specs(opt),
                 rules.tree_batch_specs(batch))
        out_sh = (in_sh[0], in_sh[1],
                  jax.tree.map(lambda _: NamedSharding(mesh, P()),
                               {"ce": 0., "aux": 0., "loss": 0.,
                                "grad_norm": 0., "lr": 0.}))
        step = ST.make_train_step(cfg)
        lowered = jax.jit(step, in_shardings=in_sh,
                          out_shardings=out_sh).lower(params, opt, batch)
        extra = {"kind": "train",
                 "tokens": shape_cfg.global_batch * shape_cfg.seq_len}
    elif shape_cfg.kind == "prefill":
        params = SP.abstract_params(cfg)
        batch = SP.train_batch_specs(cfg, shape_cfg)
        batch.pop("labels")
        prefix = cfg.frontend_len if cfg.frontend == "vision_stub" else 0
        prefill = ST.make_prefill(cfg, max_len=shape_cfg.seq_len + prefix)
        in_sh = (rules.tree_param_specs(params), rules.tree_batch_specs(batch))
        lowered = jax.jit(prefill, in_shardings=in_sh).lower(params, batch)
        extra = {"kind": "prefill",
                 "tokens": shape_cfg.global_batch * shape_cfg.seq_len}
    else:  # decode
        params = SP.abstract_params(cfg)
        cache, token = SP.decode_inputs_specs(cfg, shape_cfg)
        decode = ST.make_decode_step(cfg)
        in_sh = (rules.tree_param_specs(params),
                 rules.tree_cache_specs(cache),
                 rules.tree_batch_specs({"tokens": token})["tokens"],
                 NamedSharding(mesh, P()))
        lowered = jax.jit(decode, in_shardings=in_sh).lower(
            params, cache, token, jax.ShapeDtypeStruct((), jnp.int32))
        extra = {"kind": "decode", "tokens": shape_cfg.global_batch}
    return lowered, extra


def run_cell(arch: str, shape: str, *, multi_pod: bool, kappa=None,
             accum: str = "fp32_mantissa", reduction: str = "eager",
             scan_staging: bool = False, overrides: dict | None = None,
             tag: str = "") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    record = {
        "arch": arch, "shape": shape,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod, "status": "ok", "tag": tag,
    }
    try:
        if arch.startswith("aegis_"):
            lowered, extra = _crypto_cell(arch, shape, mesh, accum=accum,
                                          reduction=reduction, kappa=kappa,
                                          scan_staging=scan_staging)
            rules = None
        else:
            cfg = get_config(arch)
            ok, reason = shape_applicable(cfg, shape)
            if not ok:
                record.update(status="skipped", reason=reason)
                return record
            rules = ShardingRules(
                mesh, moe_replicate=bool((overrides or {}).get(
                    "_moe_replicate", False)))
            lowered, extra = _lm_cell(arch, shape, mesh, rules, overrides)
        record.update(extra)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = HC.xla_cost_dict(compiled)
        hlo = compiled.as_text()
        coll = HA.collective_bytes(hlo)
        record["memory"] = {
            k: int(getattr(mem, k, 0)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
        }
        record["bytes_per_device"] = (
            record["memory"]["argument_size_in_bytes"] +
            record["memory"]["temp_size_in_bytes"])
        record["cost_raw"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        }
        # trip-count-corrected per-device cost (XLA counts while bodies once)
        cc = HC.corrected_cost(hlo)
        record["cost_corrected"] = {k: float(v) for k, v in cc.items()}
        record["collectives_naive"] = coll
        record["roofline"] = HA.roofline_terms(
            {"flops": cc["flops"], "bytes accessed": cc["bytes"]},
            cc["collective_bytes"] * n_chips,  # cc is per-device already
            n_chips=n_chips)
        if rules is not None:
            record["sharding_fallbacks"] = rules.fallbacks[:20]
        record["compile_s"] = round(time.time() - t0, 1)
    except Exception as e:  # noqa: BLE001
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--accum", default="fp32_mantissa")
    ap.add_argument("--reduction", default="eager",
                    choices=["eager", "lazy"])
    ap.add_argument("--kappa", type=int, default=None,
                    help="lazy deferral window depth (passes per fold)")
    ap.add_argument("--scan-staging", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="ArchConfig overrides, e.g. gqa_repeat_kv=true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.isdigit() else v)

    archs = (sorted(ARCHS) + ["aegis_bn254", "aegis_dilithium"]
             if args.arch == "all" else args.arch.split(","))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_dir = args.out or os.path.abspath(ARTIFACT_DIR)
    os.makedirs(out_dir, exist_ok=True)

    for arch in archs:
        valid = list(CRYPTO_SHAPES) if arch.startswith("aegis_") else list(SHAPES)
        shapes = valid if args.shape == "all" else [
            s for s in args.shape.split(",") if s in valid]
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi_pod=multi, accum=args.accum,
                               reduction=args.reduction, kappa=args.kappa,
                               scan_staging=args.scan_staging,
                               overrides=overrides or None, tag=args.tag)
                mesh_tag = "multi" if multi else "single"
                suffix = f"_{args.tag}" if args.tag else ""
                fname = f"{arch}__{shape}__{mesh_tag}{suffix}.json"
                with open(os.path.join(out_dir, fname), "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                roof = rec.get("roofline", {})
                print(f"[{status:7s}] {arch:22s} {shape:12s} {mesh_tag:6s} "
                      f"dom={roof.get('dominant', '-'):10s} "
                      f"compile={rec.get('compile_s', 0)}s "
                      f"{rec.get('error', '')[:120]}", flush=True)


if __name__ == "__main__":
    main()
