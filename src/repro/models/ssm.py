"""Mamba-2 SSD (state-space duality) block — chunked training scan + O(1)
single-token decode state update [arXiv:2405.21060].

Scalar-per-head decay A (SSD restriction), H heads with head dim P and state
size N:    h_t = a_t · h_{t-1} + B_t ⊗ (Δ_t x_t) ;   y_t = C_t · h_t + D x_t.

Training uses the chunked dual form: intra-chunk quadratic term
(L ∘ C Bᵀ)(Δx) with L[t,u] = Π_{u<v≤t} a_v, inter-chunk contribution via a
lax.scan over the running state — the standard SSD decomposition,
O(S·chunk·(P+N)) per head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_params(cfg, rng, d_model=None):
    d = d_model or cfg.d_model
    h = cfg.ssm_heads
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    init = jax.nn.initializers.normal(0.02)
    ks = jax.random.split(rng, 4)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "in_proj": init(ks[0], (d, 2 * d_in), dt),      # x and gate z
        "bc_proj": init(ks[1], (d, 2 * n * h), dt),     # B, C per head
        "dt_proj": init(ks[2], (d, h), dt),             # per-head Δ logits
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_proj": init(ks[3], (d_in, d), dt),
    }


def init_ssm_state(cfg, batch: int, d_model: int | None = None,
                   dtype=jnp.float32):
    d = d_model or cfg.d_model
    p = cfg.ssm_expand * d // cfg.ssm_heads
    return jnp.zeros((batch, cfg.ssm_heads, p, cfg.ssm_state), dtype)


def ssd_forward(cfg, params, x, *, state=None):
    """x: (B, S, d) -> (y (B, S, d), new_state (B, H, P, N))."""
    b, s, d = x.shape
    h = cfg.ssm_heads
    d_in = cfg.ssm_expand * d
    p = d_in // h
    n = cfg.ssm_state

    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                    # (B,S,d_in) each
    bc = x @ params["bc_proj"]
    b_mat, c_mat = jnp.split(bc, 2, axis=-1)
    b_mat = b_mat.reshape(b, s, h, n).astype(jnp.float32)
    c_mat = c_mat.reshape(b, s, h, n).astype(jnp.float32)
    xh = xs.reshape(b, s, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(
        x.astype(jnp.float32) @ params["dt_proj"].astype(jnp.float32))
    a_neg = -jnp.exp(params["a_log"])                    # (H,) < 0
    log_a = dt * a_neg                                   # (B,S,H) ≤ 0
    xdt = xh * dt[..., None]                             # (B,S,H,P)

    if state is None:
        state = jnp.zeros((b, h, p, n), jnp.float32)

    if s == 1:
        a1 = jnp.exp(log_a[:, 0])                        # (B,H)
        bx = b_mat[:, 0, :, None, :] * xdt[:, 0, :, :, None]  # (B,H,P,N)
        new_state = state * a1[:, :, None, None] + bx
        y = jnp.einsum("bhpn,bhn->bhp", new_state, c_mat[:, 0])
        y = y + params["d_skip"][None, :, None] * xh[:, 0]
        y = y[:, None]                                   # (B,1,H,P)
    else:
        chunk = min(cfg.ssm_chunk, s)
        if s % chunk:
            chunk = s  # ragged sequence: single-chunk fallback (quadratic)
        nc = s // chunk
        la_c = log_a.reshape(b, nc, chunk, h)
        b_c = b_mat.reshape(b, nc, chunk, h, n)
        c_c = c_mat.reshape(b, nc, chunk, h, n)
        xdt_c = xdt.reshape(b, nc, chunk, h, p)
        cum = jnp.cumsum(la_c, axis=2)                   # inclusive (B,NC,T,H)

        # intra-chunk: y[t] = Σ_{u<=t} exp(cum_t - cum_u) (C_t·B_u) Δx_u
        scores = jnp.einsum("bgthn,bguhn->bgtuh", c_c, b_c)
        li = cum[:, :, :, None, :] - cum[:, :, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)
        y_intra = jnp.einsum("bgtuh,bguhp->bgthp", scores * l_mat, xdt_c)

        # end-of-chunk states: Σ_u exp(cum_T - cum_u) B_u ⊗ Δx_u
        total = cum[:, :, -1, :]                          # (B,NC,H)
        dec_end = jnp.exp(total[:, :, None, :] - cum)     # (B,NC,T,H)
        chunk_state = jnp.einsum("bgth,bgthn,bgthp->bghpn",
                                 dec_end, b_c, xdt_c)

        def scan_fn(st, inp):
            c_state, tot, c_chunk, cumv = inp             # leading axis = NC
            dec0 = jnp.exp(cumv)                          # (B,T,H)
            y_int = jnp.einsum("bthn,bhpn,bth->bthp", c_chunk, st, dec0)
            st_new = st * jnp.exp(tot)[:, :, None, None] + c_state
            return st_new, y_int

        new_state, y_inter = jax.lax.scan(
            scan_fn, state,
            (chunk_state.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2),
             c_c.transpose(1, 0, 2, 3, 4), cum.transpose(1, 0, 2, 3)))
        y_inter = y_inter.transpose(1, 0, 2, 3, 4)
        y = y_intra + y_inter + \
            params["d_skip"][None, None, None, :, None] * \
            xh.reshape(b, nc, chunk, h, p)
        y = y.reshape(b, s, h, p)

    y = y.reshape(b, -1, d_in)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"], new_state


# --- reference: naive sequential recurrence (oracle for tests) -----------------


def ssd_reference(cfg, params, x, *, state=None):
    """Step-by-step recurrence — O(S) sequential, used as the test oracle."""
    b, s, d = x.shape
    h = cfg.ssm_heads
    d_in = cfg.ssm_expand * d
    p = d_in // h
    n = cfg.ssm_state
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    bc = x @ params["bc_proj"]
    b_mat, c_mat = jnp.split(bc, 2, axis=-1)
    b_mat = b_mat.reshape(b, s, h, n).astype(jnp.float32)
    c_mat = c_mat.reshape(b, s, h, n).astype(jnp.float32)
    xh = xs.reshape(b, s, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(
        x.astype(jnp.float32) @ params["dt_proj"].astype(jnp.float32))
    a_t = jnp.exp(dt * (-jnp.exp(params["a_log"])))
    if state is None:
        state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(st, inp):
        at, bt, ct, xt, dtt = inp
        bx = bt[:, :, None, :] * (xt * dtt[..., None])[:, :, :, None]
        st = st * at[:, :, None, None] + bx
        y = jnp.einsum("bhpn,bhn->bhp", st, ct)
        return st, y

    st, ys = jax.lax.scan(step, state,
                          (a_t.transpose(1, 0, 2), b_mat.transpose(1, 0, 2, 3),
                           c_mat.transpose(1, 0, 2, 3), xh.transpose(1, 0, 2, 3),
                           dt.transpose(1, 0, 2)))
    ys = ys.transpose(1, 0, 2, 3) + params["d_skip"][None, None, :, None] * xh
    y = ys.reshape(b, s, d_in)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"], st
