"""Transformer building blocks: norms, RoPE, GQA attention (naive, blockwise
flash-style, decode-with-cache, sliding-window), MLPs and top-k MoE.

Functional style: params are plain dicts of jnp arrays; every function takes
(params, inputs) and is shape-polymorphic over batch/sequence.  Compute dtype
follows the inputs (bf16 in production configs); softmax/norm statistics are
always float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --- norms --------------------------------------------------------------------


def rmsnorm(x, weight):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, weight, bias):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def layernorm_np(x):
    """OLMo's non-parametric LayerNorm (no weight/bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)


def apply_norm(cfg, params, x, name: str):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, params[name]["scale"])
    if cfg.norm == "layernorm":
        return layernorm(x, params[name]["scale"], params[name]["bias"])
    return layernorm_np(x)


def norm_params(cfg, d: int):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), _dt(cfg))}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), _dt(cfg)),
                "bias": jnp.zeros((d,), _dt(cfg))}
    return {}


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --- RoPE ---------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)           # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- attention ----------------------------------------------------------------


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d)


def _group_q(q, hkv: int):
    """(B, S, Hq, Dh) -> (B, S, Hkv, G, Dh) — query heads grouped per KV head
    so GQA never materialises repeated K/V (§Perf: 16× smaller KV operands
    for llama3-405b decode)."""
    b, s, hq, dh = q.shape
    return q.reshape(b, s, hkv, hq // hkv, dh)


def naive_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0):
    """q: (B, Sq, Hq, Dh), k/v: (B, Skv, Hkv, Dh), Hkv | Hq (GQA grouped).
    Scores materialised — short sequences and decode; blockwise_attention
    covers long prefill."""
    hkv = k.shape[2]
    q5 = _group_q(q, hkv)
    scale = q.shape[-1] ** -0.5
    # mixed-precision MXU dot: bf16 operands, f32 accumulation — never
    # materialises an f32 copy of the (huge) KV cache (§Perf cell 2, I2)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k,
                        preferred_element_type=jnp.float32) * scale
    sq, skv = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    b, _, hq, dh = q.shape
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool, block: int = 1024,
                        window: int = 0):
    """Flash-style online-softmax attention: KV scanned in blocks, O(S·block)
    score memory, GQA-grouped (no KV repetition). Exact (fp32 running
    max/denominator)."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    skv = k.shape[1]
    n_blocks = -(-skv // block)
    pad = n_blocks * block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, n_blocks, block, hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blocks, block, hkv, dh).transpose(1, 0, 2, 3, 4)
    scale = dh ** -0.5
    qf = _group_q(q, hkv)
    q_pos = jnp.arange(sq)

    def body(carry, blk):
        acc, m_run, l_run, blk_idx = carry
        kblk, vblk = blk
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kblk,
                            preferred_element_type=jnp.float32) * scale
        k_pos = blk_idx * block + jnp.arange(block)
        mask = k_pos[None, :] < skv
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m_run, scores.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l_new, blk_idx + 1), None

    g = hq // hkv
    acc0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    (acc, m_run, l_run, _), _ = jax.lax.scan(
        body, (acc0, m0, l0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l_run[..., None], 1e-30)
    # (B, Hkv, G, Sq, Dh) -> (B, Sq, Hq, Dh)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)


def attention_params(cfg, rng, d_model=None):
    d = d_model or cfg.d_model
    q_dim, kv_dim = cfg.qkv_dims
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    init = jax.nn.initializers.normal(0.02)
    return {
        "wq": init(k1, (d, q_dim), _dt(cfg)),
        "wk": init(k2, (d, kv_dim), _dt(cfg)),
        "wv": init(k3, (d, kv_dim), _dt(cfg)),
        "wo": init(k4, (q_dim, d), _dt(cfg)),
    }


def attention_forward(cfg, params, x, *, positions, causal=True, cache=None,
                      cache_index=None, window=None, kv_override=None):
    """GQA attention. Returns (out, new_cache).

    cache: {"k","v"} (B, max_len, Hkv, Dh) — decode inserts at cache_index.
    kv_override: (k, v) for cross-attention (encoder outputs, pre-projected).
    """
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    window = cfg.attn_window if window is None else window
    q = (x @ params["wq"]).reshape(b, s, hq, dh)
    if kv_override is None:
        k = (x @ params["wk"]).reshape(b, s, hkv, dh)
        v = (x @ params["wv"]).reshape(b, s, hkv, dh)
        if cfg.rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        if cfg.rope:
            q = apply_rope(q, positions, cfg.rope_theta)

    new_cache = cache
    if cache is not None and kv_override is None:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
        new_cache = {"k": k_cache, "v": v_cache}
        k, v = k_cache, v_cache

    if getattr(cfg, "gqa_repeat_kv", False):
        # baseline path (§Perf before/after): materialise repeated KV heads
        k = _repeat_kv(k, hq // hkv)
        v = _repeat_kv(v, hq // hkv)

    if cache is not None and kv_override is None:
        # decode / cached path: mask beyond cache_index + s
        q_offset = cache_index
        out = naive_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    elif s >= cfg.blockwise_attn_threshold:
        out = blockwise_attention(q, k, v, causal=causal,
                                  block=cfg.attn_block_size, window=window)
    else:
        out = naive_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(b, s, hq * dh) @ params["wo"]
    return out, new_cache


# --- MLP ----------------------------------------------------------------------


def mlp_params(cfg, rng, d_ff=None):
    d, dff = cfg.d_model, d_ff or cfg.d_ff
    init = jax.nn.initializers.normal(0.02)
    if cfg.activation == "swiglu":
        k1, k2, k3 = jax.random.split(rng, 3)
        return {"wi_gate": init(k1, (d, dff), _dt(cfg)),
                "wi_up": init(k2, (d, dff), _dt(cfg)),
                "wo": init(k3, (dff, d), _dt(cfg))}
    k1, k2 = jax.random.split(rng, 2)
    return {"wi": init(k1, (d, dff), _dt(cfg)),
            "wo": init(k2, (dff, d), _dt(cfg))}


def mlp_forward(cfg, params, x):
    if cfg.activation == "swiglu":
        return (jax.nn.silu(x @ params["wi_gate"]) * (x @ params["wi_up"])) @ params["wo"]
    return jax.nn.gelu(x @ params["wi"]) @ params["wo"]


# --- MoE ----------------------------------------------------------------------


def moe_params(cfg, rng):
    d, dff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    init = jax.nn.initializers.normal(0.02)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    p = {
        "router": init(k1, (d, e), jnp.float32),
        "wi_gate": init(k2, (e, d, dff), _dt(cfg)),
        "wi_up": init(k3, (e, d, dff), _dt(cfg)),
        "wo": init(k4, (e, dff, d), _dt(cfg)),
    }
    if cfg.n_shared_experts:
        k5, k6, k7 = jax.random.split(jax.random.fold_in(rng, 7), 3)
        sdff = dff * cfg.n_shared_experts
        p["shared"] = {"wi_gate": init(k5, (d, sdff), _dt(cfg)),
                       "wi_up": init(k6, (d, sdff), _dt(cfg)),
                       "wo": init(k7, (sdff, d), _dt(cfg))}
    return p


def moe_forward(cfg, params, x, *, capacity_factor: float | None = None):
    if capacity_factor is None:
        capacity_factor = getattr(cfg, "moe_capacity_factor", 1.25)
    """Capacity-based top-k MoE with scatter dispatch / gather combine.

    Tokens are scattered into per-expert buffers (E, C, d) — the layout that
    shards over the "model" axis for expert parallelism and whose resharding
    is the MoE all-to-all in the compiled collective schedule.  FLOPs scale
    with top_k·capacity_factor, not n_experts (unlike dense dispatch).
    Overflowing tokens are dropped (Switch semantics).  Returns (out, aux).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ params["router"]           # (T, E)
    topv, topi = jax.lax.top_k(logits, k)                        # (T, K)
    gates = jax.nn.softmax(topv, axis=-1)

    # aux load-balancing loss (Switch-style)
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)
    assigned = jax.nn.one_hot(topi, e, dtype=jnp.float32).sum(1)  # (T, E)
    ce = assigned.mean(axis=0) / k
    aux = e * jnp.sum(me * ce)

    flat_e = topi.reshape(-1)                                     # (T·K,)
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (T·K, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    capacity = max(4, int(t * k / e * capacity_factor + 0.999))
    keep = pos_in_e < capacity
    pos_c = jnp.minimum(pos_in_e, capacity - 1)

    contrib = xf[flat_tok] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e, capacity, d), x.dtype).at[flat_e, pos_c].add(contrib)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["wi_up"])
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"])               # (E, C, d)

    yk = y[flat_e, pos_c] * (flat_gate * keep).astype(x.dtype)[:, None]
    out = jnp.zeros((t, d), x.dtype).at[flat_tok].add(yk)
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        sp = params["shared"]
        out = out + (jax.nn.silu(x @ sp["wi_gate"]) * (x @ sp["wi_up"])) @ sp["wo"]
    return out, aux
