"""Train / serve step factories over the model zoo.

``make_train_step(cfg)``  → jit-able (params, opt_state, batch) -> (...)
``make_prefill / make_decode_step`` → the serving path (KV/SSM caches).
All steps are pure functions of pytrees — they lower and shard cleanly under
pjit with the logical sharding rules in repro.launch.shardings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import AdamWConfig, adamw_update, init_opt_state


def cross_entropy(logits, labels, mask=None):
    """Mean CE over valid positions; logits f32 (B, S, V), labels (B, S)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(ll)
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg, params, batch, *, aux_weight: float = 0.01):
    logits, aux, _ = M.forward(cfg, params, batch, mode="train")
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:
        # modality prefix (VLM): loss only over the token tail
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    loss = cross_entropy(logits, labels, batch.get("loss_mask"))
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


def make_train_step(cfg, opt_cfg: AdamWConfig = AdamWConfig()):
    accum = max(int(getattr(cfg, "grad_accum", 1)), 1)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        else:
            # microbatched gradient accumulation: activations live for one
            # microbatch only (global batch B → B/accum per fwd+bwd), cutting
            # peak activation memory ~accum× at identical math (mean of
            # per-microbatch grads == full-batch grad for mean losses).
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def one(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, mb), has_aux=True)(params)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / accum,
                    g_acc, grads)
                return (g_acc, l_acc + loss / accum), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics_seq = jax.lax.scan(
                one, (g0, jnp.zeros((), jnp.float32)), micro)
            metrics = jax.tree.map(lambda m: m.mean(), metrics_seq)
        params, opt_state, stats = adamw_update(opt_cfg, params, grads,
                                                opt_state)
        metrics = dict(metrics, loss=loss, **stats)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, metrics = loss_fn(cfg, params, batch)
        return dict(metrics, loss=loss)
    return eval_step


def make_prefill(cfg, max_len: int):
    def prefill(params, batch):
        b = batch["tokens"].shape[0]
        enc_len = batch["embeds"].shape[1] if cfg.encoder_layers else 0
        cache = M.init_cache(cfg, b, max_len, enc_len=enc_len)
        if cfg.encoder_layers:
            enc_out = M.encode(cfg, params, batch["embeds"])
            cache = M.fill_cross_cache(cfg, params, cache, enc_out)
        logits, _, cache = M.forward(cfg, params, batch, mode="prefill",
                                     cache=cache, cache_index=0)
        return logits, cache

    return prefill


def make_decode_step(cfg):
    def decode_step(params, cache, token, cache_index):
        """token: (B, 1) int32; cache_index: scalar int32 position."""
        logits, _, cache = M.forward(cfg, params, {"tokens": token},
                                     mode="decode", cache=cache,
                                     cache_index=cache_index)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, cache

    return decode_step


def init_train_state(cfg, rng, opt_cfg: AdamWConfig = AdamWConfig()):
    params = M.init_params(cfg, rng)
    return params, init_opt_state(params)
