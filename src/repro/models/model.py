"""Composable model zoo: one forward/init covering all assigned families.

* scan-over-layers with stacked per-layer params (compile-time O(1) in depth
  — required for the 126-layer 405B dry-run),
* optional remat (jax.checkpoint) around the layer body for training,
* KV caches (full, sliding-window ring for Hymba), SSM state caches, and
  whisper cross-attention caches for decode,
* modality frontends are STUBS per the assignment: ``batch["embeds"]``
  carries precomputed frame/patch embeddings at d_model.

Modes: "train" (causal, full seq), "prefill" (returns cache), "decode"
(single token step against the cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --- parameter init -------------------------------------------------------------


def _layer_params(cfg, rng, *, kind: str):
    """kind: decoder | encoder | cross_decoder."""
    p = {}
    ks = jax.random.split(rng, 8)
    if kind != "ssm_only" and cfg.n_heads:
        p["attn"] = L.attention_params(cfg, ks[0])
        p["ln_attn"] = L.norm_params(cfg, cfg.d_model)
    if kind == "cross_decoder":
        p["cross"] = L.attention_params(cfg, ks[1])
        p["ln_cross"] = L.norm_params(cfg, cfg.d_model)
    if cfg.family == "moe":
        p["moe"] = L.moe_params(cfg, ks[2])
        p["ln_mlp"] = L.norm_params(cfg, cfg.d_model)
    elif cfg.d_ff:
        p["mlp"] = L.mlp_params(cfg, ks[3])
        p["ln_mlp"] = L.norm_params(cfg, cfg.d_model)
    if cfg.family in ("ssm", "hybrid") or kind == "ssm_only":
        p["ssm"] = S.ssm_params(cfg, ks[4])
        if "ln_attn" not in p:
            p["ln_attn"] = L.norm_params(cfg, cfg.d_model)
    return p


def init_params(cfg, rng):
    ks = jax.random.split(rng, 6)
    init = jax.nn.initializers.normal(0.02)
    params = {
        "embed": init(ks[0], (cfg.vocab_size, cfg.d_model), _dt(cfg)),
        "ln_final": L.norm_params(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init(ks[1], (cfg.d_model, cfg.vocab_size), _dt(cfg))
    if cfg.max_position_embeddings:
        params["pos_embed"] = init(
            ks[2], (cfg.max_position_embeddings, cfg.d_model), _dt(cfg))

    kind = "cross_decoder" if cfg.encoder_layers else (
        "ssm_only" if cfg.family == "ssm" else "decoder")
    layer_keys = jax.random.split(ks[3], cfg.n_layers)
    params["layers"] = jax.vmap(
        lambda k: _layer_params(cfg, k, kind=kind))(layer_keys)

    if cfg.encoder_layers:
        enc_keys = jax.random.split(ks[4], cfg.encoder_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _layer_params(cfg, k, kind="encoder"))(enc_keys)
        params["enc_ln_final"] = L.norm_params(cfg, cfg.d_model)
        params["enc_pos_embed"] = init(
            ks[5], (max(cfg.frontend_len, 1), cfg.d_model), _dt(cfg))
    return params


def abstract_params(cfg):
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# --- caches ----------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, *, enc_len: int = 0):
    """Stacked (n_layers, ...) cache pytree for decode."""
    cache_len = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    c = {}
    if cfg.n_heads:
        kv = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.d_head)
        c["k"] = jnp.zeros(kv, _dt(cfg))
        c["v"] = jnp.zeros(kv, _dt(cfg))
        if cfg.attn_window:
            c["pos"] = jnp.full((cfg.n_layers, batch, cache_len), -1, jnp.int32)
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * cfg.d_model
        c["state"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, d_in // cfg.ssm_heads,
             cfg.ssm_state), jnp.float32)
    if cfg.encoder_layers:
        c["cross_k"] = jnp.zeros(
            (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.d_head), _dt(cfg))
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
    return c


# --- layer bodies -----------------------------------------------------------------


def _windowed_insert(cfg, lp, cache_layer, k_new, v_new, index, positions):
    """Ring-buffer insert for sliding-window caches (Hymba long decode)."""
    w = cache_layer["k"].shape[1]
    slot = index % w
    k = jax.lax.dynamic_update_slice_in_dim(
        cache_layer["k"], k_new.astype(cache_layer["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache_layer["v"], v_new.astype(cache_layer["v"].dtype), slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache_layer["pos"], positions.astype(jnp.int32), slot, axis=1)
    return {"k": k, "v": v, "pos": pos}


def _attn_block(cfg, lp, x, *, positions, mode, cache_layer, index,
                window=None):
    h = L.apply_norm(cfg, lp, x, "ln_attn")
    if mode == "prefill" and cfg.attn_window and cache_layer is not None:
        # windowed prefill: full blockwise pass, then ring-fill the cache
        # with the trailing `window` tokens' K/V.
        b, s, _ = h.shape
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        q = (h @ lp["attn"]["wq"]).reshape(b, s, hq, dh)
        k = (h @ lp["attn"]["wk"]).reshape(b, s, hkv, dh)
        v = (h @ lp["attn"]["wv"]).reshape(b, s, hkv, dh)
        if cfg.rope:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        if s >= cfg.blockwise_attn_threshold:
            out = L.blockwise_attention(q, k, v, causal=True,
                                        block=cfg.attn_block_size,
                                        window=cfg.attn_window)
        else:
            out = L.naive_attention(q, k, v, causal=True,
                                    window=cfg.attn_window)
        out = out.reshape(b, s, hq * dh) @ lp["attn"]["wo"]
        w = cache_layer["k"].shape[1]
        tail = min(w, s)
        # ring invariant: position p lives at slot p % w (so decode's
        # index % w insert always overwrites the oldest entry)
        slots = positions[0, s - tail:] % w
        new_cache = {
            "k": jnp.zeros_like(cache_layer["k"]).at[:, slots].set(
                k[:, s - tail:].astype(cache_layer["k"].dtype)),
            "v": jnp.zeros_like(cache_layer["v"]).at[:, slots].set(
                v[:, s - tail:].astype(cache_layer["v"].dtype)),
            "pos": jnp.full_like(cache_layer["pos"], -1).at[:, slots].set(
                positions[:, s - tail:]),
        }
        return out, new_cache
    if mode == "decode" and cfg.attn_window and cache_layer is not None:
        # sliding-window ring cache: project, rope at absolute pos, ring insert
        b, s, _ = h.shape
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        q = (h @ lp["attn"]["wq"]).reshape(b, s, hq, dh)
        k = (h @ lp["attn"]["wk"]).reshape(b, s, hkv, dh)
        v = (h @ lp["attn"]["wv"]).reshape(b, s, hkv, dh)
        if cfg.rope:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        new_cache = _windowed_insert(cfg, lp, cache_layer, k, v, index,
                                     positions)
        scale = dh ** -0.5
        q5 = L._group_q(q, hkv)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", q5, new_cache["k"],
                            preferred_element_type=jnp.float32) * scale
        valid = (new_cache["pos"] >= 0)[:, None, :] & \
                (new_cache["pos"][:, None, :] <= positions[:, :, None]) & \
                (new_cache["pos"][:, None, :] > positions[:, :, None] - cfg.attn_window)
        scores = jnp.where(valid[:, None, None], scores, L.NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd",
                         probs.astype(new_cache["v"].dtype), new_cache["v"],
                         preferred_element_type=jnp.float32)
        out = out.astype(x.dtype).reshape(b, s, hq * dh) @ lp["attn"]["wo"]
        return out, new_cache
    out, new_cache = L.attention_forward(
        cfg, lp["attn"], h, positions=positions, causal=True,
        cache={"k": cache_layer["k"], "v": cache_layer["v"]}
        if cache_layer is not None else None,
        cache_index=index, window=window)
    if cache_layer is not None and "pos" in cache_layer:
        new_cache["pos"] = cache_layer["pos"]
    return out, new_cache


def _decoder_layer(cfg, lp, x, aux, *, positions, mode, cache_layer=None,
                   index=0, enc_out=None):
    new_cache = {}
    if cfg.family == "ssm":
        h = L.apply_norm(cfg, lp, x, "ln_attn")
        st = cache_layer.get("state") if cache_layer else None
        y, st_new = S.ssd_forward(cfg, lp["ssm"], h, state=st)
        x = x + y
        if cache_layer is not None:
            new_cache["state"] = st_new
    elif cfg.family == "hybrid":
        attn_cache = ({"k": cache_layer["k"], "v": cache_layer["v"],
                       "pos": cache_layer["pos"]}
                      if cache_layer is not None else None)
        a_out, c_new = _attn_block(cfg, lp, x, positions=positions, mode=mode,
                                   cache_layer=attn_cache, index=index)
        h = L.apply_norm(cfg, lp, x, "ln_attn")
        st = cache_layer.get("state") if cache_layer else None
        s_out, st_new = S.ssd_forward(cfg, lp["ssm"], h, state=st)
        x = x + (a_out + s_out) / 2.0
        if cache_layer is not None:
            new_cache.update(c_new)
            new_cache["state"] = st_new
    else:
        attn_cache = ({"k": cache_layer["k"], "v": cache_layer["v"]}
                      if cache_layer is not None else None)
        a_out, c_new = _attn_block(cfg, lp, x, positions=positions, mode=mode,
                                   cache_layer=attn_cache, index=index)
        x = x + a_out
        if cache_layer is not None:
            new_cache.update(c_new)

    if cfg.encoder_layers:
        h = L.apply_norm(cfg, lp, x, "ln_cross")
        if cache_layer is not None:
            kv = (cache_layer["cross_k"], cache_layer["cross_v"])
        else:
            b = enc_out.shape[0]
            kv = ((enc_out @ lp["cross"]["wk"]).reshape(
                      b, -1, cfg.n_kv_heads, cfg.d_head),
                  (enc_out @ lp["cross"]["wv"]).reshape(
                      b, -1, cfg.n_kv_heads, cfg.d_head))
        c_out, _ = L.attention_forward(
            cfg, lp["cross"], h, positions=positions, causal=False,
            kv_override=kv, window=0)
        x = x + c_out
        if cache_layer is not None:
            new_cache["cross_k"] = cache_layer["cross_k"]
            new_cache["cross_v"] = cache_layer["cross_v"]

    if cfg.family == "moe":
        h = L.apply_norm(cfg, lp, x, "ln_mlp")
        y, a = L.moe_forward(cfg, lp["moe"], h)
        x = x + y
        aux = aux + a
    elif cfg.d_ff:
        h = L.apply_norm(cfg, lp, x, "ln_mlp")
        x = x + L.mlp_forward(cfg, lp["mlp"], h)
    return x, aux, new_cache


def _encoder_layer(cfg, lp, x, *, positions):
    h = L.apply_norm(cfg, lp, x, "ln_attn")
    out, _ = L.attention_forward(cfg, lp["attn"], h, positions=positions,
                                 causal=False, window=0)
    x = x + out
    h = L.apply_norm(cfg, lp, x, "ln_mlp")
    return x + L.mlp_forward(cfg, lp["mlp"], h)


# --- full forward -----------------------------------------------------------------


def encode(cfg, params, embeds):
    """Encoder stack over precomputed frontend embeddings (B, T, d)."""
    b, t, _ = embeds.shape
    x = embeds.astype(_dt(cfg)) + params["enc_pos_embed"][None, :t]
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def body(carry, lp):
        return _encoder_layer(cfg, lp, carry, positions=positions), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(cfg, params, x, "enc_ln_final")


def _embed_tokens(cfg, params, tokens, positions):
    x = params["embed"][tokens]
    if cfg.max_position_embeddings:
        pos = jnp.minimum(positions, cfg.max_position_embeddings - 1)
        x = x + params["pos_embed"][pos]
    return x


def _logits(cfg, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x.astype(jnp.float32) @ head.astype(jnp.float32))


def forward(cfg, params, batch, *, mode: str = "train", cache=None,
            cache_index=0):
    """batch: {"tokens": (B,S) int32, optional "embeds": (B,T,d)}.

    train/prefill: full-sequence causal pass; prefill also returns the filled
    cache.  decode: tokens (B,1) against cache at cache_index.
    Returns (logits, aux_loss, new_cache).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s)) + cache_index
    x = _embed_tokens(cfg, params, tokens, positions)

    enc_out = None
    if cfg.encoder_layers and mode != "decode":
        enc_out = encode(cfg, params, batch["embeds"])
    elif cfg.frontend == "vision_stub" and "embeds" in batch and mode != "decode":
        x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    aux0 = jnp.zeros((), jnp.float32)

    if mode in ("train", "prefill") and cache is None:
        def body(carry, lp):
            xc, aux = carry
            xc, aux, _ = _decoder_layer(cfg, lp, xc, aux, positions=positions,
                                        mode=mode, enc_out=enc_out)
            return (xc, aux), None

        if cfg.remat and mode == "train":
            policy = (jax.checkpoint_policies.dots_saveable
                      if getattr(cfg, "remat_policy", "dots") == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(body, policy=policy)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
        x = L.apply_norm(cfg, params, x, "ln_final")
        logits = _logits(cfg, params, x)
        return logits, aux, None

    if mode == "prefill":
        # fill the cache with a full pass (cache provided)
        def body(carry, scanned):
            xc, aux = carry
            lp, cl = scanned
            xc, aux, c_new = _decoder_layer(
                cfg, lp, xc, aux, positions=positions, mode=mode,
                cache_layer=cl, index=cache_index, enc_out=enc_out)
            return (xc, aux), c_new

        (x, aux), new_cache = jax.lax.scan(body, (x, aux0),
                                           (params["layers"], cache))
        x = L.apply_norm(cfg, params, x, "ln_final")
        return _logits(cfg, params, x[:, -1:]), aux, new_cache

    # decode
    def body(carry, scanned):
        xc, aux = carry
        lp, cl = scanned
        xc, aux, c_new = _decoder_layer(
            cfg, lp, xc, aux, positions=positions, mode="decode",
            cache_layer=cl, index=cache_index, enc_out=enc_out)
        return (xc, aux), c_new

    (x, aux), new_cache = jax.lax.scan(body, (x, aux0),
                                       (params["layers"], cache))
    x = L.apply_norm(cfg, params, x, "ln_final")
    return _logits(cfg, params, x), aux, new_cache


def fill_cross_cache(cfg, params, cache, enc_out):
    """Precompute per-layer cross-attention K/V from encoder outputs."""
    b, t, _ = enc_out.shape

    def per_layer(lp, ck, cv):
        k = (enc_out @ lp["cross"]["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
        v = (enc_out @ lp["cross"]["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head)
        return k.astype(ck.dtype), v.astype(cv.dtype)

    k, v = jax.vmap(per_layer)(params["layers"], cache["cross_k"],
                               cache["cross_v"])
    out = dict(cache)
    out["cross_k"], out["cross_v"] = k, v
    return out
