"""jit'd wrapper for the fused staging-pass kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_ntt_tile.kernel import fused_ntt_tile_pallas
from repro.kernels.limb_matmul.ops import _pad_to, _pick_bn


def fused_ntt_tile(a_u8, b3_s8, *, modulus: int, accum: str = "int32_native",
                   interpret: bool | None = None):
    """(N, K) u8 × (K, D, n_diag) s8 -> (N, D) uint32 folded mod m."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, k = a_u8.shape
    _, d, n_diag = b3_s8.shape
    bn = _pick_bn(n)
    bd = 128 if d % 128 == 0 else d
    a_p = _pad_to(_pad_to(a_u8, 0, bn), 1, 128)
    b_p = _pad_to(b3_s8, 0, 128)
    out = fused_ntt_tile_pallas(a_p, b_p, modulus=modulus, accum=accum,
                                bn=bn, bd=bd, interpret=interpret)
    return out[:n, :d]
