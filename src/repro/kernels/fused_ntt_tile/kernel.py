"""Pallas TPU kernel (beyond-paper): one staging pass, MXU matmul + VPU fold
fused in a single kernel — the int32 diagonal planes never round-trip HBM.

Memory-term napkin math (BN254, d=256, N=128): the unfused pipeline writes
and re-reads (N, d, 7) int32 diagonals = 2 × 128·256·7·4B ≈ 1.8 MB per pass;
fused, only the (N, d) uint32 result (128 KB) leaves VMEM — a ~14× cut in
pass-local HBM traffic.  The fold still runs *after* the pass's summation
completes (Invariant 5.1 is an ordering constraint, which the in-kernel
sequencing preserves), but the paper's multi-tenant discipline keeps the
phases in separate HLO ops — so this kernel is the single-tenant /
relaxed-separation fast path (DESIGN.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, accum: str,
                  modulus: int, n_diag: int, bd: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bk = a_ref.shape[1]
    b = b_ref[...].reshape(bk, bd * n_diag)
    if accum == "fp32_mantissa":
        acc_ref[...] += jax.lax.dot(a_ref[...].astype(jnp.float32),
                                    b.astype(jnp.float32),
                                    preferred_element_type=jnp.float32)
    else:
        acc_ref[...] += jax.lax.dot(a_ref[...].astype(jnp.int32),
                                    b.astype(jnp.int32),
                                    preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _fold_and_flush():
        m = jnp.uint32(modulus)
        diags = acc_ref[...].astype(jnp.int32).reshape(
            acc_ref.shape[0], bd, n_diag)
        acc = jnp.zeros((acc_ref.shape[0], bd), jnp.uint32)
        for k in range(n_diag - 1, -1, -1):
            for _ in range(8):
                acc = acc << jnp.uint32(1)
                acc = jnp.where(acc >= m, acc - m, acc)
            dk = jnp.mod(diags[..., k], jnp.int32(modulus)).astype(jnp.uint32)
            s = acc + dk
            acc = jnp.where(s >= m, s - m, s)
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "modulus", "accum", "bn", "bd", "bk", "interpret"))
def fused_ntt_tile_pallas(a_u8, b3_s8, *, modulus: int,
                          accum: str = "int32_native", bn: int = 128,
                          bd: int = 128, bk: int = 128, interpret: bool = True):
    """(N, K) u8 × (K, D, n_diag) s8 -> (N, D) uint32 folded mod m."""
    n, k = a_u8.shape
    k2, d, n_diag = b3_s8.shape
    assert k == k2 and n % bn == 0 and d % bd == 0 and k % bk == 0
    k_steps = k // bk
    acc_dtype = jnp.float32 if accum == "fp32_mantissa" else jnp.int32

    return pl.pallas_call(
        functools.partial(_fused_kernel, k_steps=k_steps, accum=accum,
                          modulus=modulus, n_diag=n_diag, bd=bd),
        grid=(n // bn, d // bd, k_steps),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bd, n_diag), lambda i, j, kk: (kk, j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((bn, bd * n_diag), acc_dtype)],
        interpret=interpret,
    )(a_u8, b3_s8)
