"""Oracle for the fused staging pass: limb matmul + in-VMEM fold."""
import jax.numpy as jnp

from repro.core import field as F


def fused_ntt_tile_ref(a_u8, b3_s8, modulus: int, accum: str = "int32_native"):
    """a: (N, K) u8, b3: (K, D, n_diag) s8 -> (N, D) uint32 = fold(a @ b3)."""
    k, d, n_diag = b3_s8.shape
    if accum == "fp32_mantissa":
        acc = jnp.dot(a_u8.astype(jnp.float32),
                      b3_s8.reshape(k, d * n_diag).astype(jnp.float32),
                      preferred_element_type=jnp.float32).astype(jnp.int32)
    else:
        acc = jnp.dot(a_u8.astype(jnp.int32),
                      b3_s8.reshape(k, d * n_diag).astype(jnp.int32),
                      preferred_element_type=jnp.int32)
    diags = acc.reshape(a_u8.shape[0], d, n_diag)
    return F.fold_diagonals_u32(diags, jnp.uint32(modulus))
