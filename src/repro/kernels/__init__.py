"""Pallas TPU kernels for the compute hot-spots + staged_transform adapters.

* ``limb_matmul``     — fused limb-interleaved u8×s8 matmul (one staging pass
  of the matrix-form NTT), int32 or fp32-mantissa VMEM accumulation.
* ``mont_fold``       — the per-pass VPU fold (diagonals → residue mod m).
* ``fused_ntt_tile``  — beyond-paper: matmul + fold in one kernel; diagonal
  planes never round-trip HBM (single-tenant fast path).

``pallas_tile_fn``/``pallas_fused_transform`` plug these into
:func:`repro.core.limb_gemm.staged_transform`.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.limb_matmul.ops import limb_matmul
from repro.kernels.mont_fold.ops import mont_fold, mont_fold_window_fn
from repro.kernels.fused_ntt_tile.ops import fused_ntt_tile


def pallas_tile_fn(interpret: bool | None = None):
    """kernel_fn for staged_transform: Pallas limb matmul per staging pass."""

    def fn(a_tile_u32, w_planes_tile, fused_tile, plan):
        from repro.core import limbs as L
        if fused_tile is None:
            raise ValueError("pallas tile fn requires the fused operand layout")
        n = a_tile_u32.shape[0]
        limbs = L.decompose_u8(a_tile_u32, plan.data_limbs).reshape(n, -1)
        out = limb_matmul(limbs, fused_tile, accum=plan.accum,
                          interpret=interpret)
        return out.reshape(n, plan.d, plan.n_diag)

    return fn


def fused_operand_3d(plan) -> np.ndarray:
    """(d·La, d, n_diag) int8 layout for the fused kernel."""
    return plan.fused_operand.reshape(
        plan.d * plan.data_limbs, plan.d, plan.n_diag)


def pallas_fused_transform(a_u32, plan, *, interpret: bool | None = None):
    """Full staged transform with the fused matmul+fold kernel per pass.

    Eager per-pass folding (Invariant 5.1 ordering preserved in-kernel), but
    the diagonals stay in VMEM — the beyond-paper single-tenant fast path.
    """
    from repro.core import field as F
    from repro.core import limbs as L

    b3 = jnp.asarray(fused_operand_3d(plan))
    m = jnp.uint32(plan.modulus)
    la = plan.data_limbs
    n = a_u32.shape[0]
    y = jnp.zeros((n, plan.d), jnp.uint32)
    for lo, hi in plan.tile_bounds():
        limbs = L.decompose_u8(a_u32[:, lo:hi], la).reshape(n, -1)
        y_t = fused_ntt_tile(limbs, b3[lo * la:hi * la], modulus=plan.modulus,
                             accum=plan.accum, interpret=interpret)
        y = F.addmod_u32(y, y_t, m)
    return y
