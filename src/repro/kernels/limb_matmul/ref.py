"""Pure-jnp oracle for the limb-interleaved u8×s8 matmul."""
import jax.numpy as jnp


def limb_matmul_ref(a_u8, b_s8, accum: str = "int32_native"):
    """a: (N, K) u8, b: (K, M) s8 -> (N, M) int32 (exact within window).

    fp32_mantissa model accumulates in float32 (v4 MXU path) and re-enters
    the integer domain at the end — bit-faithful to the modelled hardware.
    """
    if accum == "fp32_mantissa":
        out = jnp.dot(a_u8.astype(jnp.float32), b_s8.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        return out.astype(jnp.int32)
    return jnp.dot(a_u8.astype(jnp.int32), b_s8.astype(jnp.int32),
                   preferred_element_type=jnp.int32)
