"""jit'd wrapper for the limb matmul kernel: padding + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.limb_matmul.kernel import limb_matmul_pallas


def _pad_to(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_bn(n: int) -> int:
    if n >= 128:
        return 128
    b = 8
    while b < n:
        b *= 2
    return b


def limb_matmul(a_u8, b_s8, *, accum: str = "int32_native",
                interpret: bool | None = None):
    """(N, K) u8 × (K, M) s8 -> (N, M) int32 via the Pallas kernel.

    Pads every dim to MXU-aligned block multiples (exact: zero padding).
    interpret defaults to True off-TPU (kernel body runs in Python on CPU).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, k = a_u8.shape
    m = b_s8.shape[1]
    bn = _pick_bn(n)
    a_p = _pad_to(_pad_to(a_u8, 0, bn), 1, 128)
    b_p = _pad_to(_pad_to(b_s8, 0, 128), 1, 128)
    out = limb_matmul_pallas(a_p, b_p, bn=bn, accum=accum, interpret=interpret)
    return out[:n, :m]
