"""Pallas TPU kernel: fused limb-interleaved u8×s8 matmul with int32/f32 VMEM
accumulation (one staging pass of the matrix-form NTT).

Tiling: grid (N/bn, M/bm, K/bk); A (bn, bk) u8 and B (bk, bm) s8 blocks are
staged HBM→VMEM per step, partial sums live in a VMEM scratch accumulator and
are written back once per (n, m) tile — K is the innermost ("arbitrary")
grid dimension so the accumulator never round-trips HBM.

MXU alignment: all block dims are multiples of 128 (the systolic tile edge);
ops.py zero-pads K/M/N to block multiples, which is exact for this integer
workload.  The ``fp32_mantissa`` variant accumulates in float32, reproducing
the TPU v4 MXU partial-sum path of paper Property 5.1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, accum: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if accum == "fp32_mantissa":
        a = a_ref[...].astype(jnp.float32)
        b = b_ref[...].astype(jnp.float32)
        acc_ref[...] += jax.lax.dot(a, b, preferred_element_type=jnp.float32)
    else:
        a = a_ref[...].astype(jnp.int32)
        b = b_ref[...].astype(jnp.int32)
        acc_ref[...] += jax.lax.dot(a, b, preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bk", "accum", "interpret"))
def limb_matmul_pallas(a_u8, b_s8, *, bn: int = 128, bm: int = 128,
                       bk: int = 128, accum: str = "int32_native",
                       interpret: bool = True):
    """(N, K) u8 × (K, M) s8 -> (N, M) int32. Caller pads to block multiples."""
    n, k = a_u8.shape
    k2, m = b_s8.shape
    assert k == k2 and n % bn == 0 and m % bm == 0 and k % bk == 0, (
        "ops.py must pad operands to block multiples")
    k_steps = k // bk
    acc_dtype = jnp.float32 if accum == "fp32_mantissa" else jnp.int32

    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps, accum=accum),
        grid=(n // bn, m // bm, k_steps),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bn, bm), acc_dtype)],
        interpret=interpret,
    )(a_u8, b_s8)
