"""Pallas TPU kernel: the per-pass VPU fold (diagonals → field residue mod m).

Elementwise Horner over the limb weight classes with conditional-subtract
modular doublings — pure VPU work, no MXU.  Blocked over the (rows, coeffs)
plane with the full (small) n_diag axis resident per block.

This is the operation whose *eager* per-pass scheduling the paper's Invariant
5.1 mandates; keeping it a separate kernel (vs. fused_ntt_tile) mirrors the
multi-tenant isolation discipline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fold_kernel(d_ref, o_ref, *, modulus: int, n_diag: int):
    m = jnp.uint32(modulus)
    acc = jnp.zeros(o_ref.shape, jnp.uint32)
    for k in range(n_diag - 1, -1, -1):
        # acc = (acc << 8) mod m via 8 conditional doublings (acc < m < 2^31)
        for _ in range(8):
            acc = acc << jnp.uint32(1)
            acc = jnp.where(acc >= m, acc - m, acc)
        dk = jnp.mod(d_ref[..., k], jnp.int32(modulus)).astype(jnp.uint32)
        s = acc + dk
        acc = jnp.where(s >= m, s - m, s)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("modulus", "bn", "bd", "interpret"))
def mont_fold_pallas(diags, *, modulus: int, bn: int = 8, bd: int = 256,
                     interpret: bool = True):
    """int32 (N, D, n_diag) -> uint32 (N, D): Σ_k diag_k·2^{8k} mod m."""
    n, d, n_diag = diags.shape
    assert n % bn == 0 and d % bd == 0, "ops.py must pad to block multiples"
    return pl.pallas_call(
        functools.partial(_fold_kernel, modulus=modulus, n_diag=n_diag),
        grid=(n // bn, d // bd),
        in_specs=[pl.BlockSpec((bn, bd, n_diag), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.uint32),
        interpret=interpret,
    )(diags)
