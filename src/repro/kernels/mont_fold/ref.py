"""Pure-jnp oracle for the VPU Montgomery/mod-m fold of limb diagonals."""
import jax.numpy as jnp

from repro.core import field as F


def mont_fold_ref(diags, m: int):
    """int32 (..., n_diag) weight-class diagonals -> uint32 (...) mod m."""
    return F.fold_diagonals_u32(diags, jnp.uint32(m))
