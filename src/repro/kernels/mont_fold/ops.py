"""jit'd wrapper for the VPU fold kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mont_fold.kernel import mont_fold_pallas


def mont_fold(diags, modulus: int, *, interpret: bool | None = None):
    """int32 (N, D, n_diag) -> uint32 (N, D) folded mod m."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d, n_diag = diags.shape
    bn = min(8, n) if n % 8 else 8
    bn = n if n < 8 else 8
    pad_n = (-n) % bn
    bd = min(256, d) if d % 256 else 256
    bd = d if d < 256 else 256
    pad_d = (-d) % bd
    x = jnp.pad(diags, ((0, pad_n), (0, pad_d), (0, 0)))
    out = mont_fold_pallas(x, modulus=modulus, bn=bn, bd=bd,
                           interpret=interpret)
    return out[:n, :d]


def mont_fold_window_fn(*, interpret: bool | None = None):
    """``fold_fn`` adapter for κ-window lazy mode.

    Returned callable has the ``fold_fn(acc_diag, modulus) -> uint32``
    contract of :func:`repro.core.montgomery.deferred_fold`, so the once-per-
    window deferred reduction runs through the Pallas VPU kernel instead of
    the elementwise jnp fold.  Semantics are identical (same Horner/
    conditional-subtract recurrence); diagonals may be κ-pass sums — the
    kernel's per-diagonal ``mod`` handles any int32 magnitude.
    """

    def fold(acc_diag, modulus):
        return mont_fold(acc_diag, int(modulus), interpret=interpret)

    return fold
