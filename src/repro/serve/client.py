"""Synthetic load generation for the online server.

``attach_payloads`` is the single payload synthesiser shared by the offline
replay (``launch/serve.py``) and the online client, so the two paths consume
byte-identical traces — the per-tenant parity test in
``tests/test_serve_runtime.py`` depends on this.

``LoadGenerator`` replays a trace against a :class:`CryptoServer` on a
virtual clock derived from arrival timestamps: deterministic, immune to host
jitter, and able to model hours of traffic in seconds of wall time.  Pass
``realtime=True`` to pace submissions with actual sleeps instead.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import workloads as WK
from repro.core.scheduler.queue import PoissonTrace, TenantRequest


def attach_payloads(trace: list[TenantRequest], *, seed: int = 0,
                    accum: str = "fp32_mantissa",
                    bn_degree_cap: int = 64) -> list[TenantRequest]:
    """Draw coefficient payloads for a trace (one rng stream, arrival order).

    BN254 degrees are capped (CPU-budget rows, matching the offline replay)
    and ingested to ERNS residue form; Dilithium rows stay raw u32.
    """
    rng = np.random.default_rng(seed)
    for r in trace:
        if r.workload == "dilithium":
            r.coeffs = np.asarray(rng.integers(
                0, 8380417, r.degree, dtype=np.uint64), np.uint32)
        else:
            eng = WK.make_engine("bn254", 64, accum=accum)
            r.degree = min(r.degree, bn_degree_cap)
            vals = np.array([int(x) for x in
                             rng.integers(0, 2**31, r.degree)], object)
            r.coeffs = np.asarray(eng.ingest(vals))
    return trace


@dataclasses.dataclass
class LoadResult:
    outputs: dict            # tenant_id -> result rows (numpy).  Trace
                             # tenants are unique per request; if a tenant
                             # submits several requests, this map keeps the
                             # last — `handles` carries every per-request
                             # result.
    handles: list            # every ResponseHandle, submission order
    rejected: list           # (request, AdmissionDecision) pairs
    duration_s: float        # trace horizon (virtual) or wall time (realtime)

    @property
    def n_served(self) -> int:
        return len(self.outputs)


class LoadGenerator:
    def __init__(self, trace, *, seed: int = 0, accum: str = "fp32_mantissa",
                 attach: bool = True):
        if isinstance(trace, PoissonTrace):
            trace = trace.generate()
        self.trace = sorted(trace, key=lambda r: r.arrival_time)
        if attach and any(r.coeffs is None for r in self.trace):
            attach_payloads(self.trace, seed=seed, accum=accum)

    @staticmethod
    def _realtime_advance(server, target: float, t_wall0: float,
                          t_virtual0: float) -> float:
        """Wall-clock wait until ``target``, waking for every server age
        deadline on the way so sparse traces still flush on time (pumping
        with the *current* clock, not a stale deadline)."""
        while True:
            now = time.monotonic() - t_wall0 + t_virtual0
            deadline = server.next_deadline()
            wake = target if deadline is None else min(target, deadline)
            if wake > now:
                time.sleep(wake - now)
                now = time.monotonic() - t_wall0 + t_virtual0
            if deadline is not None and deadline <= now:
                server.pump(now)
            if now >= target:
                return now

    def run(self, server, *, realtime: bool = False,
            arrival_batch: int | None = None) -> LoadResult:
        """Closed loop: submit in arrival order, pump age triggers between
        arrivals, drain at end-of-trace, collect per-tenant results.

        ``arrival_batch`` feeds the trace through the server's vectorised
        ``submit_many`` edge in consecutive chunks of that many arrivals
        (each stamped with its own trace timestamp) instead of one
        ``submit`` per request — the ingress shape the columnar admission
        path is built for.  Age deadlines that elapse before a chunk's first
        arrival are pumped first, as in the per-request path.  Virtual-clock
        only (a real-time pacer would defeat the batching)."""
        if arrival_batch is not None and realtime:
            raise ValueError("arrival_batch batches the virtual clock — "
                             "incompatible with realtime pacing")
        handles, rejected = [], []
        t_wall0 = time.monotonic()
        t_virtual0 = self.trace[0].arrival_time if self.trace else 0.0
        if arrival_batch is not None:
            for lo in range(0, len(self.trace), arrival_batch):
                chunk = self.trace[lo:lo + arrival_batch]
                first = chunk[0].arrival_time
                deadline = server.next_deadline()
                while deadline is not None and deadline <= first:
                    server.pump(deadline)
                    deadline = server.next_deadline()
                hs = server.submit_many(
                    chunk, nows=[r.arrival_time for r in chunk])
                handles.extend(hs)
                rejected.extend((r, h.decision)
                                for r, h in zip(chunk, hs) if h.rejected)
            end = self.trace[-1].arrival_time if self.trace else 0.0
            server.drain(end)
            outputs = {h.request.tenant_id: h.result()
                       for h in handles if h.done() and not h.rejected}
            return LoadResult(outputs=outputs, handles=handles,
                              rejected=rejected, duration_s=end - t_virtual0)
        for req in self.trace:
            if realtime:
                now = self._realtime_advance(server, req.arrival_time,
                                             t_wall0, t_virtual0)
            else:
                now = req.arrival_time
                # fire every age deadline that elapsed before this arrival
                deadline = server.next_deadline()
                while deadline is not None and deadline <= now:
                    server.pump(deadline)
                    deadline = server.next_deadline()
            h = server.submit(req, now=now)
            handles.append(h)
            if h.rejected:
                rejected.append((req, h.decision))
        end = (time.monotonic() - t_wall0 + t_virtual0) if realtime else (
            self.trace[-1].arrival_time if self.trace else 0.0)
        server.drain(end)
        outputs = {h.request.tenant_id: h.result()
                   for h in handles if h.done() and not h.rejected}
        return LoadResult(outputs=outputs, handles=handles, rejected=rejected,
                          duration_s=end - t_virtual0)
