"""Continuous rectangular batching.

The offline Tier-1 scheduler (:mod:`repro.core.scheduler.rectangular`) plans
batches from a complete queue snapshot.  Online, requests trickle in, so the
batcher keeps one *open* batch per (workload, degree-bucket) class and closes
it on whichever trigger fires first:

* **full** — N_c rows stacked (M-dimension occupancy target reached);
* **occupancy** — active-cell fraction of the would-be operand crossed the
  configured threshold (useful work dominates padding even with < N_c rows);
* **age** — the oldest row has waited ``max_age_s`` (latency SLO beats
  occupancy once a request has aged);
* **drain** — server shutdown flushes everything.

Closed batches are ordinary :class:`StackedBatch` objects, so Tier-2 dispatch
and the paper's packing metrics apply unchanged.  With ``pad_rows`` (default)
operands are padded with zero rows to the full ``N_c × d̂`` shape so every
batch of a class hits the co-scheduler's compiled-program cache; zero rows
transform to zero rows and are never routed back to any tenant.

With ``pad_rows=False`` the batcher emits **mergeable** batches instead:
operands carry live rows only, so the co-scheduler's M-axis super-batching
can stack same-class batches densely (no interior padding rows) and its row
ladder does the shape-stabilising padding once, on the merged operand.  The
serving layer selects this mode automatically when its co-scheduler has a
row ladder.

With a ``controller`` (:class:`repro.serve.controller.AdaptiveController`)
the close policy stops being static: the full trigger fires at the
controller's per-class *target rung* instead of ``n_c``, the age trigger
uses the per-class adapted ``max_age``, and the occupancy threshold (when
configured) is the adapted one — all bounded by the static config values.
``n_c``/``max_age_s``/``occupancy_close`` then act as the loop's initial
values and floors/ceilings rather than as the policy itself.
"""
from __future__ import annotations

import dataclasses

from repro.core.scheduler.rectangular import (StackedBatch, select_bucket,
                                              stack_rows)

CLOSE_FULL = "full"
CLOSE_AGE = "age"
CLOSE_OCCUPANCY = "occupancy"
CLOSE_DRAIN = "drain"


@dataclasses.dataclass
class _OpenBatch:
    workload: str
    d_bucket: int
    requests: list
    opened_at: float
    sum_degrees: int = 0
    bid: int = 0             # causal batch ID (0 when tracing is off)


@dataclasses.dataclass(frozen=True)
class ClosedBatch:
    batch: StackedBatch
    reason: str
    age_s: float             # oldest-row residency at close time
    batch_id: int = 0        # causal batch ID (0 when tracing is off)


class ContinuousBatcher:
    def __init__(self, *, n_c: int = 8,
                 bucket_granularity: int | None = None,
                 max_age_s: float = 0.01,
                 occupancy_close: float | None = None,
                 pad_rows: bool = True,
                 controller=None, tracer=None):
        self.n_c = n_c
        self.granularity = bucket_granularity
        self.max_age_s = max_age_s
        self.occupancy_close = occupancy_close
        self.pad_rows = pad_rows
        # Optional AdaptiveController: when present, the per-class close
        # policy below asks it for target rows / age / occupancy instead of
        # using the static values (which become the loop's bounds).
        self.controller = controller
        # Optional repro.obs.Tracer: open batches become async "batch" spans
        # whose close event lists the stacked request IDs (the trace's
        # causal middle link — submit → batch roster → launch).
        self.tracer = tracer
        self._open: dict[tuple, _OpenBatch] = {}
        self._depth = 0

    # --- per-class close policy (static or controller-driven) -----------------

    def _target_rows(self, key: tuple) -> int:
        if self.controller is not None:
            return self.controller.target_rows(key)
        return self.n_c

    def _max_age_for(self, key: tuple) -> float:
        if self.controller is not None:
            return self.controller.max_age_s(key)
        return self.max_age_s

    def _occupancy_close_for(self, key: tuple) -> float | None:
        if self.controller is not None:
            return self.controller.occupancy_close(key)
        return self.occupancy_close

    # --- introspection --------------------------------------------------------

    @property
    def depth(self) -> int:
        """Pending (accepted, not yet dispatched) request count."""
        return self._depth

    @property
    def open_batches(self) -> int:
        """Open (workload, bucket) classes awaiting a close trigger."""
        return len(self._open)

    def class_depth(self, key: tuple) -> int:
        """Pending rows of one (workload, d_bucket) class — the per-class
        backlog the adaptive controller's queue model consumes (the global
        ``depth`` would let a busy neighbour class inflate it)."""
        ob = self._open.get(key)
        return len(ob.requests) if ob is not None else 0

    def oldest_age(self, now: float) -> float:
        if not self._open:
            return 0.0
        return max(now - ob.opened_at for ob in self._open.values())

    def bucket_for(self, d: int) -> int:
        return select_bucket(d, self.granularity)

    # --- the three online triggers --------------------------------------------

    def add(self, req, now: float) -> list[ClosedBatch]:
        """Stack one request; return any batch this add closed."""
        key = (req.workload, self.bucket_for(req.degree))
        ob = self._open.get(key)
        tr = self.tracer
        if ob is None:
            ob = self._open[key] = _OpenBatch(
                workload=key[0], d_bucket=key[1], requests=[], opened_at=now)
            if tr is not None:
                ob.bid = tr.next_id()
                tr.begin("batch", ob.bid, f"batch:{key[0]}/d{key[1]}", now,
                         track="batcher",
                         args={"workload": key[0], "d_bucket": key[1]})
        ob.requests.append(req)
        ob.sum_degrees += req.degree
        self._depth += 1
        if self.controller is not None:
            self.controller.observe_arrival(key, now)
        target = self._target_rows(key)
        if len(ob.requests) >= target:
            return [self._close(key, CLOSE_FULL, now)]
        occupancy_close = self._occupancy_close_for(key)
        if occupancy_close is not None:
            occ = ob.sum_degrees / (target * ob.d_bucket)
            if occ >= occupancy_close:
                return [self._close(key, CLOSE_OCCUPANCY, now)]
        return []

    def poll(self, now: float) -> list[ClosedBatch]:
        """Close every open batch whose oldest row has exceeded its class's
        max age (static, or controller-adapted)."""
        # Same float expression as next_deadline(): pumping exactly at the
        # returned deadline must close the batch that produced it.
        due = [key for key, ob in self._open.items()
               if now >= ob.opened_at + self._max_age_for(key)]
        return [self._close(key, CLOSE_AGE, now) for key in due]

    def next_deadline(self) -> float | None:
        """Earliest future instant at which poll() will close something."""
        if not self._open:
            return None
        return min(ob.opened_at + self._max_age_for(key)
                   for key, ob in self._open.items())

    def flush(self, now: float = 0.0) -> list[ClosedBatch]:
        """Close everything (graceful drain)."""
        return [self._close(key, CLOSE_DRAIN, now) for key in list(self._open)]

    def _close(self, key: tuple, reason: str, now: float) -> ClosedBatch:
        ob = self._open.pop(key)
        self._depth -= len(ob.requests)
        if self.controller is not None:
            self.controller.observe_close(key, reason)
        if self.tracer is not None:
            # The close event carries the request-id roster — one list per
            # batch instead of one enqueue instant per request, which is
            # what keeps tracing O(batches) on the per-request hot path.
            self.tracer.end("batch", ob.bid, f"batch:{key[0]}/d{key[1]}",
                            now, track="batcher",
                            args={"reason": reason,
                                  "rows": len(ob.requests),
                                  "rids": [t for r in ob.requests
                                           if (t := getattr(r, "trace_id",
                                                            None))
                                           is not None]})
        operand = stack_rows(ob.requests, ob.d_bucket,
                             n_rows=self.n_c if self.pad_rows else None)
        batch = StackedBatch(workload=ob.workload, d_bucket=ob.d_bucket,
                             requests=ob.requests, operand=operand)
        return ClosedBatch(batch=batch, reason=reason,
                           age_s=max(0.0, now - ob.opened_at),
                           batch_id=ob.bid)
