"""The online serving event loop.

``CryptoServer`` turns the offline measurement pipeline into a server:

    submit(request) ──▶ admission ──▶ continuous batcher ──▶ co-scheduled
                                                             dispatch
         ▲                                                       │
         └──────────────── ResponseHandle.result() ◀─────────────┘

Time is explicit: every entry point takes ``now`` (seconds).  Tests and the
load generator drive a virtual clock from trace timestamps (deterministic,
faster than real time); live callers pass ``time.monotonic()``.  Dispatch
itself is measured in wall time regardless, so service-time telemetry is
real even under a virtual clock.

Per-tenant results are bit-for-bit identical to the offline
``serve_crypto`` replay on the same trace: row semantics make each tenant's
output independent of batch composition, and the batcher reuses the Tier-1
bucketing, so only the grouping differs.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp

from repro.core import validator as V
from repro.core.scheduler.coscheduler import (SliceCoScheduler,
                                              default_row_ladder)
from repro.core.scheduler.rectangular import packing_metrics
from repro.serve.admission import AdmissionController, AdmissionDecision
from repro.serve.batcher import ClosedBatch, ContinuousBatcher
from repro.serve.telemetry import BatchRecord, DispatchRecord, Telemetry

PENDING, DONE, REJECTED = "pending", "done", "rejected"


class RejectedError(RuntimeError):
    def __init__(self, decision: AdmissionDecision):
        super().__init__(f"request rejected: {decision.reason} "
                         f"(retry after {decision.retry_after_s:.4f}s)")
        self.decision = decision


class ResponseHandle:
    """Future-style handle returned by ``CryptoServer.submit``."""

    def __init__(self, request, submitted_at: float):
        self.request = request
        self.submitted_at = submitted_at
        self.completed_at: float | None = None
        self.state = PENDING
        self._value = None
        self._decision: AdmissionDecision | None = None

    def done(self) -> bool:
        return self.state != PENDING

    @property
    def rejected(self) -> bool:
        return self.state == REJECTED

    @property
    def decision(self) -> AdmissionDecision | None:
        return self._decision

    def result(self):
        if self.state == REJECTED:
            raise RejectedError(self._decision)
        if self.state == PENDING:
            raise RuntimeError("result() before dispatch — call "
                               "server.pump(now)/drain() first")
        return self._value

    @property
    def latency_s(self) -> float | None:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    def _resolve(self, value, completed_at: float):
        self._value = value
        self.completed_at = completed_at
        self.state = DONE

    def _reject(self, decision: AdmissionDecision, at: float):
        self._decision = decision
        self.completed_at = at
        self.state = REJECTED


@dataclasses.dataclass
class ServeConfig:
    # batching
    n_c: int = 8
    bucket_granularity: int | None = None   # None → power-of-two buckets
    max_age_s: float = 0.01
    occupancy_close: float | None = None
    pad_rows: bool = True
    # admission
    max_pending: int = 1024
    tenant_rate_hz: float | None = None
    tenant_burst: float = 8.0
    slo_deadline_s: float | None = None
    # dispatch
    accum: str = "fp32_mantissa"
    validate: bool = True
    n_c_max: int = 128          # M-dimension occupancy denominator (paper)
    # reduction discipline (paper §7.2.1): default mode plus per-workload-
    # class overrides, e.g. {"dilithium": "lazy"} co-schedules κ-amortised
    # Dilithium batches next to strictly-eager BN254 batches.  ``kappa``
    # bounds the deferral window (None → whole transform, checked against
    # κ_max at trace time); ``d_tile`` overrides the staging-pass width so
    # the paper's pass structure survives the roomier int32 accumulator.
    reduction: str = "eager"
    reduction_by_workload: dict | None = None
    kappa: int | None = None
    d_tile: int | None = None
    # warm start: (workload, d_bucket) pairs to trace + compile at boot so the
    # first dispatch of each listed program triggers zero new XLA traces
    # (shapes are N_c-row operands; requires pad_rows — or a row ladder,
    # whose rungs are all precompiled instead).  None skips warm start.
    warm_start: list | None = None
    # dispatch fast path (all bit-for-bit neutral):
    #   merge_dispatch — super-batch same-(workload, bucket) closed batches
    #     along M into one tall launch;
    #   row_ladder_max — pad launch heights up a geometric rung ladder
    #     (8→16→…→row_ladder_max) so trace counts are bounded by the ladder
    #     size; the batcher then emits live-row (mergeable) operands and the
    #     co-scheduler pads once, on the merged operand.  None disables;
    #   donate — donate operand buffers to the e2e programs (donate_argnums);
    #   async_pipeline — zero-sync two-phase dispatch: launch now, gather at
    #     the *next* serving event (pump/submit/drain), so the pump loop
    #     never blocks on a device→host copy between launches.  Queued
    #     batches that close while a launch is in flight merge into the next
    #     one.  Latency telemetry then dates completions at the gathering
    #     event's clock.
    merge_dispatch: bool = True
    row_ladder_max: int | None = None
    donate: bool = False
    async_pipeline: bool = False


def coscheduler_from_config(cfg: ServeConfig,
                            host: int | None = None) -> SliceCoScheduler:
    """The default Tier-2 co-scheduler for a serving config (shared by the
    single-host server and the per-host construction in repro.cluster)."""
    ladder = (default_row_ladder(cfg.row_ladder_max)
              if cfg.row_ladder_max else None)
    return SliceCoScheduler(
        accum=cfg.accum, reduction=cfg.reduction,
        reduction_by_workload=cfg.reduction_by_workload,
        kappa=cfg.kappa, d_tile=cfg.d_tile, merge=cfg.merge_dispatch,
        row_ladder=ladder, donate=cfg.donate, host=host)


class CryptoServer:
    def __init__(self, config: ServeConfig | None = None, *,
                 coscheduler: SliceCoScheduler | None = None,
                 telemetry: Telemetry | None = None):
        self.config = cfg = config or ServeConfig()
        self.cos = coscheduler or coscheduler_from_config(cfg)
        # With a row ladder the batcher emits mergeable (live-row) operands
        # and the co-scheduler pads once, on the merged operand — padding to
        # N_c here as well would interleave dead rows into super-batches.
        self.batcher = ContinuousBatcher(
            n_c=cfg.n_c, bucket_granularity=cfg.bucket_granularity,
            max_age_s=cfg.max_age_s, occupancy_close=cfg.occupancy_close,
            pad_rows=cfg.pad_rows and self.cos.row_ladder is None)
        self.admission = AdmissionController(
            max_pending=cfg.max_pending, tenant_rate_hz=cfg.tenant_rate_hz,
            tenant_burst=cfg.tenant_burst, slo_deadline_s=cfg.slo_deadline_s)
        self.telemetry = telemetry or Telemetry()
        # Zero-sync pipeline state: batches validated + staged but not yet
        # launched, and the single in-flight launch group awaiting gather.
        self._staged: list[ClosedBatch] = []
        # (closed, InflightDispatch, launch log, launch_s)
        self._flight: tuple | None = None
        # Pending handles keyed by request identity: O(1) resolve, pruned on
        # completion (a long-lived server must not accumulate history), and
        # correct when one tenant has several rows in flight.
        self._handles: dict[int, ResponseHandle] = {}
        self._validated: set[tuple] = set()
        self._draining = False
        # Cluster hook: when set (by repro.cluster), called as fn(now) and
        # must return the per-host-equivalent cluster queue depth (or None
        # when no sufficiently fresh gossip digest exists).  The SLO gate
        # then operates on bounded-staleness *cluster* state.
        self.cluster_depth_fn = None
        self.warm_traces = 0
        if cfg.warm_start:
            if not cfg.pad_rows and self.cos.row_ladder is None:
                raise ValueError(
                    "warm_start requires pad_rows (or a row ladder): "
                    "unpadded batches stack row-count-dependent operand "
                    "shapes, so pre-compiled N_c-row programs would never "
                    "be reused")
            self.warm_traces = self.cos.precompile(cfg.warm_start, cfg.n_c)

    # --- ingress --------------------------------------------------------------

    def submit(self, req, now: float | None = None) -> ResponseHandle:
        now = time.monotonic() if now is None else now
        handle = ResponseHandle(req, submitted_at=now)
        if self._draining:
            decision = AdmissionDecision(False, "draining")
        elif id(req) in self._handles:
            decision = AdmissionDecision(False, "duplicate")
        else:
            # Only consult gossip when the SLO gate can act on it — the view
            # merge is O(n_hosts) per submission, and reading digests no
            # decision consumes would pollute the gossip staleness audit.
            cluster_pending = (
                self.cluster_depth_fn(now)
                if (self.cluster_depth_fn is not None
                    and self.admission.slo_deadline_s is not None) else None)
            decision = self.admission.admit(req, now,
                                            pending=self.batcher.depth,
                                            cluster_pending=cluster_pending)
        self.telemetry.record_admission(decision.reason)
        if not decision.admitted:
            handle._reject(decision, at=now)
            return handle
        self._handles[id(req)] = handle
        self._dispatch(self.batcher.add(req, now), now)
        return handle

    @property
    def under_backpressure(self) -> bool:
        """Soft signal for clients to slow down before rejections start."""
        return self.admission.backpressure(self.batcher.depth)

    # --- clock-driven flushing ------------------------------------------------

    def pump(self, now: float | None = None) -> int:
        """Close and dispatch every age-expired batch; returns batches flushed.
        Under the async pipeline this is also the gathering edge: any launch
        left in flight by a previous event is materialised here."""
        now = time.monotonic() if now is None else now
        closed = self.batcher.poll(now)
        self._dispatch(closed, now)
        return len(closed)

    def next_deadline(self) -> float | None:
        """When pump() next has work — live loops sleep until this instant."""
        return self.batcher.next_deadline()

    def quiesce(self, now: float | None = None):
        """Drain phase 1: stop admitting, keep in-flight rows queued.

        The cluster drain barrier quiesces *every* host before flushing *any*
        host, so no request can be admitted onto an already-drained peer
        mid-barrier — the two-phase split is what makes a cluster drain
        bit-for-bit equivalent to a single-host replay of the same trace."""
        del now  # admission stop is instantaneous; kept for clock symmetry
        self._draining = True

    def drain(self, now: float | None = None) -> int:
        """Graceful shutdown: stop admitting, flush everything in flight.

        Single-host callers use this directly (quiesce + flush in one step);
        the cluster barrier calls ``quiesce`` on all hosts first, then this."""
        now = time.monotonic() if now is None else now
        self.quiesce(now)
        closed = self.batcher.flush(now)
        self._dispatch(closed, now, final=True)
        return len(closed)

    # --- dispatch -------------------------------------------------------------

    def _validate_once(self, batch):
        """Structurally validate the program in its dispatched form: twiddle
        planes as device-resident arguments, operand donation when
        configured, and — with merging on — the *maximal* super-batch height
        (the merge cap), so V1–V7 are asserted on the tall merged module the
        fast path actually runs, not a constant-baked per-batch stand-in.
        One representative height per (workload, d_bucket) is validated; the
        structural invariants are M-independent."""
        key = (batch.workload, batch.d_bucket)
        if key in self._validated:
            return
        eng = self.cos.engine_for(batch.workload, batch.d_bucket)
        rows = (batch.operand.shape[0] if batch.operand is not None
                else batch.n_c)
        if self.cos.merge:
            rows = max(rows, self.cos.merge_rows_max)
        shape = self.cos.operand_shape(batch.workload, batch.d_bucket, rows)
        args = (jnp.zeros(shape, jnp.uint32), eng.device_planes())
        donate = (0,) if self.cos.donate else ()

        def _e2e(operand, planes):
            return eng.e2e(operand, planes=planes)

        if self.cos.reduction_for(batch.workload) == "eager":
            rep = V.validate_fn(_e2e, *args, expected_passes=eng.n_passes,
                                donate_argnums=donate)
        else:
            # κ-amortised program: per-pass V1/V2 don't apply; instead assert
            # exactly one deferred fold per window survived XLA (V6/V7).
            rep = V.validate_fn(_e2e, *args, expect_eager=False,
                                expected_windows=eng.fold_profile["n_folds"],
                                n_diag=eng.n_diag, donate_argnums=donate)
        rep.raise_if_failed()
        self._validated.add(key)

    def _dispatch(self, closed: list[ClosedBatch], now: float,
                  final: bool = False):
        """Stage newly closed batches and advance the dispatch pipeline.

        Synchronous mode launches + gathers in place (one blocking edge per
        serving event, as before).  Async mode launches now and defers the
        gather to the next serving event, so the caller returns while the
        device computes and the D2H copy streams; batches closed while a
        launch is in flight merge into the next one (M-axis super-batching
        fed by the pipeline itself).  ``final`` forces a full flush (drain).
        """
        if self.config.validate:
            for cb in closed:
                self._validate_once(cb.batch)
        self._staged.extend(closed)
        if not self.config.async_pipeline:
            if self._staged:
                staged, self._staged = self._staged, []
                self._finish(staged, *self._launch(staged), now)
            return
        prev, self._flight = self._flight, None
        if self._staged:
            staged, self._staged = self._staged, []
            self._flight = (staged, *self._launch(staged))
        if prev is not None:
            # Gather *after* the new launch is enqueued: the device starts
            # the next group while the host materialises the previous one.
            self._finish(*prev, now)
        if final and self._flight is not None:
            flight, self._flight = self._flight, None
            self._finish(*flight, now)

    def _launch(self, staged: list[ClosedBatch]):
        t0 = time.perf_counter()
        flight = self.cos.launch_mixed([cb.batch for cb in staged])
        launch_s = time.perf_counter() - t0
        # Claim the launch records now — a peer host sharing this
        # co-scheduler may launch before we gather.
        return flight, self.cos.drain_dispatch_log(), launch_s

    def _finish(self, closed: list[ClosedBatch], flight, log: list,
                launch_s: float, now: float):
        # Service time = launch enqueue + blocking gather.  The async idle
        # gap between the two events is deliberately excluded: feeding it to
        # the admission EWMA would inflate the per-row service estimate by
        # the event spacing and make the SLO gate reject load the slice can
        # trivially carry.
        t1 = time.perf_counter()
        results = self.cos.gather(flight)
        service_s = launch_s + time.perf_counter() - t1
        # Attribute wall time to batches by live-row share (one synchronised
        # launch group; per-batch device timing is not observable from here).
        total_rows = sum(cb.batch.n_c for cb in closed) or 1
        self.admission.observe_service(total_rows, service_s)
        for entry in log:
            live, launched = entry["live_rows"], entry["launched_rows"]
            self.telemetry.record_dispatch(DispatchRecord(
                workload=entry["workload"], d_bucket=entry["d_bucket"],
                n_batches=entry["n_batches"], live_rows=live,
                launched_rows=launched,
                m_occupancy=min(1.0, live / self.config.n_c_max),
                m_fill=live / launched if launched else 0.0,
                donated=entry["donated"]))
        for cb, res in zip(closed, results):
            batch = cb.batch
            share = service_s * batch.n_c / total_rows
            eng = self.cos.engine_for(batch.workload, batch.d_bucket)
            d_max = (eng.plan.d_max if hasattr(eng, "plan")
                     else eng.plans[0].d_max)
            m = packing_metrics(batch.degrees, batch.d_bucket, d_max,
                                n_c_max=self.config.n_c_max)
            self.telemetry.record_batch(BatchRecord(
                workload=batch.workload, d_bucket=batch.d_bucket,
                n_c=batch.n_c, close_reason=cb.reason,
                m_occupancy=m.m_occupancy, k_occupancy=m.k_occupancy,
                queue_depth=self.batcher.depth, service_s=share,
                age_s=cb.age_s,
                reduction=eng.fold_profile["reduction"],
                n_folds=eng.fold_profile["n_folds"]))
            completed = now + share
            for i, r in enumerate(batch.requests):
                handle = self._handles.pop(id(r), None)
                if handle is None:       # direct batcher use, no submit()
                    continue
                # route by row position — a tenant may own several rows
                handle._resolve(res.rows[i], completed)
                self.telemetry.observe_latency(
                    handle.latency_s, queue_wait_s=now - handle.submitted_at)
