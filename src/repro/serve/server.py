"""The online serving event loop.

``CryptoServer`` turns the offline measurement pipeline into a server:

    submit(request) ──▶ admission ──▶ continuous batcher ──▶ co-scheduled
                                                             dispatch
         ▲                                                       │
         └──────────────── ResponseHandle.result() ◀─────────────┘

Time is explicit: every entry point takes ``now`` (seconds).  Tests and the
load generator drive a virtual clock from trace timestamps (deterministic,
faster than real time); live callers pass ``time.monotonic()``.  Dispatch
itself is measured in wall time regardless, so service-time telemetry is
real even under a virtual clock.

Per-tenant results are bit-for-bit identical to the offline
``serve_crypto`` replay on the same trace: row semantics make each tenant's
output independent of batch composition, and the batcher reuses the Tier-1
bucketing, so only the grouping differs.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import validator as V
from repro.core.scheduler.coscheduler import (SliceCoScheduler,
                                              default_row_ladder)
from repro.core.scheduler.rectangular import packing_metrics
from repro.obs.alerts import AlertEngine, default_serve_rules
from repro.obs.ledger import PenaltyLedger, launch_cycles
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import Tracer
from repro.serve.admission import AdmissionController, AdmissionDecision
from repro.serve.batcher import CLOSE_DRAIN, ClosedBatch, ContinuousBatcher
from repro.serve.controller import AdaptiveController
from repro.serve.telemetry import BatchRecord, DispatchRecord, Telemetry

PENDING, DONE, REJECTED = "pending", "done", "rejected"


class RejectedError(RuntimeError):
    def __init__(self, decision: AdmissionDecision):
        super().__init__(f"request rejected: {decision.reason} "
                         f"(retry after {decision.retry_after_s:.4f}s)")
        self.decision = decision


class ResponseHandle:
    """Future-style handle returned by ``CryptoServer.submit``."""

    def __init__(self, request, submitted_at: float):
        self.request = request
        self.submitted_at = submitted_at
        self.completed_at: float | None = None
        self.state = PENDING
        self._value = None
        self._decision: AdmissionDecision | None = None

    def done(self) -> bool:
        return self.state != PENDING

    @property
    def rejected(self) -> bool:
        return self.state == REJECTED

    @property
    def decision(self) -> AdmissionDecision | None:
        return self._decision

    def result(self):
        if self.state == REJECTED:
            raise RejectedError(self._decision)
        if self.state == PENDING:
            raise RuntimeError("result() before dispatch — call "
                               "server.pump(now)/drain() first")
        return self._value

    @property
    def latency_s(self) -> float | None:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    def _resolve(self, value, completed_at: float):
        self._value = value
        self.completed_at = completed_at
        self.state = DONE

    def _reject(self, decision: AdmissionDecision, at: float):
        self._decision = decision
        self.completed_at = at
        self.state = REJECTED


@dataclasses.dataclass
class ServeConfig:
    # batching
    n_c: int = 8
    bucket_granularity: int | None = None   # None → power-of-two buckets
    max_age_s: float = 0.01
    occupancy_close: float | None = None
    pad_rows: bool = True
    # admission
    max_pending: int = 1024
    tenant_rate_hz: float | None = None
    tenant_burst: float = 8.0
    slo_deadline_s: float | None = None
    # columnar_admission — tenant bucket state as one numpy structured array
    # behind a dense-index interner, enabling the vectorised submit_many
    # batch edge.  Decisions are bit-identical to the scalar per-tenant
    # TokenBucket dict (False), which stays as the property-tested oracle.
    columnar_admission: bool = True
    # dispatch
    accum: str = "fp32_mantissa"
    validate: bool = True
    n_c_max: int = 128          # M-dimension occupancy denominator (paper)
    # reduction discipline (paper §7.2.1): default mode plus per-workload-
    # class overrides, e.g. {"dilithium": "lazy"} co-schedules κ-amortised
    # Dilithium batches next to strictly-eager BN254 batches.  ``kappa``
    # bounds the deferral window (None → whole transform, checked against
    # κ_max at trace time); ``d_tile`` overrides the staging-pass width so
    # the paper's pass structure survives the roomier int32 accumulator.
    reduction: str = "eager"
    reduction_by_workload: dict | None = None
    kappa: int | None = None
    d_tile: int | None = None
    # warm start: (workload, d_bucket) pairs to trace + compile at boot so the
    # first dispatch of each listed program triggers zero new XLA traces
    # (shapes are N_c-row operands; requires pad_rows — or a row ladder,
    # whose rungs are all precompiled instead).  None skips warm start.
    warm_start: list | None = None
    # dispatch fast path (all bit-for-bit neutral):
    #   merge_dispatch — super-batch same-(workload, bucket) closed batches
    #     along M into one tall launch;
    #   row_ladder_max — pad launch heights up a geometric rung ladder
    #     (8→16→…→row_ladder_max) so trace counts are bounded by the ladder
    #     size; the batcher then emits live-row (mergeable) operands and the
    #     co-scheduler pads once, on the merged operand.  None disables;
    #   donate — donate operand buffers to the e2e programs (donate_argnums);
    #   async_pipeline — zero-sync two-phase dispatch: launch now, gather at
    #     the *next* serving event (pump/submit/drain), so the pump loop
    #     never blocks on a device→host copy between launches.  Queued
    #     batches that close while a launch is in flight merge into the next
    #     one.  Latency telemetry then dates completions at the gathering
    #     event's clock.
    merge_dispatch: bool = True
    row_ladder_max: int | None = None
    donate: bool = False
    async_pipeline: bool = False
    # closed-loop control plane (all bit-for-bit neutral — only grouping and
    # timing change, never row arithmetic):
    #   controller — adapt the per-class close policy (target ladder rung,
    #     max_age, occupancy threshold) from the dispatch telemetry EWMA
    #     instead of the static values above, which become the loop's
    #     initial values and floor/ceiling bounds;
    #   holdback_lambda — cross-event merge holdback: a short closed batch
    #     may wait up to λ × (predicted merge-partner ETA) for a same-class
    #     partner, capped by the SLO budget so the admission-visible p99 is
    #     never breached (0 disables; requires the controller's queue model
    #     and merge_dispatch);
    #   inflight_depth — depth-k multi-flight launch ring: up to k launch
    #     groups per workload class stay in flight before a gather blocks,
    #     so disjoint program classes keep the device saturated under
    #     bursty closes (1 reproduces the PR-4 single-flight pipeline
    #     exactly; >1 requires async_pipeline).
    controller: bool = False
    controller_alpha: float = 0.3
    controller_gain: float = 0.25
    m_fill_target: float = 0.5
    max_age_floor_s: float | None = None   # None → max_age_s / 4
    max_age_ceil_s: float | None = None    # None → max_age_s × 8 (SLO-capped)
    occupancy_floor: float | None = None   # None → occupancy_close / 2
    occupancy_ceil: float = 0.95
    holdback_lambda: float = 0.0
    holdback_slo_fraction: float = 0.5
    inflight_depth: int = 1
    # observability (repro.obs): request-lifecycle tracing into a bounded
    # ring buffer (submit/enqueue/launch/complete spans with causal IDs,
    # exportable as Chrome-trace JSON via server.trace_events()).  Off by
    # default — the per-event cost is one dict append, but the buffer is
    # only useful to callers that export it.  The penalty ledger is always
    # on: it prices launches from telemetry the server already computes.
    tracing: bool = False
    trace_capacity: int = 1 << 16
    # Continuous metrics + alerting (repro.obs.metrics / repro.obs.alerts):
    # a collector-driven registry scraped on a fixed serving-clock cadence
    # from telemetry / controller / penalty ledger, with an AlertEngine
    # evaluating multi-window burn-rate and threshold rules after every
    # scrape.  ``alert_rules`` overrides the stock rule set (None → the
    # default_serve_rules scaled off max_age_s / slo_deadline_s).
    metrics: bool = False
    metrics_period_s: float = 0.005
    metrics_capacity: int = 4096
    alert_rules: tuple | None = None
    # Replace the wall-clock service-time measurement with the penalty
    # ledger's modeled device time ((mxu+vpu)/DEVICE_HZ per launch).  Every
    # downstream wall-derived quantity — admission service-rate EWMA,
    # request latencies, penalty host_gap, scraped series, alert logs —
    # then depends only on the virtual clock and the trace, so two
    # identical runs are bit-identical end to end.  Off by default: real
    # deployments want measured time.
    deterministic_timing: bool = False
    # bound the latency/queue-wait reservoirs: past this many samples each
    # histogram collapses to a log-bucket sketch (bounded memory, ≤ ~4.5%
    # relative quantile error; count/mean/max stay exact).  None = exact
    # reservoir forever (the default — serving runs here are bounded).
    latency_sketch_bound: int | None = None
    # persistent compile cache: point the JAX compilation cache at this
    # directory so compiled programs survive process restarts — a cold boot
    # then gets the same zero-trace first dispatch an in-process warm start
    # does (pair with ``warm_start`` to populate it at first boot).
    compilation_cache_dir: str | None = None


def enable_compilation_cache(cache_dir: str) -> str:
    """Point JAX's persistent compilation cache at ``cache_dir`` (created if
    missing) and lower the persistence thresholds so even fast CPU compiles
    are cached — cold boots should warm from disk, not re-trace.  Safe to
    call repeatedly; unknown tuning knobs on older jaxlibs are skipped."""
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    for opt, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                     ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(opt, val)
        except (AttributeError, ValueError):
            pass
    return cache_dir


def coscheduler_from_config(cfg: ServeConfig, host: int | None = None,
                            devices=None) -> SliceCoScheduler:
    """The default Tier-2 co-scheduler for a serving config (shared by the
    single-host server and the per-host construction in repro.cluster).
    ``devices`` pins the slice to an explicit device subset (device-parallel
    cluster mode); None keeps the whole-process default."""
    ladder = (default_row_ladder(cfg.row_ladder_max)
              if cfg.row_ladder_max else None)
    return SliceCoScheduler(
        accum=cfg.accum, reduction=cfg.reduction,
        reduction_by_workload=cfg.reduction_by_workload,
        kappa=cfg.kappa, d_tile=cfg.d_tile, merge=cfg.merge_dispatch,
        row_ladder=ladder, donate=cfg.donate, host=host, devices=devices)


class CryptoServer:
    def __init__(self, config: ServeConfig | None = None, *,
                 coscheduler: SliceCoScheduler | None = None,
                 telemetry: Telemetry | None = None):
        self.config = cfg = config or ServeConfig()
        if cfg.inflight_depth < 1:
            raise ValueError(f"inflight_depth must be ≥ 1, got "
                             f"{cfg.inflight_depth}")
        if cfg.inflight_depth > 1 and not cfg.async_pipeline:
            raise ValueError(
                "inflight_depth > 1 needs async_pipeline: the launch ring "
                "only exists between serving events — a synchronous "
                "dispatch gathers every launch before returning")
        if cfg.holdback_lambda < 0:
            raise ValueError(f"holdback_lambda must be ≥ 0, got "
                             f"{cfg.holdback_lambda}")
        if cfg.holdback_lambda > 0 and not cfg.controller:
            raise ValueError(
                "holdback_lambda > 0 needs controller=True: the holdback "
                "window is priced from the controller's per-class queue "
                "model (arrival-rate EWMA + target rung)")
        if cfg.holdback_lambda > 0 and not cfg.merge_dispatch:
            raise ValueError(
                "holdback_lambda > 0 needs merge_dispatch: holding a batch "
                "for a merge partner is pointless if same-class batches "
                "never coalesce along M")
        # Persistent compile cache must be live before anything traces —
        # the whole point is that precompile/warm_start below hit disk.
        if cfg.compilation_cache_dir:
            enable_compilation_cache(cfg.compilation_cache_dir)
        self.cos = coscheduler or coscheduler_from_config(cfg)
        self.controller = None
        if cfg.controller:
            self.controller = AdaptiveController(
                ladder=self.cos.row_ladder or (cfg.n_c,),
                n_c=cfg.n_c, max_age_s=cfg.max_age_s,
                occupancy_close=cfg.occupancy_close, n_c_max=cfg.n_c_max,
                alpha=cfg.controller_alpha, gain=cfg.controller_gain,
                m_fill_target=cfg.m_fill_target,
                max_age_floor_s=cfg.max_age_floor_s,
                max_age_ceil_s=cfg.max_age_ceil_s,
                occupancy_floor=cfg.occupancy_floor,
                occupancy_ceil=cfg.occupancy_ceil,
                holdback_lambda=cfg.holdback_lambda,
                holdback_slo_fraction=cfg.holdback_slo_fraction,
                slo_deadline_s=cfg.slo_deadline_s)
        # Observability: one host-tagged tracer shared by the server, the
        # batcher, and the co-scheduler (so launch spans and lifecycle spans
        # land on one timeline with one causal-ID sequence).
        self.tracer = None
        if cfg.tracing:
            self.tracer = Tracer(capacity=cfg.trace_capacity,
                                 host=self.cos.host)
        # Always (re)assign, so a shared co-scheduler handed from a traced
        # run to an untraced one doesn't keep feeding the stale tracer.
        self.cos.tracer = self.tracer
        # With a row ladder the batcher emits mergeable (live-row) operands
        # and the co-scheduler pads once, on the merged operand — padding to
        # N_c here as well would interleave dead rows into super-batches.
        self.batcher = self._make_batcher()
        self.admission = AdmissionController(
            max_pending=cfg.max_pending, tenant_rate_hz=cfg.tenant_rate_hz,
            tenant_burst=cfg.tenant_burst, slo_deadline_s=cfg.slo_deadline_s,
            columnar=cfg.columnar_admission)
        self.telemetry = telemetry or Telemetry(
            sketch_bound=cfg.latency_sketch_bound)
        if self.controller is not None:
            self.telemetry.attach_section("controller",
                                          self.controller.snapshot)
        # The live penalty ledger (paper §7 decomposition as a snapshot
        # section): every launch's modeled cycles split into MXU-productive /
        # arithmetic-stall / spatial-pad / host-gap bins.
        self.ledger = PenaltyLedger(m_tile=cfg.n_c_max)
        self.telemetry.attach_section("penalty", self.ledger.snapshot)
        if self.tracer is not None:
            self.telemetry.attach_section("trace", self.tracer.snapshot)
        # Continuous metrics + alerting: collector-driven scrape at the
        # serving-clock cadence; the alert engine evaluates right after
        # every scrape so alert timestamps are scrape timestamps.
        self.metrics = None
        self.alerts = None
        if cfg.metrics:
            self.metrics = MetricsRegistry(period_s=cfg.metrics_period_s,
                                           capacity=cfg.metrics_capacity,
                                           host=self.cos.host)
            self._describe_metrics()
            self.metrics.add_collector(self._metrics_samples)
            rules = (cfg.alert_rules if cfg.alert_rules is not None
                     else default_serve_rules(
                         max_age_s=cfg.max_age_s,
                         slo_deadline_s=cfg.slo_deadline_s))
            self.alerts = AlertEngine(self.metrics, rules,
                                      tracer=self.tracer, host=self.cos.host)
            self.telemetry.attach_section("metrics", self.metrics.snapshot)
            self.telemetry.attach_section("alerts", self.alerts.snapshot)
        # Zero-sync pipeline state: batches validated + staged but not yet
        # launched, per-class launch rings of in-flight groups awaiting
        # gather (inflight_depth == 1 keeps the whole event's staged set in
        # one flight under the single ``None`` key — the PR-4 single-flight
        # pipeline exactly), and the merge-holdback pen of closed batches
        # priced to wait for a partner.
        self._staged: list[ClosedBatch] = []
        # ring key -> deque of (launch seq, closed, InflightDispatch,
        # launch log, launch_s)
        self._rings: dict = collections.OrderedDict()
        self._launch_seq = 0
        # class key -> (ClosedBatch, release_at, held_at, hid)
        self._held: dict[tuple, tuple] = {}
        # Pending handles keyed by request identity: O(1) resolve, pruned on
        # completion (a long-lived server must not accumulate history), and
        # correct when one tenant has several rows in flight.
        self._handles: dict[int, ResponseHandle] = {}
        # Fleet-assigned request ids ever admitted here — the exactly-once
        # dedup filter for failover replay: a journal entry delivered twice
        # (or re-delivered to a rebooted host) is rejected as a duplicate.
        # Deliberately durable across reset_after_failure, like the journal.
        self._seen_rids: set = set()
        self._ledger_profiles: dict[tuple, dict] = {}
        self._req_span_names: dict[str, str] = {}
        self._validated: set[tuple] = set()
        self._draining = False
        # Cluster hook: when set (by repro.cluster), called as fn(now) and
        # must return the per-host-equivalent cluster queue depth (or None
        # when no sufficiently fresh gossip digest exists).  The SLO gate
        # then operates on bounded-staleness *cluster* state.
        self.cluster_depth_fn = None
        # Cluster hooks: the owning host slice's id and the fleet-shared
        # DispatchOverlapAuditor (both set by repro.cluster; None when this
        # server runs standalone — the hot path then pays one ``is None``).
        self.host_id = self.cos.host
        self.dispatch_auditor = None
        self.warm_traces = 0
        if cfg.warm_start:
            if not cfg.pad_rows and self.cos.row_ladder is None:
                raise ValueError(
                    "warm_start requires pad_rows (or a row ladder): "
                    "unpadded batches stack row-count-dependent operand "
                    "shapes, so pre-compiled N_c-row programs would never "
                    "be reused")
            self.warm_traces = self.cos.precompile(cfg.warm_start, cfg.n_c)

    def _make_batcher(self) -> ContinuousBatcher:
        """Construct the continuous batcher from the config — used at boot
        and by ``reset_after_failure`` (a rebooted host gets a fresh one)."""
        cfg = self.config
        return ContinuousBatcher(
            n_c=cfg.n_c, bucket_granularity=cfg.bucket_granularity,
            max_age_s=cfg.max_age_s, occupancy_close=cfg.occupancy_close,
            pad_rows=cfg.pad_rows and self.cos.row_ladder is None,
            controller=self.controller, tracer=self.tracer)

    # --- ingress --------------------------------------------------------------

    def submit(self, req, now: float | None = None, *,
               handle: ResponseHandle | None = None) -> ResponseHandle:
        now = time.monotonic() if now is None else now
        # ``handle`` lets the cluster's failover path re-deliver a request
        # that already has a caller-held handle (limbo retry) — the decision
        # resolves/rejects that handle instead of allocating a second one.
        if handle is None:
            handle = ResponseHandle(req, submitted_at=now)
        rid = getattr(req, "request_id", None)
        if self._draining:
            decision = AdmissionDecision(False, "draining")
        elif id(req) in self._handles or (rid is not None
                                          and rid in self._seen_rids):
            decision = AdmissionDecision(False, "duplicate")
        else:
            # Only consult gossip when the SLO gate can act on it — the view
            # merge is O(n_hosts) per submission, and reading digests no
            # decision consumes would pollute the gossip staleness audit.
            cluster_pending = (
                self.cluster_depth_fn(now)
                if (self.cluster_depth_fn is not None
                    and self.admission.slo_deadline_s is not None) else None)
            decision = self.admission.admit(req, now,
                                            pending=self.pending_load,
                                            cluster_pending=cluster_pending)
        self.telemetry.record_admission(decision.reason)
        tr = self.tracer
        if not decision.admitted:
            if tr is not None:
                tr.instant("reject", now,
                           args={"workload": req.workload,
                                 "reason": decision.reason})
            handle._reject(decision, at=now)
            return handle
        if tr is not None:
            # The request span opens at submit and closes at completion; the
            # causal ID rides on the request object so the batcher can link
            # it to the batch it lands in.
            tid = tr.next_id()
            req.trace_id = tid
            # Name carries the workload, the batch span carries the d
            # bucket, the span length is the latency — no per-request args
            # dict or f-string (this is the hottest emitter in the stack).
            name = self._req_span_names.get(req.workload)
            if name is None:
                name = self._req_span_names.setdefault(
                    req.workload, "req:" + req.workload)
            tr.begin("request", tid, name, now)
        if rid is not None:
            self._seen_rids.add(rid)
        self._handles[id(req)] = handle
        self._dispatch(self.batcher.add(req, now), now)
        return handle

    def submit_many(self, reqs, now: float | None = None,
                    nows=None) -> list[ResponseHandle]:
        """Batch ingress: admit one arrival batch through the vectorised
        admission path, then stack every admitted row and advance the
        dispatch pipeline once for the whole batch.

        ``nows`` gives per-request clocks (arrival order, e.g. trace
        timestamps); ``now`` (or the wall clock) stamps the whole batch when
        absent.  Decisions equal the scalar per-request ``submit`` loop at
        the same batch edge bit for bit, with two deliberate batch-edge
        semantics: the gossiped cluster depth is sampled once per batch, and
        a request object repeated *within* one batch is rejected as a
        duplicate regardless of the first occurrence's decision (across
        batches, resubmitting a rejected request stays allowed, as with
        ``submit``).  Closed batches dispatch together at the batch's last
        clock — age/occupancy grouping may differ from per-request
        submission, but row semantics keep per-tenant results bit-identical
        regardless of grouping."""
        if nows is None:
            t = time.monotonic() if now is None else now
            nows_arr = np.full(len(reqs), float(t))
        else:
            nows_arr = np.asarray(nows, np.float64)
            if len(nows_arr) != len(reqs):
                raise ValueError(f"nows has {len(nows_arr)} entries for "
                                 f"{len(reqs)} requests")
        handles = [ResponseHandle(r, submitted_at=float(t))
                   for r, t in zip(reqs, nows_arr)]
        if not handles:
            return handles
        tr = self.tracer
        if self._draining:
            d = AdmissionDecision(False, "draining")
            for h, t in zip(handles, nows_arr):
                h._reject(d, at=float(t))
            self.telemetry.record_admissions({"draining": len(reqs)})
            return handles
        live_pos, dup_pos, seen, seen_rids = [], [], set(), set()
        for p, r in enumerate(reqs):
            oid = id(r)
            rid = getattr(r, "request_id", None)
            if (oid in self._handles or oid in seen
                    or (rid is not None and (rid in self._seen_rids
                                             or rid in seen_rids))):
                dup_pos.append(p)
            else:
                seen.add(oid)
                if rid is not None:
                    seen_rids.add(rid)
                live_pos.append(p)
        if dup_pos:
            d = AdmissionDecision(False, "duplicate")
            for p in dup_pos:
                handles[p]._reject(d, at=float(nows_arr[p]))
                if tr is not None:
                    tr.instant("reject", float(nows_arr[p]),
                               args={"workload": reqs[p].workload,
                                     "reason": "duplicate"})
        if not live_pos:
            self.telemetry.record_admissions({"duplicate": len(dup_pos)})
            return handles
        cluster_pending = (
            self.cluster_depth_fn(float(nows_arr[live_pos[0]]))
            if (self.cluster_depth_fn is not None
                and self.admission.slo_deadline_s is not None) else None)
        dec = self.admission.admit_batch(
            np.asarray([reqs[p].tenant_id for p in live_pos]),
            nows_arr[live_pos], pending=self.pending_load,
            cluster_pending=cluster_pending)
        counts = dec.counts()
        if dup_pos:
            counts["duplicate"] = len(dup_pos)
        self.telemetry.record_admissions(counts)
        closed: list[ClosedBatch] = []
        admitted = dec.admitted
        for j, p in enumerate(live_pos):
            req, t = reqs[p], float(nows_arr[p])
            if not admitted[j]:
                d = dec.decision(j)
                if tr is not None:
                    tr.instant("reject", t, args={"workload": req.workload,
                                                  "reason": d.reason})
                handles[p]._reject(d, at=t)
                continue
            if tr is not None:
                tid = tr.next_id()
                req.trace_id = tid
                name = self._req_span_names.get(req.workload)
                if name is None:
                    name = self._req_span_names.setdefault(
                        req.workload, "req:" + req.workload)
                tr.begin("request", tid, name, t)
            rid = getattr(req, "request_id", None)
            if rid is not None:
                self._seen_rids.add(rid)
            self._handles[id(req)] = handles[p]
            closed.extend(self.batcher.add(req, t))
        self._dispatch(closed, float(nows_arr[-1]))
        return handles

    @property
    def pending_load(self) -> int:
        """Rows occupying the slice that a new admission must queue behind:
        the batcher's open depth, rows parked in the holdback pen, and rows
        launched but not yet gathered on the async ring.  This is what the
        queue/SLO gates price waits from — ``batcher.depth`` alone is blind
        to held and in-flight rows, so λ-aggressive/async configs would
        admit load the slice cannot carry."""
        load = self.batcher.depth
        if self._held:
            load += sum(cb.batch.n_c for cb, _, _, _ in self._held.values())
        for ring in self._rings.values():
            for _, part, _, _, _ in ring:
                load += sum(cb.batch.n_c for cb in part)
        return load

    @property
    def under_backpressure(self) -> bool:
        """Soft signal for clients to slow down before rejections start."""
        return self.admission.backpressure(self.pending_load)

    # --- clock-driven flushing ------------------------------------------------

    def pump(self, now: float | None = None) -> int:
        """Close and dispatch every age-expired batch; returns batches flushed.
        Under the async pipeline this is also the gathering edge: any launch
        left in flight by a previous event is materialised here."""
        now = time.monotonic() if now is None else now
        closed = self.batcher.poll(now)
        self._dispatch(closed, now)
        return len(closed)

    def next_deadline(self) -> float | None:
        """When pump() next has work — live loops sleep until this instant.
        Holdback release deadlines count: a held batch must be launched at
        its priced window's edge even if no new request ever arrives."""
        deadline = self.batcher.next_deadline()
        for _, release_at, _, _ in self._held.values():
            deadline = (release_at if deadline is None
                        else min(deadline, release_at))
        return deadline

    @property
    def inflight_groups(self) -> int:
        """Launch groups in flight (launched, not yet gathered) across every
        per-class ring — 0 after any drain, by the quiesce contract."""
        return sum(len(ring) for ring in self._rings.values())

    def quiesce(self, now: float | None = None):
        """Drain phase 1: stop admitting, keep in-flight rows queued.

        The cluster drain barrier quiesces *every* host before flushing *any*
        host, so no request can be admitted onto an already-drained peer
        mid-barrier — the two-phase split is what makes a cluster drain
        bit-for-bit equivalent to a single-host replay of the same trace."""
        del now  # admission stop is instantaneous; kept for clock symmetry
        self._draining = True

    def drain(self, now: float | None = None) -> int:
        """Graceful shutdown: stop admitting, flush everything in flight.

        Single-host callers use this directly (quiesce + flush in one step);
        the cluster barrier calls ``quiesce`` on all hosts first, then this."""
        now = time.monotonic() if now is None else now
        self.quiesce(now)
        closed = self.batcher.flush(now)
        self._dispatch(closed, now, final=True)
        return len(closed)

    # --- failover (repro.cluster.failover drives these) -----------------------

    def recover_inflight(self, now: float) -> int:
        """Gather-ring rescue after a host death: force-gather every launch
        group still on the ring, in launch order, resolving their handles.
        The device had already computed these results when the host process
        died — recovering them beats replaying the rows, and the journal
        then sees their entries as settled.  Returns handles resolved."""
        before = len(self._handles)
        while (ring := self._oldest_ring()) is not None:
            self._finish(*ring.popleft()[1:], now)
        return before - len(self._handles)

    def reset_after_failure(self, now: float):
        """Model the reboot of a killed host: every in-memory structure
        (open batches, staged sets, rings, holdback pen, handle table) is
        gone; the rid-dedup filter, telemetry, and admission state survive
        — they live with the journal, not in host RAM, and a crashed host
        must never hand a tenant fresh token budget.  Dangling request
        trace spans are closed with a ``failover`` end and advertised in a
        ``failover_abandoned`` instant so the trace validator knows their
        causal chain continues on the survivor's replay span."""
        tr = self.tracer
        if tr is not None:
            # Close the open-batch spans the dead batcher holds (their rows
            # are the abandoned requests; the discarded ClosedBatch results
            # never dispatch), then the dangling request spans themselves.
            self.batcher.flush(now)
            rids = []
            for handle in self._handles.values():
                tid = getattr(handle.request, "trace_id", None)
                if tid is not None:
                    tr.end("request", tid, "failover", now)
                    rids.append(tid)
            if rids:
                tr.instant("failover_abandoned", now, track="failover",
                           args={"rids": rids})
        self._handles.clear()
        self._staged.clear()
        self._rings.clear()
        self._held.clear()
        if self.dispatch_auditor is not None:
            # The rings' un-gathered flights died with the host: retire them
            # from the fleet overlap audit or its concurrency counters leak
            # permanently-busy devices.
            self.dispatch_auditor.on_reset(self.host_id)
        self.batcher = self._make_batcher()
        self._draining = False

    def replay_admitted(self, entries, now: float) -> tuple[int, int]:
        """Failover replay edge: re-enter requests a dead peer had already
        admitted.  ``entries`` is ``[(request, handle), ...]`` from that
        peer's intake journal.  Admission is bypassed entirely — the
        requests were admitted and charged once, on the failed host
        (tests/test_ingress_columnar.py pins that bucket levels stay
        bit-identical) — and the draining gate is ignored: the drain
        barrier's contract is *complete everything admitted*, which
        includes rows stranded by a mid-barrier kill.  Idempotent: entries
        whose handle already resolved, or whose request id this host has
        seen, are skipped.  Returns ``(replayed, deduped)``."""
        tr = self.tracer
        closed: list[ClosedBatch] = []
        replayed = deduped = 0
        for req, handle in entries:
            rid = getattr(req, "request_id", None)
            if (handle.done() or id(req) in self._handles
                    or (rid is not None and rid in self._seen_rids)):
                deduped += 1
                continue
            if rid is not None:
                self._seen_rids.add(rid)
            self.telemetry.record_admission("replayed")
            if tr is not None:
                tid = tr.next_id()
                req.trace_id = tid
                tr.begin("request", tid, "replay:" + req.workload, now)
            self._handles[id(req)] = handle
            closed.extend(self.batcher.add(req, now))
            replayed += 1
        if replayed:
            self._dispatch(closed, now)
        return replayed, deduped

    # --- dispatch -------------------------------------------------------------

    def _validate_once(self, batch):
        """Structurally validate the program in its dispatched form: twiddle
        planes as device-resident arguments, operand donation when
        configured, and — with merging on — the *maximal* super-batch height
        (the merge cap), so V1–V7 are asserted on the tall merged module the
        fast path actually runs, not a constant-baked per-batch stand-in.
        One representative height per (workload, d_bucket) is validated; the
        structural invariants are M-independent."""
        key = (batch.workload, batch.d_bucket)
        if key in self._validated:
            return
        eng = self.cos.engine_for(batch.workload, batch.d_bucket)
        rows = (batch.operand.shape[0] if batch.operand is not None
                else batch.n_c)
        if self.cos.merge:
            rows = max(rows, self.cos.merge_rows_max)
        shape = self.cos.operand_shape(batch.workload, batch.d_bucket, rows)
        # Operand and planes both go through the co-scheduler's placement
        # funnel: on a pinned slice the validation trace must see committed
        # arrays on *its* device — mixing a default-device operand with
        # pinned planes is an XLA device-mismatch error, not a validation.
        args = (self.cos._shard(batch.workload,
                                jnp.zeros(shape, jnp.uint32)),
                self.cos.device_planes_for(batch.workload, batch.d_bucket))
        donate = (0,) if self.cos.donate else ()

        def _e2e(operand, planes):
            return eng.e2e(operand, planes=planes)

        if self.cos.reduction_for(batch.workload) == "eager":
            rep = V.validate_fn(_e2e, *args, expected_passes=eng.n_passes,
                                donate_argnums=donate)
        else:
            # κ-amortised program: per-pass V1/V2 don't apply; instead assert
            # exactly one deferred fold per window survived XLA (V6/V7).
            rep = V.validate_fn(_e2e, *args, expect_eager=False,
                                expected_windows=eng.fold_profile["n_folds"],
                                n_diag=eng.n_diag, donate_argnums=donate)
        rep.raise_if_failed()
        self._validated.add(key)

    def _class_key(self, cb: ClosedBatch) -> tuple:
        return (cb.batch.workload, cb.batch.d_bucket)

    def _ledger_profile(self, workload: str, d: int) -> dict:
        """Engine fold profile + limb counts — the penalty ledger's static
        per-class pricing inputs (cached: this sits on the dispatch path)."""
        key = (workload, d)
        prof = self._ledger_profiles.get(key)
        if prof is None:
            eng = self.cos.engine_for(workload, d)
            prof = dict(eng.fold_profile)
            prof["data_limbs"] = eng.wclass.data_limbs
            prof["tw_limbs"] = eng.wclass.tw_limbs
            self._ledger_profiles[key] = prof
        return prof

    # --- metrics scrape -------------------------------------------------------

    def _describe_metrics(self):
        """Family metadata for everything `_metrics_samples` can emit."""
        m = self.metrics
        m.describe("repro_admission_decisions_total", "counter",
                   "Admission decisions (all reasons).")
        m.describe("repro_admission_rejected_total", "counter",
                   "Rejected admissions by reason.")
        m.describe("repro_admission_slo_miss_total", "counter",
                   "Rejections by the local or cluster SLO gate.")
        m.describe("repro_requests_served_total", "counter",
                   "Requests resolved through dispatched batches.")
        m.describe("repro_batches_closed_total", "counter",
                   "Closed batches by close reason.")
        m.describe("repro_service_seconds_total", "counter",
                   "Accumulated dispatch service time.", wall=True)
        m.describe("repro_queue_depth", "gauge",
                   "Open batcher rows at the last scrape.")
        m.describe("repro_pending_load", "gauge",
                   "Rows queued, held, or in flight (the admission view).")
        m.describe("repro_inflight_groups", "gauge",
                   "Launch groups on the async ring awaiting gather.")
        m.describe("repro_dispatch_m_occupancy", "gauge",
                   "Mean achieved per-launch M occupancy (live/N_c_max).")
        m.describe("repro_latency_seconds", "gauge",
                   "Request latency quantiles.", wall=True)
        m.describe("repro_queue_wait_seconds", "gauge",
                   "Queue-wait quantiles.", wall=True)
        m.describe("repro_penalty_share", "gauge",
                   "Modeled-cycle share per penalty bin (all workloads).",
                   wall=True)
        m.describe("repro_penalty_arithmetic_stall_share", "gauge",
                   "Arithmetic-stall share of total modeled cycles.",
                   wall=True)
        m.describe("repro_controller_decisions_total", "counter",
                   "Flight-recorder entries (setpoint changes).")
        m.describe("repro_controller_target_rows", "gauge",
                   "Adaptive target ladder rung per class.")
        m.describe("repro_controller_max_age_seconds", "gauge",
                   "Adaptive age trigger per class.")

    def _metrics_samples(self, now: float):
        """The scrape collector: O(series) reads of running state, no event
        walks (``Telemetry.live`` exists so this never touches the record
        lists).  Gauges that are undefined before their first event (M
        occupancy, penalty shares) are withheld rather than emitted as 0 —
        an absent series keeps threshold alerts inactive instead of firing
        on a cold start."""
        del now
        ac = self.telemetry.admission_counts
        live = self.telemetry.live
        out = [
            ("repro_admission_decisions_total", (), sum(ac.values())),
            ("repro_admission_slo_miss_total", (),
             ac.get("slo_miss", 0) + ac.get("cluster_slo_miss", 0)),
            ("repro_requests_served_total", (), live["requests_served"]),
            ("repro_service_seconds_total", (), live["service_s_total"]),
            ("repro_queue_depth", (), self.batcher.depth),
            ("repro_pending_load", (), self.pending_load),
            ("repro_inflight_groups", (), self.inflight_groups),
        ]
        for reason, n in ac.items():
            if reason != "ok":
                out.append(("repro_admission_rejected_total",
                            (("reason", reason),), n))
        for reason, n in live["close_reasons"].items():
            out.append(("repro_batches_closed_total",
                        (("reason", reason),), n))
        if live["dispatches"]:
            out.append(("repro_dispatch_m_occupancy", (),
                        live["m_occupancy_sum"] / live["dispatches"]))
        if len(self.telemetry.latency):
            for q in (50, 95, 99):
                out.append(("repro_latency_seconds", (("q", f"p{q}"),),
                            self.telemetry.latency.percentile(q)))
                out.append(("repro_queue_wait_seconds", (("q", f"p{q}"),),
                            self.telemetry.queue_wait.percentile(q)))
        # Penalty bins aggregated across workloads: the alertable version of
        # the ledger's per-workload decomposition.
        bins = {k: 0.0 for k in ("mxu_productive", "arithmetic_stall",
                                 "spatial_pad", "host_gap")}
        for w in self.ledger._w.values():
            for k in bins:
                bins[k] += w["cycles"][k]
        total = sum(bins.values())
        if total > 0.0:
            for k, v in bins.items():
                out.append(("repro_penalty_share", (("bin", k),), v / total))
            out.append(("repro_penalty_arithmetic_stall_share", (),
                        bins["arithmetic_stall"] / total))
        if self.controller is not None:
            out.append(("repro_controller_decisions_total", (),
                        self.controller.decisions))
            for (w, b), _ in self.controller._state.items():
                cls = (("class", f"{w}/{b}"),)
                out.append(("repro_controller_target_rows", cls,
                            self.controller.target_rows((w, b))))
                out.append(("repro_controller_max_age_seconds", cls,
                            self.controller.max_age_s((w, b))))
        return out

    def _scrape_metrics(self, now: float, final: bool = False):
        """Cadence-gated scrape + alert evaluation — the `_dispatch` tail
        hook.  ``final`` (drain) forces one terminal scrape so the last
        events of a run are always sampled (strict timestamp monotonicity
        in the registry makes a same-instant force a no-op)."""
        if self.metrics is None:
            return
        scraped = (self.metrics.scrape(now) if final
                   else self.metrics.maybe_scrape(now))
        if scraped and self.alerts is not None:
            self.alerts.evaluate(now)

    # --- observability export -------------------------------------------------

    def metrics_text(self) -> str:
        """OpenMetrics exposition of the full scraped ring (backfill
        flavour: every retained sample, virtual-clock timestamps)."""
        if self.metrics is None:
            raise RuntimeError("metrics are off — construct the server with "
                               "ServeConfig(metrics=True)")
        return self.metrics.expose_text()

    def write_metrics(self, path: str) -> str:
        """Write the OpenMetrics exposition (gzip when path ends in .gz)."""
        from repro.obs.export import write_text
        text = self.metrics_text()
        write_text(path, text)
        return text

    def trace_events(self) -> list[dict]:
        """The tracer's buffered events (empty when tracing is off)."""
        return [] if self.tracer is None else self.tracer.event_dicts()

    def write_trace(self, path: str) -> dict:
        """Export the buffered trace as Chrome-trace JSON (Perfetto-ready).
        Requires ``tracing=True`` in the config."""
        if self.tracer is None:
            raise RuntimeError("tracing is off — construct the server with "
                               "ServeConfig(tracing=True) to record a trace")
        from repro.obs.export import write_chrome_trace
        return write_chrome_trace(path, self.trace_events())

    def _apply_holdback(self, closed: list[ClosedBatch], now: float,
                        final: bool) -> list[ClosedBatch]:
        """The λ-priced merge holdback: decide, per newly closed batch,
        whether to stage it now or hold it for a predicted merge partner —
        and release every previously held batch whose partner arrived (win),
        whose priced window expired (loss), or that a drain flushes.

        Holding changes grouping only — row semantics keep the eventual
        merged launch bit-for-bit equal to launching immediately — so the
        only cost is the held rows' latency, which the pricing bounds."""
        if not self._held and (self.controller is None
                               or self.config.holdback_lambda <= 0):
            return closed
        tr = self.tracer

        def _release(held_at, hid, outcome):
            self.telemetry.record_holdback(outcome, hold_s=now - held_at)
            if tr is not None:
                tr.end("holdback", hid, "hold", now, track="holdback",
                       args={"outcome": outcome})

        out: list[ClosedBatch] = []
        if final:
            for cb, _, held_at, hid in self._held.values():
                _release(held_at, hid, "flushed")
                out.append(cb)
            self._held.clear()
        else:
            for key in [k for k, (_, rel, _, _) in self._held.items()
                        if rel <= now]:
                cb, _, held_at, hid = self._held.pop(key)
                _release(held_at, hid, "losses")
                out.append(cb)
        for cb in closed:
            key = self._class_key(cb)
            held = self._held.pop(key, None)
            if held is not None:
                # The predicted partner materialised: launch both together
                # (launch_mixed coalesces them along M into one tall group).
                _release(held[2], held[3], "wins")
                out.append(held[0])
                out.append(cb)
                continue
            if (final or cb.reason == CLOSE_DRAIN
                    or cb.batch.n_c >= self.controller.target_rows(key)):
                out.append(cb)       # already at target height — nothing to
                continue             # gain from waiting
            window = self.controller.holdback_window_s(key, cb.age_s)
            if window > 0.0:
                self.telemetry.record_holdback("held", rows=cb.batch.n_c)
                hid = 0
                if tr is not None:
                    hid = tr.next_id()
                    tr.begin("holdback", hid,
                             f"hold:{key[0]}/d{key[1]}", now,
                             track="holdback",
                             args={"rows": cb.batch.n_c,
                                   "window_s": window})
                self._held[key] = (cb, now + window, now, hid)
            else:
                out.append(cb)
        return out

    def _ring_for(self, key) -> collections.deque:
        ring = self._rings.get(key)
        if ring is None:
            ring = self._rings[key] = collections.deque()
        return ring

    def _launch_staged(self, staged: list[ClosedBatch]) -> set:
        """Enqueue the staged set onto the launch ring(s) and return the
        ring keys launched.  Depth 1 keeps the whole event in one flight
        (cross-class groups share one launch_mixed — the PR-4 pipeline);
        depth > 1 cuts per workload class so each class ring can hold k of
        *its own* groups in flight."""
        if self.config.inflight_depth == 1:
            parts = [(None, staged)]
        else:
            by_class: dict = {}
            parts = []
            for cb in staged:
                key = self._class_key(cb)
                if key not in by_class:
                    by_class[key] = []
                    parts.append((key, by_class[key]))
                by_class[key].append(cb)
        for key, part in parts:
            self._launch_seq += 1
            self._ring_for(key).append((self._launch_seq, part,
                                        *self._launch(part)))
        return {key for key, _ in parts}

    def _oldest_ring(self) -> collections.deque | None:
        live = [ring for ring in self._rings.values() if ring]
        if not live:
            return None
        return min(live, key=lambda ring: ring[0][0])

    def _dispatch(self, closed: list[ClosedBatch], now: float,
                  final: bool = False):
        """Stage newly closed batches and advance the dispatch pipeline.

        Synchronous mode launches + gathers in place (one blocking edge per
        serving event, as before).  Async mode launches now and defers the
        gather, so the caller returns while the device computes and the D2H
        copy streams; batches closed while a launch is in flight merge into
        the next one (M-axis super-batching fed by the pipeline itself).
        With ``inflight_depth`` k, up to k launch groups per workload class
        ride the ring while that class keeps launching; a class that did
        not launch this event has its oldest flight materialised instead,
        so every handle resolves at the next serving event its class goes
        quiet — a busy neighbour class can never starve another class's
        in-flight results.  ``final`` forces a full flush (drain): holdback
        pen emptied, every ring retired in launch order, zero groups left
        in flight."""
        tr = self.tracer
        if tr is not None:
            # Pin wall-clock emitters (launch spans) to this serving event's
            # clock so the whole trace shares one timeline.
            tr.anchor(now)
        if self.config.validate:
            for cb in closed:
                self._validate_once(cb.batch)
        self._staged.extend(self._apply_holdback(closed, now, final))
        if not self.config.async_pipeline:
            if self._staged:
                staged, self._staged = self._staged, []
                self._finish(staged, *self._launch(staged), now)
        else:
            launched_keys = set()
            if self._staged:
                staged, self._staged = self._staged, []
                launched_keys = self._launch_staged(staged)
            if final:
                # Retire the full ring in launch order — drain leaves
                # nothing in flight (the cluster barrier counts on it).
                while (ring := self._oldest_ring()) is not None:
                    self._finish(*ring.popleft()[1:], now)
            else:
                depth = self.config.inflight_depth
                for key, ring in self._rings.items():
                    # Gather *after* the new launches are enqueued: the
                    # device starts the next group while the host
                    # materialises these.
                    while len(ring) > depth:
                        self._finish(*ring.popleft()[1:], now)
                    if key not in launched_keys and ring:
                        self._finish(*ring.popleft()[1:], now)
        if tr is not None and (closed or final):
            # Counters are a sampled timeline, not causal data: sampling at
            # batch-close/drain boundaries keeps the sawtooth visible at the
            # granularity that matters while costing O(batches), not
            # O(requests), events (the tracing-overhead gate in
            # bench_dispatch counts on this).
            tr.counter("queue_depth", now, self.batcher.depth)
            tr.counter("inflight_groups", now, self.inflight_groups)
            tr.counter("held_batches", now, len(self._held))
        # Metrics ride the same event edge: every submit/pump/drain passes
        # through here, so a cadence check per event is the whole hot-path
        # cost (the ≤5% rows/s gate in bench_dispatch counts on this).
        self._scrape_metrics(now, final=final)

    def _launch(self, staged: list[ClosedBatch]):
        t0 = time.perf_counter()
        flight = self.cos.launch_mixed([cb.batch for cb in staged])
        launch_s = time.perf_counter() - t0
        # Claim the launch records now — a peer host sharing this
        # co-scheduler may launch before we gather.
        log = self.cos.drain_dispatch_log()
        if self.dispatch_auditor is not None:
            self.dispatch_auditor.on_launch(self.host_id, flight, log)
        return flight, log, launch_s

    def _finish(self, closed: list[ClosedBatch], flight, log: list,
                launch_s: float, now: float):
        # Service time = launch enqueue + blocking gather.  The async idle
        # gap between the two events is deliberately excluded: feeding it to
        # the admission EWMA would inflate the per-row service estimate by
        # the event spacing and make the SLO gate reject load the slice can
        # trivially carry.
        t1 = time.perf_counter()
        results = self.cos.gather(flight)
        service_s = launch_s + time.perf_counter() - t1
        if self.dispatch_auditor is not None:
            self.dispatch_auditor.on_gather(flight)
        if self.config.deterministic_timing:
            # Substitute the ledger's modeled device time for the wall
            # measurement: the one wall-clock leak into the serving loop,
            # replaced so latencies, admission EWMAs, penalty bins, scraped
            # series, and alert logs are functions of the trace alone.
            service_s = sum(
                launch_cycles(
                    d=e["d_bucket"], live_rows=e["live_rows"],
                    launched_rows=e["launched_rows"],
                    profile=self._ledger_profile(e["workload"],
                                                 e["d_bucket"]),
                    m_tile=self.config.n_c_max)["device_s"]
                for e in log)
        # Attribute wall time to batches by live-row share (one synchronised
        # launch group; per-batch device timing is not observable from here).
        total_rows = sum(cb.batch.n_c for cb in closed) or 1
        self.admission.observe_service(total_rows, service_s)
        tr = self.tracer
        if tr is not None:
            # Causal middle link: which closed batches rode which launch.
            for group, _, _ in flight.groups:
                tr.instant("launch_batches", now, track="device",
                           args={"lid": group.lid,
                                 "bids": [closed[idx].batch_id
                                          for idx, _, _, _ in group.members]})
        cluster_depth = None
        if self.controller is not None and self.cluster_depth_fn is not None:
            # Fold the gossiped fleet depth into the control setpoint (the
            # bounded-staleness contract is enforced inside the view merge,
            # so the controller can never consume an over-age digest).
            cluster_depth = self.cluster_depth_fn(now)
        # Packing metrics before the launch loop: the penalty ledger prices
        # each launch's K under-fill from the live-row-weighted mean K
        # occupancy of the batches that rode its class.
        batch_metrics = []
        class_k: dict = {}
        for cb in closed:
            batch = cb.batch
            eng = self.cos.engine_for(batch.workload, batch.d_bucket)
            d_max = (eng.plan.d_max if hasattr(eng, "plan")
                     else eng.plans[0].d_max)
            m = packing_metrics(batch.degrees, batch.d_bucket, d_max,
                                n_c_max=self.config.n_c_max)
            batch_metrics.append((cb, eng, m))
            acc = class_k.setdefault((batch.workload, batch.d_bucket),
                                     [0.0, 0])
            acc[0] += m.k_occupancy * batch.n_c
            acc[1] += batch.n_c
        total_live = sum(e["live_rows"] for e in log) or 1
        for entry in log:
            live, launched = entry["live_rows"], entry["launched_rows"]
            key = (entry["workload"], entry["d_bucket"])
            if self.controller is not None:
                # Per-class backlog: the global batcher depth would let a
                # busy neighbour class snap this class's target rung to the
                # ladder top and mis-price its holdback windows.
                self.controller.observe_dispatch(
                    key, live_rows=live,
                    queue_depth=self.batcher.class_depth(key), now=now,
                    cluster_depth=cluster_depth)
                if tr is not None:
                    w, b = key
                    tr.counter(f"target_rows[{w}/d{b}]", now,
                               self.controller.target_rows(key))
                    tr.counter(f"max_age_s[{w}/d{b}]", now,
                               self.controller.max_age_s(key))
                    dec = self.controller.last_decision
                    if dec is not None:
                        # Flight-recorder echo on the timeline: the counter
                        # tracks show *what* the setpoints did, the instant
                        # says *why* (the law branch that moved them).
                        tr.instant("setpoint", now, track="counters",
                                   args={"class": dec.cls,
                                         "reason": dec.reason,
                                         "target_rows": dec.target_rows,
                                         "max_age_s": dec.max_age_s})
            self.telemetry.record_dispatch(DispatchRecord(
                workload=entry["workload"], d_bucket=entry["d_bucket"],
                n_batches=entry["n_batches"], live_rows=live,
                launched_rows=launched,
                m_occupancy=min(1.0, live / self.config.n_c_max),
                m_fill=live / launched if launched else 0.0,
                donated=entry["donated"],
                devices=tuple(entry.get("devices", ()))))
            acc = class_k.get(key)
            self.ledger.observe_launch(
                workload=entry["workload"], d=entry["d_bucket"],
                live_rows=live, launched_rows=launched,
                n_batches=entry["n_batches"],
                service_s=service_s * live / total_live,
                profile=self._ledger_profile(*key),
                k_occupancy=(acc[0] / acc[1]) if acc and acc[1] else 1.0)
        for (cb, eng, m), res in zip(batch_metrics, results):
            batch = cb.batch
            share = service_s * batch.n_c / total_rows
            self.telemetry.record_batch(BatchRecord(
                workload=batch.workload, d_bucket=batch.d_bucket,
                n_c=batch.n_c, close_reason=cb.reason,
                m_occupancy=m.m_occupancy, k_occupancy=m.k_occupancy,
                queue_depth=self.batcher.depth, service_s=share,
                age_s=cb.age_s,
                reduction=eng.fold_profile["reduction"],
                n_folds=eng.fold_profile["n_folds"]))
            completed = now + share
            for i, r in enumerate(batch.requests):
                handle = self._handles.pop(id(r), None)
                if handle is None:       # direct batcher use, no submit()
                    continue
                # route by row position — a tenant may own several rows
                handle._resolve(res.rows[i], completed)
                self.telemetry.observe_latency(
                    handle.latency_s, queue_wait_s=now - handle.submitted_at)
                rid = getattr(r, "trace_id", None)
                if tr is not None and rid is not None:
                    tr.end("request", rid, "complete", completed)
