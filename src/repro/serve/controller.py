"""Adaptive occupancy control: the feedback loop over the dispatch telemetry.

Every knob that decides *when* the continuous batcher closes and *how tall*
the dispatch fast path launches used to be static ``ServeConfig`` values
tuned for one offered load.  The paper's starvation result (M-dimension
occupancy collapsing to 6.25 % at N_c = 8 on v4 while K saturates) makes
those knobs the difference between a starved and a full systolic array — so
when load drifts away from the tuned point, achieved M occupancy collapses
with it.  This module closes the loop:

    dispatch telemetry ──▶ AdaptiveController ──▶ batcher close policy
    (per-launch live rows,      (EWMA per            (target ladder rung,
     queue depth, close       (workload, d_bucket)    max_age, occupancy
     reasons, gossiped         class)                 threshold)
     cluster depth)

**State.**  One :class:`_ClassState` per ``(workload, d_bucket)`` class:
EWMAs of the arrival rate (from inter-arrival gaps), achieved per-launch M
occupancy (live rows / N_c_max — the paper's M-dimension quantity), and
queue depth (local depth folded with the gossiped per-host-equivalent
cluster depth when the host serves inside a fleet).

**Law.**  Three setpoint moves per dispatch observation, all bounded by the
static config values (which remain as initial / floor / ceiling):

* *target rung* — the full-close height is the smallest row-ladder rung that
  the queue model predicts the class can fill within one age window
  (``rate × max_age + backlog``), clamped to ``[n_c, ladder top]``.  Tall
  closes under heavy load are where the recovered M occupancy comes from.
* *age* — starving (occupancy EWMA below target, shallow queue) grows
  ``max_age`` geometrically toward the ceiling: waiting longer is the only
  way to fill rows that have not arrived yet.  A backlog past the target
  rung shrinks it toward the floor: rows are already queued, so closing
  fast *and* tall beats waiting.  At the setpoint the age holds — the
  p50-for-M-fill trade is deliberate and bounded by the ceiling.
* *occupancy threshold* — rides the same branches between its floor and
  ceiling when an occupancy close is configured at all.

**Holdback pricing.**  ``holdback_window_s`` prices the cross-event merge
holdback: a closed-but-short batch may wait for a merge partner for at most
``λ × ETA(partner)`` where the partner ETA is the queue model's time to
assemble another close of the class (``min(max_age, target_rows / rate)``),
*capped by the SLO budget* (``holdback_slo_fraction × slo_deadline − age``)
so a held batch can never breach the admission-visible deadline — the gate
that admitted it priced its wait against the same deadline.  λ = 0 disables
holdback; larger λ trades more p50 for more M fill.

The controller is deliberately dependency-free and clock-explicit: every
entry point takes ``now`` (the serving layer's virtual or wall clock), so
control trajectories are deterministic and unit-testable.
"""
from __future__ import annotations

import collections
import dataclasses


@dataclasses.dataclass(frozen=True)
class DecisionRecord:
    """One flight-recorder entry: everything the law saw and everything it
    chose, so "why rung X at t" is machine-answerable from the snapshot.

    ``reason`` is the law branch that moved a setpoint: ``"starving"`` /
    ``"overloaded"`` (the age/occupancy branches) or ``"queue_model"`` (the
    rung re-snap alone, age held).  ``*_from`` are the pre-step setpoints.
    """
    ts: float
    cls: str                      # "{workload}/{d_bucket}"
    reason: str
    rate_hz: float
    m_occupancy_ewma: float
    depth_ewma: float
    queue_depth: int
    cluster_depth: float | None
    predicted_rows: float
    target_rows_from: int
    target_rows: int
    max_age_from_s: float
    max_age_s: float
    occupancy_from: float | None
    occupancy_close: float | None


@dataclasses.dataclass
class _ClassState:
    """Per-(workload, d_bucket) feedback state; all rates in rows/s."""
    rate_hz: float = 0.0            # EWMA arrival rate
    last_arrival: float | None = None
    m_occupancy: float | None = None  # EWMA of per-launch live/N_c_max
    depth: float = 0.0              # EWMA queue depth (cluster-folded)
    target_rows: int = 0            # current full-close height (ladder rung)
    max_age_s: float = 0.0
    occupancy_close: float | None = None
    updates: int = 0                # dispatch observations folded in
    close_reasons: dict = dataclasses.field(default_factory=dict)


class AdaptiveController:
    """Closed-loop setpoints for the continuous batcher + dispatch path.

    The static ``ServeConfig`` values become the *bounds* of the loop:
    ``n_c`` is the target-rung floor and the ladder top its ceiling;
    ``max_age_s`` is the age initial value between ``max_age_floor_s`` and
    ``max_age_ceil_s``; ``occupancy_close`` (when set) moves between
    ``occupancy_floor`` and ``occupancy_ceil``.
    """

    def __init__(self, *, ladder: tuple, n_c: int, max_age_s: float,
                 occupancy_close: float | None = None,
                 n_c_max: int = 128, alpha: float = 0.3,
                 gain: float = 0.25, m_fill_target: float = 0.5,
                 max_age_floor_s: float | None = None,
                 max_age_ceil_s: float | None = None,
                 occupancy_floor: float | None = None,
                 occupancy_ceil: float = 0.95,
                 holdback_lambda: float = 0.0,
                 holdback_slo_fraction: float = 0.5,
                 slo_deadline_s: float | None = None,
                 recorder_capacity: int = 512):
        if not ladder:
            raise ValueError("controller needs a non-empty rung ladder")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"EWMA alpha must be in (0, 1], got {alpha}")
        if gain <= 0.0:
            raise ValueError(f"controller gain must be > 0, got {gain}")
        if holdback_lambda < 0.0:
            raise ValueError(f"holdback λ must be ≥ 0, got {holdback_lambda}")
        self.ladder = tuple(ladder)
        self.rung_floor = max(1, min(n_c, self.ladder[-1]))
        self.rung_ceil = self.ladder[-1]
        self.n_c_max = n_c_max
        self.alpha = alpha
        self.gain = gain
        self.m_fill_target = m_fill_target
        self.max_age_init_s = max_age_s
        self.max_age_floor_s = (max_age_floor_s if max_age_floor_s is not None
                                else max_age_s / 4.0)
        ceil = (max_age_ceil_s if max_age_ceil_s is not None
                else max_age_s * 8.0)
        # A held or age-aged batch must stay inside the SLO budget: the age
        # ceiling may never exceed the fraction of the deadline the holdback
        # pricer is allowed to spend.
        if slo_deadline_s is not None:
            ceil = min(ceil, holdback_slo_fraction * slo_deadline_s)
        self.max_age_ceil_s = max(self.max_age_floor_s, ceil)
        self.occupancy_init = occupancy_close
        self.occupancy_floor = (occupancy_floor if occupancy_floor is not None
                                else (occupancy_close / 2.0
                                      if occupancy_close else 0.0))
        self.occupancy_ceil = occupancy_ceil
        self.holdback_lambda = holdback_lambda
        self.holdback_slo_fraction = holdback_slo_fraction
        self.slo_deadline_s = slo_deadline_s
        self._state: dict[tuple, _ClassState] = {}
        self.updates = 0
        self._cluster_depth_max = 0.0
        # Flight recorder: a bounded ring of setpoint-change records plus a
        # lifetime decision count; ``last_decision`` is the record appended
        # by the most recent observe_dispatch, or None if it held.
        self.flight: collections.deque = collections.deque(
            maxlen=max(1, int(recorder_capacity)))
        self.decisions = 0
        self.last_decision: DecisionRecord | None = None

    # --- state access ---------------------------------------------------------

    def _st(self, key: tuple) -> _ClassState:
        st = self._state.get(key)
        if st is None:
            st = self._state[key] = _ClassState(
                target_rows=self.rung_floor, max_age_s=self.max_age_init_s,
                occupancy_close=self.occupancy_init)
        return st

    def _snap_rung(self, rows: float) -> int:
        """Smallest ladder rung ≥ rows, clamped to [n_c, ladder top]."""
        for rung in self.ladder:
            if rung >= rows:
                return max(self.rung_floor, rung)
        return self.rung_ceil

    # --- the batcher-facing close policy --------------------------------------

    def target_rows(self, key: tuple) -> int:
        return self._st(key).target_rows

    def max_age_s(self, key: tuple) -> float:
        return self._st(key).max_age_s

    def occupancy_close(self, key: tuple) -> float | None:
        return self._st(key).occupancy_close

    # --- observation sinks ----------------------------------------------------

    def observe_arrival(self, key: tuple, now: float):
        """Fold one arrival into the class's inter-arrival rate EWMA."""
        st = self._st(key)
        if st.last_arrival is not None and now > st.last_arrival:
            inst = 1.0 / (now - st.last_arrival)
            st.rate_hz = (inst if st.rate_hz == 0.0 else
                          (1 - self.alpha) * st.rate_hz + self.alpha * inst)
        st.last_arrival = now

    def observe_close(self, key: tuple, reason: str):
        """Audit which trigger closed each batch (the setpoint's footprint)."""
        st = self._st(key)
        st.close_reasons[reason] = st.close_reasons.get(reason, 0) + 1

    def observe_dispatch(self, key: tuple, *, live_rows: int,
                         queue_depth: int, now: float,
                         cluster_depth: float | None = None):
        """One control step: fold a completed launch into the EWMAs and move
        the class's setpoints (see the module docstring for the law).

        ``now`` timestamps the flight-recorder entry when a setpoint moves;
        the law itself stays event-driven."""
        st = self._st(key)
        prev = (st.target_rows, st.max_age_s, st.occupancy_close)
        a = self.alpha
        m_occ = min(1.0, live_rows / self.n_c_max)
        st.m_occupancy = (m_occ if st.m_occupancy is None else
                          (1 - a) * st.m_occupancy + a * m_occ)
        depth = float(queue_depth)
        if cluster_depth is not None:
            # Gossiped fleet state folds into the *setpoint*, not just the
            # admission gate: a deep cluster queue means merge partners are
            # coming even if this host's local queue looks shallow.  The
            # digest is class-blind (total depth only), so this is a coarse
            # upper bound on the class backlog, never a substitute for it.
            depth = max(depth, float(cluster_depth))
            self._cluster_depth_max = max(self._cluster_depth_max, depth)
        st.depth = (1 - a) * st.depth + a * depth
        # Target rung: what the queue model predicts the class can fill
        # within one age window (arrivals en route + backlog already queued).
        predicted = st.rate_hz * st.max_age_s + st.depth
        st.target_rows = self._snap_rung(predicted)
        starving = (st.m_occupancy < self.m_fill_target
                    and st.depth <= st.target_rows)
        overloaded = st.depth > 2.0 * st.target_rows
        if starving:
            st.max_age_s = min(self.max_age_ceil_s,
                               st.max_age_s * (1.0 + self.gain))
            if st.occupancy_close is not None:
                st.occupancy_close = min(self.occupancy_ceil,
                                         st.occupancy_close * (1.0 + self.gain))
        elif overloaded:
            st.max_age_s = max(self.max_age_floor_s,
                               st.max_age_s * (1.0 - self.gain))
            if st.occupancy_close is not None:
                st.occupancy_close = max(self.occupancy_floor,
                                         st.occupancy_close * (1.0 - self.gain))
        # else: at the setpoint — hold, don't chatter.
        st.updates += 1
        self.updates += 1
        if (st.target_rows, st.max_age_s, st.occupancy_close) != prev:
            reason = ("starving" if starving
                      else "overloaded" if overloaded else "queue_model")
            rec = DecisionRecord(
                ts=float(now), cls=f"{key[0]}/{key[1]}", reason=reason,
                rate_hz=st.rate_hz, m_occupancy_ewma=st.m_occupancy,
                depth_ewma=st.depth, queue_depth=int(queue_depth),
                cluster_depth=(float(cluster_depth)
                               if cluster_depth is not None else None),
                predicted_rows=predicted,
                target_rows_from=prev[0], target_rows=st.target_rows,
                max_age_from_s=prev[1], max_age_s=st.max_age_s,
                occupancy_from=prev[2], occupancy_close=st.occupancy_close)
            self.flight.append(rec)
            self.decisions += 1
            self.last_decision = rec
        else:
            self.last_decision = None

    # --- holdback pricing -----------------------------------------------------

    def holdback_window_s(self, key: tuple, age_s: float) -> float:
        """How long a short closed batch may wait for a merge partner.

        0.0 means "launch now": λ disabled, no rate estimate yet, or the SLO
        budget already spent by the batch's own residency.  Positive values
        are ``min(λ × partner ETA, SLO budget)`` — the λ term prices the
        p50 the class is willing to trade, the budget term guarantees the
        admission-visible deadline survives the wait.
        """
        if self.holdback_lambda <= 0.0:
            return 0.0
        st = self._st(key)
        if st.rate_hz <= 0.0:
            return 0.0
        # Partner ETA: the next close of this class either fills to the
        # target rung (backlog + arrivals at the EWMA rate) or age-closes
        # one inter-arrival gap + one age window from now — whichever the
        # queue model predicts first.
        gap = 1.0 / st.rate_hz
        needed = max(0.0, st.target_rows - st.depth)
        eta = min(needed / st.rate_hz, gap + st.max_age_s)
        if self.slo_deadline_s is not None:
            budget = self.holdback_slo_fraction * self.slo_deadline_s - age_s
        else:
            budget = self.max_age_ceil_s - age_s
        return max(0.0, min(self.holdback_lambda * eta, budget))

    # --- export ---------------------------------------------------------------

    def snapshot(self) -> dict:
        classes = {}
        for (workload, d_bucket), st in self._state.items():
            classes[f"{workload}/{d_bucket}"] = {
                "rate_hz": st.rate_hz,
                "m_occupancy_ewma": (st.m_occupancy
                                     if st.m_occupancy is not None else 0.0),
                "depth_ewma": st.depth,
                "target_rows": st.target_rows,
                "max_age_s": st.max_age_s,
                "occupancy_close": st.occupancy_close,
                "updates": st.updates,
                "close_reasons": dict(st.close_reasons),
            }
        return {
            "updates": self.updates,
            "classes": classes,
            "cluster_depth_max": self._cluster_depth_max,
            "flight_recorder": {
                "decisions": self.decisions,
                "capacity": self.flight.maxlen,
                "records": [dataclasses.asdict(r) for r in self.flight],
            },
            "bounds": {
                "rung_floor": self.rung_floor,
                "rung_ceil": self.rung_ceil,
                "max_age_floor_s": self.max_age_floor_s,
                "max_age_init_s": self.max_age_init_s,
                "max_age_ceil_s": self.max_age_ceil_s,
                "occupancy_floor": self.occupancy_floor,
                "occupancy_ceil": self.occupancy_ceil,
                "m_fill_target": self.m_fill_target,
                "holdback_lambda": self.holdback_lambda,
            },
        }
