"""SLO-aware admission control and per-tenant rate limiting.

Online serving must bound *queueing*, not just throughput: once the offered
load exceeds the slice's service rate, every admitted request inflates the
tail latency of all tenants (the paper's §7.4 regime where TPU BN254
throughput is 3 orders below GPU baselines — overload is the common case,
not the exception).  The controller rejects early with a machine-readable
reason and a retry-after hint so clients can back off instead of timing out.
"""
from __future__ import annotations

import dataclasses


class TokenBucket:
    """Classic token bucket: ``rate_hz`` sustained, ``burst`` peak."""

    def __init__(self, rate_hz: float, burst: float):
        self.rate_hz = float(rate_hz)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._t_last: float | None = None

    def _refill(self, now: float):
        if self._t_last is not None and now > self._t_last:
            self.tokens = min(self.burst,
                              self.tokens + (now - self._t_last) * self.rate_hz)
        self._t_last = now if self._t_last is None else max(self._t_last, now)

    def try_take(self, now: float, n: float = 1.0) -> bool:
        self._refill(now)
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False

    def time_until(self, n: float = 1.0) -> float:
        """Seconds until ``n`` tokens accumulate (0 if available now)."""
        deficit = n - self.tokens
        return max(0.0, deficit / self.rate_hz) if self.rate_hz > 0 else float("inf")


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    reason: str              # "ok" | "queue_full" | "rate_limited" |
                             # "slo_miss" | "cluster_slo_miss"
    retry_after_s: float = 0.0


ADMIT = AdmissionDecision(True, "ok")


class AdmissionController:
    """Three gates: queue bound, SLO estimate, then the tenant bucket.

    The SLO gate predicts this request's queueing delay as
    ``pending / service_rate`` using an EWMA of observed dispatch throughput;
    requests that would already be late on arrival are rejected immediately
    (better a fast 429 than a slow success past its deadline).  The token
    bucket runs last so server-side rejections never debit a tenant's rate
    budget — only requests the server could actually take consume tokens.
    """

    def __init__(self, *, max_pending: int = 1024,
                 tenant_rate_hz: float | None = None,
                 tenant_burst: float = 8.0,
                 slo_deadline_s: float | None = None,
                 service_rate_init: float = 1024.0,
                 ewma_alpha: float = 0.3):
        self.max_pending = max_pending
        self.tenant_rate_hz = tenant_rate_hz
        self.tenant_burst = tenant_burst
        self.slo_deadline_s = slo_deadline_s
        self.service_rate = float(service_rate_init)   # ops/s, EWMA-updated
        self.ewma_alpha = ewma_alpha
        self._buckets: dict[int, TokenBucket] = {}

    def observe_service(self, n_ops: int, elapsed_s: float):
        """Fold a completed dispatch into the service-rate estimate."""
        if elapsed_s <= 0 or n_ops <= 0:
            return
        rate = n_ops / elapsed_s
        a = self.ewma_alpha
        self.service_rate = (1 - a) * self.service_rate + a * rate

    def estimated_wait_s(self, pending: int) -> float:
        return pending / self.service_rate if self.service_rate > 0 else float("inf")

    def backpressure(self, pending: int, *, high_watermark: float = 0.8) -> bool:
        """Soft signal: queue above the watermark — clients should slow down
        before hard rejections begin."""
        return pending >= high_watermark * self.max_pending

    def admit(self, req, now: float, pending: int,
              cluster_pending: float | None = None) -> AdmissionDecision:
        """``cluster_pending`` is the per-host-equivalent cluster queue depth
        (cluster total / live hosts) from the gossip layer; ``None`` means no
        cluster view and the SLO gate falls back to local state only.  The
        cluster check runs after the local one so ``cluster_slo_miss`` always
        means a rejection local-only state would have admitted."""
        if pending >= self.max_pending:
            return AdmissionDecision(False, "queue_full",
                                     retry_after_s=self.estimated_wait_s(pending))
        if self.slo_deadline_s is not None:
            wait = self.estimated_wait_s(pending)
            if wait > self.slo_deadline_s:
                return AdmissionDecision(False, "slo_miss", retry_after_s=wait)
            if cluster_pending is not None and cluster_pending > pending:
                cwait = self.estimated_wait_s(cluster_pending)
                if cwait > self.slo_deadline_s:
                    return AdmissionDecision(False, "cluster_slo_miss",
                                             retry_after_s=cwait)
        if self.tenant_rate_hz is not None:
            bucket = self._buckets.get(req.tenant_id)
            if bucket is None:
                bucket = self._buckets[req.tenant_id] = TokenBucket(
                    self.tenant_rate_hz, self.tenant_burst)
            if not bucket.try_take(now):
                return AdmissionDecision(False, "rate_limited",
                                         retry_after_s=bucket.time_until())
        return ADMIT
