"""SLO-aware admission control and per-tenant rate limiting.

Online serving must bound *queueing*, not just throughput: once the offered
load exceeds the slice's service rate, every admitted request inflates the
tail latency of all tenants (the paper's §7.4 regime where TPU BN254
throughput is 3 orders below GPU baselines — overload is the common case,
not the exception).  The controller rejects early with a machine-readable
reason and a retry-after hint so clients can back off instead of timing out.

Two state layouts implement one policy:

* **scalar** (``columnar=False``) — one :class:`TokenBucket` object per
  tenant in a dict, one Python decision per request.  This is the oracle:
  small, obviously correct, and what the property suite checks the fast
  path against.
* **columnar** (``columnar=True``, the default) — all tenant state lives in
  one numpy structured array (token level, last-refill instant, per-tenant
  rate/burst) keyed by a dense tenant index from :class:`TenantInterner`.
  :meth:`AdmissionController.admit_batch` then vectorises the queue-bound /
  SLO pricing and the token-bucket refill+charge over a whole arrival
  batch; steady state does zero per-request dict or object allocation.

The two paths are *bit-identical*: every float op in the vector path is
arranged exactly as the scalar path computes it (python floats are IEEE
doubles, as are numpy float64 lanes), so decisions, reasons, retry hints,
and bucket levels match exactly — not approximately — on any trace.  The
one sequential coupling, "the queue/SLO gate sees the count admitted so far
this batch", is resolved by a gate-threshold argument: within one batch the
gates only depend on ``pending + admitted_so_far``, which is non-decreasing,
so once the gate rejects it rejects every later request with the same frozen
reason/hint.  The vector path finds that cut point and replays bucket
charges before it (see ``admit_batch``).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


class TokenBucket:
    """Classic token bucket: ``rate_hz`` sustained, ``burst`` peak."""

    def __init__(self, rate_hz: float, burst: float):
        self.rate_hz = float(rate_hz)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._t_last: float | None = None

    def _refill(self, now: float):
        if self._t_last is not None and now > self._t_last:
            self.tokens = min(self.burst,
                              self.tokens + (now - self._t_last) * self.rate_hz)
        self._t_last = now if self._t_last is None else max(self._t_last, now)

    def try_take(self, now: float, n: float = 1.0) -> bool:
        self._refill(now)
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False

    def time_until(self, n: float = 1.0, *, now: float | None = None) -> float:
        """Seconds until ``n`` tokens accumulate (0 if available now).

        Pass ``now`` so the deficit is priced from the level the bucket
        would hold *at this instant* — without it, tokens accrued since the
        last charge are invisible and the hint overstates the wait for any
        bucket not charged right now."""
        if now is not None:
            self._refill(now)
        deficit = n - self.tokens
        return max(0.0, deficit / self.rate_hz) if self.rate_hz > 0 else float("inf")


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    reason: str              # "ok" | "queue_full" | "rate_limited" |
                             # "slo_miss" | "cluster_slo_miss" | "shed"
    retry_after_s: float = 0.0


ADMIT = AdmissionDecision(True, "ok")

# Machine-readable reason codes for the columnar batch path (uint8 lanes).
# SHED is issued by the cluster's failover coordinator (watermark-gated load
# shedding during a redistribution transient), never by this controller —
# it lives here so reason codes stay one authoritative enumeration.
OK, QUEUE_FULL, SLO_MISS, CLUSTER_SLO_MISS, RATE_LIMITED, SHED = range(6)
REASONS = ("ok", "queue_full", "slo_miss", "cluster_slo_miss",
           "rate_limited", "shed")


@dataclasses.dataclass
class BatchDecisions:
    """Columnar decisions for one arrival batch (positional, arrival order).

    ``reason_codes`` indexes :data:`REASONS`; ``decision(i)`` materialises
    the scalar :class:`AdmissionDecision` for position ``i`` on demand, so
    the batch path never allocates per-request objects for admitted rows."""

    admitted: np.ndarray          # bool[n]
    reason_codes: np.ndarray      # uint8[n]
    retry_after_s: np.ndarray     # float64[n]

    def __len__(self) -> int:
        return len(self.admitted)

    @property
    def n_admitted(self) -> int:
        return int(np.count_nonzero(self.admitted))

    def reasons(self) -> list[str]:
        return [REASONS[c] for c in self.reason_codes]

    def decision(self, i: int) -> AdmissionDecision:
        if self.admitted[i]:
            return ADMIT
        return AdmissionDecision(False, REASONS[self.reason_codes[i]],
                                 retry_after_s=float(self.retry_after_s[i]))

    def counts(self) -> dict[str, int]:
        """Per-reason decision counts (bulk telemetry)."""
        codes, n = np.unique(self.reason_codes, return_counts=True)
        return {REASONS[c]: int(k) for c, k in zip(codes, n)}


class TenantInterner:
    """Amortised tenant-id → dense-index map.

    Non-negative integer ids below ``dense_limit`` resolve through one numpy
    array probe (``_dense[id]``) — no hashing, no dict, vectorisable for a
    whole batch with one fancy-index gather.  Ids outside that range (huge,
    negative, or non-integer) fall back to a dict.  Indices are assigned in
    first-intern order and never recycled."""

    def __init__(self, dense_limit: int = 1 << 21):
        self.dense_limit = int(dense_limit)
        self._dense = np.full(1024, -1, np.int32)
        self._map: dict = {}
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def _grow_dense(self, need: int):
        cap = len(self._dense)
        while cap <= need:
            cap *= 2
        cap = min(cap, self.dense_limit)
        if cap > len(self._dense):
            grown = np.full(cap, -1, np.int32)
            grown[:len(self._dense)] = self._dense
            self._dense = grown

    def index_of(self, tid):
        """Existing index for ``tid`` or None (never interns)."""
        if isinstance(tid, (int, np.integer)) and 0 <= tid < self.dense_limit:
            t = int(tid)
            if t < len(self._dense):
                i = int(self._dense[t])
                return i if i >= 0 else None
            return None
        return self._map.get(tid)

    def intern(self, tid) -> int:
        if isinstance(tid, (int, np.integer)) and 0 <= tid < self.dense_limit:
            t = int(tid)
            if t >= len(self._dense):
                self._grow_dense(t)
            i = int(self._dense[t])
            if i < 0:
                i = self._n
                self._dense[t] = i
                self._n += 1
            return i
        i = self._map.get(tid)
        if i is None:
            i = self._map[tid] = self._n
            self._n += 1
        return i

    def intern_many(self, ids) -> np.ndarray:
        """Vectorised intern: one gather + one scatter for the dense ids in
        a batch; only never-seen or non-dense ids pay per-item work."""
        arr = np.asarray(ids)
        out = np.empty(len(arr), np.int64)
        if arr.dtype.kind in "iu" and len(arr):
            in_dense = (arr >= 0) & (arr < self.dense_limit)
            if in_dense.all():
                hi = int(arr.max())
                if hi >= len(self._dense):
                    self._grow_dense(hi)
                idx = self._dense[arr]
                miss = idx < 0
                if miss.any():
                    # assign in first-occurrence order so a batch intern is
                    # indistinguishable from the scalar per-item loop
                    uniq, first = np.unique(arr[miss], return_index=True)
                    new_ids = uniq[np.argsort(first, kind="stable")]
                    self._dense[new_ids] = np.arange(
                        self._n, self._n + len(new_ids), dtype=np.int32)
                    self._n += len(new_ids)
                    idx = self._dense[arr]
                out[:] = idx
                return out
        for p, tid in enumerate(arr.tolist()):
            out[p] = self.intern(tid)
        return out


_STATE_DTYPE = np.dtype([("tokens", np.float64), ("t_last", np.float64),
                         ("rate_hz", np.float64), ("burst", np.float64)])


class AdmissionController:
    """Three gates: queue bound, SLO estimate, then the tenant bucket.

    The SLO gate predicts this request's queueing delay as
    ``pending / service_rate`` using an EWMA of observed dispatch throughput;
    requests that would already be late on arrival are rejected immediately
    (better a fast 429 than a slow success past its deadline).  The token
    bucket runs last so server-side rejections never debit a tenant's rate
    budget — only requests the server could actually take consume tokens.

    ``columnar=True`` (default) keeps tenant bucket state in one structured
    array behind a :class:`TenantInterner` and enables the vectorised
    :meth:`admit_batch`; ``columnar=False`` keeps the per-tenant
    :class:`TokenBucket` dict and serves as the oracle the property suite
    compares against.  Decisions are bit-identical either way.
    """

    def __init__(self, *, max_pending: int = 1024,
                 tenant_rate_hz: float | None = None,
                 tenant_burst: float = 8.0,
                 slo_deadline_s: float | None = None,
                 service_rate_init: float = 1024.0,
                 ewma_alpha: float = 0.3,
                 columnar: bool = True):
        self.max_pending = max_pending
        self.tenant_rate_hz = tenant_rate_hz
        self.tenant_burst = tenant_burst
        self.slo_deadline_s = slo_deadline_s
        self.service_rate = float(service_rate_init)   # ops/s, EWMA-updated
        self.ewma_alpha = ewma_alpha
        self.columnar = bool(columnar)
        self._buckets: dict[int, TokenBucket] = {}     # scalar mode
        self._interner = TenantInterner()              # columnar mode
        self._state = np.zeros(0, _STATE_DTYPE)

    # --- shared estimators ----------------------------------------------------

    def observe_service(self, n_ops: int, elapsed_s: float):
        """Fold a completed dispatch into the service-rate estimate."""
        if elapsed_s <= 0 or n_ops <= 0:
            return
        rate = n_ops / elapsed_s
        a = self.ewma_alpha
        self.service_rate = (1 - a) * self.service_rate + a * rate

    def estimated_wait_s(self, pending: int) -> float:
        return pending / self.service_rate if self.service_rate > 0 else float("inf")

    def backpressure(self, pending: int, *, high_watermark: float = 0.8) -> bool:
        """Soft signal: queue above the watermark — clients should slow down
        before hard rejections begin."""
        return pending >= high_watermark * self.max_pending

    @property
    def tenants(self) -> int:
        """Distinct tenants with bucket state (either layout)."""
        return len(self._interner) if self.columnar else len(self._buckets)

    def bucket_level(self, tenant_id, now: float) -> float | None:
        """Pure probe: the token level ``tenant_id``'s bucket would hold at
        ``now`` (no state mutation) — None if the tenant has no bucket yet.
        The parity suite compares levels across layouts through this."""
        if self.tenant_rate_hz is None:
            return None
        if not self.columnar:
            b = self._buckets.get(tenant_id)
            if b is None:
                return None
            tokens, t_last = b.tokens, b._t_last
            if t_last is not None and now > t_last:
                tokens = min(b.burst, tokens + (now - t_last) * b.rate_hz)
            return tokens
        i = self._interner.index_of(tenant_id)
        if i is None:
            return None
        row = self._state[i]
        tokens = float(row["tokens"])
        t_last = float(row["t_last"])
        if now > t_last:
            gain = (now - t_last) * float(row["rate_hz"])
            if math.isnan(gain):          # fresh row (t_last = -inf), rate 0
                gain = math.inf
            tokens = min(float(row["burst"]), tokens + gain)
        return tokens

    # --- columnar state plumbing ----------------------------------------------

    def _grow_state(self, need: int):
        cap = max(1024, len(self._state))
        while cap < need:
            cap *= 2
        if cap > len(self._state):
            grown = np.zeros(cap, _STATE_DTYPE)
            grown[:len(self._state)] = self._state
            n0 = len(self._state)
            grown["tokens"][n0:] = self.tenant_burst
            # -inf marks a never-refilled row: the first refill then clamps
            # straight to burst (dt = +inf) exactly like a fresh TokenBucket,
            # and t_last picks up the true first-seen instant even when the
            # virtual clock is negative.
            grown["t_last"][n0:] = -np.inf
            grown["rate_hz"][n0:] = self.tenant_rate_hz or 0.0
            grown["burst"][n0:] = self.tenant_burst
            self._state = grown

    def _intern_rows(self, ids) -> np.ndarray:
        idx = self._interner.intern_many(ids)
        n = len(self._interner)
        if n > len(self._state):
            self._grow_state(n)
        return idx

    def _charge_one(self, i: int, now: float) -> tuple[bool, float]:
        """Scalar refill+charge of columnar row ``i`` — the same float ops
        as TokenBucket.try_take/time_until, on the structured-array lanes."""
        st = self._state
        tokens = float(st["tokens"][i])
        t_last = float(st["t_last"][i])
        rate = float(st["rate_hz"][i])
        burst = float(st["burst"][i])
        if now > t_last:
            gain = (now - t_last) * rate
            if math.isnan(gain):                      # fresh row, rate 0
                gain = math.inf
            tokens = min(burst, tokens + gain)
            t_last = now
        st["t_last"][i] = t_last
        if tokens >= 1.0:
            st["tokens"][i] = tokens - 1.0
            return True, 0.0
        st["tokens"][i] = tokens
        deficit = 1.0 - tokens
        hint = max(0.0, deficit / rate) if rate > 0 else math.inf
        return False, hint

    def set_tenant_limit(self, tenant_id, *, rate_hz: float, burst: float):
        """Per-tenant rate override.  Resets the tenant's bucket to a fresh
        full one (both layouts), so the ``tokens ≤ burst`` invariant the
        vector refill relies on holds by construction."""
        if self.columnar:
            i = self._interner.intern(tenant_id)
            if len(self._interner) > len(self._state):
                self._grow_state(len(self._interner))
            self._state[i] = (float(burst), -np.inf, float(rate_hz),
                              float(burst))
        else:
            self._buckets[tenant_id] = TokenBucket(rate_hz, burst)

    # --- per-request path -----------------------------------------------------

    def admit(self, req, now: float, pending: int,
              cluster_pending: float | None = None) -> AdmissionDecision:
        """``cluster_pending`` is the per-host-equivalent cluster queue depth
        (cluster total / live hosts) from the gossip layer; ``None`` means no
        cluster view and the SLO gate falls back to local state only.  The
        cluster check runs after the local one so ``cluster_slo_miss`` always
        means a rejection local-only state would have admitted."""
        if pending >= self.max_pending:
            return AdmissionDecision(False, "queue_full",
                                     retry_after_s=self.estimated_wait_s(pending))
        if self.slo_deadline_s is not None:
            wait = self.estimated_wait_s(pending)
            if wait > self.slo_deadline_s:
                return AdmissionDecision(False, "slo_miss", retry_after_s=wait)
            if cluster_pending is not None and cluster_pending > pending:
                cwait = self.estimated_wait_s(cluster_pending)
                if cwait > self.slo_deadline_s:
                    return AdmissionDecision(False, "cluster_slo_miss",
                                             retry_after_s=cwait)
        if self.tenant_rate_hz is not None:
            if self.columnar:
                i = self._interner.intern(req.tenant_id)
                if len(self._interner) > len(self._state):
                    self._grow_state(len(self._interner))
                ok, hint = self._charge_one(i, now)
                if not ok:
                    return AdmissionDecision(False, "rate_limited",
                                             retry_after_s=hint)
            else:
                bucket = self._buckets.get(req.tenant_id)
                if bucket is None:
                    bucket = self._buckets[req.tenant_id] = TokenBucket(
                        self.tenant_rate_hz, self.tenant_burst)
                if not bucket.try_take(now):
                    return AdmissionDecision(False, "rate_limited",
                                             retry_after_s=bucket.time_until(
                                                 now=now))
        return ADMIT

    # --- batch path -----------------------------------------------------------

    def admit_batch(self, tenant_ids, nows, *, pending: int,
                    cluster_pending: float | None = None) -> BatchDecisions:
        """Admit one arrival batch (arrival order) against entering depth
        ``pending``.  Semantically this IS the scalar loop

            for tid, t in zip(tenant_ids, nows):
                d = self.admit(req(tid), t, pending + admitted_so_far, ...)

        — same decisions, reasons, hints, and bucket state, bit for bit —
        vectorised.  ``cluster_pending`` is sampled once for the batch (the
        scalar loop at a batch edge would re-read the same digest anyway).
        """
        ids = np.asarray(tenant_ids)
        ts = np.asarray(nows, np.float64)
        if ts.ndim == 0:
            ts = np.broadcast_to(ts, ids.shape).copy()
        n = len(ids)
        if n == 0:
            return BatchDecisions(np.zeros(0, bool), np.zeros(0, np.uint8),
                                  np.zeros(0))
        if not self.columnar:
            return self._admit_batch_scalar(ids, ts, pending, cluster_pending)

        # Gate threshold: the queue/SLO gates see pending + admitted-so-far,
        # which never decreases within a batch, so there is a first admitted
        # count T at which they reject — and from then on every request gets
        # that same frozen reason and hint.  Scan the n+1 candidate counts.
        cand = pending + np.arange(n + 1)
        sr = self.service_rate
        wait_cand = (cand / sr if sr > 0
                     else np.full(n + 1, np.inf))
        rej_q = cand >= self.max_pending
        rej_s = np.zeros(n + 1, bool)
        rej_c = np.zeros(n + 1, bool)
        cwait = 0.0
        if self.slo_deadline_s is not None:
            rej_s = wait_cand > self.slo_deadline_s
            if cluster_pending is not None:
                cwait = (cluster_pending / sr) if sr > 0 else math.inf
                rej_c = ((cluster_pending > cand)
                         & (cwait > self.slo_deadline_s))
        gate_rej = rej_q | rej_s | rej_c
        if gate_rej.any():
            T = int(np.argmax(gate_rej))
            if rej_q[T]:
                g_code, g_hint = QUEUE_FULL, float(wait_cand[T])
            elif rej_s[T]:
                g_code, g_hint = SLO_MISS, float(wait_cand[T])
            else:
                g_code, g_hint = CLUSTER_SLO_MISS, float(cwait)
        else:
            T, g_code, g_hint = n + 1, OK, 0.0

        admitted = np.zeros(n, bool)
        codes = np.zeros(n, np.uint8)
        retry = np.zeros(n, np.float64)
        if T == 0:
            # Gate already closed on entry: nothing reaches the buckets, no
            # tenant is interned (the scalar loop never touches them either).
            codes[:] = g_code
            retry[:] = g_hint
            return BatchDecisions(admitted, codes, retry)

        if self.tenant_rate_hz is None:
            cut = min(T, n)
            admitted[:cut] = True
            codes[cut:] = g_code
            retry[cut:] = g_hint
            return BatchDecisions(admitted, codes, retry)

        idx = self._intern_rows(ids)
        # Occurrence rank: the r-th time a tenant appears in this batch.
        # Positions sharing a rank hit distinct state rows, so each round is
        # one safe fancy-indexed refill+charge; duplicates serialise across
        # rounds exactly like the scalar loop would.
        order = np.argsort(idx, kind="stable")
        sidx = idx[order]
        is_start = np.empty(n, bool)
        is_start[0] = True
        is_start[1:] = sidx[1:] != sidx[:-1]
        pos = np.arange(n)
        occ = np.empty(n, np.int64)
        occ[order] = pos - np.maximum.accumulate(np.where(is_start, pos, 0))
        max_occ = int(occ.max())

        # Snapshot touched rows: if the gate cuts mid-batch we restore and
        # replay only the prefix, so post-cut requests leave zero trace in
        # bucket state (they never reached the buckets in the scalar loop).
        need_snap = T <= n
        if need_snap:
            touched = np.unique(idx)
            snap = self._state[touched].copy()

        bucket_ok = np.empty(n, bool)
        bucket_hint = np.empty(n, np.float64)

        def scan(limit: int):
            st = self._state
            tok_f, tl_f = st["tokens"], st["t_last"]
            rate_f, burst_f = st["rate_hz"], st["burst"]
            for r in range(max_occ + 1):
                sel = np.nonzero((occ == r) & (pos < limit))[0]
                if not len(sel):
                    break
                i = idx[sel]
                t = ts[sel]
                tl = tl_f[i]
                rate = rate_f[i]
                burst = burst_f[i]
                # dt clamped at 0 ≡ the scalar skip-if-backwards branch:
                # min(burst, tokens + 0) is tokens under tokens ≤ burst.
                with np.errstate(invalid="ignore"):  # fresh row dt=inf × rate 0
                    gain = np.maximum(0.0, t - tl) * rate
                gain[np.isnan(gain)] = np.inf
                tok = np.minimum(burst, tok_f[i] + gain)
                ok = tok >= 1.0
                tok_f[i] = tok - ok                 # charge 1.0 where ok
                tl_f[i] = np.maximum(tl, t)
                bucket_ok[sel] = ok
                deficit = 1.0 - tok
                hint = np.divide(deficit, rate,
                                 out=np.full(len(sel), np.inf),
                                 where=rate > 0)
                bucket_hint[sel] = np.maximum(0.0, hint)

        scan(n)
        cut = n
        if need_snap:
            # First position whose *entering* admitted count reaches T — the
            # gate slams shut there; restore and replay the clean prefix.
            entering = np.concatenate(
                ([0], np.cumsum(bucket_ok[:-1], dtype=np.int64)))
            over = entering >= T
            if over.any():
                cut = int(np.argmax(over))
                self._state[touched] = snap
                scan(cut)

        admitted[:cut] = bucket_ok[:cut]
        rl = ~bucket_ok[:cut]
        codes[:cut][rl] = RATE_LIMITED
        retry[:cut][rl] = bucket_hint[:cut][rl]
        codes[cut:] = g_code
        retry[cut:] = g_hint
        return BatchDecisions(admitted, codes, retry)

    def _admit_batch_scalar(self, ids, ts, pending, cluster_pending):
        """The oracle: the literal per-request loop over admit()."""
        n = len(ids)
        admitted = np.zeros(n, bool)
        codes = np.zeros(n, np.uint8)
        retry = np.zeros(n, np.float64)
        extra = 0
        req = _BucketProbe(None)
        for p in range(n):
            req.tenant_id = ids[p] if ids.dtype.kind not in "iu" \
                else int(ids[p])
            d = self.admit(req, float(ts[p]), pending + extra,
                           cluster_pending=cluster_pending)
            admitted[p] = d.admitted
            codes[p] = REASONS.index(d.reason)
            retry[p] = d.retry_after_s
            extra += d.admitted
        return BatchDecisions(admitted, codes, retry)


class _BucketProbe:
    """Minimal request stand-in for the oracle loop (tenant_id only)."""
    __slots__ = ("tenant_id",)

    def __init__(self, tenant_id):
        self.tenant_id = tenant_id
