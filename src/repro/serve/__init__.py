"""repro.serve — online multi-tenant serving runtime (the paper, productionised).

Converts the offline measurement pipeline (Poisson replay → Tier-1 stacking
→ Tier-2 dispatch) into a server with live ingress:

* :mod:`server`    — ``CryptoServer`` event loop: submit → handle, explicit-
  clock flush policy, graceful drain;
* :mod:`admission` — queue-bound / per-tenant token-bucket / SLO gates with
  backpressure signalling;
* :mod:`batcher`   — continuous rectangular batcher (close on N_c-full, age
  timeout, or occupancy threshold);
* :mod:`telemetry` — K/M occupancy, queue depth, p50/p95/p99 latency, JSON
  export for ``BENCH_*`` tracking;
* :mod:`client`    — synthetic load generator (virtual or real-time pacing).
"""
from repro.serve.admission import (AdmissionController, AdmissionDecision,
                                   TokenBucket)
from repro.serve.batcher import ContinuousBatcher, ClosedBatch
from repro.serve.client import LoadGenerator, LoadResult, attach_payloads
from repro.serve.server import (CryptoServer, RejectedError, ResponseHandle,
                                ServeConfig)
from repro.serve.telemetry import BatchRecord, LatencyHistogram, Telemetry
