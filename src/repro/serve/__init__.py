"""repro.serve — online multi-tenant serving runtime (the paper, productionised).

Converts the offline measurement pipeline (Poisson replay → Tier-1 stacking
→ Tier-2 dispatch) into a server with live ingress:

* :mod:`server`    — ``CryptoServer`` event loop: submit → handle, explicit-
  clock flush policy, graceful drain;
* :mod:`admission` — queue-bound / per-tenant token-bucket / SLO gates with
  backpressure signalling;
* :mod:`batcher`   — continuous rectangular batcher (close on N_c-full, age
  timeout, or occupancy threshold);
* :mod:`telemetry` — K/M occupancy, queue depth, p50/p95/p99 latency,
  eager-vs-deferred reduction-stall counters, JSON export for ``BENCH_*``
  tracking;
* :mod:`client`    — synthetic load generator (virtual or real-time pacing);
* :mod:`controller` — adaptive occupancy controller: EWMA feedback over the
  dispatch telemetry drives the per-class close policy (target ladder rung,
  max_age, occupancy threshold) and prices the λ-controlled merge holdback
  against the SLO gate.

``ServeConfig.reduction_by_workload`` selects the fold discipline per
workload class (paper §7.2.1): lazy (κ-amortised deferred Montgomery
reduction) classes batch and dispatch next to strictly-eager classes, each
with its own compiled programs and HLO validation mode (eager V1–V5; lazy
adds the one-fold-per-window checks V6/V7).
"""
from repro.serve.admission import (AdmissionController, AdmissionDecision,
                                   BatchDecisions, TenantInterner,
                                   TokenBucket)
from repro.serve.batcher import ContinuousBatcher, ClosedBatch
from repro.serve.client import LoadGenerator, LoadResult, attach_payloads
from repro.serve.controller import AdaptiveController
from repro.serve.server import (CryptoServer, RejectedError, ResponseHandle,
                                ServeConfig, enable_compilation_cache)
from repro.serve.telemetry import BatchRecord, LatencyHistogram, Telemetry
