"""Serving telemetry: occupancy, queue depth, and latency distributions.

Every closed batch contributes one :class:`BatchRecord` carrying the Tier-1
packing metrics (K/M systolic occupancy — the paper's Table-5 quantities) at
the moment of dispatch, plus the queue depth it left behind and its measured
service time.  Per-request latencies feed a histogram reporting p50/p95/p99.
Snapshots are plain dicts, exportable to JSON for ``BENCH_*`` tracking.
"""
from __future__ import annotations

import dataclasses
import json
import math


class LatencyHistogram:
    """Latency reservoir with interpolated percentiles.

    Exact by default: serving runs here are bounded (seconds of trace,
    thousands of requests), so exact samples beat bucketed approximations.
    For traces that outgrow the reservoir, pass ``sketch_bound``: once the
    sample count exceeds it the reservoir collapses into log-spaced buckets
    (ratio :data:`GAMMA` per bucket → ≤ ~4.5% relative quantile error) with
    bounded memory; count / mean / max stay exact in either mode.  The
    cluster merge (:mod:`repro.cluster.telemetry`) stays exact only while
    every host is still exact — any sketched host flips ``merged_exact``
    off and the merge proceeds bucket-wise.
    """

    GAMMA = 2.0 ** 0.125     # 12 buckets per octave of latency

    def __init__(self, sketch_bound: int | None = None):
        if sketch_bound is not None and sketch_bound < 1:
            raise ValueError(f"sketch_bound must be ≥ 1, got {sketch_bound}")
        self.sketch_bound = sketch_bound
        self._samples: list[float] = []
        self._sorted = True
        self._buckets: dict[int, int] | None = None   # log-bucket counts
        self._zero = 0          # samples ≤ 0 (virtual clocks produce them)
        self._count = 0
        self._sum = 0.0
        self._max = 0.0

    @property
    def sketching(self) -> bool:
        return self._buckets is not None

    def _bucket_of(self, x: float) -> int:
        return math.floor(math.log(x) / math.log(self.GAMMA))

    def _collapse(self):
        """Exact reservoir → log-bucket sketch (one-way, on overflow)."""
        self._buckets = {}
        for x in self._samples:
            if x <= 0.0:
                self._zero += 1
            else:
                b = self._bucket_of(x)
                self._buckets[b] = self._buckets.get(b, 0) + 1
        self._samples = []
        self._sorted = True

    def observe(self, seconds: float):
        x = float(seconds)
        self._count += 1
        self._sum += x
        self._max = max(self._max, x)
        if self._buckets is not None:
            if x <= 0.0:
                self._zero += 1
            else:
                b = self._bucket_of(x)
                self._buckets[b] = self._buckets.get(b, 0) + 1
            return
        self._samples.append(x)
        self._sorted = False
        if (self.sketch_bound is not None
                and len(self._samples) > self.sketch_bound):
            self._collapse()

    def __len__(self) -> int:
        return self._count

    def percentile(self, q: float) -> float:
        """Quantile, q in [0, 100]: linear-interpolated over exact samples,
        or the geometric bucket midpoint once sketching."""
        if not self._count:
            return 0.0
        if self._buckets is not None:
            rank = (q / 100.0) * (self._count - 1)
            seen = self._zero
            if rank < seen:
                return 0.0
            for b in sorted(self._buckets):
                seen += self._buckets[b]
                if rank < seen:
                    return min(self.GAMMA ** (b + 0.5), self._max)
            return self._max
        if not self._sorted:
            self._samples.sort()
            self._sorted = True
        s = self._samples
        pos = (q / 100.0) * (len(s) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        frac = pos - lo
        return s[lo] * (1 - frac) + s[hi] * frac

    @property
    def samples(self) -> list[float]:
        """Sorted copy of the raw samples (the exactly-mergeable
        representation) — unavailable once collapsed to a sketch."""
        if self._buckets is not None:
            raise RuntimeError("histogram collapsed to a sketch at "
                               f"sketch_bound={self.sketch_bound}: exact "
                               "samples are gone; merge via the 'sketch' "
                               "summary section instead")
        if not self._sorted:
            self._samples.sort()
            self._sorted = True
        return list(self._samples)

    def sketch_state(self) -> dict:
        """The mergeable bucket representation (JSON-safe string keys)."""
        return {"gamma": self.GAMMA, "zero": self._zero,
                "buckets": {str(b): n
                            for b, n in sorted(self._buckets.items())}}

    def summary(self, include_samples: bool = False) -> dict:
        n = self._count
        out = {
            "count": n,
            "mean_s": (self._sum / n) if n else 0.0,
            "p50_s": self.percentile(50),
            "p95_s": self.percentile(95),
            "p99_s": self.percentile(99),
            "max_s": self._max if n else 0.0,
        }
        if include_samples:
            # Cluster mode: per-host snapshots carry the raw samples so the
            # merged cluster quantiles are exact (quantiles of summaries are
            # not mergeable; quantiles of concatenated samples are).  A
            # sketched host exports its buckets instead — still mergeable,
            # no longer exact.
            if self._buckets is not None:
                out["sketch"] = self.sketch_state()
            else:
                out["samples"] = self.samples
        return out


@dataclasses.dataclass(frozen=True)
class DispatchRecord:
    """One compiled-program launch (possibly several merged batches).

    Where :class:`BatchRecord` carries the *planned* packing of one closed
    batch, this carries the *achieved* M fill of what actually hit the
    device after super-batching and row-ladder padding — the quantity
    ``bench_serve``/``bench_dispatch`` track to show the recovered M
    occupancy (paper §7: M collapses to 6.25% at N_c = 8 on v4).
    """
    workload: str
    d_bucket: int
    n_batches: int           # stacked batches merged into this launch
    live_rows: int           # tenant rows (excludes ladder padding)
    launched_rows: int       # operand height on the device (ladder rung)
    m_occupancy: float       # live_rows / n_c_max — post-merge M occupancy
    m_fill: float            # live_rows / launched_rows — ladder-pad density
    donated: bool = False    # operand buffer donated to the program
    devices: tuple = ()      # device ids the launch was enqueued on (empty
                             # for records predating device pinning)


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    workload: str
    d_bucket: int
    n_c: int                 # live tenant rows (excludes shape-padding rows)
    close_reason: str        # "full" | "age" | "occupancy" | "drain"
    m_occupancy: float
    k_occupancy: float
    queue_depth: int         # pending requests left behind at dispatch
    service_s: float
    age_s: float             # oldest-request residency when the batch closed
    reduction: str = "eager"  # fold discipline of this batch's program
    n_folds: int = 0         # static VPU-fold (reduction-stall) count of the
                             # dispatched program: n_passes·C eager,
                             # ⌈n_passes/κ⌉·C deferred (paper §7.2.1)


class Telemetry:
    """Accumulates serving events; ``snapshot()`` is the export surface."""

    HOLDBACK_EVENTS = ("held", "wins", "losses", "flushed")

    def __init__(self, sketch_bound: int | None = None):
        self.batches: list[BatchRecord] = []
        self.dispatches: list[DispatchRecord] = []
        self.latency = LatencyHistogram(sketch_bound=sketch_bound)
        self.queue_wait = LatencyHistogram(sketch_bound=sketch_bound)
        self.admission_counts: dict[str, int] = {}
        self._queue_depth_sum = 0
        self._queue_depth_max = 0
        # Merge-holdback audit: every hold must end as exactly one win
        # (a partner arrived inside the priced window), loss (the window
        # expired first), or flush (drain released it).
        self.holdback = {k: 0 for k in self.HOLDBACK_EVENTS}
        self.holdback.update(held_rows=0, hold_s_sum=0.0, hold_s_max=0.0)
        # Extra snapshot sections attached by the serving layer (e.g. the
        # adaptive controller's state) — name -> zero-arg provider.
        self._sections: dict = {}
        # O(1) running counters for the metrics scrape path: snapshot() walks
        # every event record (fine once per run, too hot per scrape), so the
        # scrape collectors read these instead.
        self.live = {
            "requests_served": 0,      # Σ n_c over closed batches
            "batches": 0,
            "service_s_total": 0.0,
            "close_reasons": {},       # reason -> count
            "dispatches": 0,
            "live_rows": 0,
            "launched_rows": 0,
            "m_occupancy_sum": 0.0,    # over DispatchRecords
        }

    def attach_section(self, name: str, provider):
        """Register a callable whose result is exported under ``name`` in
        every snapshot (the controller uses this to publish its setpoints
        without telemetry knowing its shape)."""
        self._sections[name] = provider

    # --- event sinks ----------------------------------------------------------

    def record_batch(self, rec: BatchRecord):
        self.batches.append(rec)
        self._queue_depth_sum += rec.queue_depth
        self._queue_depth_max = max(self._queue_depth_max, rec.queue_depth)
        live = self.live
        live["requests_served"] += rec.n_c
        live["batches"] += 1
        live["service_s_total"] += rec.service_s
        live["close_reasons"][rec.close_reason] = (
            live["close_reasons"].get(rec.close_reason, 0) + 1)

    def record_dispatch(self, rec: DispatchRecord):
        self.dispatches.append(rec)
        live = self.live
        live["dispatches"] += 1
        live["live_rows"] += rec.live_rows
        live["launched_rows"] += rec.launched_rows
        live["m_occupancy_sum"] += rec.m_occupancy

    def record_admission(self, reason: str):
        self.admission_counts[reason] = self.admission_counts.get(reason, 0) + 1

    def record_admissions(self, counts: dict):
        """Bulk admission decisions (one arrival batch): same ledger as
        :meth:`record_admission`, one update per reason per batch instead of
        one per request — the batch ingress edge's O(1) telemetry cost."""
        for reason, k in counts.items():
            self.admission_counts[reason] = (
                self.admission_counts.get(reason, 0) + int(k))

    def record_holdback(self, event: str, *, rows: int = 0,
                        hold_s: float = 0.0):
        """``held`` when a batch enters holdback; ``wins``/``losses``/
        ``flushed`` when it leaves (with its realised hold duration)."""
        if event not in self.HOLDBACK_EVENTS:
            raise ValueError(f"unknown holdback event {event!r} "
                             f"(want one of {self.HOLDBACK_EVENTS})")
        self.holdback[event] += 1
        if event == "held":
            self.holdback["held_rows"] += rows
        else:
            self.holdback["hold_s_sum"] += hold_s
            self.holdback["hold_s_max"] = max(self.holdback["hold_s_max"],
                                              hold_s)

    def observe_latency(self, seconds: float, *, queue_wait_s: float = None):
        self.latency.observe(seconds)
        if queue_wait_s is not None:
            self.queue_wait.observe(queue_wait_s)

    # --- export ---------------------------------------------------------------

    def snapshot(self, include_samples: bool = False) -> dict:
        n_b = len(self.batches)
        per_workload: dict[str, dict] = {}
        for rec in self.batches:
            w = per_workload.setdefault(rec.workload, {
                "batches": 0, "requests": 0, "k_occupancy_sum": 0.0,
                "m_occupancy_sum": 0.0, "reduction_batches": {},
                "folds": 0})
            w["batches"] += 1
            w["requests"] += rec.n_c
            w["k_occupancy_sum"] += rec.k_occupancy
            w["m_occupancy_sum"] += rec.m_occupancy
            w["folds"] += rec.n_folds
            w["reduction_batches"][rec.reduction] = (
                w["reduction_batches"].get(rec.reduction, 0) + 1)
        for w in per_workload.values():
            w["k_occupancy_mean"] = w.pop("k_occupancy_sum") / w["batches"]
            w["m_occupancy_mean"] = w.pop("m_occupancy_sum") / w["batches"]
            # Derived label: the single fold discipline when the class is
            # uniform, "mixed" otherwise (a class can change discipline
            # mid-run, e.g. a reconfigured slice — the old field silently
            # reported whichever mode the first batch happened to use).
            modes = sorted(w["reduction_batches"])
            w["reduction"] = modes[0] if len(modes) == 1 else "mixed"
        reasons: dict[str, int] = {}
        for rec in self.batches:
            reasons[rec.close_reason] = reasons.get(rec.close_reason, 0) + 1
        # Reduction-stall counters: each VPU fold is a reduction stall of the
        # MXU pipeline; the eager/deferred split per close reason is the κ-
        # amortisation audit surface (paper §7.2.1).
        stalls = {"eager_folds": 0, "deferred_folds": 0,
                  "by_close_reason": {}}
        for rec in self.batches:
            kind = "eager_folds" if rec.reduction == "eager" else "deferred_folds"
            stalls[kind] += rec.n_folds
            by = stalls["by_close_reason"].setdefault(
                rec.close_reason, {"eager_folds": 0, "deferred_folds": 0})
            by[kind] += rec.n_folds
        # Dispatch fast path: achieved per-launch M fill after merging +
        # ladder padding (one DispatchRecord per compiled-program launch;
        # several BatchRecords may map onto one of these).
        n_d = len(self.dispatches)
        live = sum(r.live_rows for r in self.dispatches)
        launched = sum(r.launched_rows for r in self.dispatches)
        dispatch = {
            "dispatches": n_d,
            "merged_dispatches": sum(1 for r in self.dispatches
                                     if r.n_batches > 1),
            "batches_per_dispatch_mean": (
                sum(r.n_batches for r in self.dispatches) / n_d) if n_d else 0.0,
            "live_rows": live,
            "launched_rows": launched,
            "pad_fraction": (1.0 - live / launched) if launched else 0.0,
            "m_occupancy_mean": (sum(r.m_occupancy for r in self.dispatches)
                                 / n_d) if n_d else 0.0,
            "m_fill_mean": (sum(r.m_fill for r in self.dispatches) / n_d)
                           if n_d else 0.0,
            "donated": sum(1 for r in self.dispatches if r.donated),
        }
        # Per-device launch census (device-parallel fleets): which device
        # ids this host's programs were enqueued on, and how many live rows
        # each carried — the attribution basis for per-device busy time.
        by_device: dict[str, dict] = {}
        for r in self.dispatches:
            for dev in r.devices:
                slot = by_device.setdefault(
                    str(dev), {"launches": 0, "live_rows": 0})
                slot["launches"] += 1
                slot["live_rows"] += r.live_rows
        dispatch["by_device"] = by_device
        admitted = self.admission_counts.get("ok", 0)
        rejected = sum(v for k, v in self.admission_counts.items() if k != "ok")
        extra = {name: provider() for name, provider in self._sections.items()}
        return {
            **extra,
            "holdback": dict(self.holdback),
            "batches": n_b,
            "requests_served": sum(r.n_c for r in self.batches),
            "k_occupancy_mean": (sum(r.k_occupancy for r in self.batches) / n_b)
                                if n_b else 0.0,
            "m_occupancy_mean": (sum(r.m_occupancy for r in self.batches) / n_b)
                                if n_b else 0.0,
            "queue_depth_mean": (self._queue_depth_sum / n_b) if n_b else 0.0,
            "queue_depth_max": self._queue_depth_max,
            "service_s_total": sum(r.service_s for r in self.batches),
            "close_reasons": reasons,
            "reduction_stalls": stalls,
            "dispatch": dispatch,
            "per_workload": per_workload,
            "latency": self.latency.summary(include_samples),
            "queue_wait": self.queue_wait.summary(include_samples),
            "admission": {"admitted": admitted, "rejected": rejected,
                          "by_reason": dict(self.admission_counts)},
        }

    def write_json(self, path: str) -> dict:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
        return snap


class DispatchOverlapAuditor:
    """Fleet-level launch-overlap audit for device-parallel clusters.

    The cluster layer attaches one auditor across all host slices; each
    host reports program launches (``on_launch``) and retirements
    (``on_gather`` / ``on_reset``).  Every quantity is computed from the
    *event order* of launches on the shared virtual clock, so the audit is
    deterministic and testable:

    * ``launch_concurrency`` — distinct devices with un-gathered launches
      at each launch instant (mean/max).  >1 means host i's launches
      genuinely overlap host j's on separate queues.
    * ``cross_host_queue_share`` — fraction of launches enqueued while
      another host already had an un-gathered launch on the *same*
      device.  High in simulated shared-device mode; exactly 0.0 by
      construction when every host is pinned to its own device.
    """

    def __init__(self):
        self._inflight: dict[int, list] = {}   # id(flight) -> [(host, devs)]
        self.launches = 0
        self.flights = 0
        self.cross_host_shared = 0
        self._concurrency_sum = 0
        self.concurrency_max = 0
        self.per_host_devices: dict = {}       # host -> set of device ids

    def on_launch(self, host, flight, entries: list[dict]):
        """Register one ``launch_mixed`` flight: ``entries`` are the
        co-scheduler's dispatch-log records for exactly this flight."""
        units = []
        for e in entries:
            devs = frozenset(e.get("devices", ()))
            self.launches += 1
            self.per_host_devices.setdefault(host, set()).update(devs)
            for others in self._inflight.values():
                if any(h != host and (devs & d) for h, d in others):
                    self.cross_host_shared += 1
                    break
            units.append((host, devs))
        if units:
            self.flights += 1
            self._inflight[id(flight)] = units
            busy = set()
            for u in self._inflight.values():
                for _, devs in u:
                    busy |= devs
            self._concurrency_sum += len(busy)
            self.concurrency_max = max(self.concurrency_max, len(busy))

    def on_gather(self, flight):
        self._inflight.pop(id(flight), None)

    def on_reset(self, host):
        """A host was torn down without gathering (failover reset): its
        in-flight launches are gone, not merely late — drop them so the
        concurrency audit does not leak permanently-busy devices."""
        for key, units in list(self._inflight.items()):
            kept = [(h, d) for h, d in units if h != host]
            if kept:
                self._inflight[key] = kept
            else:
                del self._inflight[key]

    def snapshot(self) -> dict:
        n = self.launches
        return {
            "launches": n,
            "flights": self.flights,
            "cross_host_shared_launches": self.cross_host_shared,
            "cross_host_queue_share": (self.cross_host_shared / n) if n
                                      else 0.0,
            "launch_concurrency_mean": (
                self._concurrency_sum / self.flights) if self.flights
                else 0.0,
            "launch_concurrency_max": self.concurrency_max,
            "inflight_launches": sum(len(u) for u in
                                     self._inflight.values()),
            "per_host_devices": {str(h): sorted(d) for h, d in
                                 sorted(self.per_host_devices.items(),
                                        key=lambda kv: str(kv[0]))},
        }
