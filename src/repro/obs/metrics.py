"""Continuous metrics: bounded time-series rings + OpenMetrics exposition.

The registry is *collector-driven*: the hot path never mutates it.  Producers
(`CryptoServer`, `ClusterServer`) register collector callables that read O(1)
running counters out of `Telemetry` / `PenaltyLedger` / `AdaptiveController` /
`GossipBus`; `maybe_scrape(now)` fires on a fixed serving-clock cadence and
appends one sample per series into a bounded ring.  Because every scrape
timestamp comes off the virtual serving clock and every sampled value is
derived from deterministic state, two identical runs produce bit-identical
series (`ServeConfig.deterministic_timing` removes the one wall-clock leak —
measured dispatch service time — by substituting the penalty-ledger cycle
model).

Exposition uses the OpenMetrics text format in its *backfill* flavour: each
series emits every ringed sample as a ``name{labels} value timestamp`` line
(timestamps are virtual-clock seconds), families carry ``# HELP`` / ``# TYPE``
headers, and the document terminates with ``# EOF``.  That keeps the export a
real parseable format (promtool backfill accepts it) while preserving the
whole ring, not just the latest point.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass


Labels = tuple  # tuple[tuple[str, str], ...] — sorted (key, value) pairs

_KINDS = ("counter", "gauge")


@dataclass(frozen=True)
class MetricSpec:
    """Static family metadata: exposition headers + semantics.

    ``wall=True`` marks a series whose values derive from wall-clock
    measurement (excluded from bit-identity checks unless
    ``deterministic_timing`` replaces the measurement with the cycle model).
    """

    name: str
    kind: str = "gauge"
    help_text: str = ""
    wall: bool = False

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"metric kind must be one of {_KINDS}: {self.kind!r}")


def _canon_labels(labels) -> Labels:
    """Normalise a labels mapping/iterable into a sorted, hashable tuple."""
    if not labels:
        return ()
    if isinstance(labels, dict):
        items = labels.items()
    else:
        items = labels
    return tuple(sorted((str(k), str(v)) for k, v in items))


def _escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace("\"", "\\\"").replace("\n", "\\n")


def _fmt(value: float) -> str:
    """Deterministic shortest-repr float formatting (bit-identical reruns)."""
    v = float(value)
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


class MetricsRegistry:
    """Bounded in-memory time-series store scraped on a serving-clock cadence.

    - ``describe(name, ...)`` registers family metadata (idempotent).
    - ``add_collector(fn)`` registers ``fn(now) -> iterable[(name, labels,
      value)]``; collectors run only at scrape time.
    - ``maybe_scrape(now)`` is the hot-path entry: one float compare unless a
      scrape is due.  Scrape timestamps are strictly increasing — a forced
      terminal scrape at an already-sampled instant is a no-op, so drain
      cannot double-sample.
    - Each series is a ``deque(maxlen=capacity)`` of ``(ts, value)``; evicted
      points are counted in ``dropped_points`` so truncation is auditable.
    """

    def __init__(self, *, period_s: float = 0.005, capacity: int = 4096,
                 host: int | None = None):
        if period_s <= 0:
            raise ValueError(f"metrics period_s must be > 0: {period_s}")
        if capacity < 2:
            raise ValueError(f"metrics capacity must be >= 2: {capacity}")
        self.period_s = float(period_s)
        self.capacity = int(capacity)
        self.host = host
        self._specs: dict[str, MetricSpec] = {}
        self._series: dict[tuple[str, Labels], deque] = {}
        self._collectors: list = []
        self._last_scrape: float | None = None
        self.scrapes = 0
        self.dropped_points = 0

    # --- registration --------------------------------------------------------

    def describe(self, name: str, kind: str = "gauge", help_text: str = "",
                 wall: bool = False) -> MetricSpec:
        spec = self._specs.get(name)
        if spec is None:
            spec = MetricSpec(name, kind, help_text, wall)
            self._specs[name] = spec
        return spec

    def add_collector(self, fn) -> None:
        self._collectors.append(fn)

    # --- sampling ------------------------------------------------------------

    def observe(self, name: str, labels, ts: float, value: float) -> None:
        """Low-level append of one sample (scrape internals + synthetic tests)."""
        if name not in self._specs:
            self.describe(name)
        key = (name, _canon_labels(labels))
        ring = self._series.get(key)
        if ring is None:
            ring = self._series[key] = deque(maxlen=self.capacity)
        if len(ring) == ring.maxlen:
            self.dropped_points += 1
        ring.append((float(ts), float(value)))

    def maybe_scrape(self, now: float) -> bool:
        if self._last_scrape is not None and now - self._last_scrape < self.period_s:
            return False
        return self.scrape(now)

    def scrape(self, now: float, *, force: bool = False) -> bool:
        """Run every collector and append one point per emitted series.

        ``force`` bypasses the cadence (used for the terminal drain scrape)
        but never the strictly-increasing-timestamp invariant.
        """
        del force  # cadence is the caller's concern; monotonicity is ours
        if self._last_scrape is not None and now <= self._last_scrape:
            return False
        for fn in self._collectors:
            for name, labels, value in fn(now):
                self.observe(name, labels, now, value)
        self._last_scrape = float(now)
        self.scrapes += 1
        return True

    # --- queries -------------------------------------------------------------

    def series(self, name: str, labels=()) -> list:
        ring = self._series.get((name, _canon_labels(labels)))
        return list(ring) if ring is not None else []

    def series_keys(self) -> list:
        return sorted(self._series.keys())

    def latest(self, name: str, labels=()):
        ring = self._series.get((name, _canon_labels(labels)))
        if not ring:
            return None
        return ring[-1][1]

    def window_delta(self, name: str, labels, now: float, window_s: float):
        """``(dv, dt)`` between the newest sample and the newest sample at or
        before ``now - window_s`` (clamped to the oldest retained point).
        Returns ``None`` with fewer than two samples — burn rates need a
        baseline before they can accuse anyone of burning."""
        ring = self._series.get((name, _canon_labels(labels)))
        if ring is None or len(ring) < 2:
            return None
        ts1, v1 = ring[-1]
        cutoff = now - window_s
        ts0, v0 = ring[0]
        for ts, v in ring:
            if ts > cutoff:
                break
            ts0, v0 = ts, v
        if ts1 <= ts0:
            return None
        return (v1 - v0, ts1 - ts0)

    # --- exposition ----------------------------------------------------------

    def expose_text(self) -> str:
        """Full-ring OpenMetrics text for this registry alone."""
        return expose_registries([self])

    def snapshot(self) -> dict:
        return {
            "period_s": self.period_s,
            "capacity": self.capacity,
            "scrapes": self.scrapes,
            "series": len(self._series),
            "samples": sum(len(r) for r in self._series.values()),
            "dropped_points": self.dropped_points,
            "last_scrape": self._last_scrape,
        }


def expose_registries(registries) -> str:
    """Merge one or more registries into a single OpenMetrics document.

    Families are emitted once (headers from the first registry describing
    them); samples from a registry with ``host`` set gain a ``host`` label so
    a fleet's series stay distinguishable after the merge.  Ends with
    ``# EOF`` per the OpenMetrics spec.
    """
    order: list[str] = []
    specs: dict[str, MetricSpec] = {}
    for reg in registries:
        for name, spec in reg._specs.items():
            if name not in specs:
                specs[name] = spec
                order.append(name)
    lines: list[str] = []
    for name in order:
        spec = specs[name]
        if spec.help_text:
            lines.append(f"# HELP {name} {_escape(spec.help_text)}")
        lines.append(f"# TYPE {name} {spec.kind}")
        for reg in registries:
            for (sname, labels), ring in reg._series.items():
                if sname != name:
                    continue
                full = labels
                if reg.host is not None:
                    full = _canon_labels(labels + (("host", str(reg.host)),))
                if full:
                    label_txt = "{" + ",".join(
                        f'{k}="{_escape(v)}"' for k, v in full) + "}"
                else:
                    label_txt = ""
                for ts, value in ring:
                    lines.append(f"{name}{label_txt} {_fmt(value)} {_fmt(ts)}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def serve_metrics_http(registries, port: int, host: str = "127.0.0.1"):
    """Start a daemon-thread HTTP endpoint exposing ``/metrics``.

    Wall-clock (``--realtime``) mode only — the virtual clock has no meaning
    to an external scraper.  Returns the ``HTTPServer``; call ``.shutdown()``
    when the run ends.  Stdlib only, by design.
    """
    import threading
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    regs = list(registries)

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
            if self.path.rstrip("/") not in ("", "/metrics".rstrip("/"), "/metrics"):
                self.send_response(404)
                self.end_headers()
                return
            body = expose_registries(regs).encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "application/openmetrics-text; version=1.0.0")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # keep stdout clean
            del args

    server = ThreadingHTTPServer((host, port), _Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    return server
