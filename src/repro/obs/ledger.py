"""Live penalty ledger: per-launch modeled-cycle attribution (paper §7).

The paper decomposes the TPU finite-field deficit into an **arithmetic
penalty** (Montgomery folds run on the VPU while the MXU stalls, §7.2) and a
**spatial penalty** (M/K under-fill of the 128×128 systolic array, §7.3 —
the 6.25% M-occupancy collapse).  This module turns that decomposition into
a live, per-snapshot quantity: every compiled-program launch is priced in
modeled device cycles and split into four exhaustive, mutually exclusive
bins

* ``mxu_productive``   — MXU cycles doing live tenant work (live-row share
  of the limb-GEMM MACs, discounted by achieved K occupancy);
* ``arithmetic_stall`` — VPU fold cycles attributable to live rows (the
  §7.2 reduction-stall tax; scales with ``n_folds``, so κ-deferred classes
  show it shrink);
* ``spatial_pad``      — MXU cycles burned on M-tile rounding, ladder-pad
  rows and K under-fill, plus the VPU fold share spent on dead rows (§7.3);
* ``host_gap``         — measured service time beyond the modeled device
  cycles: dispatch, gather, transfers, compile-cache misses.

**Conservation is the contract**: the four cycle bins are an exact partition
of ``total_cycles`` by construction, so their shares sum to 1.0 (±1e-9 float
noise) per workload — tested in tests/test_obs.py and re-established after
the exact cross-host merge in :func:`merge_penalty_sections`.

The cycle model (device constants below are the v4-class geometry used by
the paper's roofline): one launch of height R (``launched_rows``, rounded up
to ``m_slots`` whole M tiles) over degree d with C channels costs

* MXU: ``m_slots · d² · data_limbs · tw_limbs · C / MXU_MACS_PER_CYCLE``
  limb-plane GEMM MACs (the d² contraction is pass-tiled but its MAC count
  is tile-invariant);
* VPU: ``n_folds · R · d · n_diag · VPU_OPS_PER_DIAG / VPU_LANES`` fold
  lane-ops (``n_folds`` already counts every channel's windows).
"""
from __future__ import annotations

MXU_MACS_PER_CYCLE = 128 * 128        # one v4-class 128×128 systolic pass
VPU_LANES = 8 * 128                   # (8, 128) vector registers
VPU_OPS_PER_DIAG = 4.0                # mul+add+shift+select per diagonal fold
DEVICE_HZ = 940e6                     # v4 clock used by the paper's roofline

SHARE_KEYS = ("mxu_productive", "arithmetic_stall", "spatial_pad", "host_gap")


def _shares(cycles: dict) -> dict:
    total = cycles["total"]
    if total <= 0.0:
        return {k: 0.0 for k in SHARE_KEYS}
    return {k: cycles[k] / total for k in SHARE_KEYS}


def launch_cycles(*, d: int, live_rows: int, launched_rows: int,
                  profile: dict, m_tile: int = 128,
                  k_occupancy: float = 1.0) -> dict:
    """Price one launch in modeled device cycles (the ledger's cycle model,
    factored out so callers can use it without a ledger — notably
    ``ServeConfig.deterministic_timing``, which substitutes
    ``(mxu + vpu) / DEVICE_HZ`` for the wall-clock service measurement to
    make the whole serving loop bit-reproducible).

    Returns ``{"mxu", "vpu", "mxu_productive", "arithmetic_stall",
    "spatial_pad", "device_s"}`` — device bins only; ``host_gap`` needs a
    measured service time and stays the ledger's business.
    """
    m_tile = max(1, int(m_tile))
    launched = max(1, int(launched_rows))
    live = min(int(live_rows), launched)
    m_slots = -(-launched // m_tile) * m_tile
    k_occ = min(max(float(k_occupancy), 0.0), 1.0)

    macs = (m_slots * float(d) * float(d) * profile["data_limbs"]
            * profile["tw_limbs"] * profile["n_channels"])
    mxu = macs / MXU_MACS_PER_CYCLE
    lane_ops = (profile["n_folds"] * launched * float(d)
                * profile["n_diag"] * VPU_OPS_PER_DIAG)
    vpu = lane_ops / VPU_LANES

    live_m = live / m_slots
    live_r = live / launched
    mxu_productive = mxu * live_m * k_occ
    arithmetic_stall = vpu * live_r
    spatial_pad = (mxu - mxu_productive) + vpu * (1.0 - live_r)
    return {"mxu": mxu, "vpu": vpu,
            "mxu_productive": mxu_productive,
            "arithmetic_stall": arithmetic_stall,
            "spatial_pad": spatial_pad,
            "device_s": (mxu + vpu) / DEVICE_HZ}


class PenaltyLedger:
    """Accumulates per-launch cycle attributions, keyed by workload."""

    def __init__(self, m_tile: int = 128):
        # M granule: the paper's N_c^max occupancy denominator — a launch
        # occupies whole 128-row systolic M slots regardless of the ladder
        # rung it compiled at, so 8 live rows in one slot read as the 6.25%
        # collapse (§7.3).
        self.m_tile = max(1, int(m_tile))
        self._w: dict[str, dict] = {}

    def observe_launch(self, *, workload: str, d: int, live_rows: int,
                       launched_rows: int, n_batches: int, service_s: float,
                       profile: dict, k_occupancy: float = 1.0):
        """Price one compiled-program launch.

        ``profile`` is the engine's fold profile augmented with limb counts
        (``n_folds``, ``n_diag``, ``n_channels``, ``data_limbs``,
        ``tw_limbs``, ``reduction``); ``k_occupancy`` is the mean achieved K
        fill of the batches in this launch (K under-fill is spatial).
        """
        launched = max(1, int(launched_rows))
        live = min(int(live_rows), launched)
        cyc = launch_cycles(d=d, live_rows=live, launched_rows=launched,
                            profile=profile, m_tile=self.m_tile,
                            k_occupancy=k_occupancy)
        mxu_productive = cyc["mxu_productive"]
        arithmetic_stall = cyc["arithmetic_stall"]
        spatial_pad = cyc["spatial_pad"]
        measured = max(0.0, float(service_s)) * DEVICE_HZ
        host_gap = max(0.0, measured - (cyc["mxu"] + cyc["vpu"]))

        w = self._w.setdefault(workload, {
            "launches": 0, "batches": 0, "live_rows": 0, "launched_rows": 0,
            "reduction_modes": {},
            "cycles": {k: 0.0 for k in SHARE_KEYS}})
        w["launches"] += 1
        w["batches"] += int(n_batches)
        w["live_rows"] += live
        w["launched_rows"] += launched
        mode = profile.get("reduction", "eager")
        w["reduction_modes"][mode] = w["reduction_modes"].get(mode, 0) + 1
        c = w["cycles"]
        c["mxu_productive"] += mxu_productive
        c["arithmetic_stall"] += arithmetic_stall
        c["spatial_pad"] += spatial_pad
        c["host_gap"] += host_gap

    def observe_host_gap(self, workload: str, gap_s: float):
        """Attribute measured non-device seconds straight into the
        ``host_gap`` bin of ``workload`` — no launch involved.  The
        failover path uses this to price a failure transient (the gossip
        detection window during which a dead host's intake sat unserved)
        onto the recovery coordinator's ledger, under a ``failover:hN``
        pseudo-workload.  Conservation holds trivially: the bin *is* the
        workload's whole cycle total."""
        w = self._w.setdefault(workload, {
            "launches": 0, "batches": 0, "live_rows": 0, "launched_rows": 0,
            "reduction_modes": {},
            "cycles": {k: 0.0 for k in SHARE_KEYS}})
        w["cycles"]["host_gap"] += max(0.0, float(gap_s)) * DEVICE_HZ

    def snapshot(self) -> dict:
        """Per-workload cycle bins + shares (the ``penalty`` section)."""
        out = {}
        for name, w in self._w.items():
            cycles = dict(w["cycles"])
            cycles["total"] = sum(cycles[k] for k in SHARE_KEYS)
            out[name] = {
                "launches": w["launches"],
                "batches": w["batches"],
                "live_rows": w["live_rows"],
                "launched_rows": w["launched_rows"],
                "reduction_modes": dict(w["reduction_modes"]),
                "cycles": cycles,
                "shares": _shares(cycles),
            }
        return out


def merge_penalty_sections(sections) -> dict:
    """Exact cross-host merge of ``penalty`` snapshot sections: raw cycle
    bins and row counts add, shares are recomputed from the merged bins (so
    conservation survives the merge exactly).  Hosts missing the section or
    a workload simply contribute nothing."""
    acc: dict[str, dict] = {}
    for sec in sections:
        if not sec:
            continue
        for name, w in sec.items():
            a = acc.setdefault(name, {
                "launches": 0, "batches": 0, "live_rows": 0,
                "launched_rows": 0, "reduction_modes": {},
                "cycles": {k: 0.0 for k in SHARE_KEYS}})
            for k in ("launches", "batches", "live_rows", "launched_rows"):
                a[k] += w.get(k, 0)
            for mode, n in w.get("reduction_modes", {}).items():
                a["reduction_modes"][mode] = (
                    a["reduction_modes"].get(mode, 0) + n)
            for k in SHARE_KEYS:
                a["cycles"][k] += w.get("cycles", {}).get(k, 0.0)
    out = {}
    for name, a in acc.items():
        cycles = dict(a["cycles"])
        cycles["total"] = sum(cycles[k] for k in SHARE_KEYS)
        out[name] = {**{k: a[k] for k in ("launches", "batches", "live_rows",
                                          "launched_rows")},
                     "reduction_modes": a["reduction_modes"],
                     "cycles": cycles, "shares": _shares(cycles)}
    return out
