"""Request-lifecycle tracing: a bounded ring-buffer span/event sink.

One :class:`Tracer` collects every observability event of one host's serving
stack.  Events are plain dicts in a ``deque`` ring buffer (bounded memory; a
full buffer drops the *oldest* events and counts the drops), so the hot path
pays one dict build + append per event and nothing else — no locks, no I/O,
no formatting.  Rendering happens offline in :mod:`repro.obs.export`.

**Clock model.**  The serving stack runs on an explicit clock (virtual trace
seconds in tests/benchmarks, ``time.monotonic`` live), while dispatch is
measured with ``time.perf_counter``.  Every event timestamp lives on the
*serving* clock: lifecycle events pass their ``now`` directly, and wall-clock
emitters (the co-scheduler's launch/gather spans) call :meth:`wall_now`,
which maps ``perf_counter`` through the offset set by :meth:`anchor` at the
enclosing serving event.  Under a virtual clock this anchors real launch
durations at virtual event times — one coherent timeline either way.

**Causal IDs.**  ``next_id()`` hands out monotonically increasing integers
shared by requests, batches, and launches (disjoint by construction), so a
trace can be joined back into submit → batch(roster) → launch → complete
chains; the validator in :mod:`repro.obs.validate` asserts exactly that.

Event phases follow the Chrome ``trace_event`` vocabulary the exporter
targets: ``"i"`` instant, ``"b"``/``"e"`` async span begin/end (async spans
of one category may overlap — requests and depth-k launch rings do),
``"B"``/``"E"`` stack-scoped sync spans, ``"C"`` counter sample.
"""
from __future__ import annotations

import collections
import time

DEFAULT_CAPACITY = 1 << 16

# Host-tagged tracers offset their causal IDs by (host+1)·ID_STRIDE so a
# fleet trace concatenated from per-host buffers never collides request/
# batch/launch IDs across hosts (each host's local sequence stays < stride).
ID_STRIDE = 1 << 40

# Async-span categories with first-class meaning to the exporter/validator.
CAT_REQUEST = "request"
CAT_BATCH = "batch"
CAT_LAUNCH = "launch"


class Tracer:
    """Bounded in-memory event sink for one host's serving stack."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 host: int | None = None):
        if capacity < 1:
            raise ValueError(f"trace capacity must be ≥ 1, got {capacity}")
        self.capacity = capacity
        self.host = host
        self.events: collections.deque = collections.deque(maxlen=capacity)
        self.dropped = 0
        self._id_base = 0 if host is None else (host + 1) * ID_STRIDE
        self._seq = 0
        self._offset = 0.0

    # --- ids + clock ----------------------------------------------------------

    def next_id(self) -> int:
        """A fresh causal ID (requests, batches, and launches share one
        monotone sequence, so IDs never collide across kinds — and host-
        tagged tracers offset by ID_STRIDE so they never collide across a
        fleet either)."""
        self._seq += 1
        return self._id_base + self._seq

    def anchor(self, now: float):
        """Pin the wall clock to the serving clock: subsequent
        :meth:`wall_now` timestamps are ``perf_counter`` re-based so that the
        instant of this call reads ``now``.  Called once per serving event."""
        self._offset = now - time.perf_counter()

    def wall_now(self) -> float:
        """Current wall instant expressed on the serving clock (see anchor)."""
        return time.perf_counter() + self._offset

    # --- event sinks ----------------------------------------------------------

    # The ring holds flat tuples ``(ph, name, ts, track, cat, id, args)`` —
    # the serving hot path pays one tuple build + deque append per event
    # and nothing else; dict rendering happens offline in event_dicts()
    # (the host tag is per-tracer constant, so it is applied there too).

    def emit(self, ph: str, name: str, ts: float, *, cat: str | None = None,
             id: int | None = None, track: str = "serve",
             args: dict | None = None):
        """Generic sink for the rare phases (sync ``B``/``E`` spans)."""
        if len(self.events) == self.capacity:
            self.dropped += 1       # deque evicts the oldest on append
        self.events.append((ph, name, ts, track, cat, id, args))

    def instant(self, name: str, ts: float, *, track: str = "serve",
                args: dict | None = None):
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(("i", name, ts, track, None, None, args))

    def begin(self, cat: str, id: int, name: str, ts: float, *,
              track: str = "serve", args: dict | None = None):
        """Async span begin (spans of one category may overlap)."""
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(("b", name, ts, track, cat, id, args))

    def end(self, cat: str, id: int, name: str, ts: float, *,
            track: str = "serve", args: dict | None = None):
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(("e", name, ts, track, cat, id, args))

    def counter(self, name: str, ts: float, value: float, *,
                track: str = "counters"):
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(("C", name, ts, track, None, None,
                            {"value": value}))

    # --- export surface -------------------------------------------------------

    def _render(self, rec: tuple) -> dict:
        ph, name, ts, track, cat, id, args = rec
        ev = {"ph": ph, "name": name, "ts": ts, "track": track,
              "host": self.host}
        if cat is not None:
            ev["cat"] = cat
        if id is not None:
            ev["id"] = id
        if args:
            ev["args"] = args
        return ev

    def event_dicts(self) -> list[dict]:
        """The buffered events rendered to the dict form the exporter and
        validator consume (offline — never on the serving path)."""
        return [self._render(r) for r in self.events]

    def drain(self) -> list[dict]:
        """Hand the buffered events to the caller and reset the buffer
        (the drop counter survives — it audits the whole run)."""
        out = self.event_dicts()
        self.events.clear()
        return out

    def snapshot(self) -> dict:
        """Ring-buffer audit for the telemetry export."""
        return {"events": len(self.events), "dropped": self.dropped,
                "capacity": self.capacity}
