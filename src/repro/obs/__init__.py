"""repro.obs — unified observability for the serving stack.

Three pillars (the measurement substrate every perf PR is judged against):

* :mod:`tracing`  — low-overhead request-lifecycle tracing: a bounded
  ring-buffer :class:`Tracer` collecting span/instant/counter events with
  causal request/batch/launch IDs, emitted by the server, batcher,
  co-scheduler, and cluster layers (host-tagged in cluster mode);
* :mod:`export`   — Chrome ``trace_event`` / Perfetto rendering of a trace
  (open the JSON in https://ui.perfetto.dev), with per-host process tracks,
  per-class device tracks for launch groups, and counter tracks for queue
  depth / ring depth / controller setpoints;
* :mod:`ledger`   — the live penalty ledger: per-launch modeled-cycle
  attribution into MXU-productive work vs VPU Montgomery-fold stalls
  (arithmetic penalty, paper §7.2) vs M/K padding (spatial penalty, §7.3)
  vs host/gather gaps, published in every telemetry snapshot;
* :mod:`validate` — trace-file schema validator (balanced spans, every
  request reaching a terminal ``complete``/``reject`` event) — the CI
  contract for ``--trace-out`` files, plus the OpenMetrics exposition
  validator backing ``--metrics-out``;
* :mod:`metrics`  — continuous metrics: a collector-driven
  :class:`MetricsRegistry` scraped on a fixed serving-clock cadence into
  bounded time-series rings, exposed as OpenMetrics text (and optionally
  over HTTP in wall-clock mode) — deterministic under the virtual clock;
* :mod:`alerts`   — SLO alerting over the scraped series: multi-window
  multi-burn-rate and threshold rules driving a pending→firing→resolved
  state machine, with firings emitted as Tracer instants on the Perfetto
  timeline.
"""
from repro.obs.alerts import (AlertEngine, BurnRateRule, ThresholdRule,
                              default_cluster_rules, default_serve_rules,
                              merge_alert_sections)
from repro.obs.export import (chrome_trace, read_text, write_chrome_trace,
                              write_text)
from repro.obs.ledger import (PenaltyLedger, launch_cycles,
                              merge_penalty_sections)
from repro.obs.metrics import (MetricsRegistry, expose_registries,
                               serve_metrics_http)
from repro.obs.tracing import Tracer
from repro.obs.validate import validate_chrome_trace, validate_openmetrics

__all__ = [
    "Tracer", "chrome_trace", "write_chrome_trace", "PenaltyLedger",
    "merge_penalty_sections", "launch_cycles", "validate_chrome_trace",
    "validate_openmetrics", "MetricsRegistry", "expose_registries",
    "serve_metrics_http", "AlertEngine", "BurnRateRule", "ThresholdRule",
    "default_serve_rules", "default_cluster_rules", "merge_alert_sections",
    "read_text", "write_text",
]
