"""Chrome ``trace_event`` / Perfetto rendering of a :class:`Tracer` buffer.

The tracer stores neutral event dicts (serving-clock seconds, logical
``track`` names, optional ``host`` tags).  This module maps them onto the
Chrome trace-event JSON object format — open the output file directly in
https://ui.perfetto.dev (or ``chrome://tracing``):

* each **host** becomes one Perfetto *process* (``pid = host + 1``; single-
  host traces use pid 1) named via ``process_name`` metadata;
* each logical **track** ("serve", "batcher", "device", "holdback",
  "counters", …) becomes one *thread* row inside its host process, named via
  ``thread_name`` metadata;
* timestamps convert from serving-clock seconds to integer-ish microseconds
  (the unit Perfetto expects);
* async spans keep their ``cat``/``id`` pair — Perfetto nests same-category
  overlapping spans (depth-k launch rings, concurrent requests) instead of
  corrupting a stack the way sync B/E would.

Export is pure: it never mutates the tracer, so it can run mid-flight.
"""
from __future__ import annotations

import gzip
import json

# Stable thread ordering inside each host process: lifecycle first, then the
# device/dispatch tracks, cluster control (drain barrier, failover spans),
# counters and alerts last.  Unknown tracks sort after these.
_TRACK_ORDER = ("serve", "batcher", "holdback", "device", "cluster",
                "failover", "counters", "alerts")


def open_text(path: str, mode: str = "rt"):
    """Open a text file, transparently gzipped when the path ends in .gz —
    the one place ``--trace-out`` / ``--metrics-out`` compression lives."""
    if str(path).endswith(".gz"):
        return gzip.open(path, mode)
    return open(path, mode.rstrip("t") or "r")


def write_text(path: str, text: str) -> None:
    with open_text(path, "wt") as f:
        f.write(text)


def read_text(path: str) -> str:
    with open_text(path, "rt") as f:
        return f.read()


def _tid(track: str) -> int:
    try:
        return _TRACK_ORDER.index(track) + 1
    except ValueError:
        return len(_TRACK_ORDER) + 1 + (hash(track) % 101)


def chrome_trace(events: list[dict], *, label: str = "repro.serve") -> dict:
    """Render tracer events as a Chrome trace-event JSON object.

    ``events`` is ``Tracer.events`` (or the concatenation of several hosts'
    buffers — each event carries its own ``host`` tag, ``None`` meaning the
    single-host/cluster-control process, which gets pid 1; host h gets
    pid h+2 so host 0 never shares a process with the control track).
    """
    out: list[dict] = []
    seen: set = set()   # (pid, tid) pairs that already have name metadata
    host_names: dict[int, str] = {}
    for ev in events:
        host = ev.get("host")
        pid = 1 if host is None else int(host) + 2
        track = ev.get("track", "serve")
        tid = _tid(track)
        if pid not in host_names:
            host_names[pid] = (label if host is None
                               else f"{label} host {host}")
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": host_names[pid]}})
            out.append({"ph": "M", "name": "process_sort_index", "pid": pid,
                        "tid": 0, "args": {"sort_index": pid}})
        if (pid, tid) not in seen:
            seen.add((pid, tid))
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": track}})
            out.append({"ph": "M", "name": "thread_sort_index", "pid": pid,
                        "tid": tid, "args": {"sort_index": tid}})
        row = {"ph": ev["ph"], "name": ev["name"], "pid": pid, "tid": tid,
               "ts": ev["ts"] * 1e6}
        if "cat" in ev:
            row["cat"] = ev["cat"]
        if "id" in ev:
            row["id"] = ev["id"]
        if ev["ph"] == "i":
            row["s"] = "t"          # thread-scoped instant marker
        if "args" in ev:
            row["args"] = ev["args"]
        out.append(row)
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"label": label}}


def write_chrome_trace(path: str, events: list[dict], *,
                       label: str = "repro.serve") -> dict:
    trace = chrome_trace(events, label=label)
    with open_text(path, "wt") as f:
        json.dump(trace, f)
    return trace
