"""Chrome-trace schema validator — the CI contract for ``--trace-out``.

``validate_chrome_trace`` checks a trace dict (as produced by
:func:`repro.obs.export.chrome_trace`, or ``json.load`` of a trace file)
against the protocol the serving stack emits:

* structural: every event row has ``ph``/``name``/``pid``/``tid``/``ts``
  with a known phase and non-negative timestamp;
* balance: every async ``b`` (cat, id, pid) has a matching ``e`` later in
  the stream; sync ``B``/``E`` pairs nest LIFO per (pid, tid);
* causality: every admitted request (a ``cat="request"`` span) is closed by
  a terminal ``e`` AND chains submit → batch → launch — its rid appears in
  the ``args.rids`` roster of a closed batch span, and that batch id
  appears in a ``launch_batches`` instant naming a launch span.  Rejected
  requests appear only as ``reject`` instants and need no chain.  One
  exemption: requests abandoned by a host failure (their span ends with a
  ``failover`` event and their rid is listed in a ``failover_abandoned``
  instant) must still balance but carry no chain — the replayed request
  opens a fresh span on the surviving host, and *that* span chains.

Violations raise ``ValueError`` with the offending id; success returns a
stats dict (span/chain counts) the smoke tests assert on.

``validate_openmetrics`` plays the same role for ``--metrics-out``: it
parses the OpenMetrics text exposition (backfill flavour — repeated
timestamped samples per series) and asserts family headers, sample syntax,
per-series timestamp monotonicity, counter monotonicity, and the ``# EOF``
terminator.
"""
from __future__ import annotations

import re

_PHASES = {"B", "E", "b", "e", "i", "C", "M"}

_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"          # metric name
    r"(\{[^}]*\})?"                          # optional labels
    r" (-?(?:[0-9.]+(?:[eE][+-]?[0-9]+)?|inf|nan))"   # value
    r"(?: (-?[0-9.]+(?:[eE][+-]?[0-9]+)?))?$")        # optional timestamp


def validate_chrome_trace(trace) -> dict:
    """Accepts the trace dict itself or a path to a trace file (plain or
    ``.gz`` — the ``--trace-out foo.json.gz`` round-trip)."""
    if isinstance(trace, (str, bytes)):
        import json

        from repro.obs.export import open_text
        with open_text(trace, "rt") as f:
            trace = json.load(f)
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a 'traceEvents' list")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")

    opens: dict = {}       # (cat, id, pid) -> open-count for async spans
    spans: dict = {}       # (cat, id) -> {"b": n, "e": n} across hosts
    stacks: dict = {}      # (pid, tid) -> [names] for sync B/E nesting
    enq: dict = {}         # rid -> set of bids (from batch-close rosters)
    launch_of: dict = {}   # bid -> lid (from launch_batches instants)
    requests: set = set()
    abandoned: set = set() # rids closed by host failure (replayed elsewhere)
    rejects = 0

    for i, ev in enumerate(events):
        for field in ("ph", "name", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        ph = ev["ph"]
        if ph not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        if ph == "M":
            continue
        if "ts" not in ev:
            raise ValueError(f"event {i} missing 'ts': {ev}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} has bad ts {ev['ts']!r}")

        if ph in ("b", "e"):
            if "cat" not in ev or "id" not in ev:
                raise ValueError(f"async event {i} missing cat/id: {ev}")
            key = (ev["cat"], ev["id"], ev["pid"])
            rec = spans.setdefault((ev["cat"], ev["id"]), {"b": 0, "e": 0})
            if ph == "b":
                opens[key] = opens.get(key, 0) + 1
                rec["b"] += 1
                if ev["cat"] == "request":
                    requests.add(ev["id"])
            else:
                if opens.get(key, 0) < 1:
                    raise ValueError(
                        f"event {i}: 'e' without open 'b' for {key}")
                opens[key] -= 1
                rec["e"] += 1
                if ev["cat"] == "batch":
                    # the close event carries the batch's request roster —
                    # the submit → batch half of the causal chain
                    for rid in ev.get("args", {}).get("rids", ()):
                        enq.setdefault(rid, set()).add(ev["id"])
        elif ph == "B":
            stacks.setdefault((ev["pid"], ev["tid"]), []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get((ev["pid"], ev["tid"]), [])
            if not stack:
                raise ValueError(f"event {i}: 'E' on empty stack "
                                 f"(pid={ev['pid']}, tid={ev['tid']})")
            stack.pop()
        elif ph == "i":
            args = ev.get("args", {})
            if ev["name"] == "launch_batches":
                for bid in args["bids"]:
                    launch_of[bid] = args["lid"]
            elif ev["name"] == "reject":
                rejects += 1
            elif ev["name"] == "failover_abandoned":
                abandoned.update(args.get("rids", ()))
        elif ph == "C":
            if "value" not in ev.get("args", {}):
                raise ValueError(f"counter event {i} missing args.value")

    unbalanced = [k for k, n in opens.items() if n != 0]
    if unbalanced:
        raise ValueError(f"unbalanced async spans (open 'b' without 'e'): "
                         f"{sorted(unbalanced)[:5]}")
    dangling = [(pt, s) for pt, s in stacks.items() if s]
    if dangling:
        raise ValueError(f"unclosed sync spans: {dangling[:5]}")

    # Causal chain: every admitted request reaches a terminal complete via
    # a batch-roster → launch link.
    for rid in sorted(requests):
        rec = spans[("request", rid)]
        if rec["e"] < rec["b"]:
            raise ValueError(f"request {rid} never completed")
        if rid in abandoned:
            continue       # chain continues on the survivor's replay span
        bids = enq.get(rid)
        if not bids:
            raise ValueError(f"request {rid} has no enqueue link to a batch "
                             f"(no closed batch span lists it in args.rids)")
        for bid in bids:
            brec = spans.get(("batch", bid))
            if brec is None or brec["e"] < brec["b"]:
                raise ValueError(f"request {rid}: batch {bid} span "
                                 f"missing or unclosed")
            lid = launch_of.get(bid)
            if lid is None:
                raise ValueError(f"request {rid}: batch {bid} never "
                                 f"reached a launch")
            lrec = spans.get(("launch", lid))
            if lrec is None or lrec["e"] < lrec["b"]:
                raise ValueError(f"request {rid}: launch {lid} span "
                                 f"missing or unclosed")

    n_cat = lambda c: sum(1 for (cat, _), r in spans.items()
                          if cat == c and r["b"] > 0)
    return {
        "events": len(events),
        "requests": len(requests),
        "rejects": rejects,
        "batches": n_cat("batch"),
        "launches": n_cat("launch"),
    }


def validate_openmetrics(text: str) -> dict:
    """Validate an OpenMetrics exposition (see module docstring); pass a
    path (plain or ``.gz``) instead of text to validate a ``--metrics-out``
    file from disk.  Returns ``{"families", "series", "samples"}``."""
    if "\n" not in text and (text.endswith(".gz") or text.endswith(".om")
                             or text.endswith(".txt")
                             or not text.lstrip().startswith("#")):
        from repro.obs.export import read_text
        text = read_text(text)
    lines = text.splitlines()
    if not lines or lines[-1] != "# EOF":
        raise ValueError("exposition must terminate with '# EOF'")
    kinds: dict[str, str] = {}
    last_ts: dict[tuple, float] = {}
    last_val: dict[tuple, float] = {}
    samples = 0
    for i, line in enumerate(lines[:-1]):
        if not line:
            raise ValueError(f"line {i}: empty line inside exposition")
        if line.startswith("#"):
            parts = line.split(" ", 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(f"line {i}: bad comment line {line!r}")
            if parts[1] == "TYPE":
                name, kind = parts[2], parts[3] if len(parts) > 3 else ""
                if kind not in ("counter", "gauge", "histogram", "summary",
                                "unknown"):
                    raise ValueError(f"line {i}: unknown TYPE {kind!r}")
                if name in kinds:
                    raise ValueError(f"line {i}: duplicate TYPE for {name}")
                kinds[name] = kind
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {i}: unparseable sample {line!r}")
        name, labels, value, ts = m.groups()
        if name not in kinds:
            raise ValueError(f"line {i}: sample for {name} precedes its "
                             f"'# TYPE' header")
        samples += 1
        key = (name, labels or "")
        if ts is not None:
            t = float(ts)
            if key in last_ts and t <= last_ts[key]:
                raise ValueError(f"line {i}: non-increasing timestamp for "
                                 f"{key}: {t} after {last_ts[key]}")
            last_ts[key] = t
        v = float(value)
        if kinds[name] == "counter":
            if key in last_val and v < last_val[key]:
                raise ValueError(f"line {i}: counter {key} decreased "
                                 f"({last_val[key]} -> {v})")
            last_val[key] = v
    return {"families": len(kinds),
            "series": len(set(last_ts) | set(last_val)),
            "samples": samples}
