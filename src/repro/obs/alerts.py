"""SLO alerting over scraped series: multi-window burn rates + thresholds.

Rules evaluate against a `MetricsRegistry` ring at scrape cadence and drive a
``inactive → pending → firing → resolved`` state machine per rule.  Burn-rate
rules follow the multi-window multi-burn-rate pattern: each ``(long_s,
short_s, factor)`` window pair demands the error-budget burn exceed ``factor``
over *both* the long window (sustained burn) and the short window (still
burning now); pairs are OR-ed so a fast pair pages on hard overload while a
slow pair catches low-grade budget leaks.  Transitions append to a bounded
event log and are emitted as Tracer instants on the ``alerts`` track, so
firings land on the Perfetto timeline next to the dispatch spans that caused
them.

Everything here is driven off the serving clock — with a deterministic
registry (see `repro.obs.metrics`), two identical runs produce bit-identical
alert event logs.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

INACTIVE = "inactive"
PENDING = "pending"
FIRING = "firing"


def _series_ref(ref):
    name, labels = ref
    return name, labels


@dataclass(frozen=True)
class ThresholdRule:
    """Fire when ``series <op> value`` holds continuously for ``for_s``.

    ``series`` is ``(metric_name, labels)``.  A missing series means the
    signal is undefined (e.g. occupancy before the first dispatch) — the rule
    stays inactive rather than firing on an absent denominator.
    """

    name: str
    series: tuple
    op: str
    value: float
    for_s: float = 0.0
    severity: str = "page"

    def __post_init__(self):
        if self.op not in (">", "<"):
            raise ValueError(f"threshold op must be '>' or '<': {self.op!r}")

    def observed(self, registry, now):
        del now
        sname, labels = _series_ref(self.series)
        return registry.latest(sname, labels)

    def condition(self, registry, now):
        v = self.observed(registry, now)
        if v is None:
            return False, None
        hit = v > self.value if self.op == ">" else v < self.value
        return hit, v


@dataclass(frozen=True)
class BurnRateRule:
    """Multi-window multi-burn-rate over a ratio of two counter series.

    ``num`` / ``den`` are ``(metric_name, labels)`` counter references;
    ``budget`` is the error budget as a fraction (0.05 = 5% of events may be
    bad); ``windows`` is a tuple of ``(long_s, short_s, factor)`` pairs.
    Burn over a window W is ``(Δnum/Δden) / budget`` using ring deltas
    clamped to the oldest retained sample.
    """

    name: str
    num: tuple
    den: tuple
    budget: float
    windows: tuple = field(default_factory=tuple)
    for_s: float = 0.0
    severity: str = "page"

    def __post_init__(self):
        if not 0.0 < self.budget <= 1.0:
            raise ValueError(f"budget must be in (0, 1]: {self.budget}")
        if not self.windows:
            raise ValueError("burn-rate rule needs at least one window pair")

    def burn(self, registry, now, window_s: float):
        nname, nlabels = _series_ref(self.num)
        dname, dlabels = _series_ref(self.den)
        dn = registry.window_delta(nname, nlabels, now, window_s)
        dd = registry.window_delta(dname, dlabels, now, window_s)
        if dn is None or dd is None or dd[0] <= 0:
            return None
        return (dn[0] / dd[0]) / self.budget

    def condition(self, registry, now):
        worst = None
        hit = False
        for long_s, short_s, factor in self.windows:
            b_long = self.burn(registry, now, long_s)
            b_short = self.burn(registry, now, short_s)
            if b_long is None or b_short is None:
                continue
            pair = min(b_long, b_short)
            if worst is None or pair > worst:
                worst = pair
            if b_long > factor and b_short > factor:
                hit = True
        return hit, worst


class AlertEngine:
    """Pending→firing→resolved state machine over a rule set.

    ``evaluate(now)`` is called right after each scrape.  Transitions:

    - condition becomes true  → ``pending`` (logged);
    - pending held ``for_s``  → ``firing`` (logged + tracer instant);
    - pending, condition false → ``cancelled`` (back to inactive);
    - firing, condition false → ``resolved`` (logged + tracer instant).

    The event log is a bounded ring; totals survive eviction.
    """

    def __init__(self, registry, rules, *, tracer=None, capacity: int = 1024,
                 host: int | None = None):
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate alert rule names: {names}")
        self.registry = registry
        self.rules = tuple(rules)
        self.tracer = tracer
        self.host = host
        self.log = deque(maxlen=int(capacity))
        self.events_total = 0
        self.fired = {r.name: 0 for r in self.rules}
        self.resolved = {r.name: 0 for r in self.rules}
        self._state = {r.name: {"state": INACTIVE, "since": None, "value": None}
                       for r in self.rules}

    # --- transitions ---------------------------------------------------------

    def _log(self, now, rule, transition, value):
        event = {"ts": float(now), "rule": rule.name,
                 "transition": transition,
                 "value": None if value is None else float(value)}
        self.log.append(event)
        self.events_total += 1
        if transition == "firing":
            self.fired[rule.name] += 1
        elif transition == "resolved":
            self.resolved[rule.name] += 1
        if self.tracer is not None and transition in ("firing", "resolved"):
            self.tracer.instant(f"alert_{transition}:{rule.name}", now,
                                track="alerts",
                                args={"rule": rule.name,
                                      "severity": rule.severity,
                                      "value": event["value"]})
        return event

    def evaluate(self, now: float) -> list:
        """Evaluate every rule at ``now``; returns this call's transitions."""
        out = []
        for rule in self.rules:
            st = self._state[rule.name]
            hit, value = rule.condition(self.registry, now)
            st["value"] = value
            if st["state"] == INACTIVE:
                if hit:
                    st["state"] = PENDING
                    st["since"] = float(now)
                    out.append(self._log(now, rule, "pending", value))
            if st["state"] == PENDING:
                if not hit:
                    st["state"] = INACTIVE
                    st["since"] = None
                    out.append(self._log(now, rule, "cancelled", value))
                elif now - st["since"] >= rule.for_s:
                    st["state"] = FIRING
                    out.append(self._log(now, rule, "firing", value))
            elif st["state"] == FIRING and not hit:
                st["state"] = INACTIVE
                st["since"] = None
                out.append(self._log(now, rule, "resolved", value))
        return out

    # --- introspection -------------------------------------------------------

    def state(self, rule_name: str) -> str:
        return self._state[rule_name]["state"]

    def snapshot(self) -> dict:
        return {
            "rules": {
                r.name: {
                    "state": self._state[r.name]["state"],
                    "since": self._state[r.name]["since"],
                    "last_value": self._state[r.name]["value"],
                    "severity": r.severity,
                    "fired": self.fired[r.name],
                    "resolved": self.resolved[r.name],
                }
                for r in self.rules
            },
            "events_total": self.events_total,
            "log": list(self.log),
        }


def default_serve_rules(*, max_age_s: float, slo_deadline_s: float | None = None):
    """The stock single-host rule set, scaled off the batcher age trigger.

    - ``slo_burn``: admission SLO-miss rate burn (fast pair pages on hard
      overload, slow pair catches sustained low-grade rejection);
    - ``p99_latency``: request latency ceiling;
    - ``m_occupancy_floor``: the paper's M-axis collapse, live;
    - ``arithmetic_stall_share``: Montgomery-fold stall cycles dominating the
      modeled-cycle budget.
    """
    ma = float(max_age_s)
    lat_ceiling = 5.0 * slo_deadline_s if slo_deadline_s is not None else 50.0 * ma
    return (
        BurnRateRule(
            name="slo_burn",
            num=("repro_admission_slo_miss_total", ()),
            den=("repro_admission_decisions_total", ()),
            budget=0.05,
            windows=((10.0 * ma, 2.5 * ma, 8.0), (40.0 * ma, 10.0 * ma, 2.0)),
        ),
        ThresholdRule(
            name="p99_latency",
            series=("repro_latency_seconds", (("q", "p99"),)),
            op=">", value=lat_ceiling, for_s=2.0 * ma,
        ),
        ThresholdRule(
            name="m_occupancy_floor",
            series=("repro_dispatch_m_occupancy", ()),
            op="<", value=0.02, for_s=20.0 * ma, severity="ticket",
        ),
        ThresholdRule(
            name="arithmetic_stall_share",
            series=("repro_penalty_arithmetic_stall_share", ()),
            op=">", value=0.9, for_s=20.0 * ma, severity="ticket",
        ),
    )


def default_cluster_rules(*, staleness_bound_s: float,
                          shed_budget: float = 0.05):
    """Fleet-level rules: a silent host is a dead host (detection — the
    failover coordinator cordons on the same signal), plus the recovery
    side: ``failover_shed`` burns when the redistribution transient sheds
    more than ``shed_budget`` of cluster ingress (both counters come from
    the coordinator; absent series — no failover layer — keep it inactive).
    """
    bound = float(staleness_bound_s)
    return (
        ThresholdRule(
            name="gossip_silence",
            series=("repro_gossip_silence_seconds_max", ()),
            op=">", value=bound, for_s=0.0,
        ),
        ThresholdRule(
            name="gossip_staleness",
            series=("repro_gossip_used_staleness_seconds_max", ()),
            op=">", value=0.8 * bound, for_s=0.0, severity="ticket",
        ),
        BurnRateRule(
            name="failover_shed",
            num=("repro_cluster_sheds_total", ()),
            den=("repro_cluster_ingress_total", ()),
            budget=shed_budget,
            windows=((8.0 * bound, 2.0 * bound, 2.0),),
        ),
    )


def merge_alert_sections(sections) -> dict:
    """Merge per-host `AlertEngine.snapshot()` dicts for fleet telemetry:
    per-rule fired/resolved totals summed, a census of hosts currently
    firing, and the union event count."""
    sections = [s for s in sections if s]
    if not sections:
        return {}
    rules: dict[str, dict] = {}
    for snap in sections:
        for name, st in snap.get("rules", {}).items():
            agg = rules.setdefault(name, {"fired": 0, "resolved": 0,
                                          "hosts_firing": 0,
                                          "severity": st.get("severity")})
            agg["fired"] += st.get("fired", 0)
            agg["resolved"] += st.get("resolved", 0)
            if st.get("state") == FIRING:
                agg["hosts_firing"] += 1
    return {
        "rules": rules,
        "events_total": sum(s.get("events_total", 0) for s in sections),
        "hosts": len(sections),
    }
