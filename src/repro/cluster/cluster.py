"""Multi-host sharded serving: the cluster event loop.

``ClusterServer`` shards :class:`repro.serve.CryptoServer` across N
simulated host slices, each owning its own
:class:`~repro.core.scheduler.coscheduler.SliceCoScheduler` (its own
engines, compiled-program cache, and device-group assignment):

    submit ──▶ tenant-hash router ──▶ host h: admission ──▶ batcher ──▶
                    │                      ▲       ▲         dispatch
                    │                      │       │ adaptive controller
                    │                      │       │ (close policy setpoint)
                    └── gossip bus ────────┴───────┘ per-host-equivalent
                        cluster depth (bounded staleness)

With ``ServeConfig.controller`` each host runs its own adaptive occupancy
controller, but the gossiped per-host-equivalent cluster depth folds into
every host's setpoint: a host whose local queue looks shallow still raises
its target rung when the fleet is deep, because merge partners routed to it
are already en route.

The cluster exposes the same explicit-clock surface as a single server
(``submit(req, now)`` / ``pump(now)`` / ``next_deadline()`` /
``drain(now)``), so the existing :class:`repro.serve.LoadGenerator` drives
an N-host cluster unchanged, deterministically, under the virtual clock.

**Drain barrier.**  ``drain`` is two-phase: first *every* host is quiesced
(ingress rejected fleet-wide), only then is any host flushed, and finally
the barrier record is collected into telemetry.  Quiescing all before
flushing any means no request can slip onto an already-drained host, so a
cluster drain yields bit-for-bit the same per-tenant results as a
single-host replay of the same trace (row semantics make each tenant's
arithmetic independent of batch composition; the router only changes the
grouping).
"""
from __future__ import annotations

import dataclasses
import json
import time

from repro.obs.alerts import AlertEngine, default_cluster_rules
from repro.obs.export import write_chrome_trace, write_text
from repro.obs.metrics import MetricsRegistry, expose_registries
from repro.obs.tracing import Tracer
from repro.core.scheduler.coscheduler import partition_devices
from repro.serve.server import (CryptoServer, ResponseHandle, ServeConfig,
                                coscheduler_from_config)
from repro.serve.telemetry import DispatchOverlapAuditor
from repro.cluster.failover import FailoverCoordinator, FaultPlan
from repro.cluster.gossip import GossipBus
from repro.cluster.router import TenantHashRouter
from repro.cluster.telemetry import merge_snapshots


@dataclasses.dataclass
class ClusterConfig:
    n_hosts: int = 2
    gossip_period_s: float = 0.002
    gossip_staleness_factor: float = 2.0   # digest usable for period × factor
    pinned: dict | None = None             # tenant_id -> host overrides
    # Deterministic fault injection: a FaultPlan (or a parseable
    # "kill@T:hN,..." spec with times in absolute virtual-clock seconds —
    # CLI front-ends pre-scale fraction-of-duration specs) applied on the
    # tick edge.  None serves failure-free.
    fault_plan: FaultPlan | str | None = None
    # Watermark-based load shedding during a failover redistribution
    # transient: fraction of serve.max_pending above which a tenant's owner
    # is considered saturated — non-sticky tenants divert power-of-two to
    # their rendezvous alternate, the rest shed with reason "shed".  None
    # (default) never sheds.
    shed_watermark: float | None = None
    shed_transient_s: float | None = None  # None → 2 × staleness bound
    # Device-parallel fleet: partition the process's JAX devices across the
    # host slices (coscheduler.partition_devices) and pin each host's
    # compiled programs, operands, and twiddle planes to its own slice, so
    # host i's launches queue behind host i's — not the whole fleet's.
    # False (default) keeps the single-queue simulated mode, the
    # deterministic oracle device mode is proven bit-for-bit against.
    device_parallel: bool = False
    serve: ServeConfig = dataclasses.field(default_factory=ServeConfig)


class ClusterServer:
    """N host slices behind one tenant-hash ingress.

    ``coscheduler_factory(host_id)`` overrides per-host co-scheduler
    construction — tests use it to share one compiled-program cache across
    hosts (bit-identical results, minutes less XLA compile time); production
    construction gives every host its own.
    """

    def __init__(self, config: ClusterConfig | None = None, *,
                 coscheduler_factory=None):
        self.config = cfg = config or ClusterConfig()
        self.router = TenantHashRouter(cfg.n_hosts, pinned=cfg.pinned)
        self.gossip = GossipBus(cfg.n_hosts, period_s=cfg.gossip_period_s,
                                staleness_factor=cfg.gossip_staleness_factor)
        self.hosts: list[CryptoServer] = []
        # Device partition: host h's slice of the process's devices (None
        # columns in simulated mode).  A coscheduler_factory overrides cos
        # construction entirely — a device-parallel factory is expected to
        # pin its own devices (the bench shares one pinned co-scheduler per
        # device to keep compile time linear in devices, not hosts).
        self.device_partition = (partition_devices(cfg.n_hosts)
                                 if cfg.device_parallel else None)
        # One fleet-wide launch-overlap auditor across every host: the
        # device-pinning audit trail (per-host device ids, launch
        # concurrency, cross-host queue sharing) in snapshot().
        self.dispatch_audit = DispatchOverlapAuditor()
        for h in range(cfg.n_hosts):
            if coscheduler_factory is not None:
                cos = coscheduler_factory(h)
            else:
                # Each host gets the full dispatch fast path (super-batching,
                # row ladder, donation) from the shared serve config.
                cos = coscheduler_from_config(
                    cfg.serve, host=h,
                    devices=(self.device_partition[h]
                             if self.device_partition else None))
            srv = CryptoServer(cfg.serve, coscheduler=cos)
            srv.host_id = h
            srv.dispatch_auditor = self.dispatch_audit
            srv.cluster_depth_fn = self._make_depth_fn(h)
            if srv.tracer is not None and srv.tracer.host is None:
                # A factory-built co-scheduler may not carry its host id;
                # tag the tracer here so fleet-trace events keep their
                # per-host process track.  (Note: sharing ONE co-scheduler
                # across hosts also shares its tracer hook — last host
                # wins — so traced clusters should use per-host
                # co-schedulers, the default construction.)
                srv.tracer.host = h
            if srv.metrics is not None and srv.metrics.host is None:
                # Same backfill for the metrics registry: the host label is
                # what keeps per-host series distinguishable (and the fleet
                # exposition parseable) after the registries merge.
                srv.metrics.host = h
                srv.alerts.host = h
            self.hosts.append(srv)
        self._submissions = [0] * cfg.n_hosts
        self._barrier: dict | None = None
        # Cluster-control tracer (host=None → its own Perfetto process):
        # carries the drain-barrier span over the fleet timeline.
        self.tracer = Tracer(host=None) if cfg.serve.tracing else None
        # Fleet-level metrics + alerting: per-host registries come with the
        # shared serve config; this registry (host=None, like the control
        # tracer) holds the gossip-side series — publish/view audit, per-host
        # publish silence — and its engine runs the dead-host sensing rules.
        self.metrics = None
        self.alerts = None
        if cfg.serve.metrics:
            self.metrics = MetricsRegistry(
                period_s=cfg.serve.metrics_period_s,
                capacity=cfg.serve.metrics_capacity, host=None)
            self._describe_metrics()
            self.metrics.add_collector(self._metrics_samples)
            self.alerts = AlertEngine(
                self.metrics,
                default_cluster_rules(
                    staleness_bound_s=self.gossip.staleness_bound_s),
                tracer=self.tracer, host=None)
        # Failure handling: fault injection, silence-driven cordon, journal
        # replay, transient shedding (repro.cluster.failover).
        plan = cfg.fault_plan
        if isinstance(plan, str):
            plan = FaultPlan.parse(plan)
        self.failover = FailoverCoordinator(
            self, plan, shed_watermark=cfg.shed_watermark,
            shed_transient_s=cfg.shed_transient_s)

    # --- gossip wiring --------------------------------------------------------

    def _make_depth_fn(self, host_id: int):
        def depth_fn(now: float) -> float:
            # pending_load, not batcher.depth: held and in-flight rows
            # occupy the slice just as queued ones do (holdback-aware
            # admission), locally and in the published digests alike.
            view = self.gossip.cluster_view(
                host_id, self.hosts[host_id].pending_load, now)
            return view.per_host_equiv
        return depth_fn

    def _tick(self, now: float):
        """One fleet control edge: apply due fault-plan events, run every
        due gossip publish (period-gated, *serving* hosts only — a killed
        or paused host is exactly a host that stops publishing), then
        silence-driven cordon sensing and the fleet metrics scrape."""
        self.failover.apply_due(now)
        for h, srv in enumerate(self.hosts):
            if self.failover.publishing(h):
                if self.gossip.maybe_publish(
                        h, srv.pending_load, now,
                        open_batches=srv.batcher.open_batches):
                    self.failover.journals[h].compact()
        self.failover.sense(now)
        if self.metrics is not None and self.metrics.maybe_scrape(now):
            self.alerts.evaluate(now)

    # --- fleet metrics --------------------------------------------------------

    def _describe_metrics(self):
        m = self.metrics
        m.describe("repro_gossip_publishes_total", "counter",
                   "Digest publishes across the fleet.")
        m.describe("repro_gossip_views_total", "counter",
                   "Bounded-staleness view merges.")
        m.describe("repro_gossip_stale_drops_total", "counter",
                   "Digests dropped at read time for exceeding the bound.")
        m.describe("repro_gossip_silence_seconds", "gauge",
                   "Per-host publish silence (dead-host sensing signal).")
        m.describe("repro_gossip_silence_seconds_max", "gauge",
                   "Worst publish silence across the fleet.")
        m.describe("repro_gossip_used_staleness_seconds_max", "gauge",
                   "Oldest digest any decision actually consumed.")
        m.describe("repro_cluster_queue_rows", "gauge",
                   "Fleet pending load (sum of per-host pending_load).")
        m.describe("repro_cluster_ingress_total", "counter",
                   "Requests tagged at cluster ingress (failover rids).")
        m.describe("repro_cluster_sheds_total", "counter",
                   "Requests shed during a failover redistribution "
                   "transient (burn-rate numerator for failover_shed).")
        m.describe("repro_cluster_replayed_total", "counter",
                   "Journal entries replayed onto survivors after a cordon.")
        m.describe("repro_cluster_live_hosts", "gauge",
                   "Hosts currently in the rendezvous live set.")
        m.describe("repro_cluster_limbo_requests", "gauge",
                   "Requests parked for a dead-but-uncordoned owner.")

    def _metrics_samples(self, now: float):
        bus = self.gossip
        out = [
            ("repro_gossip_publishes_total", (), bus.publishes),
            ("repro_gossip_views_total", (), bus.views),
            ("repro_gossip_stale_drops_total", (), bus.stale_drops),
            ("repro_gossip_used_staleness_seconds_max", (),
             bus._used_staleness_max),
            ("repro_cluster_queue_rows", (),
             sum(srv.pending_load for srv in self.hosts)),
            ("repro_cluster_ingress_total", (), self.failover.ingress),
            ("repro_cluster_sheds_total", (), self.failover.sheds),
            ("repro_cluster_replayed_total", (), self.failover.replayed),
            ("repro_cluster_live_hosts", (), len(self.router.live_hosts)),
            ("repro_cluster_limbo_requests", (), len(self.failover.limbo)),
        ]
        silence = bus.silence_s(now)
        if silence:
            for hid, age in silence.items():
                out.append(("repro_gossip_silence_seconds",
                            (("peer", str(hid)),), age))
            out.append(("repro_gossip_silence_seconds_max", (),
                        max(silence.values())))
        return out

    def metrics_text(self) -> str:
        """One OpenMetrics document for the fleet: per-host registries
        (samples host-labelled) merged with the cluster-level registry."""
        if self.metrics is None:
            raise RuntimeError("metrics are off — set ServeConfig(metrics="
                               "True) in the cluster config")
        regs = [srv.metrics for srv in self.hosts if srv.metrics is not None]
        regs.append(self.metrics)
        return expose_registries(regs)

    def write_metrics(self, path: str) -> str:
        """Write the fleet exposition (gzip when path ends in .gz)."""
        text = self.metrics_text()
        write_text(path, text)
        return text

    # --- the CryptoServer-shaped surface --------------------------------------

    def submit(self, req, now: float | None = None):
        now = time.monotonic() if now is None else now
        self._tick(now)
        self.failover.tag(req)
        return self._submit_routed(req, now)

    def _submit_routed(self, req, now: float,
                       handle: ResponseHandle | None = None):
        """Route one tagged request through the failover coordinator and
        land it: on its owner host (journaled when admitted), in the limbo
        retry queue (owner dead, cordon pending), or shed.  ``handle``
        threads an existing caller handle through a limbo re-delivery."""
        kind, host, decision = self.failover.route(req, now)
        if kind == "host":
            self._submissions[host] += 1
            h = self.hosts[host].submit(req, now=now, handle=handle)
            if not h.rejected:
                self.failover.journals[host].record(
                    rid=req.request_id, tenant_id=req.tenant_id,
                    request=req, handle=h, reason="ok", recorded_at=now)
            return h
        if handle is None:
            handle = ResponseHandle(req, submitted_at=now)
        if kind == "limbo":
            self.failover.hold_limbo(host, req, handle)
        else:  # shed
            handle._reject(decision, at=now)
            self.failover.note_shed(host, req, now)
        return handle

    def submit_many(self, reqs, now: float | None = None, nows=None):
        """Batch ingress: shard one arrival batch by the rendezvous router
        and feed each host's share through its vectorised ``submit_many``
        edge (arrival order preserved within a host; handles returned in the
        original batch order).  Requests routed to limbo or shed by the
        failover coordinator are pulled out of the batch individually."""
        now = time.monotonic() if now is None else now
        if nows is None:
            nows = [now] * len(reqs)
        self._tick(float(nows[0]) if len(reqs) else now)
        shard_pos: dict[int, list[int]] = {}
        handles = [None] * len(reqs)
        for p, req in enumerate(reqs):
            self.failover.tag(req)
            kind, host, decision = self.failover.route(req, float(nows[p]))
            if kind == "host":
                shard_pos.setdefault(host, []).append(p)
                continue
            t = float(nows[p])
            handle = ResponseHandle(req, submitted_at=t)
            if kind == "limbo":
                self.failover.hold_limbo(host, req, handle)
            else:
                handle._reject(decision, at=t)
                self.failover.note_shed(host, req, t)
            handles[p] = handle
        for host, positions in shard_pos.items():
            self._submissions[host] += len(positions)
            hs = self.hosts[host].submit_many(
                [reqs[p] for p in positions],
                nows=[nows[p] for p in positions])
            journal = self.failover.journals[host]
            for p, h in zip(positions, hs):
                handles[p] = h
                if not h.rejected:
                    journal.record(
                        rid=reqs[p].request_id, tenant_id=reqs[p].tenant_id,
                        request=reqs[p], handle=h, reason="ok",
                        recorded_at=float(nows[p]))
        return handles

    def pump(self, now: float | None = None) -> int:
        now = time.monotonic() if now is None else now
        self._tick(now)
        return sum(srv.pump(now) for h, srv in enumerate(self.hosts)
                   if self.failover.serving(h))

    def next_deadline(self) -> float | None:
        # A dead host's deadlines are unreachable until it recovers — the
        # pump loop must not spin on them (its queued work is replayed or
        # recovered at cordon).
        deadlines = [d for h, srv in enumerate(self.hosts)
                     if self.failover.serving(h)
                     and (d := srv.next_deadline()) is not None]
        return min(deadlines) if deadlines else None

    @property
    def under_backpressure(self) -> bool:
        return any(srv.under_backpressure
                   for h, srv in enumerate(self.hosts)
                   if self.failover.serving(h))

    def drain(self, now: float | None = None) -> int:
        """Distributed two-phase drain barrier (see module docstring).

        Failure-aware: fault-plan events scripted *before* the drain
        instant apply pre-barrier (and any dead host is force-cordoned —
        the barrier's flush RPC fails fast, a stronger signal than gossip
        silence); an event scripted at exactly the drain instant lands
        *mid*-barrier, between quiesce and flush, and its journal is
        replayed onto the (already-draining) survivors so the barrier
        still completes with every admitted request resolved."""
        now = time.monotonic() if now is None else now
        fo = self.failover
        # Pre-barrier tick: strictly-earlier fault events, gossip, sensing.
        fo.apply_due(now, inclusive=False)
        for h, srv in enumerate(self.hosts):
            if fo.publishing(h):
                self.gossip.maybe_publish(
                    h, srv.pending_load, now,
                    open_batches=srv.batcher.open_batches)
        fo.sense(now)
        fo.cordon_dead(now)
        if self.tracer is not None:
            self.tracer.emit("B", "drain_barrier", now, track="cluster",
                             args={"hosts": len(self.hosts)})
        # Phase 1 — quiesce: fleet-wide ingress stop before any flush
        # (paused hosts are reachable on the data plane and quiesce too).
        for h, srv in enumerate(self.hosts):
            if fo.serving(h):
                srv.quiesce(now)
        self._barrier = {"quiesced_at": now,
                         "hosts": len(self.hosts),
                         "complete": False}
        # Mid-barrier seam: a kill scripted at the drain instant fires
        # here, after quiesce — its journal replays onto survivors whose
        # ingress is already stopped (replay_admitted bypasses draining).
        fo.apply_due(now)
        fo.cordon_dead(now, cause="drain_probe")
        # Phase 2 — drain: flush every live host's open batches, holdback
        # pens, and launch rings (depth-k flights retired inside srv.drain).
        flushed = sum(srv.drain(now) for h, srv in enumerate(self.hosts)
                      if fo.serving(h))
        # Phase 3 — collect: the barrier record lands in telemetry.  The
        # in-flight census is the ring-drain audit — a complete barrier must
        # leave zero launch groups outstanding on any host (a reset dead
        # host holds none by construction).
        self._barrier.update(
            drained_at=now, batches_flushed=flushed,
            serving_hosts=sum(1 for h in range(len(self.hosts))
                              if fo.serving(h)),
            inflight_groups=sum(srv.inflight_groups for srv in self.hosts),
            complete=True)
        if self.tracer is not None:
            self.tracer.emit("E", "drain_barrier", now, track="cluster",
                             args={"batches_flushed": flushed})
        # Terminal fleet scrape: the post-drain state (zero in-flight, final
        # silence ages) is always sampled, mirroring each host's own drain
        # scrape (a same-instant repeat is a no-op by ring monotonicity).
        if self.metrics is not None and self.metrics.scrape(now):
            self.alerts.evaluate(now)
        return flushed

    @property
    def drained(self) -> bool:
        return bool(self._barrier and self._barrier["complete"])

    # --- telemetry ------------------------------------------------------------

    def snapshot(self, include_samples: bool = False) -> dict:
        """Cluster snapshot: merged fleet metrics + per-host + gossip audit.

        Per-host snapshots always carry raw samples internally so the merged
        quantiles are exact; ``include_samples`` controls whether they stay
        in the exported per-host sections.
        """
        host_snaps = [srv.telemetry.snapshot(include_samples=True)
                      for srv in self.hosts]
        merged = merge_snapshots(host_snaps)
        if not include_samples:
            for snap in host_snaps:
                snap["latency"].pop("samples", None)
                snap["queue_wait"].pop("samples", None)
        out = {
            "n_hosts": len(self.hosts),
            "merged": merged,
            "per_host": host_snaps,
            "gossip": self.gossip.snapshot(),
            "routing": {
                "per_host_submissions": list(self._submissions),
                "pinned_tenants": len(self.router.pinned),
                "live_hosts": list(self.router.live_hosts),
            },
            "failover": self.failover.snapshot(),
            "drain_barrier": self._barrier,
            "devices": {
                "device_parallel": bool(self.config.device_parallel),
                "per_host": [list(srv.cos.device_ids())
                             for srv in self.hosts],
                "distinct": len({d for srv in self.hosts
                                 for d in srv.cos.device_ids()}),
            },
            "dispatch_overlap": self.dispatch_audit.snapshot(),
        }
        if self.metrics is not None:
            out["cluster_metrics"] = self.metrics.snapshot()
            out["cluster_alerts"] = self.alerts.snapshot()
        return out

    def write_json(self, path: str, include_samples: bool = False) -> dict:
        snap = self.snapshot(include_samples=include_samples)
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
        return snap

    # --- fleet trace ----------------------------------------------------------

    def trace_events(self) -> list[dict]:
        """One merged fleet trace: every host's buffered events (host-tagged,
        so each host keeps its own Perfetto process track) plus the cluster-
        control events, in timestamp order."""
        events = [] if self.tracer is None else self.tracer.event_dicts()
        for srv in self.hosts:
            events.extend(srv.trace_events())
        # Per-host streams stay in emission order (span begins precede their
        # ends); Perfetto orders by timestamp itself, so no global sort that
        # could interleave a sub-µs-inverted begin/end pair.
        return events

    def write_trace(self, path: str) -> dict:
        """Export the merged fleet trace as Chrome-trace JSON."""
        if self.tracer is None:
            raise RuntimeError("tracing is off — set ServeConfig(tracing="
                               "True) in the cluster config to record")
        return write_chrome_trace(path, self.trace_events(),
                                  label="repro.cluster")
