"""Host-failure recovery: deterministic fault injection, cordon, replay.

The cluster layer could *sense* a dead host (``GossipBus.silence_s`` + the
``gossip_silence`` alert); this module makes the fleet *survive* one, with
every step driven by the same virtual clock as the servers so chaos runs
are bit-reproducible:

* :class:`FaultPlan` — scripted ``kill`` / ``pause`` / ``recover`` events
  per host, parsed from ``kill@T:hN,recover@T:hN,...`` specs and applied on
  the ``ClusterServer._tick`` edge (an event is never applied twice, and
  two runs of the same plan on the same trace produce identical fleets);
* :class:`IntakeJournal` — the per-host append-only record of
  admitted-but-undispatched requests (request id, tenant, payload ref,
  admission decision).  The journal is the durability boundary: host RAM
  (open batches, launch rings) dies with the host, the journal does not;
* :class:`FailoverCoordinator` — the control loop: routes ingress around
  known-dead hosts (a limbo retry queue models the LB's failed connection),
  cordons a host when its gossip silence crosses the staleness bound,
  rescues completed-but-ungathered results from the dead host's launch
  rings, **replays** its journal's still-pending entries onto the
  survivors chosen by rendezvous order (idempotently — request-id dedup at
  ``CryptoServer.submit`` edges makes double-delivery harmless), and sheds
  load during the redistribution transient via watermark-gated
  power-of-two-choices on the gossip digest, bounded by tenant stickiness.

Failure semantics, precisely:

* ``kill``  — the host process dies: it stops publishing digests, stops
  serving, and loses all in-memory state.  Its journal and its gather ring
  (device-side results of already-launched groups) survive and are
  recovered at cordon; on ``recover`` the host rejoins empty.
* ``pause`` — a gossip-plane partition only: the host stops publishing but
  keeps serving the requests it holds.  Silence still crosses the bound,
  so the fleet cordons it (new arrivals re-route), but nothing is replayed
  — its in-flight work completes locally and ``recover`` rejoins it with
  state intact.
* ``recover`` — the host publishes a fresh digest immediately (the rejoin
  announce — this is what resolves the ``gossip_silence`` alert) and
  returns to the router's live set.

Exactly-once: every admitted request either completes on its original host
(possibly rescued from the gather ring) or is replayed exactly once onto a
survivor; request-id dedup rejects any second delivery.  The chaos parity
suite (tests/test_failover.py) proves per-tenant results after a
kill/recover run bit-for-bit equal to the no-failure replay.
"""
from __future__ import annotations

import dataclasses
import math
import re

from repro.serve.admission import AdmissionDecision

KILL, PAUSE, RECOVER = "kill", "pause", "recover"
SERVING, DEAD, PAUSED = "serving", "dead", "paused"

_EVENT_RE = re.compile(
    r"^(kill|pause|recover)@([0-9]*\.?[0-9]+(?:[eE][+-]?[0-9]+)?):h([0-9]+)$")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: apply ``kind`` to ``host`` at virtual time ``t``."""
    t: float
    kind: str
    host: int

    def __post_init__(self):
        if self.kind not in (KILL, PAUSE, RECOVER):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.t < 0:
            raise ValueError(f"fault time must be >= 0 (got {self.t})")
        if self.host < 0:
            raise ValueError(f"fault host must be >= 0 (got {self.host})")

    def spec(self) -> str:
        return f"{self.kind}@{self.t:g}:h{self.host}"


class FaultPlan:
    """An ordered, consumed-once script of :class:`FaultEvent`.

    ``due`` pops every event whose time has arrived; the coordinator calls
    it on each tick, so event application is as deterministic as the tick
    stream itself.  CLI specs carry times as *fractions of the run
    duration* (``kill@0.5:h1`` = mid-run) and are materialised with
    :meth:`scaled`; programmatic plans use absolute virtual-clock seconds
    directly.
    """

    def __init__(self, events):
        events = list(events)
        for ev in events:
            if not isinstance(ev, FaultEvent):
                raise TypeError(f"expected FaultEvent, got {ev!r}")
        # Stable sort: same-instant events keep author order (a kill
        # scripted before a recover at the same t applies first).
        self.events = tuple(sorted(events, key=lambda e: e.t))
        self._next = 0

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse ``kill@T:hN,recover@T:hN,pause@T:hN`` (comma-separated)."""
        events = []
        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            m = _EVENT_RE.match(part)
            if m is None:
                raise ValueError(
                    f"bad fault spec {part!r} — expected "
                    f"kill@T:hN / pause@T:hN / recover@T:hN")
            events.append(FaultEvent(t=float(m.group(2)), kind=m.group(1),
                                     host=int(m.group(3))))
        return cls(events)

    def scaled(self, duration_s: float) -> "FaultPlan":
        """Fraction-of-duration times → absolute virtual-clock seconds."""
        if duration_s <= 0:
            raise ValueError(f"duration must be > 0 (got {duration_s})")
        return FaultPlan([FaultEvent(t=e.t * float(duration_s), kind=e.kind,
                                     host=e.host) for e in self.events])

    def due(self, now: float, *, inclusive: bool = True):
        """Pop every unapplied event with ``t <= now`` (``t < now`` when
        ``inclusive`` is False — the drain barrier uses the exclusive form
        so an event scripted at exactly the drain instant lands *mid*
        barrier, after quiesce)."""
        out = []
        while self._next < len(self.events):
            ev = self.events[self._next]
            if ev.t <= now if inclusive else ev.t < now:
                out.append(ev)
                self._next += 1
            else:
                break
        return out

    @property
    def remaining(self) -> int:
        return len(self.events) - self._next

    def __len__(self) -> int:
        return len(self.events)

    def describe(self) -> str:
        return ",".join(e.spec() for e in self.events)


@dataclasses.dataclass
class JournalEntry:
    """One admitted-but-possibly-undispatched request, durably recorded."""
    rid: int                 # fleet-unique request id (dedup key)
    tenant_id: object
    request: object          # payload ref (the TenantRequest itself)
    handle: object           # the caller's ResponseHandle — done() == safe
    reason: str              # admission decision that let it in ("ok")
    recorded_at: float
    replayed: bool = False


class IntakeJournal:
    """Per-host append-only intake journal.

    An entry is *pending* while its handle is unresolved and it has not
    been replayed elsewhere; the pending set is exactly what a survivor
    must replay when this host dies.  ``compact`` drops settled entries so
    a long-lived host's journal stays O(pending), called on the gossip
    publish edge (the same cadence real journals checkpoint at).
    """

    def __init__(self, host: int):
        self.host = host
        self.entries: list[JournalEntry] = []
        self.recorded = 0
        self.compacted = 0

    def record(self, rid: int, tenant_id, request, handle, reason: str,
               recorded_at: float) -> JournalEntry:
        e = JournalEntry(rid=rid, tenant_id=tenant_id, request=request,
                         handle=handle, reason=reason,
                         recorded_at=recorded_at)
        self.entries.append(e)
        self.recorded += 1
        return e

    def pending(self) -> list[JournalEntry]:
        return [e for e in self.entries
                if not e.replayed and not e.handle.done()]

    def pending_tenants(self) -> set:
        """Tenants with live intake here — the stickiness bound: shedding
        never diverts a tenant whose rows are already on this host."""
        return {e.tenant_id for e in self.entries
                if not e.replayed and not e.handle.done()}

    def compact(self):
        settled = [e for e in self.entries
                   if e.replayed or e.handle.done()]
        if len(settled) > 64:
            self.compacted += len(settled)
            self.entries = [e for e in self.entries
                            if not (e.replayed or e.handle.done())]

    def snapshot(self) -> dict:
        return {"host": self.host, "recorded": self.recorded,
                "pending": len(self.pending()),
                "compacted": self.compacted}


class FailoverCoordinator:
    """The fleet's failure-handling control loop (owned by ClusterServer).

    State machine per host: ``serving`` → (``kill``|``pause``) →
    cordoned-on-silence → (``recover``) → ``serving``.  Detection is
    signal-driven — a host is cordoned because its *gossip silence* crossed
    the staleness bound, never because the coordinator peeked at the fault
    plan — so the same code path handles scripted chaos and (in a real
    deployment) genuine silence.
    """

    def __init__(self, cluster, plan: FaultPlan | None = None, *,
                 shed_watermark: float | None = None,
                 shed_transient_s: float | None = None):
        self.cluster = cluster
        self.plan = plan
        n = len(cluster.hosts)
        self.state = {h: SERVING for h in range(n)}
        self.cordoned: set[int] = set()
        self.journals = [IntakeJournal(h) for h in range(n)]
        # (destination host, request, handle): submissions routed to a host
        # that is dead but not yet cordoned — the LB's connection failed and
        # the request sits in its retry queue until cordon re-routes it.
        self.limbo: list[tuple] = []
        self.events: list[dict] = []
        self.shed_watermark = shed_watermark
        bound = cluster.gossip.staleness_bound_s
        self.shed_transient_s = (float(shed_transient_s)
                                 if shed_transient_s is not None
                                 else 2.0 * bound)
        self._transient_until = -math.inf
        self._next_rid = 0
        # fleet counters (exported as cluster metrics + snapshot)
        self.ingress = 0
        self.sheds = 0
        self.diverted = 0
        self.replayed = 0
        self.recovered = 0
        self.deduped = 0
        self.limbo_delivered = 0

    # --- request tagging ------------------------------------------------------

    def tag(self, req):
        """Assign a fleet-unique, monotone request id at ingress (the
        journal/replay dedup key).  A caller-supplied ``request_id`` (e.g.
        an LB retry of the same request object) is preserved."""
        self.ingress += 1
        if getattr(req, "request_id", None) is None:
            req.request_id = self._next_rid
            self._next_rid += 1

    # --- fault plan -----------------------------------------------------------

    def apply_due(self, now: float, *, inclusive: bool = True):
        if self.plan is None:
            return
        for ev in self.plan.due(now, inclusive=inclusive):
            getattr(self, ev.kind)(ev.host, now)

    def kill(self, host: int, now: float):
        """Host process death: publishing stops, serving stops, RAM is
        gone.  Detection and recovery happen later, via silence."""
        if self.state[host] == DEAD:
            return
        self.state[host] = DEAD
        self._event(now, KILL, host)

    def pause(self, host: int, now: float):
        """Gossip-plane partition: the host keeps serving but goes silent."""
        if self.state[host] != SERVING:
            return
        self.state[host] = PAUSED
        self._event(now, PAUSE, host)

    def recover(self, host: int, now: float):
        """Rejoin: publish immediately (resolving the silence alert) and
        return to the live set.  A killed host that somehow recovers before
        the fleet cordoned it is cordoned first — its RAM is gone either
        way, so its journal must be replayed before it serves again."""
        was = self.state[host]
        if was == DEAD and host not in self.cordoned:
            self._cordon(host, now, cause="recover_probe")
        self.state[host] = SERVING
        srv = self.cluster.hosts[host]
        self.cluster.gossip.publish(host, srv.pending_load, now,
                                    open_batches=srv.batcher.open_batches)
        if host in self.cordoned:
            self.cluster.router.restore(host)
            self.cordoned.discard(host)
        self._event(now, RECOVER, host, was=was)

    # --- sensing & cordon -----------------------------------------------------

    def publishing(self, host: int) -> bool:
        return self.state[host] == SERVING

    def serving(self, host: int) -> bool:
        """Data-plane liveness: a paused host still computes and answers."""
        return self.state[host] != DEAD

    def sense(self, now: float):
        """Silence-driven cordon: any host whose publish silence exceeds
        the gossip staleness bound is cut from the router's live set.
        This is the *only* trigger on the normal serving path — the
        coordinator never consults its own fault knowledge to detect."""
        bound = self.cluster.gossip.staleness_bound_s
        for hid, age in self.cluster.gossip.silence_s(now).items():
            if age > bound and hid not in self.cordoned:
                self._cordon(hid, now, cause="gossip_silence")

    def cordon_dead(self, now: float, cause: str = "drain_probe"):
        """Force-cordon every dead-but-uncordoned host — the drain barrier
        uses this: its flush RPC fails fast (connection refused), a
        stronger failure signal than waiting out gossip silence."""
        for host, st in self.state.items():
            if st == DEAD and host not in self.cordoned:
                self._cordon(host, now, cause=cause)

    def _cordon(self, host: int, now: float, cause: str):
        cluster = self.cluster
        cluster.router.cordon(host)
        self.cordoned.add(host)
        tr = cluster.tracer
        silence = cluster.gossip.silence_s(now).get(host, 0.0)
        if tr is not None:
            tr.emit("B", f"failover:h{host}", now, track="failover",
                    args={"cause": cause, "silence_s": silence})
        recovered = replayed = deduped = delivered = 0
        mode = "reroute_only"
        if self.state[host] == DEAD:
            mode = "replay"
            srv = cluster.hosts[host]
            # 1. Gather-ring rescue: results of groups the host launched
            #    before dying are materialised, not recomputed — their
            #    handles resolve and their journal entries read as settled.
            recovered = srv.recover_inflight(now)
            self.recovered += recovered
            # 2. Reboot the dead slice (closes its dangling trace spans and
            #    drops its RAM) *before* replay re-tags the requests with
            #    survivor-side trace ids.
            srv.reset_after_failure(now)
            # 3. Replay the journal's pending entries onto the post-cordon
            #    owners.  Dedup at the submit edge makes this idempotent.
            replayed, deduped = self._replay(host, now)
            # 4. Deliver the LB's limbo queue for this host: never-admitted
            #    requests re-route through normal admission on the owner.
            delivered = self._deliver_limbo(host, now)
            # 5. Price the transient: the detection window is time the dead
            #    host's intake sat unserved — host-gap cycles on the
            #    rendezvous successor's ledger (it runs the recovery).
            successor = cluster.router.successor(host)
            cluster.hosts[successor].ledger.observe_host_gap(
                f"failover:h{host}", silence)
            self._transient_until = max(self._transient_until,
                                        now + self.shed_transient_s)
        if tr is not None:
            tr.emit("E", f"failover:h{host}", now, track="failover",
                    args={"mode": mode, "recovered": recovered,
                          "replayed": replayed, "deduped": deduped,
                          "limbo_delivered": delivered})
        # Forensics for device-parallel fleets: which device slice the dead
        # host's in-flight arrays lived on.  The gather-ring rescue above
        # works regardless — jax materialises committed arrays from any
        # device — but post-mortems need the pin to reason about what the
        # rescue actually pulled across.
        self._event(now, "cordon", host, cause=cause, mode=mode,
                    recovered=recovered, replayed=replayed,
                    deduped=deduped, limbo_delivered=delivered,
                    silence_s=silence,
                    device_ids=list(cluster.hosts[host].cos.device_ids()))

    def _replay(self, host: int, now: float) -> tuple[int, int]:
        cluster = self.cluster
        pending = self.journals[host].pending()
        by_target: dict[int, list[JournalEntry]] = {}
        for e in pending:
            by_target.setdefault(cluster.router.host_for(e.tenant_id),
                                 []).append(e)
        replayed = deduped = 0
        for target, entries in sorted(by_target.items()):
            n_ok, n_dup = cluster.hosts[target].replay_admitted(
                [(e.request, e.handle) for e in entries], now)
            replayed += n_ok
            deduped += n_dup
            for e in entries:
                e.replayed = True
                # Re-journal on the new owner: a later failure of the
                # survivor replays these again (cascade-safe).
                self.journals[target].record(
                    rid=e.rid, tenant_id=e.tenant_id, request=e.request,
                    handle=e.handle, reason=e.reason, recorded_at=now)
        self.replayed += replayed
        self.deduped += deduped
        return replayed, deduped

    def _deliver_limbo(self, host: int, now: float) -> int:
        mine = [(r, h) for d, r, h in self.limbo if d == host]
        self.limbo = [(d, r, h) for d, r, h in self.limbo if d != host]
        for req, handle in mine:
            self.cluster._submit_routed(req, now, handle=handle)
        self.limbo_delivered += len(mine)
        return len(mine)

    # --- ingress routing ------------------------------------------------------

    def route(self, req, now: float):
        """Route one tagged request: ``("host", h, None)`` to submit,
        ``("limbo", h, None)`` to park (owner dead, cordon pending), or
        ``("shed", owner, decision)`` to reject under the transient
        watermark."""
        router = self.cluster.router
        owner = router.host_for(req.tenant_id)
        if self.state[owner] == DEAD:
            return ("limbo", owner, None)
        if self.shed_watermark is not None and now < self._transient_until:
            return self._shed_or_divert(req, owner, now)
        return ("host", owner, None)

    def _depth(self, host: int, now: float) -> float:
        """Power-of-two-choices depth signal: the gossip digest (what a
        real LB would hold), live pending_load when no digest survives."""
        dig = self.cluster.gossip._digests.get(host)
        if dig is not None:
            return float(dig.queue_depth)
        return float(self.cluster.hosts[host].pending_load)

    def _shed_or_divert(self, req, owner: int, now: float):
        wm_rows = self.shed_watermark * self.cluster.config.serve.max_pending
        if self._depth(owner, now) < wm_rows:
            return ("host", owner, None)
        decision = AdmissionDecision(
            False, "shed",
            retry_after_s=max(0.0, self._transient_until - now))
        # Stickiness bound: a tenant with rows already on the owner (or a
        # pin) must not split across hosts mid-transient — shed instead.
        sticky = (req.tenant_id in self.cluster.router.pinned
                  or req.tenant_id in
                  self.journals[owner].pending_tenants())
        if sticky:
            return ("shed", owner, decision)
        alt = self.cluster.router.choices(req.tenant_id, k=2)
        if len(alt) < 2:
            return ("shed", owner, decision)
        second = alt[1] if alt[0] == owner else alt[0]
        if not self.serving(second):
            return ("shed", owner, decision)
        # Power-of-two-choices: least-loaded of {owner, rendezvous
        # alternate}, still bounded by the watermark.
        if self._depth(second, now) >= wm_rows:
            return ("shed", owner, decision)
        self.diverted += 1
        return ("host", second, None)

    def hold_limbo(self, host: int, req, handle):
        self.limbo.append((host, req, handle))

    def note_shed(self, owner: int, req, now: float):
        self.sheds += 1
        srv = self.cluster.hosts[owner]
        srv.telemetry.record_admission("shed")
        if srv.tracer is not None:
            srv.tracer.instant("reject", now,
                               args={"workload": req.workload,
                                     "reason": "shed"})

    # --- drain-time audit -----------------------------------------------------

    def lost(self) -> int:
        """Requests neither settled nor recoverable — must be 0 always:
        limbo entries are delivered at cordon and every journal entry is
        settled or replayed."""
        n = len(self.limbo)
        for host, st in self.state.items():
            if st == DEAD:
                n += len(self.journals[host].pending())
        return n

    # --- audit ----------------------------------------------------------------

    def _event(self, now: float, kind: str, host: int, **details):
        ev = {"t": float(now), "kind": kind, "host": int(host), **details}
        self.events.append(ev)
        tr = self.cluster.tracer
        if tr is not None and kind in (KILL, PAUSE, RECOVER):
            tr.instant(f"fault:{kind}", now, track="failover",
                       args={"host": host})
        return ev

    def snapshot(self) -> dict:
        from repro.cluster.telemetry import summarize_failover
        return {
            "events": list(self.events),
            "summary": summarize_failover(self.events),
            "host_states": {h: s for h, s in sorted(self.state.items())},
            "cordoned": sorted(self.cordoned),
            "journals": [j.snapshot() for j in self.journals],
            "ingress": self.ingress,
            "sheds": self.sheds,
            "diverted": self.diverted,
            "replayed": self.replayed,
            "recovered": self.recovered,
            "deduped": self.deduped,
            "limbo_delivered": self.limbo_delivered,
            "limbo_pending": len(self.limbo),
            "lost": self.lost(),
            "transient_until": (None if self._transient_until == -math.inf
                                else self._transient_until),
        }
