"""Tenant ingress routing: rendezvous (HRW) hashing over a live-host set.

Every request enters the cluster through one stateless function: tenant →
host.  Stability matters more than balance here — a tenant must land on the
same host for its whole session so per-tenant state (token buckets, open
batch rows) never splits across hosts, and the mapping must be reproducible
across processes and Python runs (``hash()`` is salted per process; CRC32
is not).  Balance comes from the hash's uniformity; skewed *load* (one hot
tenant) is exactly what the gossip layer and the bench's adversarial
distributions are there to expose, not something the router hides.

The router is **rendezvous** (highest-random-weight): each live host gets a
deterministic 64-bit score per tenant and the tenant lands on the argmax.
Unlike the old ``hash % n_hosts`` partition, removing one host from the
live set (``cordon``) remaps *only* that host's tenants — every other
tenant's argmax is untouched — so a host failure migrates the minimum
possible state (property-tested in tests/test_failover.py).  ``restore``
is the exact inverse: the pre-cordon mapping returns bit-for-bit.

``pinned`` overrides the hash per tenant — the operational escape hatch for
isolating a noisy tenant on its own host or co-locating tenants that share
compiled programs.  A pin to a cordoned host falls back to the rendezvous
choice over the live set (the pin resumes when the host is restored).
"""
from __future__ import annotations

import zlib

_MASK64 = (1 << 64) - 1
_HOST_SALT = 0x9E3779B97F4A7C15     # golden-ratio odd constant
_KEY_SPREAD = 0x100000001B3         # FNV prime lifts the 32-bit CRC to 64


def stable_tenant_hash(tenant_id) -> int:
    """Process-independent 32-bit hash of a tenant id (int or str)."""
    return zlib.crc32(str(tenant_id).encode("utf-8")) & 0xFFFFFFFF


def _mix64(x: int) -> int:
    """splitmix64/murmur3 finalizer: full-avalanche 64-bit mix, pure int."""
    x &= _MASK64
    x ^= x >> 33
    x = (x * 0xFF51AFD7ED558CCD) & _MASK64
    x ^= x >> 33
    x = (x * 0xC4CEB9FE1A85EC53) & _MASK64
    x ^= x >> 33
    return x


def rendezvous_score(tenant_hash: int, host: int) -> int:
    """The HRW weight of ``host`` for a tenant (higher wins)."""
    return _mix64((tenant_hash * _KEY_SPREAD) ^ ((host + 1) * _HOST_SALT))


class TenantHashRouter:
    """Rendezvous-hash partition of tenants onto the live subset of
    ``n_hosts`` host slices."""

    def __init__(self, n_hosts: int,
                 pinned: dict | None = None):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1 (got {n_hosts})")
        self.n_hosts = n_hosts
        self.pinned = dict(pinned or {})
        for tid, host in self.pinned.items():
            if not 0 <= host < n_hosts:
                raise ValueError(f"pinned tenant {tid!r} -> host {host} "
                                 f"outside [0, {n_hosts})")
        self._live = set(range(n_hosts))

    # --- live-set membership --------------------------------------------------

    @property
    def live_hosts(self) -> tuple:
        return tuple(sorted(self._live))

    def is_live(self, host: int) -> bool:
        return host in self._live

    def cordon(self, host: int) -> bool:
        """Remove ``host`` from the live set (its tenants remap; nobody
        else's do).  Idempotent; refuses to cordon the last live host —
        with no survivor there is nowhere to re-route or replay to."""
        if host not in self._live:
            return False
        if len(self._live) == 1:
            raise RuntimeError(f"cannot cordon host {host}: it is the last "
                               f"live host — no survivor to re-route to")
        self._live.discard(host)
        return True

    def restore(self, host: int) -> bool:
        """Return ``host`` to the live set (exact inverse of ``cordon``:
        the pre-cordon tenant mapping comes back bit-for-bit)."""
        if not 0 <= host < self.n_hosts:
            raise ValueError(f"host {host} outside [0, {self.n_hosts})")
        if host in self._live:
            return False
        self._live.add(host)
        return True

    # --- tenant → host --------------------------------------------------------

    def host_for(self, tenant_id) -> int:
        pin = self.pinned.get(tenant_id)
        if pin is not None and pin in self._live:
            return pin
        th = stable_tenant_hash(tenant_id)
        # argmax of the HRW score; ties (2^-64 per pair) break on host id.
        return max(self._live,
                   key=lambda h: (rendezvous_score(th, h), h))

    def choices(self, tenant_id, k: int = 2) -> list[int]:
        """The top-``k`` live hosts by rendezvous order for a tenant —
        ``choices(t)[0] == host_for(t)`` absent a pin, and ``choices(t)[1]``
        is the failover / power-of-two-choices alternate: the host the
        tenant would remap to if its owner were cordoned."""
        th = stable_tenant_hash(tenant_id)
        ranked = sorted(self._live,
                        key=lambda h: (rendezvous_score(th, h), h),
                        reverse=True)
        return ranked[:k]

    def successor(self, dead_host: int) -> int:
        """The live host designated (by rendezvous order on the *host* id)
        to coordinate recovery of ``dead_host`` — deterministic fleet-wide
        without any election round."""
        key = stable_tenant_hash(f"host:{dead_host}")
        live = self._live - {dead_host}
        if not live:
            raise RuntimeError(f"no live successor for host {dead_host}")
        return max(live, key=lambda h: (rendezvous_score(key, h), h))

    def partition(self, tenant_ids) -> dict[int, list]:
        """Group tenant ids by destination host (diagnostics / benchmarks)."""
        out: dict[int, list] = {h: [] for h in range(self.n_hosts)}
        for tid in tenant_ids:
            out[self.host_for(tid)].append(tid)
        return out
