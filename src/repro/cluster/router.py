"""Tenant-hash ingress routing.

Every request enters the cluster through one stateless function: tenant →
host.  Stability matters more than balance here — a tenant must land on the
same host for its whole session so per-tenant state (token buckets, open
batch rows) never splits across hosts, and the mapping must be reproducible
across processes and Python runs (``hash()`` is salted per process; CRC32
is not).  Balance comes from the hash's uniformity; skewed *load* (one hot
tenant) is exactly what the gossip layer and the bench's adversarial
distributions are there to expose, not something the router hides.

``pinned`` overrides the hash per tenant — the operational escape hatch for
isolating a noisy tenant on its own host or co-locating tenants that share
compiled programs.
"""
from __future__ import annotations

import zlib


def stable_tenant_hash(tenant_id) -> int:
    """Process-independent 32-bit hash of a tenant id (int or str)."""
    return zlib.crc32(str(tenant_id).encode("utf-8")) & 0xFFFFFFFF


class TenantHashRouter:
    """Stable hash partition of tenants onto ``n_hosts`` host slices."""

    def __init__(self, n_hosts: int,
                 pinned: dict | None = None):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1 (got {n_hosts})")
        self.n_hosts = n_hosts
        self.pinned = dict(pinned or {})
        for tid, host in self.pinned.items():
            if not 0 <= host < n_hosts:
                raise ValueError(f"pinned tenant {tid!r} -> host {host} "
                                 f"outside [0, {n_hosts})")

    def host_for(self, tenant_id) -> int:
        pin = self.pinned.get(tenant_id)
        if pin is not None:
            return pin
        return stable_tenant_hash(tenant_id) % self.n_hosts

    def partition(self, tenant_ids) -> dict[int, list]:
        """Group tenant ids by destination host (diagnostics / benchmarks)."""
        out: dict[int, list] = {h: [] for h in range(self.n_hosts)}
        for tid in tenant_ids:
            out[self.host_for(tid)].append(tid)
        return out
