"""Cluster telemetry: merging per-host snapshots into fleet-level metrics.

Each host exports the same JSON snapshot a single-host server does; the
cluster layer merges K of them into one document.  Counters and sums merge
exactly.  Means merge exactly because each snapshot carries its weight
(batch / request counts).  Quantiles do **not** merge from summaries — the
p99 of per-host p99s is not the cluster p99 — so per-host snapshots in
cluster mode carry their raw latency samples and the merge recomputes
quantiles over the concatenation:

* with samples present (``merged_exact: true``): merged quantiles equal the
  quantiles of the concatenated per-request records up to float round-off
  (the documented tolerance is 1e-9 relative);
* without samples (``merged_exact: false``): quantiles fall back to a
  count-weighted mean of the per-host quantiles — an approximation whose
  error grows with cross-host spread; ``max_s`` stays exact (max of maxes).

Load imbalance is the cluster-only signal: requests per host, the
max/mean ratio (1.0 = perfectly even), and the coefficient of variation.
A single hot tenant drives max/mean toward the host count — the spatial
collapse regime the paper prices out per pod (§7).
"""
from __future__ import annotations

import math

from repro.serve.telemetry import LatencyHistogram

MERGE_TOLERANCE_REL = 1e-9   # documented float-roundoff bound (exact path)


def _merge_counter_dicts(dicts) -> dict:
    out: dict = {}
    for d in dicts:
        for k, v in d.items():
            out[k] = out.get(k, 0) + v
    return out


def _weighted_mean(pairs) -> float:
    """pairs: (value, weight).  0.0 when all weights are zero."""
    total = sum(w for _, w in pairs)
    if not total:
        return 0.0
    return sum(v * w for v, w in pairs) / total


def _merge_histograms(summaries: list[dict]) -> dict:
    """Merge per-host latency/queue-wait summaries (see module docstring)."""
    if all("samples" in s for s in summaries):
        h = LatencyHistogram()
        for s in summaries:
            for v in s["samples"]:
                h.observe(v)
        merged = h.summary()
        merged["merged_exact"] = True
        return merged
    counts = [s.get("count", 0) for s in summaries]
    merged = {"count": sum(counts),
              "mean_s": _weighted_mean(
                  [(s.get("mean_s", 0.0), c) for s, c in zip(summaries,
                                                             counts)]),
              "max_s": max((s.get("max_s", 0.0) for s in summaries),
                           default=0.0),
              "merged_exact": False}
    for q in ("p50_s", "p95_s", "p99_s"):
        merged[q] = _weighted_mean(
            [(s.get(q, 0.0), c) for s, c in zip(summaries, counts)])
    return merged


def _merge_per_workload(snaps: list[dict]) -> dict:
    out: dict = {}
    for snap in snaps:
        for wname, w in snap.get("per_workload", {}).items():
            m = out.setdefault(wname, {
                "batches": 0, "requests": 0, "folds": 0,
                "reduction": w["reduction"],
                "_k_sum": 0.0, "_m_sum": 0.0})
            if m["reduction"] != w["reduction"]:
                raise ValueError(
                    f"hosts disagree on reduction mode for {wname!r}: "
                    f"{m['reduction']} vs {w['reduction']} — per-class "
                    f"reduction config must be cluster-uniform")
            m["batches"] += w["batches"]
            m["requests"] += w["requests"]
            m["folds"] += w["folds"]
            m["_k_sum"] += w["k_occupancy_mean"] * w["batches"]
            m["_m_sum"] += w["m_occupancy_mean"] * w["batches"]
    for m in out.values():
        b = m["batches"] or 1
        m["k_occupancy_mean"] = m.pop("_k_sum") / b
        m["m_occupancy_mean"] = m.pop("_m_sum") / b
    return out


def _merge_dispatch(snaps: list[dict]) -> dict:
    """Merge the per-host dispatch-fast-path sections (counters sum; means
    are dispatch-weighted; pad_fraction is recomputed from the merged row
    totals so it stays exact).  Hosts predating the section contribute
    nothing."""
    parts = [s.get("dispatch") for s in snaps]
    parts = [p for p in parts if p]
    out = {"dispatches": 0, "merged_dispatches": 0, "live_rows": 0,
           "launched_rows": 0, "donated": 0}
    for p in parts:
        for k in out:
            out[k] += p.get(k, 0)
    weights = [p.get("dispatches", 0) for p in parts]
    for key in ("batches_per_dispatch_mean", "m_occupancy_mean",
                "m_fill_mean"):
        out[key] = _weighted_mean(
            [(p.get(key, 0.0), w) for p, w in zip(parts, weights)])
    out["pad_fraction"] = (1.0 - out["live_rows"] / out["launched_rows"]
                           if out["launched_rows"] else 0.0)
    return out


def _merge_holdback(snaps: list[dict]) -> dict:
    """Merge the per-host λ-holdback audits: event counters and held rows
    sum, the realised hold durations keep their fleet-wide max and total.
    Hosts predating the section contribute nothing."""
    out = {"held": 0, "wins": 0, "losses": 0, "flushed": 0,
           "held_rows": 0, "hold_s_sum": 0.0, "hold_s_max": 0.0}
    for snap in snaps:
        h = snap.get("holdback")
        if not h:
            continue
        for k in ("held", "wins", "losses", "flushed", "held_rows",
                  "hold_s_sum"):
            out[k] += h.get(k, 0)
        out["hold_s_max"] = max(out["hold_s_max"], h.get("hold_s_max", 0.0))
    return out


def _merge_controller(snaps: list[dict]) -> dict | None:
    """Fleet summary of the per-host adaptive controllers (None when no host
    runs one).  Setpoints are host-local by design — each host's loop reacts
    to its own slice — so the merge reports the update-weighted fleet means
    and extrema, not a single merged setpoint."""
    parts = [s.get("controller") for s in snaps]
    parts = [p for p in parts if p]
    if not parts:
        return None
    updates = [p.get("updates", 0) for p in parts]
    class_states = [c for p in parts for c in p.get("classes", {}).values()]
    weights = [c.get("updates", 0) for c in class_states]
    return {
        "hosts": len(parts),
        "updates": sum(updates),
        "cluster_depth_max": max(p.get("cluster_depth_max", 0.0)
                                 for p in parts),
        "m_occupancy_ewma_mean": _weighted_mean(
            [(c.get("m_occupancy_ewma", 0.0), w)
             for c, w in zip(class_states, weights)]),
        "target_rows_max": max((c.get("target_rows", 0)
                                for c in class_states), default=0),
        "max_age_s_max": max((c.get("max_age_s", 0.0)
                              for c in class_states), default=0.0),
    }


def _merge_reduction_stalls(snaps: list[dict]) -> dict:
    out = {"eager_folds": 0, "deferred_folds": 0, "by_close_reason": {}}
    for snap in snaps:
        stalls = snap.get("reduction_stalls")
        if not stalls:
            continue
        out["eager_folds"] += stalls["eager_folds"]
        out["deferred_folds"] += stalls["deferred_folds"]
        for reason, by in stalls["by_close_reason"].items():
            slot = out["by_close_reason"].setdefault(
                reason, {"eager_folds": 0, "deferred_folds": 0})
            slot["eager_folds"] += by["eager_folds"]
            slot["deferred_folds"] += by["deferred_folds"]
    return out


def load_imbalance(per_host_requests: list[int]) -> dict:
    """Fleet skew metrics over per-host served-request counts."""
    n = len(per_host_requests)
    mean = sum(per_host_requests) / n if n else 0.0
    if mean == 0.0:
        return {"per_host_requests": list(per_host_requests),
                "max_over_mean": 1.0, "cv": 0.0}
    var = sum((r - mean) ** 2 for r in per_host_requests) / n
    return {
        "per_host_requests": list(per_host_requests),
        "max_over_mean": max(per_host_requests) / mean,
        "cv": math.sqrt(var) / mean,
    }


def merge_snapshots(snaps: list[dict]) -> dict:
    """Merge K per-host telemetry snapshots into one cluster snapshot.

    The merged document has the same schema as a single-host snapshot (so
    downstream BENCH_* tooling needs no cluster special-case) plus
    ``latency.merged_exact`` / ``queue_wait.merged_exact`` flags and a
    ``load_imbalance`` section.
    """
    if not snaps:
        raise ValueError("merge_snapshots needs at least one host snapshot")
    batches = [s["batches"] for s in snaps]
    admission_by = _merge_counter_dicts(s["admission"]["by_reason"]
                                        for s in snaps)
    merged = {
        "batches": sum(batches),
        "requests_served": sum(s["requests_served"] for s in snaps),
        "k_occupancy_mean": _weighted_mean(
            [(s["k_occupancy_mean"], b) for s, b in zip(snaps, batches)]),
        "m_occupancy_mean": _weighted_mean(
            [(s["m_occupancy_mean"], b) for s, b in zip(snaps, batches)]),
        "queue_depth_mean": _weighted_mean(
            [(s["queue_depth_mean"], b) for s, b in zip(snaps, batches)]),
        "queue_depth_max": max(s["queue_depth_max"] for s in snaps),
        "service_s_total": sum(s["service_s_total"] for s in snaps),
        "close_reasons": _merge_counter_dicts(s["close_reasons"]
                                              for s in snaps),
        "reduction_stalls": _merge_reduction_stalls(snaps),
        "dispatch": _merge_dispatch(snaps),
        "holdback": _merge_holdback(snaps),
        "per_workload": _merge_per_workload(snaps),
        "latency": _merge_histograms([s["latency"] for s in snaps]),
        "queue_wait": _merge_histograms([s["queue_wait"] for s in snaps]),
        "admission": {
            "admitted": sum(s["admission"]["admitted"] for s in snaps),
            "rejected": sum(s["admission"]["rejected"] for s in snaps),
            "by_reason": admission_by,
        },
        "load_imbalance": load_imbalance(
            [s["requests_served"] for s in snaps]),
        "n_hosts": len(snaps),
    }
    controller = _merge_controller(snaps)
    if controller is not None:
        merged["controller"] = controller
    return merged
