"""Cluster telemetry: merging per-host snapshots into fleet-level metrics.

Each host exports the same JSON snapshot a single-host server does; the
cluster layer merges K of them into one document.  Counters and sums merge
exactly.  Means merge exactly because each snapshot carries its weight
(batch / request counts).  Quantiles do **not** merge from summaries — the
p99 of per-host p99s is not the cluster p99 — so per-host snapshots in
cluster mode carry their raw latency samples and the merge recomputes
quantiles over the concatenation:

* with samples present (``merged_exact: true``): merged quantiles equal the
  quantiles of the concatenated per-request records up to float round-off
  (the documented tolerance is 1e-9 relative);
* without samples (``merged_exact: false``): quantiles fall back to a
  count-weighted mean of the per-host quantiles — an approximation whose
  error grows with cross-host spread; ``max_s`` stays exact (max of maxes).

Load imbalance is the cluster-only signal: requests per host, the
max/mean ratio (1.0 = perfectly even), and the coefficient of variation.
A single hot tenant drives max/mean toward the host count — the spatial
collapse regime the paper prices out per pod (§7).
"""
from __future__ import annotations

import math

from repro.obs.alerts import merge_alert_sections
from repro.obs.ledger import merge_penalty_sections
from repro.serve.telemetry import LatencyHistogram

MERGE_TOLERANCE_REL = 1e-9   # documented float-roundoff bound (exact path)


def _merge_counter_dicts(dicts) -> dict:
    out: dict = {}
    for d in dicts:
        for k, v in d.items():
            out[k] = out.get(k, 0) + v
    return out


def _weighted_mean(pairs) -> float:
    """pairs: (value, weight).  0.0 when all weights are zero."""
    total = sum(w for _, w in pairs)
    if not total:
        return 0.0
    return sum(v * w for v, w in pairs) / total


def _sketch_quantile(buckets: dict, zero: int, count: int, max_s: float,
                     gamma: float, q: float) -> float:
    """Quantile of a merged log-bucket sketch: cumulative walk to the rank,
    geometric bucket midpoint as the representative value."""
    if not count:
        return 0.0
    rank = (q / 100.0) * (count - 1)
    seen = zero
    if rank < seen:
        return 0.0
    for b in sorted(buckets):
        seen += buckets[b]
        if rank < seen:
            return min(gamma ** (b + 0.5), max_s)
    return max_s


def _merge_histograms(summaries: list[dict]) -> dict:
    """Merge per-host latency/queue-wait summaries (see module docstring).
    Degenerate hosts (empty or missing summaries) contribute nothing."""
    summaries = [s for s in summaries if s]
    if not summaries:
        return {"count": 0, "mean_s": 0.0, "p50_s": 0.0, "p95_s": 0.0,
                "p99_s": 0.0, "max_s": 0.0, "merged_exact": True}
    if all("samples" in s for s in summaries):
        h = LatencyHistogram()
        for s in summaries:
            for v in s["samples"]:
                h.observe(v)
        merged = h.summary()
        merged["merged_exact"] = True
        return merged
    if all(("samples" in s) or ("sketch" in s) for s in summaries):
        # ≥1 host collapsed to a log-bucket sketch: merge bucket-wise (exact
        # hosts are bucketed on the fly), keep count/mean/max exact, and
        # flip merged_exact off — quantiles now carry the sketch's bounded
        # relative error.
        gamma = LatencyHistogram.GAMMA
        for s in summaries:
            g = s.get("sketch", {}).get("gamma", gamma)
            if abs(g - gamma) > 1e-12:
                raise ValueError(f"sketch gamma mismatch: host exported "
                                 f"{g}, merge expects {gamma}")
        buckets: dict[int, int] = {}
        zero = count = 0
        total = max_s = 0.0
        for s in summaries:
            n = s.get("count", 0)
            count += n
            total += s.get("mean_s", 0.0) * n
            max_s = max(max_s, s.get("max_s", 0.0))
            if "sketch" in s:
                zero += s["sketch"].get("zero", 0)
                for b, c in s["sketch"].get("buckets", {}).items():
                    buckets[int(b)] = buckets.get(int(b), 0) + c
            else:
                for v in s["samples"]:
                    if v <= 0.0:
                        zero += 1
                    else:
                        b = math.floor(math.log(v) / math.log(gamma))
                        buckets[b] = buckets.get(b, 0) + 1
        merged = {"count": count, "mean_s": (total / count) if count else 0.0,
                  "max_s": max_s, "merged_exact": False}
        for q, key in ((50, "p50_s"), (95, "p95_s"), (99, "p99_s")):
            merged[key] = _sketch_quantile(buckets, zero, count, max_s,
                                           gamma, q)
        return merged
    counts = [s.get("count", 0) for s in summaries]
    merged = {"count": sum(counts),
              "mean_s": _weighted_mean(
                  [(s.get("mean_s", 0.0), c) for s, c in zip(summaries,
                                                             counts)]),
              "max_s": max((s.get("max_s", 0.0) for s in summaries),
                           default=0.0),
              "merged_exact": False}
    for q in ("p50_s", "p95_s", "p99_s"):
        merged[q] = _weighted_mean(
            [(s.get(q, 0.0), c) for s, c in zip(summaries, counts)])
    return merged


def _merge_per_workload(snaps: list[dict]) -> dict:
    """Per-mode batch counts merge exactly across hosts — a fleet may
    legitimately run one class eager on some hosts and κ-deferred on others
    (or flip mid-run), so the merge reports the counts and derives the
    ``reduction`` label (single mode, or "mixed") instead of rejecting the
    disagreement.  Hosts predating ``reduction_batches`` are synthesised
    from their single ``reduction`` label."""
    out: dict = {}
    for snap in snaps:
        for wname, w in snap.get("per_workload", {}).items():
            m = out.setdefault(wname, {
                "batches": 0, "requests": 0, "folds": 0,
                "reduction_batches": {},
                "_k_sum": 0.0, "_m_sum": 0.0})
            batches = w.get("batches", 0)
            modes = w.get("reduction_batches")
            if modes is None:
                modes = {w.get("reduction", "eager"): batches}
            for mode, n in modes.items():
                m["reduction_batches"][mode] = (
                    m["reduction_batches"].get(mode, 0) + n)
            m["batches"] += batches
            m["requests"] += w.get("requests", 0)
            m["folds"] += w.get("folds", 0)
            m["_k_sum"] += w.get("k_occupancy_mean", 0.0) * batches
            m["_m_sum"] += w.get("m_occupancy_mean", 0.0) * batches
    for m in out.values():
        b = m["batches"] or 1
        m["k_occupancy_mean"] = m.pop("_k_sum") / b
        m["m_occupancy_mean"] = m.pop("_m_sum") / b
        modes = sorted(k for k, v in m["reduction_batches"].items() if v)
        m["reduction"] = modes[0] if len(modes) == 1 else (
            "mixed" if modes else "eager")
    return out


def _merge_dispatch(snaps: list[dict]) -> dict:
    """Merge the per-host dispatch-fast-path sections (counters sum; means
    are dispatch-weighted; pad_fraction is recomputed from the merged row
    totals so it stays exact).  Hosts predating the section contribute
    nothing."""
    parts = [s.get("dispatch") for s in snaps]
    parts = [p for p in parts if p]
    out = {"dispatches": 0, "merged_dispatches": 0, "live_rows": 0,
           "launched_rows": 0, "donated": 0}
    for p in parts:
        for k in out:
            out[k] += p.get(k, 0)
    weights = [p.get("dispatches", 0) for p in parts]
    for key in ("batches_per_dispatch_mean", "m_occupancy_mean",
                "m_fill_mean"):
        out[key] = _weighted_mean(
            [(p.get(key, 0.0), w) for p, w in zip(parts, weights)])
    out["pad_fraction"] = (1.0 - out["live_rows"] / out["launched_rows"]
                           if out["launched_rows"] else 0.0)
    by_device: dict = {}
    for p in parts:
        for dev, slot in p.get("by_device", {}).items():
            m = by_device.setdefault(dev, {"launches": 0, "live_rows": 0})
            m["launches"] += slot.get("launches", 0)
            m["live_rows"] += slot.get("live_rows", 0)
    out["by_device"] = by_device
    return out


def _merge_holdback(snaps: list[dict]) -> dict:
    """Merge the per-host λ-holdback audits: event counters and held rows
    sum, the realised hold durations keep their fleet-wide max and total.
    Hosts predating the section contribute nothing."""
    out = {"held": 0, "wins": 0, "losses": 0, "flushed": 0,
           "held_rows": 0, "hold_s_sum": 0.0, "hold_s_max": 0.0}
    for snap in snaps:
        h = snap.get("holdback")
        if not h:
            continue
        for k in ("held", "wins", "losses", "flushed", "held_rows",
                  "hold_s_sum"):
            out[k] += h.get(k, 0)
        out["hold_s_max"] = max(out["hold_s_max"], h.get("hold_s_max", 0.0))
    return out


def _merge_controller(snaps: list[dict]) -> dict | None:
    """Fleet summary of the per-host adaptive controllers (None when no host
    runs one).  Setpoints are host-local by design — each host's loop reacts
    to its own slice — so the merge reports the update-weighted fleet means
    and extrema, not a single merged setpoint."""
    parts = [s.get("controller") for s in snaps]
    parts = [p for p in parts if p]
    if not parts:
        return None
    updates = [p.get("updates", 0) for p in parts]
    class_states = [c for p in parts for c in p.get("classes", {}).values()]
    weights = [c.get("updates", 0) for c in class_states]
    return {
        "hosts": len(parts),
        "updates": sum(updates),
        "cluster_depth_max": max(p.get("cluster_depth_max", 0.0)
                                 for p in parts),
        "m_occupancy_ewma_mean": _weighted_mean(
            [(c.get("m_occupancy_ewma", 0.0), w)
             for c, w in zip(class_states, weights)]),
        "target_rows_max": max((c.get("target_rows", 0)
                                for c in class_states), default=0),
        "max_age_s_max": max((c.get("max_age_s", 0.0)
                              for c in class_states), default=0.0),
    }


def _merge_reduction_stalls(snaps: list[dict]) -> dict:
    out = {"eager_folds": 0, "deferred_folds": 0, "by_close_reason": {}}
    for snap in snaps:
        stalls = snap.get("reduction_stalls")
        if not stalls:
            continue
        out["eager_folds"] += stalls.get("eager_folds", 0)
        out["deferred_folds"] += stalls.get("deferred_folds", 0)
        for reason, by in stalls.get("by_close_reason", {}).items():
            slot = out["by_close_reason"].setdefault(
                reason, {"eager_folds": 0, "deferred_folds": 0})
            slot["eager_folds"] += by.get("eager_folds", 0)
            slot["deferred_folds"] += by.get("deferred_folds", 0)
    return out


def load_imbalance(per_host_requests: list[int]) -> dict:
    """Fleet skew metrics over per-host served-request counts."""
    n = len(per_host_requests)
    mean = sum(per_host_requests) / n if n else 0.0
    if mean == 0.0:
        return {"per_host_requests": list(per_host_requests),
                "max_over_mean": 1.0, "cv": 0.0}
    var = sum((r - mean) ** 2 for r in per_host_requests) / n
    return {
        "per_host_requests": list(per_host_requests),
        "max_over_mean": max(per_host_requests) / mean,
        "cv": math.sqrt(var) / mean,
    }


def summarize_failover(events: list[dict]) -> dict:
    """Roll a failover coordinator's event log up into fleet counts: fault
    injections by kind, cordons by cause, and the recovery-side aggregates
    (replayed / recovered / deduped / limbo-delivered) summed over cordon
    events.  The summary is what lands in ``snapshot()["failover"]`` — the
    raw event list rides alongside for forensics."""
    out = {"kills": 0, "pauses": 0, "recovers": 0, "cordons": 0,
           "cordons_by_cause": {}, "replayed": 0, "recovered": 0,
           "deduped": 0, "limbo_delivered": 0}
    for ev in events:
        kind = ev.get("kind")
        if kind == "kill":
            out["kills"] += 1
        elif kind == "pause":
            out["pauses"] += 1
        elif kind == "recover":
            out["recovers"] += 1
        elif kind == "cordon":
            out["cordons"] += 1
            cause = ev.get("cause", "unknown")
            out["cordons_by_cause"][cause] = (
                out["cordons_by_cause"].get(cause, 0) + 1)
            for k in ("replayed", "recovered", "deduped", "limbo_delivered"):
                out[k] += ev.get(k, 0)
    return out


def merge_snapshots(snaps: list[dict]) -> dict:
    """Merge K per-host telemetry snapshots into one cluster snapshot.

    The merged document has the same schema as a single-host snapshot (so
    downstream BENCH_* tooling needs no cluster special-case) plus
    ``latency.merged_exact`` / ``queue_wait.merged_exact`` flags and a
    ``load_imbalance`` section.
    """
    if not snaps:
        raise ValueError("merge_snapshots needs at least one host snapshot")
    # Every lookup below is defensive: a degenerate host (zero batches,
    # empty histograms, predates a section) contributes zeros, never a
    # KeyError — the fleet merge must survive a host that served nothing.
    batches = [s.get("batches", 0) for s in snaps]
    admission = [s.get("admission", {}) for s in snaps]
    merged = {
        "batches": sum(batches),
        "requests_served": sum(s.get("requests_served", 0) for s in snaps),
        "k_occupancy_mean": _weighted_mean(
            [(s.get("k_occupancy_mean", 0.0), b)
             for s, b in zip(snaps, batches)]),
        "m_occupancy_mean": _weighted_mean(
            [(s.get("m_occupancy_mean", 0.0), b)
             for s, b in zip(snaps, batches)]),
        "queue_depth_mean": _weighted_mean(
            [(s.get("queue_depth_mean", 0.0), b)
             for s, b in zip(snaps, batches)]),
        "queue_depth_max": max((s.get("queue_depth_max", 0) for s in snaps),
                               default=0),
        "service_s_total": sum(s.get("service_s_total", 0.0) for s in snaps),
        "close_reasons": _merge_counter_dicts(s.get("close_reasons", {})
                                              for s in snaps),
        "reduction_stalls": _merge_reduction_stalls(snaps),
        "dispatch": _merge_dispatch(snaps),
        "holdback": _merge_holdback(snaps),
        "per_workload": _merge_per_workload(snaps),
        "penalty": merge_penalty_sections(
            [s.get("penalty") for s in snaps]),
        "latency": _merge_histograms([s.get("latency") for s in snaps]),
        "queue_wait": _merge_histograms([s.get("queue_wait")
                                         for s in snaps]),
        "admission": {
            "admitted": sum(a.get("admitted", 0) for a in admission),
            "rejected": sum(a.get("rejected", 0) for a in admission),
            "by_reason": _merge_counter_dicts(a.get("by_reason", {})
                                              for a in admission),
        },
        "load_imbalance": load_imbalance(
            [s.get("requests_served", 0) for s in snaps]),
        "n_hosts": len(snaps),
    }
    controller = _merge_controller(snaps)
    if controller is not None:
        merged["controller"] = controller
    alerts = merge_alert_sections([s.get("alerts") for s in snaps])
    if alerts:
        merged["alerts"] = alerts
    metrics = _merge_metrics_audit(snaps)
    if metrics is not None:
        merged["metrics"] = metrics
    return merged


def _merge_metrics_audit(snaps: list[dict]) -> dict | None:
    """Fleet sum of the per-host registry audits (None when no host scrapes
    — hosts predating the section contribute nothing)."""
    parts = [s.get("metrics") for s in snaps]
    parts = [p for p in parts if p]
    if not parts:
        return None
    return {
        "hosts": len(parts),
        "scrapes": sum(p.get("scrapes", 0) for p in parts),
        "series": sum(p.get("series", 0) for p in parts),
        "samples": sum(p.get("samples", 0) for p in parts),
        "dropped_points": sum(p.get("dropped_points", 0) for p in parts),
    }
