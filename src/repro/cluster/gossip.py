"""Queue-depth gossip with explicitly bounded staleness.

Hosts cannot see each other's queues synchronously — in a real pod each
admission decision would need a cross-host RPC on the critical path.  The
standard fix is gossip: each host periodically publishes a tiny digest
(queue depth, open batches) and every peer keeps the last digest it saw.
Admission then runs on *bounded-staleness* cluster state: a digest is
usable only while ``now - published_at <= period_s × staleness_factor``;
older digests are dropped (and counted), never silently trusted.  The bound
is the contract the acceptance test checks — no admission decision may
consume a digest older than twice the gossip period under the default
factor.

The bus is an in-process simulation of that exchange, driven by the same
virtual clock as the servers, so every staleness scenario (a host that
stops publishing, a clock jump past the bound) is deterministic and
testable on one machine.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HostDigest:
    """What one host tells the fleet about itself — deliberately tiny."""
    host_id: int
    queue_depth: int         # pending (admitted, undispatched) requests
    open_batches: int        # open (workload, bucket) rows awaiting close
    published_at: float      # virtual-clock publish instant


@dataclasses.dataclass(frozen=True)
class ClusterView:
    """Merged picture one host sees at a decision instant.

    ``local`` is always live (a host knows its own queue exactly); peers
    contribute their last *fresh* digest.  ``per_host_equiv`` is the
    mean-field depth the admission SLO gate consumes: total known depth
    averaged over the hosts that contributed, i.e. "if the cluster drained
    evenly, how deep is the queue in front of this request".
    """
    host_id: int
    local_depth: int
    peer_depth: int          # Σ fresh peers' digested depth
    contributing_hosts: int  # self + fresh peers
    stale_dropped: int       # peers whose digest aged past the bound
    max_staleness_s: float   # oldest digest actually used (0 if peers empty)

    @property
    def total_depth(self) -> int:
        return self.local_depth + self.peer_depth

    @property
    def per_host_equiv(self) -> float:
        return self.total_depth / max(1, self.contributing_hosts)


class GossipBus:
    """Periodic digest exchange between the hosts of one cluster."""

    def __init__(self, n_hosts: int, *, period_s: float = 0.002,
                 staleness_factor: float = 2.0):
        if period_s <= 0:
            raise ValueError(f"gossip period must be > 0 (got {period_s})")
        self.n_hosts = n_hosts
        self.period_s = float(period_s)
        self.staleness_factor = float(staleness_factor)
        self._digests: dict[int, HostDigest] = {}
        self._last_pub: dict[int, float] = {}
        # audit counters (exported into the cluster telemetry snapshot)
        self.publishes = 0
        self.views = 0
        self.stale_drops = 0
        self.pruned_digests = 0
        self.revives = 0    # publishes by a host whose digest had been pruned
        self._used_staleness_max = 0.0
        self._used_staleness_sum = 0.0
        self._used_staleness_n = 0

    @property
    def staleness_bound_s(self) -> float:
        """Max digest age any decision may consume (period × factor)."""
        return self.period_s * self.staleness_factor

    # --- publish side ---------------------------------------------------------

    def due(self, host_id: int, now: float) -> bool:
        last = self._last_pub.get(host_id)
        return last is None or now - last >= self.period_s

    def publish(self, host_id: int, queue_depth: int, now: float,
                open_batches: int = 0):
        if host_id in self._last_pub and host_id not in self._digests:
            # A host that had been pruned as dead is publishing again — the
            # rejoin audit the failover recover path asserts on.
            self.revives += 1
        self._digests[host_id] = HostDigest(
            host_id=host_id, queue_depth=int(queue_depth),
            open_batches=int(open_batches), published_at=now)
        self._last_pub[host_id] = now
        self.publishes += 1

    def maybe_publish(self, host_id: int, queue_depth: int, now: float,
                      open_batches: int = 0) -> bool:
        if not self.due(host_id, now):
            return False
        self.publish(host_id, queue_depth, now, open_batches)
        return True

    # --- read side ------------------------------------------------------------

    def cluster_view(self, host_id: int, local_depth: int,
                     now: float) -> ClusterView:
        """Bounded-staleness merge at one decision instant.

        Digests older than ``staleness_bound_s`` are dropped here, at read
        time — dropping at publish time would not catch a peer that simply
        went quiet.  The staleness of every digest actually consumed is
        recorded so telemetry can prove the bound was honored.

        A digest that ages past the bound is *pruned* on the view that first
        drops it: a departed host costs one ``stale_drops`` count total, not
        one per view forever, and the merge scan stays O(live hosts).  A
        pruned host that comes back simply publishes a fresh digest."""
        bound = self.staleness_bound_s
        peer_depth, used, dropped = 0, 0.0, 0
        contributing = 1
        dead = []
        for hid, dig in self._digests.items():
            if hid == host_id:
                continue                     # own queue is read live
            age = now - dig.published_at
            if age > bound:
                dropped += 1
                dead.append(hid)
                continue
            peer_depth += dig.queue_depth
            contributing += 1
            used = max(used, age)
        for hid in dead:
            del self._digests[hid]
        self.pruned_digests += len(dead)
        self.views += 1
        self.stale_drops += dropped
        self._used_staleness_max = max(self._used_staleness_max, used)
        self._used_staleness_sum += used
        self._used_staleness_n += 1
        return ClusterView(host_id=host_id, local_depth=local_depth,
                           peer_depth=peer_depth,
                           contributing_hosts=contributing,
                           stale_dropped=dropped, max_staleness_s=used)

    def silence_s(self, now: float) -> dict[int, float]:
        """Per-host publish silence: ``now - last publish`` for every host
        that has ever published.  The dead-host sensing signal — a host
        whose silence exceeds ``staleness_bound_s`` has no usable digest
        anywhere in the fleet; the failover coordinator cordons on exactly
        this threshold.

        Contract with ``cluster_view``'s pruning: pruning removes a dead
        host's *digest* (``_digests``) only, never its ``_last_pub`` entry,
        so silence keeps growing after the prune and the ``gossip_silence``
        alert stays firing until an actual republish — a cordoned host must
        not read as healthy just because its stale digest was garbage-
        collected (regression-tested in tests/test_metrics_alerts.py)."""
        return {hid: max(0.0, now - last)
                for hid, last in self._last_pub.items()}

    # --- export ---------------------------------------------------------------

    def snapshot(self) -> dict:
        n = self._used_staleness_n
        return {
            "period_s": self.period_s,
            "staleness_bound_s": self.staleness_bound_s,
            "publishes": self.publishes,
            "views": self.views,
            "stale_drops": self.stale_drops,
            "pruned_digests": self.pruned_digests,
            "revives": self.revives,
            "used_staleness_max_s": self._used_staleness_max,
            "used_staleness_mean_s": (self._used_staleness_sum / n) if n
                                     else 0.0,
        }
