"""repro.cluster — multi-host sharded serving on one machine.

The paper's fleet-economics framing (§2, §7: per-pod cost deficits,
multi-tenant spatial collapse) needs cross-host effects to be measurable:
skewed tenant load, admission on stale global queue depth, coordinated
drains.  This package shards the single-host :mod:`repro.serve` runtime
across N simulated host slices, all under the same deterministic virtual
clock:

* :mod:`router`    — tenant ingress by rendezvous (highest-random-weight)
  hashing over the *live* host set (stable CRC32 tenant keys, explicit
  tenant→host pinning overrides, cordon/restore with minimal remapping);
* :mod:`gossip`    — per-host queue-depth digests on a configurable period;
  the SLO admission gate consumes bounded-staleness *cluster* state, and
  staleness is audited, never hidden;
* :mod:`cluster`   — ``ClusterServer``: one ``CryptoServer`` +
  ``SliceCoScheduler`` per host, a two-phase distributed drain barrier
  (quiesce ingress everywhere → drain every host → collect), and the same
  explicit-clock surface as a single server so ``LoadGenerator`` drives a
  cluster unchanged;
* :mod:`failover`  — host-failure recovery: deterministic fault injection
  (``FaultPlan``), silence-driven cordon, per-host intake journals, lossless
  idempotent replay onto rendezvous survivors, and watermark-gated shedding
  during the redistribution transient;
* :mod:`telemetry` — merges K per-host JSON snapshots into cluster-level
  p50/p95/p99 (exact, via raw samples), per-host occupancy, and
  load-imbalance metrics.

Cluster drains are bit-for-bit equivalent to a single-host replay of the
same trace (``tests/test_cluster.py`` sweeps N ∈ {1, 2, 4} with mixed
eager/lazy reduction classes), and so are kill/recover chaos runs
(``tests/test_failover.py``: surviving-tenant results bit-equal, no request
lost or double-served).
"""
from repro.cluster.cluster import ClusterConfig, ClusterServer
from repro.cluster.failover import (FailoverCoordinator, FaultEvent,
                                    FaultPlan, IntakeJournal)
from repro.cluster.gossip import ClusterView, GossipBus, HostDigest
from repro.cluster.router import (TenantHashRouter, rendezvous_score,
                                  stable_tenant_hash)
from repro.cluster.telemetry import (MERGE_TOLERANCE_REL, load_imbalance,
                                     merge_snapshots, summarize_failover)
