"""Sharded checkpoint/restart with integrity hashes, rotation, async save and
elastic restore (resharding onto a different mesh).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json
Leaves are addressed by their tree path; the manifest records shapes, dtypes,
a SHA-256 per payload, plus arbitrary JSON extra state (data-iterator step,
mesh shape) so a restore can re-shard onto a different device topology
(jax.device_put with the new sharding does the placement).
"""
from __future__ import annotations

import concurrent.futures
import hashlib
import json
import os
import shutil

import numpy as np
import jax


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            flat[key + "@bf16"] = arr.astype(np.float32)
        else:
            flat[key] = arr
    return flat


def _unflatten(like, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key + "@bf16" in flat:
            leaves.append(flat[key + "@bf16"].astype(jax.numpy.bfloat16))
        else:
            leaves.append(flat[key].astype(leaf.dtype)
                          if hasattr(leaf, "dtype") else flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, tree, extra: dict | None = None):
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **flat)
    digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    manifest = {
        "step": step,
        "sha256": digest,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)   # atomic publish
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like, step: int | None = None):
    """Returns (tree, extra).  Verifies integrity before deserialising."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    npz_path = os.path.join(path, "arrays.npz")
    digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    if digest != manifest["sha256"]:
        raise IOError(f"checkpoint {path} failed integrity check")
    flat = dict(np.load(npz_path))
    return _unflatten(like, flat), manifest["extra"]


class CheckpointManager:
    """Rotation + async save + restore-latest."""

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self._pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                      if async_save else None)
        self._pending = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree, extra: dict | None = None):
        # materialise on host before handing to the writer thread
        tree = jax.tree.map(np.asarray, tree)
        if self._pool is None:
            save_checkpoint(self.directory, step, tree, extra)
            self._rotate()
        else:
            self.wait()
            self._pending = self._pool.submit(self._save_and_rotate, step,
                                              tree, extra)

    def _save_and_rotate(self, step, tree, extra):
        save_checkpoint(self.directory, step, tree, extra)
        self._rotate()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _rotate(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like):
        self.wait()
        return restore_checkpoint(self.directory, like)

    def latest_step(self):
        self.wait()
        return latest_step(self.directory)
