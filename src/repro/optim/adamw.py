"""AdamW with decoupled weight decay, global-norm clipping and warmup-cosine
schedule — pure JAX (no optax dependency).  Moments are float32 regardless of
parameter dtype (bf16 params + f32 m/v is the memory model the dry-run
reports)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (
            update + cfg.weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
