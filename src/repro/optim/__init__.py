from repro.optim.adamw import AdamWConfig, init_opt_state, adamw_update, global_norm
