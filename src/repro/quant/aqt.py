"""The paper's arithmetic core applied to AI workloads: u8×s8 DotGeneral with
``preferred_element_type=int32`` (the AQT-documented lowering of §5.1/§6.2)
as a quantised matmul mode for LM projection layers.

This is the *same* MXU path the crypto pipeline uses — the int32 (v5e/v5p) or
fp32-mantissa (v4) accumulator semantics characterised in Table 1 — so the
accumulator-exactness bound transfers: a K-dim reduction of u8×s8 products is
bit-exact while K·(255·128) stays inside the window.  For inexact bf16 LMs
this is a quantisation scheme (W8A8 symmetric); for the crypto engines it is
an exactness guarantee.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.limb_gemm import MAX_PIXEL_PRODUCT, accumulator_window


def quantize_symmetric(x, bits: int = 8, axis=-1):
    """Per-channel symmetric quantisation -> (int8 codes, f32 scales)."""
    xf = x.astype(jnp.float32)
    maxval = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(maxval, 1e-12) / (2 ** (bits - 1) - 1)
    codes = jnp.clip(jnp.round(xf / scale), -(2 ** (bits - 1) - 1),
                     2 ** (bits - 1) - 1).astype(jnp.int8)
    return codes, scale


def exact_k_bound(accum: str = "int32_native") -> int:
    """Max contraction length with guaranteed-exact accumulation (Prop 5.1)."""
    return accumulator_window(accum) // MAX_PIXEL_PRODUCT


def quantized_matmul(x, w_codes, w_scale, *, accum: str = "int32_native"):
    """(..., K) activations × (K, N) int8 weights via the AQT int32 path.

    w_scale: (1, N) per-output-column scales (from quantize_symmetric axis=0).
    """
    x_codes, x_scale = quantize_symmetric(x, axis=-1)
    if accum == "fp32_mantissa":
        acc = jnp.dot(x_codes.astype(jnp.float32),
                      w_codes.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
    else:
        acc = jnp.dot(x_codes.astype(jnp.int32), w_codes.astype(jnp.int32),
                      preferred_element_type=jnp.int32).astype(jnp.float32)
    return acc * x_scale * w_scale


class QuantizedLinear:
    """W8A8 projection layer sharing the crypto pipeline's MXU discipline."""

    def __init__(self, w, *, accum: str = "int32_native"):
        self.codes, self.scale = quantize_symmetric(w, axis=0)  # per-out-col
        self.accum = accum

    def __call__(self, x):
        x_codes, x_scale = quantize_symmetric(x, axis=-1)
        if self.accum == "fp32_mantissa":
            acc = jnp.dot(x_codes.astype(jnp.float32),
                          self.codes.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        else:
            acc = jnp.dot(x_codes.astype(jnp.int32),
                          self.codes.astype(jnp.int32),
                          preferred_element_type=jnp.int32).astype(jnp.float32)
        return (acc * x_scale * self.scale).astype(x.dtype)
