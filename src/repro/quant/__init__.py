from repro.quant.aqt import QuantizedLinear, quantized_matmul, quantize_symmetric
