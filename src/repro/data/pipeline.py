"""Deterministic sharded synthetic LM data pipeline.

Counter-based randomness (Philox keyed by (seed, step, host_shard)) makes
every batch a pure function of the step index — so restarts, elastic
re-sharding, and backup-worker re-issue (straggler mitigation) all reproduce
bit-identical data without coordination.  The iterator state is a single
integer; it checkpoints alongside the model.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    frontend_len: int = 0      # >0: also emit stub modality embeddings
    d_model: int = 0


class SyntheticLMStream:
    """Per-host shard of the global batch; state = step counter."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self._step = 0

    def _rng(self, step: int) -> np.random.Generator:
        mixed = (self.cfg.seed * 0x9E3779B97F4A7C15 + self.host_id) % (1 << 64)
        key = np.array([mixed, step], np.uint64)
        return np.random.Generator(np.random.Philox(key=key))

    def batch_at(self, step: int) -> dict:
        rng = self._rng(step)
        # structured synthetic data: Zipf-ish marginals + local repetition so
        # the LM loss actually decreases during the example training run
        z = rng.zipf(1.3, size=(self.local_batch, self.cfg.seq_len + 1))
        tokens = (z % self.cfg.vocab_size).astype(np.int32)
        rep = rng.integers(0, self.cfg.seq_len // 2 + 1)
        tokens[:, rep: 2 * rep] = tokens[:, :rep]  # copy motif
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.cfg.frontend_len:
            out["embeds"] = rng.normal(
                size=(self.local_batch, self.cfg.frontend_len,
                      self.cfg.d_model)).astype(np.float32)
        return out

    def __next__(self) -> dict:
        b = self.batch_at(self._step)
        self._step += 1
        return b

    def __iter__(self):
        return self

    # --- checkpointable state -------------------------------------------------
    def state(self) -> dict:
        return {"step": self._step, "seed": self.cfg.seed,
                "host_id": self.host_id, "n_hosts": self.n_hosts}

    def restore(self, state: dict):
        assert state["seed"] == self.cfg.seed
        self._step = int(state["step"])

    def reshard(self, host_id: int, n_hosts: int) -> "SyntheticLMStream":
        """Elastic re-sharding: same global stream, new host partition."""
        s = SyntheticLMStream(self.cfg, host_id=host_id, n_hosts=n_hosts)
        s._step = self._step
        return s
