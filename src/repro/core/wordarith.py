"""Multi-word exact integer arithmetic in base β = 2**12 ("digit-12").

The TPU VPU has no 64-bit integer ALU: every wide operation must decompose
into lanes whose products and partial sums stay inside the int32 window — the
architectural constraint the paper characterises.  We use 12-bit digits so a
digit product is < 2**24 and dozens of them accumulate in int32 without
carry interruptions; carries are then normalised in a handful of vectorised
passes.  (The MXU-side path uses 8-bit limbs — see limb_gemm — this module is
the VPU-side complement used by the Montgomery/base-extension phase.)
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

BETA_BITS = 12
BETA = 1 << BETA_BITS
DIGIT_MASK = BETA - 1


# --- Host-side (Python bignum) conversions -----------------------------------


def int_to_digits(x: int, n: int) -> np.ndarray:
    if x < 0:
        raise ValueError("negative")
    out = np.zeros(n, np.uint32)
    for j in range(n):
        out[j] = x & DIGIT_MASK
        x >>= BETA_BITS
    if x:
        raise ValueError(f"{n} digits insufficient")
    return out


def digits_to_int(d: np.ndarray) -> int:
    x = 0
    for j in range(len(d) - 1, -1, -1):
        x = (x << BETA_BITS) + int(d[j])
    return x


def digits_to_int_batch(d: np.ndarray) -> np.ndarray:
    """(..., n) digit arrays -> object array of Python ints."""
    flat = d.reshape(-1, d.shape[-1])
    out = np.array([digits_to_int(row) for row in flat], object)
    return out.reshape(d.shape[:-1])


# --- Device-side helpers ------------------------------------------------------


def u32_to_digits(x, n: int):
    """uint32 [...] -> (..., n) uint32 digit-12 planes."""
    x = x.astype(jnp.uint32)
    return jnp.stack(
        [(x >> jnp.uint32(BETA_BITS * t)) & jnp.uint32(DIGIT_MASK) for t in range(n)],
        axis=-1,
    )


def normalize_digits(d, passes: int = 6):
    """int32 (..., n) possibly-denormal digits -> uint32 canonical digits.

    Each pass moves carries one step up while dividing their magnitude by β;
    starting magnitudes < 2**30 vanish within 4 passes (6 for safety margin).
    The represented integer must be non-negative.
    """
    d = d.astype(jnp.int32)
    beta = jnp.int32(BETA)
    for _ in range(passes):
        q = jnp.floor_divide(d, beta)          # python-style floor for negatives
        r = d - q * beta                        # in [0, β)
        carry = jnp.pad(q, [(0, 0)] * (d.ndim - 1) + [(1, 0)])[..., :-1]
        d = r + carry
    return d.astype(jnp.uint32)


def scalar_conv_accumulate(scalars, const_digits, out_digits: int):
    """Σ_i scalars[..., i] · const_i as denormal digit-12 planes.

    scalars: uint32 (..., k), each < 2**31 (three digit-12 planes).
    const_digits: uint32 (k, n_c) — host-precomputed digit-12 constants.
    Returns int32 (..., out_digits), denormal (caller normalises/subtracts).

    Implemented as three int32 matmuls (one per scalar digit plane), i.e. the
    dense base-extension matrix-vector products of paper §6.2.
    """
    k, n_c = const_digits.shape
    sc_d = u32_to_digits(scalars, 3).astype(jnp.int32)    # (..., k, 3)
    cd = const_digits.astype(jnp.int32)
    out = jnp.zeros(scalars.shape[:-1] + (out_digits,), jnp.int32)
    for t in range(3):
        part = jnp.matmul(sc_d[..., t], cd)                # (..., n_c) < 2**28
        out = out.at[..., t:t + n_c].add(part)
    return out


def cond_subtract(t, p_digits):
    """Multi-digit conditional subtract: t - p if t >= p else t (canonical)."""
    n = p_digits.shape[0]
    t32 = t.astype(jnp.int32)
    p32 = p_digits.astype(jnp.int32)
    diff = jnp.zeros_like(t32)
    borrow = jnp.zeros(t.shape[:-1], jnp.int32)
    for j in range(n):
        d = t32[..., j] - p32[j] - borrow
        b = (d < 0).astype(jnp.int32)
        diff = diff.at[..., j].set(d + b * BETA)
        borrow = b
    take_diff = borrow == 0  # t >= p
    return jnp.where(take_diff[..., None], diff, t32).astype(jnp.uint32)


def digits_submod_p(a, b, p_digits):
    """(a - b) mod p over canonical digit arrays (a, b < p)."""
    n = p_digits.shape[0]
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    p32 = p_digits.astype(jnp.int32)
    diff = jnp.zeros_like(a32)
    summ = jnp.zeros_like(a32)
    borrow = jnp.zeros(a.shape[:-1], jnp.int32)
    carry = jnp.zeros(a.shape[:-1], jnp.int32)
    for j in range(n):
        d = a32[..., j] - b32[..., j] - borrow
        bo = (d < 0).astype(jnp.int32)
        diff = diff.at[..., j].set(d + bo * BETA)
        borrow = bo
        s = diff[..., j] + p32[j] + carry  # diff[...,j] is final here (serial)
        summ = summ.at[..., j].set(s & DIGIT_MASK)
        carry = s >> BETA_BITS               # final top carry (=1) drops: +p-β^n
    underflow = borrow == 1
    return jnp.where(underflow[..., None], summ, diff).astype(jnp.uint32)


def digits_geq(t, p_digits):
    """t >= p comparison over canonical digit arrays."""
    borrow = jnp.zeros(t.shape[:-1], jnp.int32)
    t32 = t.astype(jnp.int32)
    for j in range(p_digits.shape[0]):
        d = t32[..., j] - jnp.int32(p_digits[j]) - borrow
        borrow = (d < 0).astype(jnp.int32)
    return borrow == 0
