"""u8 limb decomposition / recomposition and balanced signed recoding.

Device operands (polynomial coefficients) are staged as **unsigned** u8 limbs,
twiddle matrices as **balanced signed** s8 limbs — the AQT-documented u8×s8
DotGeneral lowering the paper measures.  Balanced recoding keeps every twiddle
digit in [-128, 127] so the s8 operand is representable for moduli < 2**31,
bounding each MXU cross-product by 255·128 = 32,640 (paper §5.1).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def decompose_u8(x, n_limbs: int):
    """uint32 [...] -> u8 limb planes [..., n_limbs], little-endian."""
    x = x.astype(jnp.uint32)
    limbs = [(x >> jnp.uint32(8 * k)) & jnp.uint32(0xFF) for k in range(n_limbs)]
    return jnp.stack(limbs, axis=-1).astype(jnp.uint8)


def recompose_u32(limbs):
    """u8 limb planes [..., n_limbs] -> uint32 [...]."""
    limbs = limbs.astype(jnp.uint32)
    out = jnp.zeros(limbs.shape[:-1], jnp.uint32)
    for k in range(limbs.shape[-1] - 1, -1, -1):
        out = (out << jnp.uint32(8)) + limbs[..., k]
    return out


# --- Host-side (numpy / Python-int) helpers ---------------------------------


def balanced_residue(w: np.ndarray, m: int) -> np.ndarray:
    """Map residues in [0, m) to balanced representatives in (-m/2, m/2]."""
    w = w.astype(np.int64)
    return np.where(w > m // 2, w - m, w)


def signed_digits(x: np.ndarray, n_limbs: int) -> np.ndarray:
    """Balanced base-256 signed-digit recode of int64 values.

    Digits lie in [-128, 127]; covers |x| <= 127·(256^n - 1)/255 + eps, which
    holds for balanced residues of any modulus < 2**31 at n_limbs=4 and for
    balanced Dilithium residues (|x| <= Q/2 < 2**22) at n_limbs=3.
    """
    x = x.astype(np.int64)
    digits = np.zeros(x.shape + (n_limbs,), np.int64)
    rem = x.copy()
    for k in range(n_limbs):
        d = ((rem + 128) & 0xFF) - 128  # digit in [-128, 127], rem ≡ d (mod 256)
        digits[..., k] = d
        rem = (rem - d) >> 8
    if np.any(rem != 0):
        raise ValueError("values out of range for signed-digit recode")
    if np.any(digits > 127) or np.any(digits < -128):
        raise ValueError("digit overflow")
    return digits.astype(np.int8)


def unsigned_digits_np(x: np.ndarray, n_limbs: int) -> np.ndarray:
    """numpy little-endian u8 digit extraction (host twin of decompose_u8)."""
    x = x.astype(np.uint64)
    out = np.zeros(x.shape + (n_limbs,), np.uint8)
    for k in range(n_limbs):
        out[..., k] = ((x >> np.uint64(8 * k)) & np.uint64(0xFF)).astype(np.uint8)
    return out


def signed_digits_value(digits: np.ndarray) -> np.ndarray:
    """Recompose signed digits back to int64 values (test helper)."""
    digits = digits.astype(np.int64)
    val = np.zeros(digits.shape[:-1], np.int64)
    for k in range(digits.shape[-1] - 1, -1, -1):
        val = (val << 8) + digits[..., k]
    return val
