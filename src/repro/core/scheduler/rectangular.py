"""Tier 1 — the Rectangular Scheduler (paper §4.1).

Groups same-workload requests into degree buckets, pads each tenant's
``1 × d_i`` vector to the bucket maximum, and stacks ``N_c`` of them into a
dense ``N_c × d̂_max`` operand mapped to the systolic array's M dimension.
Row semantics give cross-tenant arithmetic isolation (Property 5.1); the
packing metrics quantify the paper's Table 5 trade-offs.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.scheduler.queue import TenantRequest


@dataclasses.dataclass
class StackedBatch:
    workload: str
    d_bucket: int                    # padded operand degree d̂_max
    requests: list                   # the N_c tenant requests (row order)
    operand: np.ndarray | None       # (N_c, d̂) uint32 (or None if metadata-only)

    @property
    def n_c(self) -> int:
        return len(self.requests)

    @property
    def degrees(self) -> list[int]:
        return [r.degree for r in self.requests]


def bucket_degree(d: int, granularity: int = 64) -> int:
    """Pad degree to the bucket boundary (multiple of `granularity`)."""
    return max(granularity, granularity * math.ceil(d / granularity))


def bucket_pow2(d: int, floor: int = 64) -> int:
    """Power-of-two bucket — every bucket is an NTT-friendly transform size
    for both workload classes (2-adicity: Dilithium ≤ 2^13, BN254 Fr ≤ 2^28).
    Used by the execution path; the granular buckets above are kept for the
    paper's Table-5 packing-metric convention."""
    return max(floor, 1 << (d - 1).bit_length())


def select_bucket(d: int, granularity: int | None = None) -> int:
    """The one bucketing policy shared by offline planning and the online
    batcher: pow2 (execution path) unless a granularity selects the paper's
    Table-5 convention."""
    if granularity is None:
        return bucket_pow2(d)
    return bucket_degree(d, granularity)


@dataclasses.dataclass(frozen=True)
class PackingMetrics:
    batch_fill: float        # Σ d_i / (N_c · d̂)  — active cells per row
    padding_waste: float     # 1 − Σ d_i / (N_c · footprint); footprint =
                             # ⌈d̂/d_max⌉·d_max (paper §7.4: hardware passes
                             # burn full d_max windows — Dilithium d=256 →
                             # 342 footprint → 25% waste)
    staging_overhead: float  # (⌈d̂/d_max⌉ − 1)/⌈d̂/d_max⌉ (re-injection passes)
    m_occupancy: float       # N_c / 128 — M-dimension systolic occupancy
    k_occupancy: float       # in-window K-dimension column occupancy


def packing_metrics(degrees: list[int], d_bucket: int, d_max: int,
                    n_c_max: int = 128) -> PackingMetrics:
    n_c = len(degrees)
    total = n_c * d_bucket
    fill = sum(degrees) / total if total else 0.0
    n_pass = math.ceil(d_bucket / d_max)
    staging = (n_pass - 1) / n_pass
    footprint = n_pass * d_max
    waste = 1.0 - (sum(degrees) / (n_c * footprint)) if n_c else 0.0
    # K occupancy: within active dispatch windows, the fraction of K slots
    # holding non-padded operand cells.  Uniform d == bucket ⇒ 1.0.
    k_occ = fill  # row-stacking makes K-column occupancy == per-row fill
    return PackingMetrics(
        batch_fill=fill, padding_waste=waste, staging_overhead=staging,
        m_occupancy=min(1.0, n_c / n_c_max), k_occupancy=k_occ)


def block_diagonal_zero_fraction(degrees: list[int]) -> float:
    """Structural-zero fraction of the monolithic block-diagonal alternative.

    Stacking N_c polynomials as a (Σd_i) × (Σd_i) block-diagonal operand
    wastes 1 − Σd_i²/(Σd_i)² of the array — the waste Tier 1 eliminates.
    """
    s = sum(degrees)
    if s == 0:
        return 0.0
    return 1.0 - sum(d * d for d in degrees) / (s * s)


def stack_rows(reqs: list, d_bucket: int,
               n_rows: int | None = None) -> np.ndarray | None:
    """Assemble tenant payloads into a dense ``n_rows × d_bucket`` operand.

    Each request's coefficients fill row i up to its degree; the remainder is
    zero padding.  ``n_rows`` > len(reqs) appends all-zero rows so every batch
    of a (workload, bucket) class shares one operand shape — the online
    batcher uses this to keep the co-scheduler's compiled-program cache warm.
    Returns None for metadata-only requests (dry-run / trace replay).
    """
    if not reqs or any(r.coeffs is None for r in reqs):
        return None
    payload = reqs[0].coeffs
    rows = len(reqs) if n_rows is None else max(n_rows, len(reqs))
    shape = (rows, d_bucket) + payload.shape[1:]
    a = np.zeros(shape, np.uint32)
    for i, r in enumerate(reqs):
        a[i, : r.degree] = r.coeffs
    return a


def merge_operands(operands: list[np.ndarray],
                   n_rows: int | None = None) -> np.ndarray:
    """Concatenate same-class stacked operands along M into one tall operand.

    ``operands`` must share every trailing dimension (same ``(workload,
    d_bucket)`` class guarantees it); ``n_rows`` > the concatenated height
    appends all-zero rows, which is how the dispatch fast path pads a merged
    super-batch up to its row-ladder rung.  Row semantics (Property 5.1) make
    the merged launch bit-for-bit equal to the per-operand launches.
    """
    total = sum(op.shape[0] for op in operands)
    rows = total if n_rows is None else max(n_rows, total)
    out = np.zeros((rows,) + operands[0].shape[1:], operands[0].dtype)
    lo = 0
    for op in operands:
        out[lo:lo + op.shape[0]] = op
        lo += op.shape[0]
    return out


class RectangularScheduler:
    """Builds dense stacked operands from a workload-homogeneous queue."""

    def __init__(self, *, n_c: int = 8, bucket_granularity: int | None = None):
        """bucket_granularity=None (default) → power-of-two buckets (always
        NTT-transformable); an int selects the paper's granular buckets
        (metric-compatible with Table 5)."""
        self.n_c = n_c
        self.granularity = bucket_granularity

    def bucket_for(self, d: int) -> int:
        return select_bucket(d, self.granularity)

    def plan_batches(self, requests: list[TenantRequest]) -> list[StackedBatch]:
        """Group by (workload, bucket) and cut into N_c-row stacked batches."""
        groups: dict[tuple, list[TenantRequest]] = {}
        for r in requests:
            key = (r.workload, self.bucket_for(r.degree))
            groups.setdefault(key, []).append(r)
        batches = []
        for (workload, d_bucket), reqs in sorted(groups.items()):
            for lo in range(0, len(reqs), self.n_c):
                chunk = reqs[lo:lo + self.n_c]
                batches.append(StackedBatch(
                    workload=workload, d_bucket=d_bucket, requests=chunk,
                    operand=self._assemble(chunk, d_bucket)))
        return batches

    def _assemble(self, reqs: list[TenantRequest], d_bucket: int):
        return stack_rows(reqs, d_bucket)

    def unstack(self, batch: StackedBatch, result: np.ndarray) -> dict[int, np.ndarray]:
        """Route batched rows back to tenants (isomorphic to isolated eval)."""
        return {r.tenant_id: result[i] for i, r in enumerate(batch.requests)}
