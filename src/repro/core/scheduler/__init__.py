"""Aegis two-tier scheduling (paper §4).

Tier 1 — :mod:`rectangular`: degree-bucketed dense row-stacking of tenant
polynomials into ``N_c × d̂_max`` operands (no block-diagonal structural
zeros), with the paper's packing metrics (batch fill, padding waste, staging
overhead, M/K-dimension occupancy).

Tier 2 — :mod:`coscheduler`: slice-level dispatch of workload-homogeneous
batches onto disjoint device groups (Dilithium next to BN254 concurrently),
with workload-zone tags carried into the HLO for the post-hoc validator.

:mod:`queue` — ingress queue + Poisson trace synthesis (paper §7.4).
"""
from repro.core.scheduler.queue import TenantRequest, PoissonTrace, IngressQueue
from repro.core.scheduler.rectangular import (RectangularScheduler,
                                              StackedBatch, packing_metrics,
                                              bucket_degree, bucket_pow2,
                                              stack_rows)
from repro.core.scheduler.coscheduler import SliceCoScheduler
