"""Ingress queue and synthetic multi-tenant arrival traces (paper §7.4)."""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class TenantRequest:
    tenant_id: int
    workload: str            # "dilithium" | "bn254" | ...
    degree: int              # unpadded degree d_i
    arrival_time: float      # seconds since trace start
    coeffs: np.ndarray | None = None   # optional payload (uint32 [d] or [d, C])


@dataclasses.dataclass(frozen=True)
class PoissonTrace:
    """Synthetic arrival trace: Poisson arrivals, workload mixture, degree law.

    Paper §7.4: λ = 4,096 req/s aggregate, 50:50 Dilithium:BN254 balanced
    mixture, degrees uniform in [64, 512].
    """

    rate_hz: float = 4096.0
    duration_s: float = 1.0
    mixture: tuple = (("dilithium", 0.5), ("bn254", 0.5))
    degree_low: int = 64
    degree_high: int = 512
    uniform_degree: int | None = None   # fixed-degree traces (d=256 headline)
    seed: int = 0

    def generate(self) -> list[TenantRequest]:
        rng = np.random.default_rng(self.seed)
        n = rng.poisson(self.rate_hz * self.duration_s)
        times = np.sort(rng.uniform(0.0, self.duration_s, n))
        names = [m[0] for m in self.mixture]
        probs = np.asarray([m[1] for m in self.mixture])
        kinds = rng.choice(len(names), size=n, p=probs / probs.sum())
        if self.uniform_degree is not None:
            degs = np.full(n, self.uniform_degree)
        else:
            degs = rng.integers(self.degree_low, self.degree_high + 1, n)
        return [TenantRequest(tenant_id=i, workload=names[kinds[i]],
                              degree=int(degs[i]), arrival_time=float(times[i]))
                for i in range(n)]


class IngressQueue:
    """Per-workload-class FIFO queues (type-homogeneity segregation, §4.1)."""

    def __init__(self):
        self._queues: dict[str, deque] = {}

    def push(self, req: TenantRequest):
        self._queues.setdefault(req.workload, deque()).append(req)

    def push_trace(self, trace: list[TenantRequest]):
        for r in trace:
            self.push(r)

    def pop_batch(self, workload: str, n_c: int) -> list[TenantRequest]:
        q = self._queues.get(workload)
        if not q:
            return []
        out = []
        while q and len(out) < n_c:
            out.append(q.popleft())
        return out

    def depth(self, workload: str) -> int:
        return len(self._queues.get(workload, ()))

    @property
    def workloads(self) -> list[str]:
        return [k for k, q in self._queues.items() if q]
