"""Tier 2 — the Slice-Level Co-Scheduler (paper §4.1).

Maps workload-homogeneous stacked batches onto *disjoint device groups* of a
pod slice so heterogeneous cryptographic primitives (Dilithium next to BN254)
execute concurrently without sharing TensorCores.  Per-class jit programs are
dispatched with batch rows sharded across the group's devices; workload-zone
scopes (:mod:`repro.core.zones`) travel into the HLO for the post-hoc
validator.

On a 1-device CPU test rig every group degenerates to the same device —
multi-device behaviour is exercised via subprocess tests and the pod-slice
dry-run.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import limb_gemm as G
from repro.core import workloads as WK
from repro.core.scheduler.rectangular import StackedBatch


@dataclasses.dataclass
class DispatchResult:
    batch: StackedBatch
    outputs: dict          # tenant_id -> result rows (numpy; last-wins if a
                           # tenant has several rows — use `rows` to route by
                           # position)
    stats: dict
    rows: object = None    # (n_rows, ...) result array, batch row order


class SliceCoScheduler:
    """Static workload → device-group assignment over a pod slice.

    ``reduction`` sets the default fold discipline; ``reduction_by_workload``
    overrides it per workload class, so lazy (κ-amortised) tenants can share
    the slice with strictly-eager tenants — each class keeps its own engines,
    compiled programs, and device group, so the disciplines never mix inside
    one program (paper §7.2.1).  Mode strings are validated here: a typo must
    fail construction, not silently trace the eager path.
    """

    def __init__(self, assignment: dict[str, list] | None = None,
                 *, accum: str = "fp32_mantissa", reduction: str = "eager",
                 reduction_by_workload: dict[str, str] | None = None,
                 kappa: int | None = None, d_tile: int | None = None,
                 host: int | None = None):
        devices = jax.devices()
        if assignment is None:
            # default: split the slice evenly across workload classes
            assignment = {"dilithium": devices[: max(1, len(devices) // 2)],
                          "bn254": devices[max(1, len(devices) // 2):] or devices}
        self.assignment = assignment
        self.accum = accum
        self.reduction = G.check_reduction(reduction)
        self.reduction_by_workload = dict(reduction_by_workload or {})
        for w, mode in self.reduction_by_workload.items():
            if w not in WK.CLASSES:
                raise ValueError(f"unknown workload class {w!r} in "
                                 f"reduction_by_workload")
            G.check_reduction(mode)
        self.kappa = kappa
        self.d_tile = d_tile
        # Cluster mode runs one co-scheduler per host slice; the owning host id
        # travels into per-host telemetry so compiled-program caches and trace
        # counters stay attributable after snapshots are merged.
        self.host = host
        self._meshes = {
            w: Mesh(np.asarray(devs), ("rows",))
            for w, devs in assignment.items()
        }
        self._engines: dict = {}
        self._jitted: dict = {}
        # (workload, d_bucket) -> number of times XLA retraced the program.
        # Incremented inside the traced body, so cached executions leave it
        # untouched; one count per distinct operand shape is the healthy state.
        self.trace_counts: dict = {}

    def reduction_for(self, workload: str) -> str:
        """The fold discipline this slice applies to a workload class."""
        return self.reduction_by_workload.get(workload, self.reduction)

    def engine_for(self, workload: str, d: int):
        key = (workload, d)
        if key not in self._engines:
            self._engines[key] = WK.make_engine(
                workload, d, accum=self.accum,
                reduction=self.reduction_for(workload), kappa=self.kappa,
                d_tile=self.d_tile)
        return self._engines[key]

    def jitted_for(self, workload: str, d: int):
        """One compiled e2e program per (workload, d_bucket), reused across
        dispatches — rebuilding ``jax.jit(eng.e2e)`` per dispatch discards the
        executable cache and recompiles every batch."""
        key = (workload, d)
        if key not in self._jitted:
            eng = self.engine_for(workload, d)

            def _e2e(operand, _eng=eng, _key=key):
                self.trace_counts[_key] = self.trace_counts.get(_key, 0) + 1
                return _eng.e2e(operand)

            self._jitted[key] = jax.jit(_e2e)
        return self._jitted[key]

    def operand_shape(self, workload: str, d: int, n_c: int) -> tuple:
        """Device operand shape of one stacked batch — the jit cache key."""
        if workload == "dilithium":
            return (n_c, d)
        return (n_c, d, self.engine_for(workload, d).n_channels)

    def precompile(self, programs, n_c: int) -> int:
        """Warm-start the compiled-program cache: trace + compile the known
        ``(workload, d_bucket)`` set for ``n_c``-row operands before first
        dispatch, so cold-start p99 is not dominated by XLA compilation.
        Returns the number of fresh traces this triggered; a later dispatch
        of any warmed program at the same shape must trigger zero more
        (asserted via ``trace_counts`` in the serving tests)."""
        n_new = 0
        for workload, d in programs:
            key = (workload, d)
            before = self.trace_counts.get(key, 0)
            operand = jnp.zeros(self.operand_shape(workload, d, n_c),
                                jnp.uint32)
            out = self.jitted_for(workload, d)(self._shard(workload, operand))
            jax.block_until_ready(out)
            n_new += self.trace_counts.get(key, 0) - before
        return n_new

    def _shard(self, workload: str, operand: jnp.ndarray):
        mesh = self._meshes[workload]
        n_dev = mesh.devices.size
        rows = operand.shape[0]
        if rows % n_dev == 0 and n_dev > 1:
            spec = P("rows")
        else:
            spec = P()
        return jax.device_put(operand, NamedSharding(mesh, spec))

    def _launch(self, batch: StackedBatch):
        """Enqueue one stacked batch on its workload's device group and return
        the in-flight device result without materialising it."""
        eng = self.engine_for(batch.workload, batch.d_bucket)
        if batch.workload == "dilithium":
            operand = jnp.asarray(batch.operand)            # (N_c, d)
        else:
            if batch.operand.ndim == 2:                     # raw words → residues
                operand = eng.ingest(batch.operand.astype(object))
            else:
                operand = jnp.asarray(batch.operand)        # (N_c, d, C)
        operand = self._shard(batch.workload, operand)
        out = self.jitted_for(batch.workload, batch.d_bucket)(operand)
        return batch, eng, out

    def _materialise(self, batch: StackedBatch, eng, out) -> DispatchResult:
        res = np.asarray(out)
        outputs = {r.tenant_id: res[i] for i, r in enumerate(batch.requests)}
        # last_stats is trace-time state (one channel's staged_transform);
        # fold_profile is the static whole-program census — deterministic per
        # (workload, d_bucket) and what the serve telemetry aggregates.
        stats = dict(getattr(eng, "last_stats", {}) or {})
        stats.update(eng.fold_profile)
        return DispatchResult(batch=batch, outputs=outputs, stats=stats,
                              rows=res)

    def dispatch(self, batch: StackedBatch) -> DispatchResult:
        """Execute one stacked batch on its workload's device group."""
        return self._materialise(*self._launch(batch))

    def dispatch_mixed(self, batches: list[StackedBatch]) -> list[DispatchResult]:
        """Concurrent heterogeneous dispatch: per-class programs launched
        back-to-back; XLA queues them on disjoint device groups so Dilithium
        and BN254 batches overlap on real multi-device slices.  All launches
        happen before any host transfer — materialising between launches
        would serialise the groups behind a blocking ``np.asarray``."""
        inflight = [self._launch(b) for b in batches]
        return [self._materialise(*f) for f in inflight]
