"""Tier 2 — the Slice-Level Co-Scheduler (paper §4.1).

Maps workload-homogeneous stacked batches onto *disjoint device groups* of a
pod slice so heterogeneous cryptographic primitives (Dilithium next to BN254)
execute concurrently without sharing TensorCores.  Per-class jit programs are
dispatched with batch rows sharded across the group's devices; workload-zone
scopes (:mod:`repro.core.zones`) travel into the HLO for the post-hoc
validator.

On a 1-device CPU test rig every group degenerates to the same device —
multi-device behaviour is exercised via subprocess tests and the pod-slice
dry-run.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import workloads as WK
from repro.core.scheduler.rectangular import StackedBatch


@dataclasses.dataclass
class DispatchResult:
    batch: StackedBatch
    outputs: dict          # tenant_id -> result rows (numpy)
    stats: dict


class SliceCoScheduler:
    """Static workload → device-group assignment over a pod slice."""

    def __init__(self, assignment: dict[str, list] | None = None,
                 *, accum: str = "fp32_mantissa", reduction: str = "eager"):
        devices = jax.devices()
        if assignment is None:
            # default: split the slice evenly across workload classes
            assignment = {"dilithium": devices[: max(1, len(devices) // 2)],
                          "bn254": devices[max(1, len(devices) // 2):] or devices}
        self.assignment = assignment
        self.accum = accum
        self.reduction = reduction
        self._meshes = {
            w: Mesh(np.asarray(devs), ("rows",))
            for w, devs in assignment.items()
        }
        self._engines: dict = {}

    def engine_for(self, workload: str, d: int):
        key = (workload, d)
        if key not in self._engines:
            self._engines[key] = WK.make_engine(
                workload, d, accum=self.accum, reduction=self.reduction)
        return self._engines[key]

    def _shard(self, workload: str, operand: jnp.ndarray):
        mesh = self._meshes[workload]
        n_dev = mesh.devices.size
        rows = operand.shape[0]
        if rows % n_dev == 0 and n_dev > 1:
            spec = P("rows")
        else:
            spec = P()
        return jax.device_put(operand, NamedSharding(mesh, spec))

    def dispatch(self, batch: StackedBatch) -> DispatchResult:
        """Execute one stacked batch on its workload's device group."""
        eng = self.engine_for(batch.workload, batch.d_bucket)
        if batch.workload == "dilithium":
            operand = jnp.asarray(batch.operand)            # (N_c, d)
        else:
            if batch.operand.ndim == 2:                     # raw words → residues
                operand = eng.ingest(batch.operand.astype(object))
            else:
                operand = jnp.asarray(batch.operand)        # (N_c, d, C)
        operand = self._shard(batch.workload, operand)
        out = jax.jit(eng.e2e)(operand)
        res = np.asarray(out)
        outputs = {r.tenant_id: res[i] for i, r in enumerate(batch.requests)}
        return DispatchResult(batch=batch, outputs=outputs,
                              stats=dict(getattr(eng, "last_stats", {}) or {}))

    def dispatch_mixed(self, batches: list[StackedBatch]) -> list[DispatchResult]:
        """Concurrent heterogeneous dispatch: per-class programs launched
        back-to-back; XLA queues them on disjoint device groups so Dilithium
        and BN254 batches overlap on real multi-device slices."""
        return [self.dispatch(b) for b in batches]
