"""Tier 2 — the Slice-Level Co-Scheduler (paper §4.1) + the dispatch fast path.

Maps workload-homogeneous stacked batches onto *disjoint device groups* of a
pod slice so heterogeneous cryptographic primitives (Dilithium next to BN254)
execute concurrently without sharing TensorCores.  Per-class jit programs are
dispatched with batch rows sharded across the group's devices; workload-zone
scopes (:mod:`repro.core.zones`) travel into the HLO for the post-hoc
validator.

The dispatch fast path (the hottest loop in the repo) adds three levers, all
bit-for-bit neutral:

* **M-axis super-batching** (``merge``) — ``dispatch_mixed`` coalesces
  same-``(workload, d_bucket, reduction)`` stacked batches into one tall
  operand before launch, recovering the M-dimension fill the paper measures
  collapsing to 6.25% on v4.  Row semantics (Property 5.1) make the merged
  launch equal to the per-batch launches row-for-row.
* **Row-ladder compile cache** (``row_ladder``) — batch heights are padded up
  to a small geometric ladder of rungs (e.g. 8→16→…→128) so ``trace_counts``
  per ``(workload, d_bucket)`` is bounded by the ladder size instead of by
  the number of distinct arrival counts; padded rows are all-zero and sliced
  off before tenant routing.  ``precompile`` warms every rung.
* **Zero-sync two-phase pipeline** — ``launch_mixed`` enqueues every program
  and starts the device→host copies asynchronously; ``gather`` materialises
  later, so a pump loop can launch batch *n+1* before batch *n*'s result
  crosses PCIe.  ``donate=True`` additionally donates the operand buffer to
  its e2e program (``donate_argnums``), and twiddle/fused planes are passed
  as device-resident jit arguments (uploaded once per engine) instead of
  being re-embedded as host constants at every trace.

On a 1-device CPU test rig every group degenerates to the same device —
multi-device behaviour is exercised via subprocess tests and the pod-slice
dry-run.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import limb_gemm as G
from repro.core import workloads as WK
from repro.core.scheduler.rectangular import StackedBatch, merge_operands

# Bounded history of per-launch merge/padding records (the serving layer
# drains it into telemetry after every dispatch; non-serving callers just
# let old entries fall off).
DISPATCH_LOG_MAX = 4096

# Minimum legal row-ladder rung.  A rung below the systolic M-tile height
# compiles a program whose operand cannot be split across a device group
# (and on real slices wastes a full sublane tile per launch); historically a
# sub-tile or shuffled ladder only surfaced later as a confusing
# launch-shape error inside XLA — now it is rejected at construction.
MIN_ROW_TILE = 2


def resolve_devices(devices):
    """Normalise a ``devices=`` spec into a list of live ``jax.Device``s.

    ``None`` means "every device in the process" (today's behaviour).
    Entries may be integer device ids or ``jax.Device`` objects; anything
    out of range, unknown, or listed twice fails loudly here — a duplicate
    or phantom device in a host slice's pin list would otherwise surface
    as two hosts silently serialising on one queue (the exact failure mode
    device pinning exists to remove).
    """
    all_devs = list(jax.devices())
    if devices is None:
        return all_devs
    by_id = {d.id: d for d in all_devs}
    resolved, seen = [], set()
    for i, entry in enumerate(devices):
        if isinstance(entry, (int, np.integer)):
            dev = by_id.get(int(entry))
            if dev is None:
                raise ValueError(
                    f"devices[{i}] = {entry} is out of range: this process "
                    f"has {len(all_devs)} JAX device(s) (ids "
                    f"0..{len(all_devs) - 1}); on CPU, widen the slice with "
                    f"XLA_FLAGS --xla_force_host_platform_device_count=N "
                    f"before the first jax use")
        else:
            dev = by_id.get(getattr(entry, "id", None))
            if dev is None or dev is not entry:
                raise ValueError(
                    f"devices[{i}] = {entry!r} is not a device of this "
                    f"process (jax.devices() has ids "
                    f"0..{len(all_devs) - 1})")
        if dev.id in seen:
            raise ValueError(
                f"devices[{i}] names device {dev.id} twice: a host slice "
                f"pinned to a repeated device would share a launch queue "
                f"with itself — each pin must be distinct")
        seen.add(dev.id)
        resolved.append(dev)
    if not resolved:
        raise ValueError("devices= must name at least one device "
                         "(use None for the whole process)")
    return resolved


def partition_devices(n_parts: int, devices=None) -> list[list]:
    """Split the process's devices into ``n_parts`` host slices.

    With D ≥ n_parts devices each slice gets a contiguous near-even chunk
    (first ``D mod n_parts`` slices get the extra device); with D <
    n_parts, slices wrap round-robin onto single devices — hosts then
    share queues, which the dispatch-overlap audit makes visible rather
    than hiding.  The cluster layer uses this when ``device_parallel`` is
    on; benches/tests call it directly to build per-device co-schedulers.
    """
    if n_parts < 1:
        raise ValueError(f"partition_devices needs n_parts >= 1, "
                         f"got {n_parts}")
    devs = resolve_devices(devices)
    if len(devs) >= n_parts:
        base, extra = divmod(len(devs), n_parts)
        out, lo = [], 0
        for i in range(n_parts):
            hi = lo + base + (1 if i < extra else 0)
            out.append(devs[lo:hi])
            lo = hi
        return out
    return [[devs[i % len(devs)]] for i in range(n_parts)]


def validate_row_ladder(row_ladder) -> tuple[int, ...]:
    """Validate a compile-cache rung ladder at construction time.

    Rungs must be unique, strictly increasing, and at least
    ``MIN_ROW_TILE`` tall; anything else raises a ``ValueError`` naming the
    offending rung instead of letting a mis-shaped ladder reach dispatch.
    """
    ladder = tuple(int(r) for r in row_ladder)
    if not ladder:
        raise ValueError("row_ladder must name at least one rung")
    low = [r for r in ladder if r < MIN_ROW_TILE]
    if low:
        raise ValueError(
            f"row_ladder rungs must be ≥ {MIN_ROW_TILE} (the minimum M-tile "
            f"height): got {low} in {ladder}")
    for prev, cur in zip(ladder, ladder[1:]):
        if cur == prev:
            raise ValueError(
                f"row_ladder has a duplicate rung {cur} in {ladder}: each "
                f"rung is one compiled program — duplicates would double-"
                f"count the trace budget")
        if cur < prev:
            raise ValueError(
                f"row_ladder must be strictly increasing, got {cur} after "
                f"{prev} in {ladder}: launch_rows snaps a height to the "
                f"first rung that fits, so a shuffled ladder launches at "
                f"the wrong height")
    return ladder


def default_row_ladder(n_max: int, n_min: int = 8) -> tuple[int, ...]:
    """Geometric rung set ``n_min, 2·n_min, … ≥ n_max`` (the compile-cache
    ladder).  ``len(default_row_ladder(128)) == 5`` — and so is the bound on
    ``trace_counts`` per program class."""
    if n_max < 1 or n_min < 1:
        raise ValueError(f"row ladder needs positive bounds "
                         f"(got n_min={n_min}, n_max={n_max})")
    rungs, r = [], n_min
    while r < n_max:
        rungs.append(r)
        r *= 2
    rungs.append(n_max)     # top rung is exactly n_max (the merge cap)
    return tuple(rungs)


@dataclasses.dataclass
class DispatchResult:
    batch: StackedBatch
    outputs: dict          # tenant_id -> result rows (numpy; last-wins if a
                           # tenant has several rows — use `rows` to route by
                           # position)
    stats: dict
    rows: object = None    # (n_rows, ...) result array, batch row order


@dataclasses.dataclass
class _LaunchGroup:
    """One compiled-program launch: ≥1 same-class batches stacked along M."""
    workload: str
    d_bucket: int
    members: list          # (input index, StackedBatch, row_lo, row_hi)
    operand_rows: int = 0  # stacked operand height before ladder padding
    live_rows: int = 0     # tenant rows only (excludes batcher zero-pad rows)
    lid: int = 0           # causal launch ID (0 when tracing is off)


@dataclasses.dataclass
class InflightDispatch:
    """launch_mixed → gather handle: device results with D2H copies already
    streaming; gathering materialises without re-synchronising launches."""
    groups: list           # (_LaunchGroup, engine, device result)
    n_batches: int


class SliceCoScheduler:
    """Static workload → device-group assignment over a pod slice.

    ``reduction`` sets the default fold discipline; ``reduction_by_workload``
    overrides it per workload class, so lazy (κ-amortised) tenants can share
    the slice with strictly-eager tenants — each class keeps its own engines,
    compiled programs, and device group, so the disciplines never mix inside
    one program (paper §7.2.1).  Mode strings are validated here: a typo must
    fail construction, not silently trace the eager path — and so must an
    all-eager config carrying κ>1, which used to construct silently and only
    blow up (or record a bogus κ) deep in dispatch.
    """

    def __init__(self, assignment: dict[str, list] | None = None,
                 *, accum: str = "fp32_mantissa", reduction: str = "eager",
                 reduction_by_workload: dict[str, str] | None = None,
                 kappa: int | None = None, d_tile: int | None = None,
                 merge: bool = True, row_ladder: tuple | None = None,
                 merge_rows_max: int = 128, donate: bool = False,
                 host: int | None = None, devices=None):
        # devices= pins this co-scheduler to an explicit device sub-slice
        # (ints or jax.Device objects; validated by resolve_devices).  The
        # pin is what makes a cluster host slice's launches land on *its*
        # device instead of the process default: operands are committed via
        # _shard, and the engine's cached twiddle planes are re-homed per
        # co-scheduler (device_planes_for) because make_engine is shared
        # process-wide.  devices=None keeps today's behaviour bit-for-bit.
        self._pinned = devices is not None
        pinned = resolve_devices(devices)
        if assignment is None:
            # default: split the slice evenly across workload classes
            assignment = {"dilithium": pinned[: max(1, len(pinned) // 2)],
                          "bn254": pinned[max(1, len(pinned) // 2):] or pinned}
            self.devices = pinned
        else:
            ordered: dict[int, object] = {}
            for devs in assignment.values():
                for d in devs:
                    ordered.setdefault(d.id, d)
            self.devices = list(ordered.values())
        self.assignment = assignment
        self.accum = accum
        self.reduction = G.check_reduction(reduction)
        self.reduction_by_workload = dict(reduction_by_workload or {})
        for w, mode in self.reduction_by_workload.items():
            if w not in WK.CLASSES:
                raise ValueError(f"unknown workload class {w!r} in "
                                 f"reduction_by_workload")
            G.check_reduction(mode)
        # κ only means something under lazy folding: if no class is lazy,
        # reject the deferral depth at construction time.
        modes = {self.reduction} | set(self.reduction_by_workload.values())
        if "lazy" not in modes:
            G.check_reduction(self.reduction, kappa)
        self.kappa = kappa
        self.d_tile = d_tile
        self.merge = merge
        if row_ladder is not None:
            row_ladder = validate_row_ladder(row_ladder)
        self.row_ladder = row_ladder
        self.merge_rows_max = (row_ladder[-1] if row_ladder
                               else merge_rows_max)
        self.donate = donate
        # Cluster mode runs one co-scheduler per host slice; the owning host id
        # travels into per-host telemetry so compiled-program caches and trace
        # counters stay attributable after snapshots are merged.
        self.host = host
        self._meshes = {
            w: Mesh(np.asarray(devs), ("rows",))
            for w, devs in assignment.items()
        }
        self._engines: dict = {}
        self._jitted: dict = {}
        # (workload, d_bucket) -> device-resident twiddle/fused planes.
        # Engines (make_engine) are an lru-cached *process-wide* resource
        # whose device_planes() upload lands on the default device; a pinned
        # co-scheduler re-homes the planes onto its own mesh exactly once
        # here, so N host slices never share one host's plane buffers.
        self._planes: dict = {}
        # (workload, d_bucket) -> number of times XLA retraced the program.
        # Incremented inside the traced body, so cached executions leave it
        # untouched; with a row ladder the count is bounded by the ladder
        # size (one trace per rung), asserted by the retrace-guard tests.
        self.trace_counts: dict = {}
        # One record per launched program (merge width, live vs launched
        # rows) — the serving telemetry's per-dispatch M-occupancy source.
        self.dispatch_log: collections.deque = collections.deque(
            maxlen=DISPATCH_LOG_MAX)
        # Observability hook (repro.obs.Tracer), installed by the serving
        # layer when tracing is on: launches then emit device-track spans on
        # the anchored serving clock and dispatch_log entries carry a causal
        # launch ID ("lid") linking them to batch/request spans.
        self.tracer = None

    def reduction_for(self, workload: str) -> str:
        """The fold discipline this slice applies to a workload class."""
        return self.reduction_by_workload.get(workload, self.reduction)

    def device_ids(self, workload: str | None = None) -> tuple[int, ...]:
        """Device ids this co-scheduler launches on — the whole slice, or
        one workload class's group (telemetry / placement assertions)."""
        if workload is None:
            return tuple(d.id for d in self.devices)
        return tuple(d.id for d in self._meshes[workload].devices.flat)

    def device_planes_for(self, workload: str, d: int):
        """The engine's device-resident planes, re-homed onto this
        co-scheduler's device group when pinned (passthrough otherwise —
        the engine cache's default-device upload is already correct for an
        unpinned slice, and re-uploading would double memory)."""
        key = (workload, d)
        planes = self._planes.get(key)
        if planes is None:
            planes = self.engine_for(workload, d).device_planes()
            if self._pinned:
                sharding = NamedSharding(self._meshes[workload], P())
                planes = jax.device_put(planes, sharding)
            self._planes[key] = planes
        return planes

    def engine_for(self, workload: str, d: int):
        key = (workload, d)
        if key not in self._engines:
            mode = self.reduction_for(workload)
            # κ belongs to the lazy classes only: an eager engine carrying a
            # deferral depth would refuse to trace (check_reduction) — and
            # recording one that never happened would corrupt bench records.
            self._engines[key] = WK.make_engine(
                workload, d, accum=self.accum, reduction=mode,
                kappa=self.kappa if mode == "lazy" else None,
                d_tile=self.d_tile)
        return self._engines[key]

    def jitted_for(self, workload: str, d: int):
        """One compiled e2e program per (workload, d_bucket), reused across
        dispatches — rebuilding ``jax.jit(eng.e2e)`` per dispatch discards the
        executable cache and recompiles every batch.  The twiddle planes are
        jit *arguments* (device-resident, uploaded once per engine), so a
        ladder retrace at a new batch height re-embeds no host constants; with
        ``donate`` the operand buffer is donated to the program."""
        key = (workload, d)
        if key not in self._jitted:
            eng = self.engine_for(workload, d)

            def _e2e(operand, planes, _eng=eng, _key=key):
                self.trace_counts[_key] = self.trace_counts.get(_key, 0) + 1
                return _eng.e2e(operand, planes=planes)

            self._jitted[key] = jax.jit(
                _e2e, donate_argnums=(0,) if self.donate else ())
        return self._jitted[key]

    def launch_rows(self, n_rows: int) -> int:
        """Launched operand height for ``n_rows`` live rows: the smallest
        ladder rung ≥ n_rows, or n_rows itself without a ladder (or beyond
        the top rung — oversize batches launch at natural height)."""
        if self.row_ladder is not None:
            for rung in self.row_ladder:
                if rung >= n_rows:
                    return rung
        return n_rows

    def operand_shape(self, workload: str, d: int, n_c: int) -> tuple:
        """Device operand shape of one ``n_c``-live-row launch — the jit
        cache key (ladder-padded when a row ladder is configured)."""
        rows = self.launch_rows(n_c)
        if workload == "dilithium":
            return (rows, d)
        return (rows, d, self.engine_for(workload, d).n_channels)

    def precompile(self, programs, n_c: int) -> int:
        """Warm-start the compiled-program cache: trace + compile the known
        ``(workload, d_bucket)`` set before first dispatch, so cold-start p99
        is not dominated by XLA compilation.  Without a row ladder one
        ``n_c``-row shape per program is warmed; with a ladder every rung is
        (live heights then always hit a warm rung).  Returns the number of
        fresh traces this triggered; a later dispatch of any warmed program
        at a warmed shape must trigger zero more (asserted via
        ``trace_counts`` in the serving tests)."""
        rungs = list(self.row_ladder) if self.row_ladder else [n_c]
        n_new = 0
        for workload, d in programs:
            key = (workload, d)
            planes = self.device_planes_for(workload, d)
            before = self.trace_counts.get(key, 0)
            for rung in rungs:
                operand = jnp.zeros(self.operand_shape(workload, d, rung),
                                    jnp.uint32)
                out = self.jitted_for(workload, d)(
                    self._shard(workload, operand), planes)
                jax.block_until_ready(out)
            n_new += self.trace_counts.get(key, 0) - before
        return n_new

    def _shard(self, workload: str, operand: jnp.ndarray):
        mesh = self._meshes[workload]
        n_dev = mesh.devices.size
        rows = operand.shape[0]
        if rows % n_dev == 0 and n_dev > 1:
            spec = P("rows")
        else:
            spec = P()
        return jax.device_put(operand, NamedSharding(mesh, spec))

    # --- group planning + launch ----------------------------------------------

    def _plan_groups(self, batches: list[StackedBatch]) -> list[_LaunchGroup]:
        """Cut a dispatch set into launch groups: same-(workload, d_bucket,
        reduction) batches coalesce along M (``merge``) up to the top ladder
        rung / ``merge_rows_max``; groups keep first-appearance launch order
        and members remember their input index for order-preserving gather."""
        groups: list[_LaunchGroup] = []
        open_group: dict[tuple, _LaunchGroup] = {}
        for i, b in enumerate(batches):
            rows = b.operand.shape[0] if b.operand is not None else b.n_c
            key = (b.workload, b.d_bucket, self.reduction_for(b.workload))
            g = open_group.get(key) if self.merge else None
            if g is None or g.operand_rows + rows > self.merge_rows_max:
                g = _LaunchGroup(workload=b.workload, d_bucket=b.d_bucket,
                                 members=[])
                groups.append(g)
                if self.merge:
                    open_group[key] = g
            g.members.append((i, b, g.operand_rows, g.operand_rows + rows))
            g.operand_rows += rows
            g.live_rows += b.n_c
        return groups

    def _member_operand(self, batch: StackedBatch, eng) -> np.ndarray:
        if batch.workload == "dilithium":
            return np.asarray(batch.operand, np.uint32)    # (N, d)
        if batch.operand.ndim == 2:                        # raw words → residues
            return np.asarray(eng.ingest(batch.operand.astype(object)))
        return np.asarray(batch.operand)                   # (N, d, C)

    def _launch(self, group: _LaunchGroup):
        """Enqueue one launch group on its workload's device group and return
        the in-flight device result without materialising it."""
        eng = self.engine_for(group.workload, group.d_bucket)
        members = [self._member_operand(b, eng)
                   for _, b, _, _ in group.members]
        rows = self.launch_rows(group.operand_rows)
        if len(members) == 1 and members[0].shape[0] == rows:
            operand_np = members[0]        # singleton at a rung: no host copy
        else:
            operand_np = merge_operands(members, n_rows=rows)
        operand = self._shard(group.workload, jnp.asarray(operand_np))
        out = self.jitted_for(group.workload, group.d_bucket)(
            operand, self.device_planes_for(group.workload, group.d_bucket))
        tr = self.tracer
        if tr is not None:
            group.lid = tr.next_id()
            tr.begin("launch", group.lid,
                     f"launch:{group.workload}/d{group.d_bucket}",
                     tr.wall_now(), track="device",
                     args={"live_rows": group.live_rows,
                           "launched_rows": int(operand_np.shape[0]),
                           "n_batches": len(group.members)})
        # live_rows counts tenant rows only — batcher zero-pad rows inside a
        # member operand are dead M just like ladder padding, so they must
        # not inflate the achieved-fill telemetry.
        self.dispatch_log.append({
            "workload": group.workload, "d_bucket": group.d_bucket,
            "n_batches": len(group.members), "live_rows": group.live_rows,
            "launched_rows": int(operand_np.shape[0]),
            "donated": self.donate, "lid": group.lid,
            "devices": self.device_ids(group.workload)})
        return group, eng, out

    def _materialise(self, group: _LaunchGroup, eng, out):
        """Gather one group's device result and split it back into one
        :class:`DispatchResult` per member batch (ladder-pad rows dropped,
        rows routed by position within each member's slice)."""
        res = np.asarray(out)
        tr = self.tracer
        if tr is not None:
            tr.end("launch", group.lid,
                   f"launch:{group.workload}/d{group.d_bucket}",
                   tr.wall_now(), track="device")
        # last_stats is trace-time state (one channel's staged_transform);
        # fold_profile is the static whole-program census — deterministic per
        # (workload, d_bucket) and what the serve telemetry aggregates.
        stats = dict(getattr(eng, "last_stats", {}) or {})
        stats.update(eng.fold_profile)
        results = []
        for idx, batch, lo, hi in group.members:
            rows = res[lo:hi]
            outputs = {r.tenant_id: rows[i]
                       for i, r in enumerate(batch.requests)}
            results.append((idx, DispatchResult(
                batch=batch, outputs=outputs, stats=dict(stats), rows=rows)))
        return results

    @staticmethod
    def _start_transfer(out):
        """Begin the device→host copy without blocking (phase 2 of the
        zero-sync pipeline; ``np.asarray`` in gather then finds the bytes
        already on their way)."""
        for leaf in jax.tree_util.tree_leaves(out):
            copy = getattr(leaf, "copy_to_host_async", None)
            if copy is not None:
                copy()

    # --- public dispatch surface ----------------------------------------------

    def launch_mixed(self, batches: list[StackedBatch]) -> InflightDispatch:
        """Phase 1+2 of a dispatch: enqueue every launch group (all launches
        before any host transfer — materialising between launches would
        serialise the device groups behind a blocking ``np.asarray``), then
        start every device→host copy asynchronously."""
        inflight = [self._launch(g) for g in self._plan_groups(batches)]
        for _, _, out in inflight:
            self._start_transfer(out)
        return InflightDispatch(groups=inflight, n_batches=len(batches))

    def gather(self, flight: InflightDispatch) -> list[DispatchResult]:
        """Phase 3: materialise an in-flight dispatch, input batch order."""
        results: list = [None] * flight.n_batches
        for f in flight.groups:
            for idx, dr in self._materialise(*f):
                results[idx] = dr
        return results

    def dispatch(self, batch: StackedBatch) -> DispatchResult:
        """Execute one stacked batch on its workload's device group."""
        return self.dispatch_mixed([batch])[0]

    def dispatch_mixed(self, batches: list[StackedBatch]) -> list[DispatchResult]:
        """Concurrent heterogeneous dispatch: per-class programs launched
        back-to-back; XLA queues them on disjoint device groups so Dilithium
        and BN254 batches overlap on real multi-device slices, while
        same-class batches coalesce into tall super-batches (``merge``)."""
        return self.gather(self.launch_mixed(batches))

    def drain_dispatch_log(self) -> list[dict]:
        """Hand the accumulated per-launch records to the caller (serving
        telemetry) and reset the log."""
        log = list(self.dispatch_log)
        self.dispatch_log.clear()
        return log
