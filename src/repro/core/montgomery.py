"""Digit-12 Montgomery REDC (the eager "VPU Montgomery reduction" phase).

CIOS-style REDC over β = 2**12 digits: ``redc_digits(Y)`` returns the
canonical digit representation of Y·β^{-nred} mod p.  Combined with the
Montgomery-corrected CRT accumulation in :func:`repro.core.rns.rns_to_field`,
the β^{nred} factors cancel and the output is exactly X mod p.

Every intermediate stays < 2**25 (digit products < 2**24 + carries), i.e.
inside the int32 exactness window — the wide-ALU-free discipline the paper
measures.  This is deliberately a long serial dependency chain of elementwise
vector ops: the structurally-mandated VPU bottleneck (paper Table 3).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import wordarith as W


def redc_digits(y_digits, chain):
    """y_digits: uint32 (..., ny) canonical digit-12 (ny >= nred + 2).

    Returns uint32 (..., nred) canonical digits of Y·β^{-nred} mod p.
    """
    n = chain.n_red_digits
    p_dig = [int(x) for x in chain.p_digits]
    p_prime = jnp.uint32(chain.p_prime)
    mask = jnp.uint32(W.DIGIT_MASK)

    ny = y_digits.shape[-1]
    t = [y_digits[..., j].astype(jnp.uint32) for j in range(ny)]

    for _ in range(n):
        q = (t[0] * p_prime) & mask                      # < 2^12
        # t = (t + q·p) >> (one digit); running carry < 2^13
        carry = (t[0] + q * jnp.uint32(p_dig[0])) >> jnp.uint32(W.BETA_BITS)
        for j in range(1, ny):
            pj = p_dig[j] if j < n else 0
            acc = t[j] + q * jnp.uint32(pj) + carry      # < 2^25
            t[j - 1] = acc & mask
            carry = acc >> jnp.uint32(W.BETA_BITS)
        t[ny - 1] = carry

    out = jnp.stack(t[:n], axis=-1)
    # REDC bound: result < 2p (top digits beyond nred are zero by range).
    return W.cond_subtract(out, jnp.asarray(chain.p_digits))


def digits_to_words_u32(digits):
    """(..., nd) digit-12 -> (..., ceil(nd·12/32)) uint32 words (output form)."""
    nd = digits.shape[-1]
    total_bits = nd * W.BETA_BITS
    n_words = (total_bits + 31) // 32
    out = []
    d = digits.astype(jnp.uint32)
    for w in range(n_words):
        lo_bit = 32 * w
        acc = jnp.zeros(digits.shape[:-1], jnp.uint32)
        for j in range(nd):
            b = j * W.BETA_BITS - lo_bit
            if -W.BETA_BITS < b < 32:
                if b >= 0:
                    acc = acc | ((d[..., j] << jnp.uint32(b))
                                 if b else d[..., j])
                else:
                    acc = acc | (d[..., j] >> jnp.uint32(-b))
        out.append(acc)
    return jnp.stack(out, axis=-1)
