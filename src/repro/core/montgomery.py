"""Montgomery reduction phases: eager digit-12 REDC + the deferred κ-window fold.

**Eager path** — CIOS-style REDC over β = 2**12 digits: ``redc_digits(Y)``
returns the canonical digit representation of Y·β^{-nred} mod p.  Combined
with the Montgomery-corrected CRT accumulation in
:func:`repro.core.rns.rns_to_field`, the β^{nred} factors cancel and the
output is exactly X mod p.

Every intermediate stays < 2**25 (digit products < 2**24 + carries), i.e.
inside the int32 exactness window — the wide-ALU-free discipline the paper
measures.  This is deliberately a long serial dependency chain of elementwise
vector ops: the structurally-mandated VPU bottleneck (paper Table 3).

**Deferred path** (paper §7.2.1) — ``deferred_fold`` is the single per-window
modular reduction of the κ-amortised lazy discipline: the staged transform
accumulates unreduced limb-convolution diagonals across up to κ staging
passes (:class:`repro.core.accumulator.LazyWindowAccumulator` proves the
overflow bound at trace time) and reduces once per window here.  Each fold is
wrapped in a ``lazy_window_{i}`` scope so the HLO validator can statically
assert "exactly one fold per window" survived XLA (no re-fusion back to the
eager per-pass schedule).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import wordarith as W


def fold_diagonals_lax(diags, m_u32):
    """Window-scoped VPU fold built from raw lax primitives.

    Bit-for-bit identical to :func:`repro.core.field.fold_diagonals_u32`
    (same Horner/conditional-subtract recurrence), but every op is emitted
    through ``jax.lax`` directly: jnp helpers like ``jnp.mod``/``jnp.where``
    are internally jitted and jax caches their jaxpr *with the name stack of
    the first trace*, which would stamp every later window's reduction ops
    with ``lazy_window_0`` and blind the validator's per-window census (V7).
    Raw primitives always inherit the live scope.
    """
    from jax import lax
    n_diag = diags.shape[-1]
    m_i32 = lax.convert_element_type(m_u32, jnp.int32)
    acc = jnp.zeros(diags.shape[:-1], jnp.uint32)
    m_b = jnp.broadcast_to(m_u32, acc.shape)
    for k in range(n_diag - 1, -1, -1):
        for _ in range(8):                      # (acc << 8) mod m, acc < m
            acc = lax.shift_left(acc, jnp.broadcast_to(jnp.uint32(1), acc.shape))
            acc = lax.select(lax.ge(acc, m_b), lax.sub(acc, m_b), acc)
        d_k = diags[..., k]
        r = lax.rem(d_k, jnp.broadcast_to(m_i32, d_k.shape))
        r = lax.select(lax.lt(r, jnp.zeros_like(r)),
                       lax.add(r, jnp.broadcast_to(m_i32, r.shape)), r)
        s = lax.add(acc, lax.convert_element_type(r, jnp.uint32))
        acc = lax.select(lax.ge(s, m_b), lax.sub(s, m_b), s)
    return acc


def deferred_fold(acc_diag, modulus, *, window_index: int, fold_fn=None):
    """Fold one κ-window of unreduced diagonals to a canonical residue.

    acc_diag: int32 (..., n_diag) — the summed diagonals of every staging pass
    in window ``window_index`` (bounds proven by the lazy accumulator).
    ``fold_fn(acc_diag, m_u32) -> uint32`` overrides the reduction
    implementation (e.g. the Pallas ``mont_fold`` kernel); default is the
    elementwise VPU Horner fold.

    The window scope is load-bearing: validator check V6/V7 keys on
    ``lazy_window_{i}/vpu_fold_lazy`` to count fold sites per window.
    """
    with jax.named_scope(f"lazy_window_{window_index}"), \
         jax.named_scope("vpu_fold_lazy"):
        if fold_fn is not None:
            return fold_fn(acc_diag, modulus)   # raw (static) modulus
        return fold_diagonals_lax(acc_diag, jnp.uint32(modulus))


def redc_digits(y_digits, chain):
    """y_digits: uint32 (..., ny) canonical digit-12 (ny >= nred + 2).

    Returns uint32 (..., nred) canonical digits of Y·β^{-nred} mod p.
    """
    n = chain.n_red_digits
    p_dig = [int(x) for x in chain.p_digits]
    p_prime = jnp.uint32(chain.p_prime)
    mask = jnp.uint32(W.DIGIT_MASK)

    ny = y_digits.shape[-1]
    t = [y_digits[..., j].astype(jnp.uint32) for j in range(ny)]

    for _ in range(n):
        q = (t[0] * p_prime) & mask                      # < 2^12
        # t = (t + q·p) >> (one digit); running carry < 2^13
        carry = (t[0] + q * jnp.uint32(p_dig[0])) >> jnp.uint32(W.BETA_BITS)
        for j in range(1, ny):
            pj = p_dig[j] if j < n else 0
            acc = t[j] + q * jnp.uint32(pj) + carry      # < 2^25
            t[j - 1] = acc & mask
            carry = acc >> jnp.uint32(W.BETA_BITS)
        t[ny - 1] = carry

    out = jnp.stack(t[:n], axis=-1)
    # REDC bound: result < 2p (top digits beyond nred are zero by range).
    return W.cond_subtract(out, jnp.asarray(chain.p_digits))


def digits_to_words_u32(digits):
    """(..., nd) digit-12 -> (..., ceil(nd·12/32)) uint32 words (output form)."""
    nd = digits.shape[-1]
    total_bits = nd * W.BETA_BITS
    n_words = (total_bits + 31) // 32
    out = []
    d = digits.astype(jnp.uint32)
    for w in range(n_words):
        lo_bit = 32 * w
        acc = jnp.zeros(digits.shape[:-1], jnp.uint32)
        for j in range(nd):
            b = j * W.BETA_BITS - lo_bit
            if -W.BETA_BITS < b < 32:
                if b >= 0:
                    acc = acc | ((d[..., j] << jnp.uint32(b))
                                 if b else d[..., j])
                else:
                    acc = acc | (d[..., j] >> jnp.uint32(-b))
        out.append(acc)
    return jnp.stack(out, axis=-1)
