"""Explicit Residue Number System (ERNS) chain for BN254 (paper §6.2).

Eight 31-bit NTT-friendly base channels plus one redundant channel for
Shenoy–Kumaresan exact base extension ("eight base residues plus an auxiliary
residue for overflow handling").  Each channel runs its own matrix-form
transform (limb_gemm); per-coefficient results re-enter the field through a
Montgomery reduction whose base-extension matrix-vector products are the
>2,100 limb-level operations the paper counts.

Exactness envelope (see DESIGN.md §2): channel arithmetic is exact mod m_i for
all inputs; CRT recovery of the integer value — and hence the F_p result — is
exact whenever the true integer value stays below M = Π m_i (≈ 2**248 for the
paper's 9-residue chain).  The extended 17-channel chain (``bn254_full``)
makes full-range d≤256 polynomial products exact end-to-end.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax.numpy as jnp

from repro.core import field as F
from repro.core import primes as P
from repro.core import wordarith as W

TWO_ADICITY = 17  # supports negacyclic transforms up to d = 2**16


@dataclasses.dataclass(frozen=True)
class RnsChain:
    """Host-precomputed ERNS constants (all device arrays are numpy here)."""

    p: int                       # the target field prime (BN254 Fr)
    base: tuple                  # n base moduli
    redundant: int               # auxiliary modulus m_r
    M: int                       # Π base
    inv_Mi_mod_mi: np.ndarray    # (n,) uint32 — (M/m_i)^{-1} mod m_i
    Mi_mod_mr: np.ndarray        # (n,) uint32 — (M/m_i) mod m_r
    M_inv_mod_mr: int            # M^{-1} mod m_r
    # Montgomery-corrected CRT matrices (digit-12):
    Ti_digits: np.ndarray        # (n, nd) uint32 — (M/m_i · β^nred mod p)
    V_digits: np.ndarray         # (nd,) uint32 — (-M · β^nred) mod p
    p_digits: np.ndarray         # (nred,) uint32
    p_prime: int                 # -p^{-1} mod β
    n_red_digits: int            # Montgomery digit count for p

    @property
    def n(self) -> int:
        return len(self.base)

    @property
    def moduli(self) -> tuple:
        return self.base + (self.redundant,)


@functools.lru_cache(maxsize=8)
def make_chain(n_channels: int = 9, p: int = F.BN254_FR) -> RnsChain:
    """Build the chain: n_channels-1 base moduli + 1 redundant."""
    ms = P.ntt_friendly_primes(n_channels, TWO_ADICITY)
    base, m_r = ms[:-1], ms[-1]
    n = len(base)
    M = 1
    for m in base:
        M *= m

    inv_mi = np.array([pow(M // m, -1, m) for m in base], np.uint32)
    mi_mr = np.array([(M // m) % m_r for m in base], np.uint32)
    minv_mr = pow(M % m_r, -1, m_r)

    nred = (p.bit_length() + W.BETA_BITS - 1) // W.BETA_BITS + 1  # slack digit
    beta_pow = pow(1 << W.BETA_BITS, nred, p)
    nd = nred + 2
    ti = np.stack([W.int_to_digits((M // m) * beta_pow % p, nd) for m in base])
    v = W.int_to_digits((-(M * beta_pow)) % p, nd)  # ≡ -M·β^nred (mod p), ≥ 0
    p_digits = W.int_to_digits(p, nred)
    p_prime = (-pow(p, -1, 1 << W.BETA_BITS)) % (1 << W.BETA_BITS)

    return RnsChain(
        p=p, base=base, redundant=m_r, M=M,
        inv_Mi_mod_mi=inv_mi, Mi_mod_mr=mi_mr, M_inv_mod_mr=minv_mr,
        Ti_digits=ti, V_digits=v, p_digits=p_digits, p_prime=p_prime,
        n_red_digits=nred,
    )


# --- Host conversions ---------------------------------------------------------


def to_rns_np(values, chain: RnsChain) -> np.ndarray:
    """Python-int/object array [...] -> (..., n+1) uint32 residues."""
    vals = np.asarray(values, object)
    out = np.zeros(vals.shape + (chain.n + 1,), np.uint32)
    for i, m in enumerate(chain.moduli):
        out[..., i] = (vals % m).astype(np.uint32)
    return out


def from_rns_np(res: np.ndarray, chain: RnsChain) -> np.ndarray:
    """Exact host CRT over the base channels (ignores redundant): -> ints."""
    res = np.asarray(res)
    out = np.zeros(res.shape[:-1], object)
    for i, m in enumerate(chain.base):
        mi = chain.M // m
        out = out + res[..., i].astype(object) * (int(chain.inv_Mi_mod_mi[i]) * mi)
    return out % chain.M


# --- Device: Shenoy–Kumaresan α + Montgomery reduction to F_p -----------------


def sk_alpha(residues, chain: RnsChain):
    """Exact CRT overflow count α for values < M (uses the redundant channel).

    residues: uint32 (..., n+1) — base channels then redundant.
    Returns (xi (..., n) uint32, alpha (...,) uint32 with alpha < n).
    """
    mr = jnp.uint32(chain.redundant)
    base = jnp.asarray(np.array(chain.base, np.uint32))
    xi = F.mulmod_u32(residues[..., : chain.n],
                      jnp.asarray(chain.inv_Mi_mod_mi), base)
    # Σ ξ_i (M/m_i) mod m_r
    acc = jnp.zeros(residues.shape[:-1], jnp.uint32)
    for i in range(chain.n):
        t = F.mulmod_u32(xi[..., i] % mr, jnp.uint32(chain.Mi_mod_mr[i]), mr)
        acc = F.addmod_u32(acc, t, mr)
    diff = F.submod_u32(acc, residues[..., chain.n] % mr, mr)
    alpha = F.mulmod_u32(diff, jnp.uint32(chain.M_inv_mod_mr), mr)
    return xi, alpha


def rns_to_field(residues, chain: RnsChain):
    """(..., n+1) uint32 residues of X < M  ->  (..., nred) digit-12 of X mod p.

    Pipeline: SK α → Montgomery-corrected CRT accumulation (base-extension
    matrix-vector products in digit-12) → digit-12 Montgomery REDC → canonical
    residue digits of X mod p.
    """
    from repro.core import montgomery as MG  # local import to avoid cycle
    xi, alpha = sk_alpha(residues, chain)
    nd = chain.Ti_digits.shape[1]
    acc = W.scalar_conv_accumulate(xi, jnp.asarray(chain.Ti_digits), nd + 3)
    # -α·U ≡ α·V (mod p) with V = (-M·β^nred) mod p ≥ 0 keeps Y non-negative:
    # Y = Σ ξ_i T_i + α·V ≡ X·β^nred (mod p), Y < 8·2^31·p + 8p ≈ 2^288.
    comp = W.scalar_conv_accumulate(alpha[..., None],
                                    jnp.asarray(chain.V_digits)[None, :],
                                    nd + 3)
    acc = acc + comp
    y_digits = W.normalize_digits(acc)
    return MG.redc_digits(y_digits, chain)
