"""Post-hoc Structural HLO Validator (paper §6.3).

Intercepts the lowered + compiled module just prior to dispatch and statically
asserts the separation invariants against the *stock* XLA output:

  V1 (Invariant 5.1, strict reduction ordering): within each staged transform,
      every pass-k VPU fold is emitted after pass-k's MXU dot and before
      pass-(k+1)'s MXU dot — no reduction inside an open summation window.
  V2 (barrier survival): the lowered module carries one
      ``optimization_barrier`` per adjacent staging-pass pair.
  V3 (workload-zone fusion separation): no fused computation in the optimized
      HLO mixes ops from two distinct ``wzone_*`` scopes.
  V4 (precision-zone homogeneity): no fused computation mixes distinct
      ``pzone_*`` scopes (e.g. 3-limb Dilithium with 4-limb BN254 blocks).
  V5 (disjoint addressing): no input/output buffer donation aliases tensors
      across distinct workload zones.
  V6 (κ-window fold survival, lazy modules): the optimized module carries
      exactly one ``vpu_fold_lazy`` site per deferral window (scope
      ``lazy_window_{i}``, qualified by channel for multi-channel engines)
      and **zero** eager per-pass folds — XLA must not have re-fused the
      deferred schedule back to the eager one (paper §7.2.1).
  V7 (single fold per window, lazy modules): in the trace-order-faithful
      lowered module, each window scope contains exactly one fold's worth of
      modular-reduction ops (``n_diag`` remainders) — a window that reduces
      twice is an eager fold hiding under a lazy label.

Any violation raises :class:`ValidationError` (dispatch abort) and carries the
offending subgraph snippet for triage.  The validator also returns the static
op census (dots, folds, barriers) used for the κ lazy-amortisation analysis
(paper §7.2.1).
"""
from __future__ import annotations

import dataclasses
import re

import jax

WZONE_RE = re.compile(r"wzone_[A-Za-z0-9_]+")
PZONE_RE = re.compile(r"pzone_[A-Za-z0-9_]+")
PASS_RE = re.compile(r"staging_pass_(\d+)")
OPNAME_RE = re.compile(r'op_name="([^"]*)"')
# Window key carries the channel qualifier so BN254's per-channel windows
# with the same index stay distinct.
LAZY_WIN_RE = re.compile(r"(?:channel_\d+/)?lazy_window_\d+(?=/vpu_fold_lazy)")
EAGER_FOLD_RE = re.compile(r"staging_pass_\d+/vpu_fold(?!_lazy)")


class ValidationError(AssertionError):
    def __init__(self, violations):
        self.violations = violations
        super().__init__("HLO structural validation failed:\n" +
                         "\n".join(f"  [{v[0]}] {v[1]}" for v in violations))


@dataclasses.dataclass
class ValidationReport:
    ok: bool
    violations: list
    n_barriers: int
    n_dots: int
    n_folds: int
    zones: set
    precision_zones: set

    def raise_if_failed(self):
        if not self.ok:
            raise ValidationError(self.violations)


def _entry_computation(hlo_text: str) -> str:
    """The ENTRY computation block of an optimized HLO module."""
    idx = hlo_text.find("ENTRY ")
    return hlo_text[idx:] if idx >= 0 else hlo_text


def _fusion_blocks(hlo_text: str) -> list[str]:
    """All non-entry computation bodies (fused computations and callees)."""
    blocks, cur, inside = [], [], False
    for line in hlo_text.splitlines():
        if line.startswith("%") and line.rstrip().endswith("{"):
            inside, cur = True, [line]
        elif inside and line.startswith("}"):
            cur.append(line)
            blocks.append("\n".join(cur))
            inside = False
        elif inside:
            cur.append(line)
    return blocks


def validate_module(lowered_text: str, compiled_text: str, *,
                    expected_passes: int | None = None,
                    expect_eager: bool = True,
                    expected_windows: int | None = None,
                    n_diag: int | None = None) -> ValidationReport:
    violations = []

    # --- V6/V7: κ-window fold structure of a lazy module ----------------------
    if expected_windows is not None:
        win_scopes = set(LAZY_WIN_RE.findall(compiled_text))
        if len(win_scopes) != expected_windows:
            violations.append((
                "V6", f"{len(win_scopes)} deferred-fold windows in the "
                f"optimized module, expected {expected_windows} "
                f"(windows seen: {sorted(win_scopes)[:8]})"))
        eager_folds = set(EAGER_FOLD_RE.findall(compiled_text))
        if eager_folds:
            violations.append((
                "V6", f"lazy module contains eager per-pass folds "
                f"{sorted(eager_folds)[:4]} — XLA (or the trace) re-fused "
                f"the deferred schedule back to eager"))
        if n_diag is not None:
            # Count the modular-reduction instructions each window scope
            # carries in the optimized module (op_name metadata survives
            # fusion).  One fold reduces exactly n_diag diagonals → n_diag
            # remainder instructions per window; 2·n_diag means a second fold
            # is hiding under the window's lazy label, 0 means the fold is
            # missing or not the elementwise form this check audits (kernel
            # fold_fn programs lower to custom-calls — don't pass n_diag for
            # those).  Every discovered window scope is checked, so a window
            # with no remainders at all is flagged, not skipped.
            per_window: dict[str, int] = {}
            for ln in compiled_text.splitlines():
                if not re.search(r"= \S+ remainder\(", ln):
                    continue
                mo = OPNAME_RE.search(ln)
                name = mo.group(1) if mo else ""
                wm = LAZY_WIN_RE.search(name)
                if wm:
                    per_window[wm.group(0)] = per_window.get(wm.group(0), 0) + 1
            for win in sorted(win_scopes | set(per_window)):
                count = per_window.get(win, 0)
                if count != n_diag:
                    violations.append((
                        "V7", f"window {win} carries {count} modular-reduction "
                        f"ops (expected {n_diag} — exactly one fold per "
                        f"window)"))

    # --- V2: barrier survival in the lowered module --------------------------
    n_barriers = len(re.findall(r"optimization_barrier", lowered_text))
    if expect_eager and expected_passes and expected_passes > 1:
        want = expected_passes - 1
        if n_barriers < want:
            violations.append((
                "V2", f"{n_barriers} optimization_barriers for "
                f"{expected_passes} staging passes (need >= {want})"))

    # --- V1: strict reduction ordering (program order of the traced module) --
    # The lowered StableHLO preserves trace emission order (no hoisting yet):
    # between any two consecutive MXU summation windows (dot_general / pallas
    # kernel calls) there must be >= 1 modular-reduction op (stablehlo.remainder
    # from the fold) — i.e. no reduction is deferred into the next open
    # summation, and no summation starts before the previous fold ran.
    low_lines = lowered_text.splitlines()
    dot_pat = re.compile(
        r"stablehlo\.dot_general|stablehlo\.custom_call.*(tpu_custom_call|pallas)")
    # resolve the MLIR loc table (debug_info=True) so only *pointwise-phase*
    # dots count as summation windows — the Montgomery/base-extension digit
    # matmuls legitimately run fold-free (they ARE the reduction).
    loc_names = dict(re.findall(r'^(#loc\d+) = loc\("([^"]*)"', lowered_text,
                                re.M))
    has_locs = bool(loc_names)

    def _window_key(ln: str):
        """None if not a pointwise dot; else the summation-window scope key
        (channel_i/staging_pass_k) — several partial-product dots inside one
        pass share a window."""
        if not dot_pat.search(ln):
            return None
        if not has_locs:
            return "?"
        m = re.search(r"loc\((#loc\d+)\)", ln)
        name = loc_names.get(m.group(1), "") if m else ""
        if m and name and "mxu_pointwise" not in name:
            return None  # Montgomery/base-extension matmul — not a window
        wm = re.search(r"((channel_\d+/)?staging_pass_\d+)", name)
        return wm.group(1) if wm else (name or "?")

    dots = [(i, _window_key(ln)) for i, ln in enumerate(low_lines)]
    dots = [(i, k) for i, k in dots if k is not None]
    rem_idx = [i for i, ln in enumerate(low_lines)
               if "stablehlo.remainder" in ln or "call @remainder" in ln]
    barrier_idx = [i for i, ln in enumerate(low_lines)
                   if "optimization_barrier" in ln]
    if expect_eager and len(dots) > 1:
        for (a, ka), (b, kb) in zip(dots, dots[1:]):
            if ka == kb:
                continue  # same summation window (multi-plane partials)
            n_rem = sum(1 for r in rem_idx if a < r < b)
            if n_rem == 0:
                violations.append((
                    "V1", f"no VPU reduction between summation windows "
                    f"{ka}→{kb} at lowered lines {a}..{b} (open-summation "
                    f"fold violation)"))

    # --- census over the optimized entry computation --------------------------
    entry = _entry_computation(compiled_text)
    dots, folds = [], []
    for i, ln in enumerate(entry.splitlines()):
        mo = OPNAME_RE.search(ln)
        if not mo:
            continue
        op_name = mo.group(1)
        if "mxu_pointwise" in op_name and ("dot" in ln or "fusion" in ln):
            dots.append(i)
        if "vpu_fold" in op_name:
            folds.append(i)

    # --- V3/V4: fusion zone separation ---------------------------------------
    zones_seen, pzones_seen = set(), set()
    for block in _fusion_blocks(compiled_text) + [entry]:
        is_fusion = block.lstrip().startswith("%fused")
        wz = set(WZONE_RE.findall(block))
        pz = set(PZONE_RE.findall(block))
        zones_seen |= wz
        pzones_seen |= pz
        if is_fusion:
            if len(wz) > 1:
                violations.append((
                    "V3", f"fused computation mixes workload zones {sorted(wz)}: "
                    f"{block.splitlines()[0][:120]}"))
            if len(pz) > 1:
                violations.append((
                    "V4", f"fused computation mixes precision zones {sorted(pz)}:"
                    f" {block.splitlines()[0][:120]}"))

    # --- V5: no cross-zone buffer donation ------------------------------------
    alias = re.findall(r"input_output_alias=\{[^}]*\}", compiled_text)
    if alias and len(zones_seen) > 1:
        # donation is allowed, but only within a single-zone module
        violations.append((
            "V5", f"buffer donation present in a multi-zone module: {alias[0][:120]}"))

    return ValidationReport(
        ok=not violations, violations=violations, n_barriers=n_barriers,
        n_dots=len(dots), n_folds=len(folds), zones=zones_seen,
        precision_zones=pzones_seen)


def validate_fn(fn, *args, expected_passes: int | None = None,
                expect_eager: bool = True, expected_windows: int | None = None,
                n_diag: int | None = None,
                donate_argnums=()) -> ValidationReport:
    """Lower + compile ``fn`` and run the structural validator on both texts.

    ``expected_windows``/``n_diag`` arm the lazy-mode V6/V7 checks (pass
    ``expect_eager=False`` alongside — a κ-amortised program intentionally
    defers folds out of the per-pass schedule V1/V2 police)."""
    lowered = jax.jit(fn, donate_argnums=donate_argnums).lower(*args)
    compiled = lowered.compile()
    try:
        low_txt = lowered.as_text(debug_info=True)
    except TypeError:  # older jax
        low_txt = lowered.as_text()
    return validate_module(low_txt, compiled.as_text(),
                           expected_passes=expected_passes,
                           expect_eager=expect_eager,
                           expected_windows=expected_windows,
                           n_diag=n_diag)


def fold_census(fn, *args) -> dict:
    """Static op census for the κ analysis (paper §7.2.1): counts distinct
    VPU-fold scheduling sites in the compiled module — one per staging pass
    under the eager discipline, one total under the lazy/MORPH discipline."""
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    rep = validate_module(lowered.as_text(), compiled.as_text(),
                          expect_eager=False)
    txt = compiled.as_text()
    pass_folds = set(re.findall(r"staging_pass_(\d+)/vpu_fold", txt))
    lazy_windows = set(LAZY_WIN_RE.findall(txt))
    # κ-window scopes when present; plain vpu_fold_lazy (scan form) counts 1.
    n_lazy = len(lazy_windows) or (1 if "vpu_fold_lazy" in txt else 0)
    n_fold_ops = len(re.findall(r"vpu_fold", txt))
    return {"n_dots": rep.n_dots,
            "n_fold_scopes": len(pass_folds) + n_lazy,
            "n_lazy_windows": len(lazy_windows),
            "n_fold_tagged_ops": n_fold_ops, "n_barriers": rep.n_barriers}
