"""Host-side prime / root-of-unity generation for the ERNS channel chain.

Everything in this module runs on the host with Python bignums (exactly how a
TPU deployment stages constants from the host VM). Device code never calls
into here at trace time except through precomputed numpy arrays.
"""
from __future__ import annotations

import functools

# Deterministic Miller-Rabin witnesses: correct for all n < 3.3e24.
_MR_WITNESSES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in _MR_WITNESSES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


@functools.lru_cache(maxsize=None)
def ntt_friendly_primes(count: int, two_adicity: int, max_bits: int = 31) -> tuple[int, ...]:
    """Largest ``count`` primes m < 2**max_bits with m ≡ 1 (mod 2**two_adicity).

    two_adicity bounds the largest power-of-two transform length the channel
    supports (negacyclic d up to 2**(two_adicity-1)).
    """
    step = 1 << two_adicity
    found: list[int] = []
    # Largest k·2^a + 1 below 2^max_bits.
    k = ((1 << max_bits) - 2) // step
    while len(found) < count and k > 0:
        cand = k * step + 1
        if is_prime(cand):
            found.append(cand)
        k -= 1
    if len(found) < count:
        raise ValueError(f"not enough {max_bits}-bit primes with 2-adicity {two_adicity}")
    return tuple(found)


def primitive_root_of_unity(m: int, order: int) -> int:
    """A primitive ``order``-th root of unity mod prime m (order | m-1)."""
    if (m - 1) % order != 0:
        raise ValueError(f"order {order} does not divide {m}-1")
    # Factor `order` (a power of two times small factors in our usage).
    factors = _distinct_prime_factors(order)
    cofactor = (m - 1) // order
    g = 2
    while True:
        w = pow(g, cofactor, m)
        if w != 1 and all(pow(w, order // q, m) != 1 for q in factors):
            return w
        g += 1
        if g > 10_000:
            raise RuntimeError("failed to find primitive root")


def _distinct_prime_factors(n: int) -> list[int]:
    out, d = [], 2
    while d * d <= n:
        if n % d == 0:
            out.append(d)
            while n % d == 0:
                n //= d
        d += 1
    if n > 1:
        out.append(n)
    return out
