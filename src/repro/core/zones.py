"""Workload / precision zone tagging (paper §4.2).

JAX cannot attach arbitrary mhlo.custom_call attributes from user code, but
``jax.named_scope`` threads scope names into every HLO op's ``op_name``
metadata — which survives XLA's optimisation pipeline (including into fused
computations).  The zone discipline is therefore:

* ``workload_zone(name)``   → scope ``wzone_<name>``
* ``precision_zone(limbs)`` → scope ``pzone_<limbs>limb``
* ``tenant_zone(i)``        → scope ``tzone_<i>``

and the post-hoc validator (:mod:`repro.core.validator`) statically asserts
on the compiled module that no fused computation mixes distinct zones, that
staging barriers survived lowering, and that reduction ordering holds
(Invariant 5.1).  This reproduces the paper's CustomCall-annotation mechanism
with stock-JAX machinery; DESIGN.md records the substitution.
"""
from __future__ import annotations

import contextlib

import jax

WZONE_PREFIX = "wzone_"
PZONE_PREFIX = "pzone_"
TZONE_PREFIX = "tzone_"


@contextlib.contextmanager
def workload_zone(name: str):
    with jax.named_scope(f"{WZONE_PREFIX}{name}"):
        yield


@contextlib.contextmanager
def precision_zone(limbs: int):
    with jax.named_scope(f"{PZONE_PREFIX}{limbs}limb"):
        yield


@contextlib.contextmanager
def tenant_zone(tenant_id: int):
    with jax.named_scope(f"{TZONE_PREFIX}{tenant_id}"):
        yield
