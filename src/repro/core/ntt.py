"""Number Theoretic Transform constructions.

* ``ntt_matrix`` — the dense matrix-form NTT operand (paper's O(d²) object),
  host-precomputed with Python bignums / numpy gathers.
* ``cooley_tukey_ntt`` — the asymptotically optimal O(d log d) radix-2 NTT in
  pure JAX uint32 arithmetic (the "GPU-style" algorithmic baseline of Fig. 3).
* ``morph_stage_matrices`` — the MORPH single-tenant baseline: the radix-2
  butterfly expressed as a sequence of log2(d) dense tile-resident GEMMs
  against permuted twiddle blocks (paper §7.2.1).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import field as F
from repro.core import primes as P


# --- Host-side matrix construction -------------------------------------------


def _power_table(base: int, count: int, m: int) -> np.ndarray:
    out = np.empty(count, object)
    acc = 1
    for k in range(count):
        out[k] = acc
        acc = acc * base % m
    return out.astype(np.uint32) if m < 2**32 else out


@functools.lru_cache(maxsize=64)
def _roots(m: int, order: int) -> int:
    return P.primitive_root_of_unity(m, order)


def ntt_matrix(d: int, m: int, *, negacyclic: bool = False) -> np.ndarray:
    """Dense forward-NTT matrix W (uint32, d×d) with y = a @ W (mod m).

    Cyclic:      W[i, j] = ω^{ij},          ω a primitive d-th root.
    Negacyclic:  W[i, j] = ψ^{i(2j+1)},     ψ a primitive 2d-th root
                 (evaluation at odd powers of ψ — the Dilithium convention).
    """
    if negacyclic:
        psi = _roots(m, 2 * d)
        table = _power_table(psi, 2 * d, m)
        i = np.arange(d, dtype=np.int64)[:, None]
        j = np.arange(d, dtype=np.int64)[None, :]
        idx = (i * (2 * j + 1)) % (2 * d)
        return table[idx]
    omega = _roots(m, d)
    table = _power_table(omega, d, m)
    i = np.arange(d, dtype=np.int64)[:, None]
    j = np.arange(d, dtype=np.int64)[None, :]
    idx = (i * j) % d
    return table[idx]


def intt_matrix(d: int, m: int, *, negacyclic: bool = False) -> np.ndarray:
    """Inverse transform matrix: (a @ W) @ Winv == a (mod m)."""
    w = ntt_matrix(d, m, negacyclic=negacyclic).astype(object)
    dinv = pow(d, m - 2, m)
    if negacyclic:
        psi = _roots(m, 2 * d)
        psi_inv = pow(psi, 2 * d - 1, m)
        # Winv[j, i] = d^{-1} ψ^{-i(2j+1)}
        i = np.arange(d, dtype=np.int64)[None, :]
        j = np.arange(d, dtype=np.int64)[:, None]
        table = _power_table(psi_inv, 2 * d, m)
        idx = (i * (2 * j + 1)) % (2 * d)
        out = (table[idx].astype(object) * dinv) % m
        return out.astype(np.uint32)
    omega = _roots(m, d)
    omega_inv = pow(omega, d - 1, m)
    table = _power_table(omega_inv, d, m)
    i = np.arange(d, dtype=np.int64)[None, :]
    j = np.arange(d, dtype=np.int64)[:, None]
    idx = (i * j) % d
    out = (table[idx].astype(object) * dinv) % m
    return out.astype(np.uint32)


def matrix_ntt_oracle_np(a: np.ndarray, w: np.ndarray, m: int) -> np.ndarray:
    """Exact host oracle: (a @ W) mod m with Python bignums."""
    acc = a.astype(object) @ w.astype(object)
    return (acc % m).astype(np.uint32)


# --- O(d log d) Cooley-Tukey in pure JAX uint32 ------------------------------


def _bit_reverse_perm(d: int) -> np.ndarray:
    bits = d.bit_length() - 1
    idx = np.arange(d)
    rev = np.zeros(d, np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


@functools.lru_cache(maxsize=64)
def _ct_stage_twiddles(d: int, m: int) -> tuple:
    """Per-stage twiddle vectors for iterative radix-2 DIT (cyclic)."""
    omega = _roots(m, d)
    stages = []
    span = 1
    while span < d:
        w_span = pow(omega, d // (2 * span), m)
        stages.append(_power_table(w_span, span, m))
        span *= 2
    return tuple(stages)


def cooley_tukey_ntt(a_u32, m: int, *, negacyclic: bool = False):
    """Radix-2 DIT NTT over uint32; a_u32: (..., d). O(d log d) mulmods."""
    d = a_u32.shape[-1]
    mj = jnp.uint32(m)
    if negacyclic:
        psi = _roots(m, 2 * d)
        pre = jnp.asarray(_power_table(psi, d, m))
        a_u32 = F.mulmod_u32(a_u32, pre, mj)
    rev = jnp.asarray(_bit_reverse_perm(d))
    x = jnp.take(a_u32, rev, axis=-1)
    for tw in _ct_stage_twiddles(d, m):
        span = tw.shape[0]
        tw_j = jnp.asarray(tw)
        shp = x.shape[:-1] + (d // (2 * span), 2, span)
        xr = x.reshape(shp)
        u = xr[..., 0, :]
        t = F.mulmod_u32(xr[..., 1, :], tw_j, mj)
        lo = F.addmod_u32(u, t, mj)
        hi = F.submod_u32(u, t, mj)
        x = jnp.stack([lo, hi], axis=-2).reshape(x.shape[:-1] + (d,))
    return x


def cooley_tukey_oracle_np(a: np.ndarray, m: int, *, negacyclic: bool = False) -> np.ndarray:
    """Host bignum oracle for the CT transform = matrix NTT (same convention).

    Cyclic CT computes â_j = Σ a_i ω^{ij}; equals a @ ntt_matrix. The
    negacyclic pre-twist ψ^i gives evaluation at ψ^{2j+1}... but the DIT
    output ordering matches the cyclic matrix on the twisted input, so we
    simply reuse the matrix oracle.
    """
    if negacyclic:
        psi = _roots(m, 2 * len(a) if a.ndim == 1 else 2 * a.shape[-1])
        d = a.shape[-1]
        pre = _power_table(psi, d, m).astype(object)
        a = (a.astype(object) * pre) % m
    w = ntt_matrix(a.shape[-1], m, negacyclic=False)
    return matrix_ntt_oracle_np(a, w, m)


# --- MORPH baseline: butterfly as dense per-stage GEMMs ----------------------


@functools.lru_cache(maxsize=16)
def morph_stage_matrices(d: int, m: int) -> tuple:
    """Dense (d×d) uint32 matrices S_1..S_log2(d) plus the bit-reversal
    permutation matrix P such that a @ P @ S_1 @ ... @ S_k == cyclic NTT(a).

    Built by applying the iterative butterfly stages to identity columns with
    bignum arithmetic — each S_s has exactly 2 nonzeros per row, but MORPH
    dispatches it as a dense tile-resident GEMM.
    """
    rev = _bit_reverse_perm(d)
    perm = np.zeros((d, d), np.uint32)
    perm[rev, np.arange(d)] = 1

    mats = []
    span = 1
    omega = _roots(m, d)
    while span < d:
        w_span = pow(omega, d // (2 * span), m)
        tw = _power_table(w_span, span, m)
        s = np.zeros((d, d), object)
        nblocks = d // (2 * span)
        for blk in range(nblocks):
            base = blk * 2 * span
            for j in range(span):
                u, v = base + j, base + span + j
                # lo = u + tw*v ; hi = u - tw*v   (row = input, col = output)
                s[u, u] = 1
                s[u, v] = 1
                s[v, u] = int(tw[j])
                s[v, v] = (m - int(tw[j])) % m
        mats.append((s % m).astype(np.uint32))
        span *= 2
    return (perm,) + tuple(mats)
