"""Limb-interleaved exact matrix transforms on the MXU (paper §5.1, §6.2).

A field dot product  y_j = Σ_i a_i · W_ij  (mod m)  is staged as:

  1. u8 limb planes of the data (unsigned) and balanced s8 limb planes of the
     twiddle matrix (signed) — :mod:`repro.core.limbs`;
  2. one **fused interleaved DotGeneral** per staging pass: the limbs of both
     operands are geometrically interleaved into a single (N, d·La)×(d·La,
     d·n_diag) matmul whose K dimension accumulates the multi-limb convolution
     directly (paper's Property 5.1 packing), OR the mathematically identical
     per-plane form (La·Lw separate dots) used for large d and as a reference;
  3. the VPU reduction: under the **eager** (multi-tenant isolation)
     discipline, one fold per staging pass with
     ``jax.lax.optimization_barrier`` between passes; under the **lazy**
     κ-amortised discipline (paper §7.2.1), unreduced int32 diagonals
     accumulate across up to κ passes
     (:class:`repro.core.accumulator.LazyWindowAccumulator` proves the
     overflow bound at trace time) and fold once per window via
     :func:`repro.core.montgomery.deferred_fold`.  ``kappa=None`` selects the
     whole-transform single-window (MORPH-style) mode.

Accumulator models:

* ``fp32_mantissa`` — the TPU v4 behaviour: partial sums materialise through
  the MXU FP32 path; exact only within the 2**24 mantissa window.  Modelled
  bit-exactly by accumulating in float32.
* ``int32_native`` — the v5e/v5p behaviour: true int32 accumulation, exact to
  2**31 - 1.

The per-pass degree ceiling d_max = ⌊window / (C · 32640)⌋ (C = densest
diagonal) reproduces the paper's d_max^BN = 128 and d_max^Dil = 171 exactly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import accumulator as ACC
from repro.core import field as F
from repro.core import limbs as L
# Accumulator-discipline primitives live in repro.core.accumulator; re-exported
# here because this module is their historical home (G.MAX_PIXEL_PRODUCT etc.).
from repro.core.accumulator import (AccumModel, MAX_PIXEL_PRODUCT,  # noqa: F401
                                    accumulator_window)

Reduction = Literal["eager", "lazy"]
REDUCTIONS = ("eager", "lazy")


def check_reduction(reduction: str, kappa: int | None = None) -> str:
    """Validate a reduction-mode string (typos must fail loudly, not silently
    trace the eager path).  When ``kappa`` is supplied, also reject the
    eager+κ>1 combination — deferral depth only means something under lazy
    folding, and recording one that never happened corrupts bench records."""
    if reduction not in REDUCTIONS:
        raise ValueError(f"unknown reduction mode {reduction!r}; "
                         f"expected one of {REDUCTIONS}")
    if reduction == "eager" and kappa not in (None, 1):
        raise ValueError("kappa-amortisation requires reduction='lazy' "
                         f"(got kappa={kappa} with eager folds)")
    return reduction


def lazy_window_sizes(n_passes: int, d_tile: int, c: int, accum: AccumModel,
                      kappa: int | None) -> tuple[int, ...]:
    """κ-window cut of a staged transform, overflow-checked for ``accum``.

    c = densest convolution diagonal multiplicity (min of the limb counts);
    raises ValueError when the requested deferral depth exceeds
    κ_max(accum, d_tile, c) — the trace-time assert of the lazy discipline.
    """
    return ACC.window_plan(n_passes, kappa, ACC.kappa_max(accum, d_tile, c))


def staging_d_max(data_limbs: int, tw_limbs: int, accum: AccumModel) -> int:
    """Per-pass unpadded degree ceiling before VPU re-injection (Prop. 5.1)."""
    c = min(data_limbs, tw_limbs)  # densest convolution diagonal
    return accumulator_window(accum) // (c * MAX_PIXEL_PRODUCT)


@dataclasses.dataclass(frozen=True)
class ChannelPlan:
    """Precompiled single-channel transform: twiddle limb planes + staging."""

    modulus: int
    d: int
    data_limbs: int
    tw_limbs: int
    accum: AccumModel
    w_planes: np.ndarray        # (d, d, Lw) int8, balanced signed digits
    fused_operand: np.ndarray | None  # (d·La, d·n_diag) int8, or None for big d

    @property
    def n_diag(self) -> int:
        return self.data_limbs + self.tw_limbs - 1

    @property
    def d_max(self) -> int:
        return staging_d_max(self.data_limbs, self.tw_limbs, self.accum)

    @property
    def n_passes(self) -> int:
        return math.ceil(self.d / self.d_max)

    def tile_bounds(self, d_max: int | None = None) -> list[tuple[int, int]]:
        step = d_max or self.d_max
        out, lo = [], 0
        while lo < self.d:
            hi = min(lo + step, self.d)
            out.append((lo, hi))
            lo = hi
        return out


def plane_operands(plan: ChannelPlan):
    """Device-resident copies of a plan's twiddle tensors, uploaded once.

    Returns ``(w_planes, fused_operand)`` with exactly one entry non-None
    (matching the plan's mode).  Passing these to
    :func:`staged_transform` via ``planes=`` makes the twiddle tensors jit
    *arguments* instead of baked host constants, so (a) the host→device
    upload happens once per engine rather than once per trace, and (b)
    ladder retraces at new batch heights reuse the same device buffers —
    the dispatch fast path's zero-re-embedding contract.
    """
    if plan.fused_operand is not None:
        return (None, jax.device_put(plan.fused_operand))
    return (jax.device_put(plan.w_planes), None)


def _fused_operand(w_planes: np.ndarray, data_limbs: int) -> np.ndarray:
    """Interleave twiddle limb planes into the fused (d·La, d·n_diag) matrix."""
    d, d2, lw = w_planes.shape
    assert d == d2
    n_diag = data_limbs + lw - 1
    fused = np.zeros((d, data_limbs, d, n_diag), np.int8)
    for p in range(data_limbs):
        for q in range(lw):
            fused[:, p, :, p + q] = w_planes[:, :, q]
    return fused.reshape(d * data_limbs, d * n_diag)


def make_channel_plan(
    w_u32: np.ndarray,
    modulus: int,
    *,
    data_limbs: int,
    tw_limbs: int,
    accum: AccumModel = "fp32_mantissa",
    fuse_below: int = 2049,
) -> ChannelPlan:
    """Host-side precompilation of a channel twiddle matrix."""
    d = w_u32.shape[0]
    assert w_u32.shape == (d, d)
    balanced = L.balanced_residue(w_u32, modulus)
    planes = L.signed_digits(balanced, tw_limbs)  # (d, d, Lw) int8
    fused = _fused_operand(planes, data_limbs) if d <= fuse_below else None
    return ChannelPlan(
        modulus=modulus, d=d, data_limbs=data_limbs, tw_limbs=tw_limbs,
        accum=accum, w_planes=planes, fused_operand=fused,
    )


# --- Device-side diagonal computation ----------------------------------------


def _dot(a, b, accum: AccumModel):
    """The accumulator-model-faithful dot: f32 (v4) or int32 (v5p) partials."""
    if accum == "fp32_mantissa":
        out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        return out  # caller converts; rounding beyond 2^24 is the modelled HW
    return jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                   preferred_element_type=jnp.int32)


def tile_diagonals(a_tile_u32, w_planes_tile, fused_tile, plan: ChannelPlan):
    """Diagonal sums for one staging pass.

    a_tile_u32: (N, dt) uint32 coefficients for this pass.
    w_planes_tile: (dt, d, Lw) int8 (device array) — per-plane mode.
    fused_tile: (dt·La, d·n_diag) int8 or None — fused interleaved mode.
    Returns int32 (N, d, n_diag).
    """
    n = a_tile_u32.shape[0]
    la = plan.data_limbs
    limbs = L.decompose_u8(a_tile_u32, la)  # (N, dt, La) u8
    with jax.named_scope("mxu_pointwise"):
        if fused_tile is not None:
            a_flat = limbs.reshape(n, -1)  # (N, dt·La) — K = (i, p)
            out = _dot(a_flat, fused_tile, plan.accum)
            out = out.reshape(n, plan.d, plan.n_diag)
        else:
            parts = []
            for k in range(plan.n_diag):
                terms = []
                for p in range(la):
                    q = k - p
                    if 0 <= q < plan.tw_limbs:
                        terms.append(_dot(limbs[..., p], w_planes_tile[..., q],
                                          plan.accum))
                parts.append(sum(terms[1:], terms[0]))
            out = jnp.stack(parts, axis=-1)
        if plan.accum == "fp32_mantissa":
            # f32 partials re-enter the integer pipeline here (VPU boundary).
            out = out.astype(jnp.int32)
    return out


def staged_transform(
    a_u32,
    plan: ChannelPlan,
    *,
    reduction: Reduction = "eager",
    kappa: int | None = None,
    barriers: bool = True,
    kernel_fn=None,
    fold_fn=None,
    d_max: int | None = None,
    planes=None,
):
    """Full staged matrix transform of one channel.

    a_u32: (N, d) uint32 coefficients (values < modulus).
    Returns ((N, d) uint32 result, stats dict with fold/pass/window counts).

    ``planes`` — optional ``(w_planes, fused_operand)`` pair of *traced or
    device-resident* twiddle tensors (see :func:`plane_operands`).  When
    given, staging tiles are sliced from them instead of re-embedding the
    host-side plan constants into every trace; semantics are identical.

    eager: fold + optimization_barrier after every staging pass (the
      multi-tenant isolation discipline — Invariant 5.1); ``kappa`` must be
      None or 1.
    lazy: accumulate unreduced int32 diagonals across up to κ passes per
      window and fold once per window (paper §7.2.1 amortisation).
      ``kappa=None`` means one window for the whole transform (MORPH-style);
      either way the deferral depth is checked against the analytic
      κ_max(accum, d_tile, c) at trace time and overflowing windows raise.
    ``fold_fn(acc, m) -> uint32`` swaps the deferred-fold implementation
    (e.g. :func:`repro.kernels.mont_fold.ops.mont_fold` in kernel mode).
    """
    check_reduction(reduction, kappa)
    step = min(d_max or plan.d_max, plan.d)
    if step > plan.d_max:
        # Property 5.1: one staging pass must itself fit the accumulator
        # window — an oversized tile silently rounds under fp32, so refuse
        # it on every path (the lazy path would also catch it via κ_max=0).
        raise ValueError(
            f"staging tile d_tile={step} exceeds the {plan.accum} per-pass "
            f"ceiling d_max={plan.d_max}")
    m = jnp.uint32(plan.modulus)
    n = a_u32.shape[0]
    tiles = plan.tile_bounds(d_max)
    stats = {"n_passes": len(tiles), "n_folds": 0, "reduction": reduction,
             "kappa": 1, "n_windows": len(tiles)}

    acc = None
    if reduction == "lazy":
        c = min(plan.data_limbs, plan.tw_limbs)
        windows = lazy_window_sizes(len(tiles), step, c, plan.accum, kappa)
        stats["kappa"] = windows[0]
        stats["n_windows"] = len(windows)
        acc = ACC.LazyWindowAccumulator(plan.modulus, plan.accum, c,
                                        kappa=windows[0], fold_fn=fold_fn)

    w_full, f_full = planes if planes is not None else (None, None)
    y = jnp.zeros((n, plan.d), jnp.uint32)
    for t, (lo, hi) in enumerate(tiles):
        with jax.named_scope(f"staging_pass_{t}"):
            a_tile = a_u32[:, lo:hi]
            w_tile, f_tile = None, None
            if plan.fused_operand is not None:
                la = plan.data_limbs
                f_tile = (f_full[lo * la:hi * la] if f_full is not None
                          else jnp.asarray(plan.fused_operand[lo * la:hi * la]))
            else:
                w_tile = (w_full[lo:hi] if w_full is not None
                          else jnp.asarray(plan.w_planes[lo:hi]))
            if kernel_fn is not None:
                diag = kernel_fn(a_tile, w_tile, f_tile, plan)
            else:
                diag = tile_diagonals(a_tile, w_tile, f_tile, plan)
            if reduction == "eager":
                with jax.named_scope("vpu_fold"):
                    y_t = F.fold_diagonals_u32(diag, m)
                    y = F.addmod_u32(y, y_t, m)
                stats["n_folds"] += 1
        if reduction == "eager":
            if barriers and t + 1 < len(tiles):
                # Invariant 5.1: no fold scheduled inside an open summation;
                # the barrier forbids XLA from coalescing adjacent passes.
                y, a_u32 = jax.lax.optimization_barrier((y, a_u32))
        else:
            acc.add(diag, hi - lo)
            if acc.ready() or t + 1 == len(tiles):
                y = F.addmod_u32(y, acc.fold(), m)
                stats["n_folds"] += 1
                if barriers and t + 1 < len(tiles):
                    # window-granular Invariant 5.1: passes inside a window
                    # may coalesce (that is the amortisation), windows not.
                    y, a_u32 = jax.lax.optimization_barrier((y, a_u32))
    return y, stats


def staged_transform_traced(
    a_u32,
    w_planes,
    *,
    modulus: int,
    data_limbs: int,
    accum: AccumModel = "fp32_mantissa",
    reduction: Reduction = "eager",
    kappa: int | None = None,
    barriers: bool = True,
    d_max: int | None = None,
):
    """Staged transform with the twiddle limb planes as a *traced* operand.

    w_planes: (d, d, Lw) int8 (balanced signed digits) — an input rather than
    a baked constant, so (a) huge-degree dry-runs lower with
    ShapeDtypeStructs and zero host memory, and (b) the twiddle tensor can be
    sharded over the mesh (output-column TP).  Per-plane mode only.
    Semantics identical to :func:`staged_transform` (including κ windows).
    """
    check_reduction(reduction, kappa)
    m = jnp.uint32(modulus)
    n, d = a_u32.shape
    tw_limbs = w_planes.shape[-1]
    n_diag = data_limbs + tw_limbs - 1
    ceiling = staging_d_max(data_limbs, tw_limbs, accum)
    step = d_max or ceiling
    if min(step, d) > ceiling:
        raise ValueError(f"staging tile d_tile={min(step, d)} exceeds the "
                         f"{accum} per-pass ceiling d_max={ceiling}")
    tiles = []
    lo = 0
    while lo < d:
        tiles.append((lo, min(lo + step, d)))
        lo = tiles[-1][1]

    acc = None
    if reduction == "lazy":
        c = min(data_limbs, tw_limbs)
        windows = lazy_window_sizes(len(tiles), min(step, d), c, accum, kappa)
        acc = ACC.LazyWindowAccumulator(modulus, accum, c, kappa=windows[0])

    y = jnp.zeros((n, d), jnp.uint32)
    for t, (lo, hi) in enumerate(tiles):
        with jax.named_scope(f"staging_pass_{t}"):
            limbs = L.decompose_u8(a_u32[:, lo:hi], data_limbs)
            w_tile = w_planes[lo:hi]
            with jax.named_scope("mxu_pointwise"):
                parts = []
                for k in range(n_diag):
                    terms = []
                    for p in range(data_limbs):
                        q = k - p
                        if 0 <= q < tw_limbs:
                            terms.append(_dot(limbs[..., p], w_tile[..., q],
                                              accum))
                    parts.append(sum(terms[1:], terms[0]))
                diag = jnp.stack(parts, axis=-1)
                if accum == "fp32_mantissa":
                    diag = diag.astype(jnp.int32)
            if reduction == "eager":
                with jax.named_scope("vpu_fold"):
                    y = F.addmod_u32(y, F.fold_diagonals_u32(diag, m), m)
        if reduction == "eager":
            if barriers and t + 1 < len(tiles):
                y, a_u32 = jax.lax.optimization_barrier((y, a_u32))
        else:
            acc.add(diag, hi - lo)
            if acc.ready() or t + 1 == len(tiles):
                y = F.addmod_u32(y, acc.fold(), m)
                if barriers and t + 1 < len(tiles):
                    y, a_u32 = jax.lax.optimization_barrier((y, a_u32))
    return y


def staged_transform_scan(
    a_u32,
    w_planes,
    *,
    modulus: int,
    data_limbs: int,
    accum: AccumModel = "fp32_mantissa",
    d_max: int | None = None,
    reduction: Reduction = "eager",
    kappa: int | None = None,
):
    """Staged transform with a lax.scan over staging passes (or κ-windows).

    Requires d % tile == 0 (pads otherwise).  The loop-carried dependency
    through the folded accumulator gives a *stronger* serialization guarantee
    than optimization barriers (Invariant 5.1 holds by dataflow), and the HLO
    stays O(1) in the pass count — at d=8192 this cuts compile time ~50×
    versus the unrolled module.  This is the beyond-paper "scan staging"
    variant measured in EXPERIMENTS.md §Perf.

    Lazy mode scans over κ-windows: each scan step accumulates κ unrolled
    passes unreduced and folds once, so the fold count is n_passes/κ by
    dataflow.  Being a loop, every window shares one trace — the validator's
    per-window census applies to the unrolled :func:`staged_transform` form.
    """
    check_reduction(reduction, kappa)
    m = jnp.uint32(modulus)
    n, d = a_u32.shape
    tw_limbs = w_planes.shape[-1]
    n_diag = data_limbs + tw_limbs - 1
    ceiling = staging_d_max(data_limbs, tw_limbs, accum)
    step = min(d_max or ceiling, d)
    if step > ceiling:
        raise ValueError(f"staging tile d_tile={step} exceeds the {accum} "
                         f"per-pass ceiling d_max={ceiling}")

    k_eff = 1
    if reduction == "lazy":
        c = min(data_limbs, tw_limbs)
        n_tiles_raw = math.ceil(d / step)
        windows = lazy_window_sizes(n_tiles_raw, step, c, accum, kappa)
        k_eff = windows[0]

    # Pad so the pass axis cuts evenly into windows of k_eff tiles; zero
    # tiles contribute zero diagonals and fold harmlessly.
    pad = (-d) % (step * k_eff)
    if pad:
        a_u32 = jnp.pad(a_u32, ((0, 0), (0, pad)))
        w_planes = jnp.pad(w_planes, ((0, pad), (0, 0), (0, 0)))
    n_tiles = (d + pad) // step
    a_tiles = a_u32.reshape(n, n_tiles, step).transpose(1, 0, 2)
    w_tiles = w_planes.reshape(n_tiles, step, d, tw_limbs)

    def diagonals(a_t, w_t):
        limbs = L.decompose_u8(a_t, data_limbs)
        parts = []
        for k in range(n_diag):
            terms = []
            for p in range(data_limbs):
                q = k - p
                if 0 <= q < tw_limbs:
                    terms.append(_dot(limbs[..., p], w_t[..., q], accum))
            parts.append(sum(terms[1:], terms[0]))
        diag = jnp.stack(parts, axis=-1)
        if accum == "fp32_mantissa":
            diag = diag.astype(jnp.int32)
        return diag

    if reduction == "lazy":
        n_windows = n_tiles // k_eff
        aw = a_tiles.reshape(n_windows, k_eff, n, step)
        ww = w_tiles.reshape(n_windows, k_eff, step, d, tw_limbs)

        def window_body(y, inp):
            a_w, w_w = inp
            acc = None
            for j in range(k_eff):      # unreduced κ-deep accumulation
                diag = diagonals(a_w[j], w_w[j])
                acc = diag if acc is None else acc + diag
            with jax.named_scope("vpu_fold_lazy"):
                y = F.addmod_u32(y, F.fold_diagonals_u32(acc, m), m)
            return y, None

        y0 = jnp.zeros((n, d), jnp.uint32)
        y, _ = jax.lax.scan(window_body, y0, (aw, ww))
        return y

    def body(carry, inp):
        a_t, w_t = inp
        y = F.addmod_u32(carry, F.fold_diagonals_u32(diagonals(a_t, w_t), m), m)
        return y, None

    y0 = jnp.zeros((n, d), jnp.uint32)
    y, _ = jax.lax.scan(body, y0, (a_tiles, w_tiles))
    return y


def matrix_transform_ref(a_u32, w_u32, modulus: int):
    """Pure mulmod/addmod jnp oracle: y = a @ W mod m (no limb machinery)."""
    m = jnp.uint32(modulus)

    def body(j, y):
        col = w_u32[:, j]
        prod = F.mulmod_u32(a_u32, col[None, :], m)
        # tree-free sequential modular accumulation
        s = jnp.zeros(a_u32.shape[0], jnp.uint32)

        def inner(i, s):
            return F.addmod_u32(s, prod[:, i], m)

        s = jax.lax.fori_loop(0, prod.shape[1], inner, s)
        return y.at[:, j].set(s)

    y0 = jnp.zeros_like(a_u32)
    return jax.lax.fori_loop(0, w_u32.shape[1], body, y0)
