"""Limb-interleaved exact matrix transforms on the MXU (paper §5.1, §6.2).

A field dot product  y_j = Σ_i a_i · W_ij  (mod m)  is staged as:

  1. u8 limb planes of the data (unsigned) and balanced s8 limb planes of the
     twiddle matrix (signed) — :mod:`repro.core.limbs`;
  2. one **fused interleaved DotGeneral** per staging pass: the limbs of both
     operands are geometrically interleaved into a single (N, d·La)×(d·La,
     d·n_diag) matmul whose K dimension accumulates the multi-limb convolution
     directly (paper's Property 5.1 packing), OR the mathematically identical
     per-plane form (La·Lw separate dots) used for large d and as a reference;
  3. a VPU fold per staging pass: diagonals → field value mod m
     (:func:`repro.core.field.fold_diagonals_u32`), with
     ``jax.lax.optimization_barrier`` between passes (eager / multi-tenant
     discipline) or a single deferred fold (lazy / single-tenant discipline).

Accumulator models:

* ``fp32_mantissa`` — the TPU v4 behaviour: partial sums materialise through
  the MXU FP32 path; exact only within the 2**24 mantissa window.  Modelled
  bit-exactly by accumulating in float32.
* ``int32_native`` — the v5e/v5p behaviour: true int32 accumulation, exact to
  2**31 - 1.

The per-pass degree ceiling d_max = ⌊window / (C · 32640)⌋ (C = densest
diagonal) reproduces the paper's d_max^BN = 128 and d_max^Dil = 171 exactly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import field as F
from repro.core import limbs as L

MAX_PIXEL_PRODUCT = 255 * 128  # u8 × s8 worst case (paper §5.1)

AccumModel = Literal["fp32_mantissa", "int32_native"]
Reduction = Literal["eager", "lazy"]

_WINDOW = {"fp32_mantissa": 1 << 24, "int32_native": (1 << 31) - 1}


def accumulator_window(accum: AccumModel) -> int:
    return _WINDOW[accum]


def staging_d_max(data_limbs: int, tw_limbs: int, accum: AccumModel) -> int:
    """Per-pass unpadded degree ceiling before VPU re-injection (Prop. 5.1)."""
    c = min(data_limbs, tw_limbs)  # densest convolution diagonal
    return accumulator_window(accum) // (c * MAX_PIXEL_PRODUCT)


@dataclasses.dataclass(frozen=True)
class ChannelPlan:
    """Precompiled single-channel transform: twiddle limb planes + staging."""

    modulus: int
    d: int
    data_limbs: int
    tw_limbs: int
    accum: AccumModel
    w_planes: np.ndarray        # (d, d, Lw) int8, balanced signed digits
    fused_operand: np.ndarray | None  # (d·La, d·n_diag) int8, or None for big d

    @property
    def n_diag(self) -> int:
        return self.data_limbs + self.tw_limbs - 1

    @property
    def d_max(self) -> int:
        return staging_d_max(self.data_limbs, self.tw_limbs, self.accum)

    @property
    def n_passes(self) -> int:
        return math.ceil(self.d / self.d_max)

    def tile_bounds(self, d_max: int | None = None) -> list[tuple[int, int]]:
        step = d_max or self.d_max
        out, lo = [], 0
        while lo < self.d:
            hi = min(lo + step, self.d)
            out.append((lo, hi))
            lo = hi
        return out


def _fused_operand(w_planes: np.ndarray, data_limbs: int) -> np.ndarray:
    """Interleave twiddle limb planes into the fused (d·La, d·n_diag) matrix."""
    d, d2, lw = w_planes.shape
    assert d == d2
    n_diag = data_limbs + lw - 1
    fused = np.zeros((d, data_limbs, d, n_diag), np.int8)
    for p in range(data_limbs):
        for q in range(lw):
            fused[:, p, :, p + q] = w_planes[:, :, q]
    return fused.reshape(d * data_limbs, d * n_diag)


def make_channel_plan(
    w_u32: np.ndarray,
    modulus: int,
    *,
    data_limbs: int,
    tw_limbs: int,
    accum: AccumModel = "fp32_mantissa",
    fuse_below: int = 2049,
) -> ChannelPlan:
    """Host-side precompilation of a channel twiddle matrix."""
    d = w_u32.shape[0]
    assert w_u32.shape == (d, d)
    balanced = L.balanced_residue(w_u32, modulus)
    planes = L.signed_digits(balanced, tw_limbs)  # (d, d, Lw) int8
    fused = _fused_operand(planes, data_limbs) if d <= fuse_below else None
    return ChannelPlan(
        modulus=modulus, d=d, data_limbs=data_limbs, tw_limbs=tw_limbs,
        accum=accum, w_planes=planes, fused_operand=fused,
    )


# --- Device-side diagonal computation ----------------------------------------


def _dot(a, b, accum: AccumModel):
    """The accumulator-model-faithful dot: f32 (v4) or int32 (v5p) partials."""
    if accum == "fp32_mantissa":
        out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        return out  # caller converts; rounding beyond 2^24 is the modelled HW
    return jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                   preferred_element_type=jnp.int32)


def tile_diagonals(a_tile_u32, w_planes_tile, fused_tile, plan: ChannelPlan):
    """Diagonal sums for one staging pass.

    a_tile_u32: (N, dt) uint32 coefficients for this pass.
    w_planes_tile: (dt, d, Lw) int8 (device array) — per-plane mode.
    fused_tile: (dt·La, d·n_diag) int8 or None — fused interleaved mode.
    Returns int32 (N, d, n_diag).
    """
    n = a_tile_u32.shape[0]
    la = plan.data_limbs
    limbs = L.decompose_u8(a_tile_u32, la)  # (N, dt, La) u8
    with jax.named_scope("mxu_pointwise"):
        if fused_tile is not None:
            a_flat = limbs.reshape(n, -1)  # (N, dt·La) — K = (i, p)
            out = _dot(a_flat, fused_tile, plan.accum)
            out = out.reshape(n, plan.d, plan.n_diag)
        else:
            parts = []
            for k in range(plan.n_diag):
                terms = []
                for p in range(la):
                    q = k - p
                    if 0 <= q < plan.tw_limbs:
                        terms.append(_dot(limbs[..., p], w_planes_tile[..., q],
                                          plan.accum))
                parts.append(sum(terms[1:], terms[0]))
            out = jnp.stack(parts, axis=-1)
        if plan.accum == "fp32_mantissa":
            # f32 partials re-enter the integer pipeline here (VPU boundary).
            out = out.astype(jnp.int32)
    return out


def staged_transform(
    a_u32,
    plan: ChannelPlan,
    *,
    reduction: Reduction = "eager",
    barriers: bool = True,
    kernel_fn=None,
    d_max: int | None = None,
):
    """Full staged matrix transform of one channel.

    a_u32: (N, d) uint32 coefficients (values < modulus).
    Returns ((N, d) uint32 result, stats dict with fold/pass counts).

    eager: fold + optimization_barrier after every staging pass (the
      multi-tenant isolation discipline — Invariant 5.1).
    lazy: accumulate int32 diagonals across passes while the accumulator
      window allows, folding once (single-tenant MORPH-style discipline).
    """
    m = jnp.uint32(plan.modulus)
    n = a_u32.shape[0]
    tiles = plan.tile_bounds(d_max)
    stats = {"n_passes": len(tiles), "n_folds": 0}

    if reduction == "lazy":
        c = min(plan.data_limbs, plan.tw_limbs)
        if plan.d * c * MAX_PIXEL_PRODUCT > accumulator_window("int32_native"):
            raise ValueError("lazy reduction would overflow even int32 window")
        if plan.accum == "fp32_mantissa" and plan.d > plan.d_max:
            raise ValueError(
                "lazy reduction across passes violates the fp32 mantissa "
                "window (Property 5.1) — the paper's point"
            )

    acc_diag = None
    y = jnp.zeros((n, plan.d), jnp.uint32)
    for t, (lo, hi) in enumerate(tiles):
        with jax.named_scope(f"staging_pass_{t}"):
            a_tile = a_u32[:, lo:hi]
            w_tile = None if plan.fused_operand is not None else jnp.asarray(
                plan.w_planes[lo:hi])
            f_tile = None
            if plan.fused_operand is not None:
                la = plan.data_limbs
                f_tile = jnp.asarray(
                    plan.fused_operand[lo * la:hi * la])
            if kernel_fn is not None:
                diag = kernel_fn(a_tile, w_tile, f_tile, plan)
            else:
                diag = tile_diagonals(a_tile, w_tile, f_tile, plan)
            if reduction == "eager":
                with jax.named_scope("vpu_fold"):
                    y_t = F.fold_diagonals_u32(diag, m)
                    y = F.addmod_u32(y, y_t, m)
                stats["n_folds"] += 1
        if reduction == "eager":
            if barriers and t + 1 < len(tiles):
                # Invariant 5.1: no fold scheduled inside an open summation;
                # the barrier forbids XLA from coalescing adjacent passes.
                y, a_u32 = jax.lax.optimization_barrier((y, a_u32))
        else:
            acc_diag = diag if acc_diag is None else acc_diag + diag
    if reduction == "lazy":
        with jax.named_scope("vpu_fold_lazy"):
            y = F.fold_diagonals_u32(acc_diag, m)
        stats["n_folds"] += 1
    return y, stats


def staged_transform_traced(
    a_u32,
    w_planes,
    *,
    modulus: int,
    data_limbs: int,
    accum: AccumModel = "fp32_mantissa",
    reduction: Reduction = "eager",
    barriers: bool = True,
    d_max: int | None = None,
):
    """Staged transform with the twiddle limb planes as a *traced* operand.

    w_planes: (d, d, Lw) int8 (balanced signed digits) — an input rather than
    a baked constant, so (a) huge-degree dry-runs lower with
    ShapeDtypeStructs and zero host memory, and (b) the twiddle tensor can be
    sharded over the mesh (output-column TP).  Per-plane mode only.
    Semantics identical to :func:`staged_transform`.
    """
    m = jnp.uint32(modulus)
    n, d = a_u32.shape
    tw_limbs = w_planes.shape[-1]
    n_diag = data_limbs + tw_limbs - 1
    step = d_max or staging_d_max(data_limbs, tw_limbs, accum)
    tiles = []
    lo = 0
    while lo < d:
        tiles.append((lo, min(lo + step, d)))
        lo = tiles[-1][1]

    if reduction == "lazy" and accum == "fp32_mantissa" and d > step:
        raise ValueError("lazy reduction violates the fp32 mantissa window")

    acc_diag = None
    y = jnp.zeros((n, d), jnp.uint32)
    for t, (lo, hi) in enumerate(tiles):
        with jax.named_scope(f"staging_pass_{t}"):
            limbs = L.decompose_u8(a_u32[:, lo:hi], data_limbs)
            w_tile = w_planes[lo:hi]
            with jax.named_scope("mxu_pointwise"):
                parts = []
                for k in range(n_diag):
                    terms = []
                    for p in range(data_limbs):
                        q = k - p
                        if 0 <= q < tw_limbs:
                            terms.append(_dot(limbs[..., p], w_tile[..., q],
                                              accum))
                    parts.append(sum(terms[1:], terms[0]))
                diag = jnp.stack(parts, axis=-1)
                if accum == "fp32_mantissa":
                    diag = diag.astype(jnp.int32)
            if reduction == "eager":
                with jax.named_scope("vpu_fold"):
                    y = F.addmod_u32(y, F.fold_diagonals_u32(diag, m), m)
        if reduction == "eager":
            if barriers and t + 1 < len(tiles):
                y, a_u32 = jax.lax.optimization_barrier((y, a_u32))
        else:
            acc_diag = diag if acc_diag is None else acc_diag + diag
    if reduction == "lazy":
        with jax.named_scope("vpu_fold_lazy"):
            y = F.fold_diagonals_u32(acc_diag, m)
    return y


def staged_transform_scan(
    a_u32,
    w_planes,
    *,
    modulus: int,
    data_limbs: int,
    accum: AccumModel = "fp32_mantissa",
    d_max: int | None = None,
    reduction: Reduction = "eager",
):
    """Eager staged transform with a lax.scan over staging passes.

    Requires d % tile == 0 (pads otherwise).  The loop-carried dependency
    through the folded accumulator gives a *stronger* serialization guarantee
    than optimization barriers (Invariant 5.1 holds by dataflow), and the HLO
    stays O(1) in the pass count — at d=8192 this cuts compile time ~50×
    versus the unrolled module.  This is the beyond-paper "scan staging"
    variant measured in EXPERIMENTS.md §Perf.
    """
    m = jnp.uint32(modulus)
    n, d = a_u32.shape
    tw_limbs = w_planes.shape[-1]
    n_diag = data_limbs + tw_limbs - 1
    step = d_max or staging_d_max(data_limbs, tw_limbs, accum)
    step = min(step, d)
    pad = (-d) % step
    if pad:
        a_u32 = jnp.pad(a_u32, ((0, 0), (0, pad)))
        w_planes = jnp.pad(w_planes, ((0, pad), (0, 0), (0, 0)))
    n_tiles = (d + pad) // step
    a_tiles = a_u32.reshape(n, n_tiles, step).transpose(1, 0, 2)
    w_tiles = w_planes.reshape(n_tiles, step, d, tw_limbs)

    if reduction == "lazy":
        c = min(data_limbs, tw_limbs)
        if accum == "fp32_mantissa" and d > step:
            raise ValueError("lazy reduction violates the fp32 mantissa window")
        if d * c * MAX_PIXEL_PRODUCT > accumulator_window("int32_native"):
            raise ValueError("lazy reduction would overflow the int32 window")

    def body(carry, inp):
        a_t, w_t = inp
        limbs = L.decompose_u8(a_t, data_limbs)
        parts = []
        for k in range(n_diag):
            terms = []
            for p in range(data_limbs):
                q = k - p
                if 0 <= q < tw_limbs:
                    terms.append(_dot(limbs[..., p], w_t[..., q], accum))
            parts.append(sum(terms[1:], terms[0]))
        diag = jnp.stack(parts, axis=-1)
        if accum == "fp32_mantissa":
            diag = diag.astype(jnp.int32)
        if reduction == "lazy":
            return carry + diag, None
        y = F.addmod_u32(carry, F.fold_diagonals_u32(diag, m), m)
        return y, None

    if reduction == "lazy":
        acc0 = jnp.zeros((n, d, n_diag), jnp.int32)
        acc, _ = jax.lax.scan(body, acc0, (a_tiles, w_tiles))
        with jax.named_scope("vpu_fold_lazy"):
            return F.fold_diagonals_u32(acc, m)
    y0 = jnp.zeros((n, d), jnp.uint32)
    y, _ = jax.lax.scan(body, y0, (a_tiles, w_tiles))
    return y


def matrix_transform_ref(a_u32, w_u32, modulus: int):
    """Pure mulmod/addmod jnp oracle: y = a @ W mod m (no limb machinery)."""
    m = jnp.uint32(modulus)

    def body(j, y):
        col = w_u32[:, j]
        prod = F.mulmod_u32(a_u32, col[None, :], m)
        # tree-free sequential modular accumulation
        s = jnp.zeros(a_u32.shape[0], jnp.uint32)

        def inner(i, s):
            return F.addmod_u32(s, prod[:, i], m)

        s = jax.lax.fori_loop(0, prod.shape[1], inner, s)
        return y.at[:, j].set(s)

    y0 = jnp.zeros_like(a_u32)
    return jax.lax.fori_loop(0, w_u32.shape[1], body, y0)
