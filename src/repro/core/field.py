"""Prime-field parameters and exact 32-bit modular arithmetic in JAX.

TPUs (and this framework's device code) have no 64-bit integer ALU path worth
using — the whole point of the paper.  Every device-side primitive here is
exact using only uint32/int32 operations:

* ``addmod_u32`` / ``submod_u32`` — trivial conditional-subtract forms.
* ``mulmod_u32`` — 16-bit schoolbook split with shift-by-one modular doubling,
  exact for any modulus m < 2**31.

These are the "VPU-side" scalar primitives.  The MXU-side path (int8 limb
matmuls) lives in :mod:`repro.core.limb_gemm`.
"""
from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp

# --- Field constants (host-side Python bignums) -----------------------------

# BN254 scalar field (Fr) — the NTT field of Groth16/PLONK over BN254.
BN254_FR = 21888242871839275222246405745257275088548364400416034343698204186575808495617
BN254_FR_TWO_ADICITY = 28

# CRYSTALS-Dilithium / ML-DSA prime, q = 2^23 - 2^13 + 1.
DILITHIUM_Q = 8380417
DILITHIUM_ZETA = 1753  # primitive 512th root of unity mod Q (FIPS 204)


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """A prime field as staged on the accelerator."""

    name: str
    modulus: int          # Python bignum; may exceed 32 bits (BN254)
    limbs: int            # u8 limbs per 32-bit staged word
    n_channels: int       # RNS channels (1 = direct single-word field)

    @property
    def bits(self) -> int:
        return self.modulus.bit_length()


DILITHIUM_FIELD = FieldSpec("dilithium", DILITHIUM_Q, limbs=3, n_channels=1)
BN254_FIELD = FieldSpec("bn254", BN254_FR, limbs=4, n_channels=9)


# --- Exact uint32 modular arithmetic (vectorised, jit-safe) ------------------


def addmod_u32(a, b, m):
    """(a + b) mod m for a, b < m < 2**31 (uint32 arrays)."""
    s = a + b
    return jnp.where(s >= m, s - m, s)


def submod_u32(a, b, m):
    """(a - b) mod m for a, b < m < 2**31 (uint32 arrays)."""
    return jnp.where(a >= b, a - b, a + m - b)


def _shiftk_mod(x, m, k: int):
    """(x << k) mod m via k conditional doublings; x < m < 2**31."""
    for _ in range(k):
        x = x << jnp.uint32(1)
        x = jnp.where(x >= m, x - m, x)
    return x


def shift8_mod(x, m):
    return _shiftk_mod(x, m, 8)


def shift16_mod(x, m):
    return _shiftk_mod(x, m, 16)


def mulmod_u32(a, b, m):
    """(a * b) mod m, exact, for a, b < m < 2**31. All uint32.

    16-bit schoolbook: a·b = p11·2^32 + (p10+p01)·2^16 + p00 with every
    partial product representable in uint32.
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    a1, a0 = a >> jnp.uint32(16), a & jnp.uint32(0xFFFF)
    b1, b0 = b >> jnp.uint32(16), b & jnp.uint32(0xFFFF)
    p11 = a1 * b1           # < 2^30
    p10 = a1 * b0           # < 2^31
    p01 = a0 * b1           # < 2^31
    p00 = a0 * b0           # < 2^32 (uint32-exact)
    r = shift16_mod(p11 % m, m)
    r = addmod_u32(r, p10 % m, m)
    r = addmod_u32(r, p01 % m, m)
    r = shift16_mod(r, m)
    return addmod_u32(r, p00 % m, m)


def negmod_u32(a, m):
    return jnp.where(a == 0, a, m - a)


def fold_diagonals_u32(diags, m):
    """Fold limb-weight diagonals into a field value mod m (the "VPU fold").

    diags: int32 [..., n_diag] — diagonal k carries weight 2**(8k); entries may
    be negative (balanced twiddle recode).  m: uint32 scalar (m < 2**31).
    Returns uint32 [...] = (sum_k diags[...,k] << 8k) mod m.

    Horner from the top: acc = (acc << 8 + D_k) mod m.  acc < m < 2**31 so the
    doubling chain never overflows uint32.
    """
    m_i32 = m.astype(jnp.int32) if hasattr(m, "astype") else jnp.int32(m)
    n_diag = diags.shape[-1]
    acc = jnp.zeros(diags.shape[:-1], jnp.uint32)
    for k in range(n_diag - 1, -1, -1):
        acc = shift8_mod(acc, m)
        dk = jnp.mod(diags[..., k], m_i32).astype(jnp.uint32)  # non-negative
        acc = addmod_u32(acc, dk, m)
    return acc


@functools.lru_cache(maxsize=None)
def field_for(name: str) -> FieldSpec:
    if name == "dilithium":
        return DILITHIUM_FIELD
    if name == "bn254":
        return BN254_FIELD
    raise KeyError(name)
