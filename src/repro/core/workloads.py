"""Workload engines: the paper's unit-of-work "op" per cryptographic class.

* ``DilithiumEngine`` — forward negacyclic NTT over Q = 8,380,417 (3-limb
  u8×s8, single channel).  One op = one forward NTT of degree d (paper §7).
* ``BN254Engine``     — 9-channel ERNS matrix-form transform with
  CRT-consistent twiddles + per-coefficient Shenoy–Kumaresan / Montgomery
  reduction.  One op = one coefficient-wise full-field polynomial
  multiplication: dual staging passes + in-GEMM matmul + >2,100
  base-extension Montgomery ops (paper §6.2).  ``n_channels=18`` selects the
  extended full-exactness chain (``bn254_full``).

Engines are pure-JAX modules; ``evaluate``/``reduce``/``e2e`` jit cleanly and
are dispatched by the Tier-1/Tier-2 schedulers.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import field as F
from repro.core import limb_gemm as G
from repro.core import ntt as NTT
from repro.core import rns as R


@dataclasses.dataclass(frozen=True)
class WorkloadClass:
    """Workload-class descriptor used by the scheduler for zone segregation."""

    name: str
    precision_zone: int    # limb count — MXU type-homogeneity class
    data_limbs: int
    tw_limbs: int
    n_channels: int


DILITHIUM = WorkloadClass("dilithium", precision_zone=3, data_limbs=3,
                          tw_limbs=3, n_channels=1)
BN254 = WorkloadClass("bn254", precision_zone=4, data_limbs=4, tw_limbs=4,
                      n_channels=9)
BN254_FULL = WorkloadClass("bn254_full", precision_zone=4, data_limbs=4,
                           tw_limbs=4, n_channels=18)

CLASSES = {c.name: c for c in (DILITHIUM, BN254, BN254_FULL)}


def _fold_profile(plans, reduction: str, kappa: int | None,
                  d_tile: int | None) -> dict:
    """Static fold/window census of an engine's compiled program (one entry
    per channel plan; all channels share a plan shape).  Mirrors the window
    maths of :func:`repro.core.limb_gemm.staged_transform` exactly — the
    serve telemetry and HLO validator both consume this."""
    plan = plans[0]
    step = min(d_tile or plan.d_max, plan.d)
    if step > plan.d_max:
        raise ValueError(
            f"staging tile d_tile={step} exceeds the {plan.accum} per-pass "
            f"ceiling d_max={plan.d_max}")
    n_passes = math.ceil(plan.d / step)
    if reduction == "eager":
        windows_per_channel = n_passes
    else:
        c = min(plan.data_limbs, plan.tw_limbs)
        windows_per_channel = len(
            G.lazy_window_sizes(n_passes, step, c, plan.accum, kappa))
    return {
        "reduction": reduction,
        "kappa": kappa,
        "n_passes": n_passes,
        "n_channels": len(plans),
        "windows_per_channel": windows_per_channel,
        "n_folds": windows_per_channel * len(plans),
        "n_diag": plan.n_diag,
    }


class DilithiumEngine:
    """Forward negacyclic NTT over F_Q; exact end-to-end for all inputs."""

    wclass = DILITHIUM

    def __init__(self, d: int, *, accum: G.AccumModel = "fp32_mantissa",
                 reduction: G.Reduction = "eager", kappa: int | None = None,
                 d_tile: int | None = None):
        self.d = d
        self.accum = accum
        self.reduction = G.check_reduction(reduction)
        self.kappa = kappa
        # Staging-pass tile override: None → the accumulator-window ceiling
        # d_max.  A smaller tile (e.g. the fp32-era 171) under int32_native
        # keeps the paper's pass structure while κ defers the folds.
        self.d_tile = d_tile
        # FIPS-204 negacyclic convention needs 2d | Q-1 (2-adicity 13 → d ≤
        # 4096); larger edge-polynomial degrees use the cyclic transform.
        self.negacyclic = (F.DILITHIUM_Q - 1) % (2 * d) == 0
        w = NTT.ntt_matrix(d, F.DILITHIUM_Q, negacyclic=self.negacyclic)
        self.plan = G.make_channel_plan(
            w, F.DILITHIUM_Q, data_limbs=3, tw_limbs=3, accum=accum)
        self.fold_profile = _fold_profile([self.plan], self.reduction, kappa,
                                          d_tile)
        self._device_planes = None

    @property
    def n_passes(self) -> int:
        return self.fold_profile["n_passes"]

    @property
    def n_diag(self) -> int:
        return self.plan.n_diag

    def device_planes(self):
        """Per-channel ``(w_planes, fused)`` twiddle tensors, uploaded to the
        device once per engine (dispatch fast path: retraces at new batch
        heights reuse these buffers instead of re-embedding host constants)."""
        if self._device_planes is None:
            self._device_planes = [G.plane_operands(self.plan)]
        return self._device_planes

    def evaluate(self, a_u32, *, kernel_fn=None, planes=None):
        """(N, d) uint32 -> (N, d) uint32 forward NTT (one op per row)."""
        with jax.named_scope("wzone_dilithium"), jax.named_scope("pzone_3limb"):
            y, self.last_stats = G.staged_transform(
                a_u32, self.plan, reduction=self.reduction, kappa=self.kappa,
                d_max=self.d_tile, kernel_fn=kernel_fn,
                planes=planes[0] if planes is not None else None)
        return y

    e2e = evaluate  # Dilithium op == the forward transform

    def oracle_np(self, a_np: np.ndarray) -> np.ndarray:
        w = NTT.ntt_matrix(self.d, F.DILITHIUM_Q, negacyclic=self.negacyclic)
        return NTT.matrix_ntt_oracle_np(a_np, w, F.DILITHIUM_Q)


class BN254Engine:
    """ERNS matrix transform + per-coefficient Montgomery reduction."""

    def __init__(self, d: int, *, accum: G.AccumModel = "fp32_mantissa",
                 reduction: G.Reduction = "eager", kappa: int | None = None,
                 d_tile: int | None = None, n_channels: int = 9,
                 p: int = F.BN254_FR, evaluation_matrix: np.ndarray | None = None):
        self.wclass = BN254 if n_channels == 9 else BN254_FULL
        self.d = d
        self.accum = accum
        self.reduction = G.check_reduction(reduction)
        self.kappa = kappa
        self.d_tile = d_tile
        self.chain = R.make_chain(n_channels, p=p)
        # CRT-consistent evaluation operand: residues of one integer matrix Ω.
        if evaluation_matrix is None:
            evaluation_matrix = NTT.ntt_matrix(d, p)  # F_p NTT twiddles
        self.omega = evaluation_matrix
        self.plans = []
        for m in self.chain.moduli:
            w_ch = (evaluation_matrix.astype(object) % m).astype(np.uint32)
            self.plans.append(G.make_channel_plan(
                w_ch, m, data_limbs=4, tw_limbs=4, accum=accum))
        self.fold_profile = _fold_profile(self.plans, self.reduction, kappa,
                                          d_tile)
        self._device_planes = None

    @property
    def n_channels(self) -> int:
        return len(self.chain.moduli)

    @property
    def n_passes(self) -> int:
        return self.fold_profile["n_passes"]

    @property
    def n_diag(self) -> int:
        return self.plans[0].n_diag

    def ingest(self, coeffs_np: np.ndarray):
        """Host object-int coefficients [..., d] -> (..., d, C) uint32."""
        return jnp.asarray(R.to_rns_np(coeffs_np, self.chain))

    def device_planes(self):
        """Per-channel ``(w_planes, fused)`` twiddle tensors, uploaded to the
        device once per engine (dispatch fast path: retraces at new batch
        heights reuse these buffers instead of re-embedding host constants)."""
        if self._device_planes is None:
            self._device_planes = [G.plane_operands(p) for p in self.plans]
        return self._device_planes

    def evaluate(self, a_res, *, kernel_fn=None, planes=None):
        """(N, d, C) uint32 residues -> (N, d, C) transformed residues."""
        outs = []
        self.last_stats = None
        with jax.named_scope("wzone_bn254"), jax.named_scope("pzone_4limb"):
            for ci, plan in enumerate(self.plans):
                with jax.named_scope(f"channel_{ci}"):
                    y, st = G.staged_transform(
                        a_res[..., ci], plan, reduction=self.reduction,
                        kappa=self.kappa, d_max=self.d_tile,
                        kernel_fn=kernel_fn,
                        planes=planes[ci] if planes is not None else None)
                outs.append(y)
                self.last_stats = st
        return jnp.stack(outs, axis=-1)

    def reduce(self, y_res):
        """(N, d, C) transformed residues -> (N, d, nred) field digits."""
        with jax.named_scope("wzone_bn254"), jax.named_scope("vpu_montgomery"):
            return R.rns_to_field(y_res, self.chain)

    def e2e(self, a_res, *, kernel_fn=None, planes=None):
        """The paper's BN254 op for N stacked tenant rows."""
        return self.reduce(self.evaluate(a_res, kernel_fn=kernel_fn,
                                         planes=planes))

    # --- host oracles ---------------------------------------------------------

    def oracle_eval_np(self, coeffs_np: np.ndarray) -> np.ndarray:
        """Exact bignum evaluation X_j = Σ a_i Ω_ij (object ints)."""
        return coeffs_np.astype(object) @ self.omega.astype(object)

    def in_envelope(self, coeffs_np: np.ndarray) -> bool:
        x = self.oracle_eval_np(coeffs_np)
        return int(np.max(x)) < self.chain.M


@functools.lru_cache(maxsize=32)
def make_engine(name: str, d: int, accum: str = "fp32_mantissa",
                reduction: str = "eager", kappa: int | None = None,
                d_tile: int | None = None):
    kw = dict(accum=accum, reduction=reduction, kappa=kappa, d_tile=d_tile)
    if name == "dilithium":
        return DilithiumEngine(d, **kw)
    if name == "bn254":
        return BN254Engine(d, n_channels=9, **kw)
    if name == "bn254_full":
        return BN254Engine(d, n_channels=18, **kw)
    raise KeyError(name)
