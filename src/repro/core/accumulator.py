"""Accumulator disciplines: exactness windows, probes, and κ-amortisation.

This module owns the two accumulator models the paper measures and every
derived quantity the rest of the stack needs:

* ``fp32_mantissa`` (TPU v4 path) — partial sums materialise through the MXU
  FP32 accumulator; exact iff every unreduced integer stays <= 2**24;
* ``int32_native`` (v5e/v5p path) — true int32 accumulation, exact through
  2**31 - 1.

Three layers build on the window bound W(accum):

1. **Table-1 probes** (``probe_exact``/``table1_rows``) — empirical
   bit-exactness of a DotGeneral whose true partial sum equals a target S.
   On CPU the float32 matmul reproduces the v4 rounding behaviour bit-exactly
   (2**24 + 1 is not representable in binary32 regardless of summation order).
2. **The κ_max derivation** (``kappa_max``) — one staging pass over a tile of
   ``d_tile`` coefficients produces limb-convolution diagonals bounded by
   ``d_tile · c · MAX_PIXEL_PRODUCT`` (c = densest diagonal multiplicity, the
   number of (p, q) limb pairs sharing a weight class).  Deferring the VPU
   fold across κ passes keeps the unreduced sum exact iff
   ``κ · d_tile · c · MAX_PIXEL_PRODUCT <= W(accum)``, hence

       κ_max(accum, d_tile, c) = ⌊W(accum) / (d_tile · c · MAX_PIXEL_PRODUCT)⌋.

   ``kappa_max_bruteforce`` re-derives the same number by direct search (the
   machine-checked overflow bound the property suite asserts).
3. **The κ-window accumulator** (``LazyWindowAccumulator``) — the trace-time
   object :func:`repro.core.limb_gemm.staged_transform` drives in lazy mode:
   it sums unreduced int32 diagonal planes across passes, *asserts the
   analytic bound on every add*, and folds once per window through
   :func:`repro.core.montgomery.deferred_fold`.
"""
from __future__ import annotations

import math
from typing import Literal

import numpy as np
import jax.numpy as jnp

# u8 × s8 worst-case pixel product (paper §5.1); the twiddle recode is
# balanced-signed, so |w| <= 128 while data limbs stay unsigned <= 255.
MAX_PIXEL_PRODUCT = 255 * 128

AccumModel = Literal["fp32_mantissa", "int32_native"]

_WINDOW = {"fp32_mantissa": 1 << 24, "int32_native": (1 << 31) - 1}


def accumulator_window(accum: AccumModel) -> int:
    """Largest S such that every integer in [-S, S] survives the accumulator."""
    return _WINDOW[accum]


# --- κ-amortisation bound (paper §7.2.1) --------------------------------------


def pass_bound(d_tile: int, c: int,
               pixel_product: int = MAX_PIXEL_PRODUCT) -> int:
    """Worst-case |diagonal entry| contributed by ONE staging pass.

    Each diagonal entry sums ``d_tile`` coefficient positions × at most ``c``
    limb pairs × one u8·s8 product each; signs can align, so the triangle
    bound is attained (all data limbs 255, all twiddle limbs ±128).
    """
    return d_tile * c * pixel_product


def kappa_max(accum: AccumModel, d_tile: int, c: int,
              pixel_product: int = MAX_PIXEL_PRODUCT) -> int:
    """Analytic max deferral depth: most passes one window may accumulate.

    Derivation: after κ passes the unreduced sum is bounded by
    κ · pass_bound; exactness requires that bound <= W(accum).  κ_max = 0
    means even a single pass of this tile width overflows the discipline —
    the tile itself is illegal.
    """
    return accumulator_window(accum) // pass_bound(d_tile, c, pixel_product)


def exact_window_bruteforce(accum: AccumModel) -> int:
    """Largest S with [0, S] fully representable, found by search (not formula).

    Doubling scan + bisection over the first integer the accumulator cannot
    hold: for fp32 that is the first non-representable integer (2**24 + 1),
    for int32 the first value past the two's-complement ceiling.
    """
    if accum == "int32_native":
        # int32 holds every integer up to the type ceiling; probe the dtype
        # itself (wrap-around cast) rather than trusting the formula.
        def fits(v: int) -> bool:
            return int(np.array(v, np.int64).astype(np.int32)) == v
    else:
        def fits(v: int) -> bool:
            return float(np.float32(v)) == float(v)

    # [0, S] is fully representable iff S and S-1 both fit: once the float
    # spacing exceeds 1 no two consecutive integers fit, so the predicate is
    # monotone and bisectable (isolated representable evens don't fool it).
    def contig(s: int) -> bool:
        return fits(s) and fits(s - 1)

    hi = 2
    while contig(hi):
        hi *= 2
    lo = hi // 2
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if contig(mid):
            lo = mid
        else:
            hi = mid
    return lo


def pass_bound_bruteforce(d_tile: int, la: int, lw: int,
                          data_max: int = 255, tw_mag: int = 128) -> int:
    """Exhaustive worst-case |diagonal| over extreme operand assignments.

    For small (d_tile, la, lw) word sizes, enumerate every extreme data/twiddle
    limb assignment (data in {0, data_max}, twiddles in {-tw_mag, +tw_mag})
    and maximise |Σ_i Σ_{p+q=k} a_p[i] · w_q[i]| over diagonals k.  Matches
    ``pass_bound(d_tile, min(la, lw))`` — the analytic triangle bound is tight.
    """
    n_diag = la + lw - 1
    best = 0
    data_choices = [0, data_max]
    tw_choices = [-tw_mag, tw_mag]
    n_a = len(data_choices) ** (d_tile * la)
    n_w = len(tw_choices) ** (d_tile * lw)
    if n_a * n_w > 1 << 20:
        raise ValueError("word size too large for exhaustive search")
    for ai in range(n_a):
        a = [[data_choices[(ai >> (i * la + p)) & 1] for p in range(la)]
             for i in range(d_tile)]
        for wi in range(n_w):
            w = [[tw_choices[(wi >> (i * lw + q)) & 1] for q in range(lw)]
                 for i in range(d_tile)]
            for k in range(n_diag):
                s = sum(a[i][p] * w[i][k - p]
                        for i in range(d_tile)
                        for p in range(la) if 0 <= k - p < lw)
                best = max(best, abs(s))
    return best


def kappa_max_bruteforce(accum: AccumModel, d_tile: int, la: int, lw: int,
                         data_max: int = 255, tw_mag: int = 128) -> int:
    """κ_max by direct search: brute-force window / brute-force pass bound."""
    bound = pass_bound_bruteforce(d_tile, la, lw, data_max, tw_mag)
    return exact_window_bruteforce(accum) // bound


def window_plan(n_passes: int, kappa: int | None, k_max: int) -> tuple[int, ...]:
    """Cut ``n_passes`` staging passes into κ-sized deferral windows.

    ``kappa=None`` selects the whole-transform single-window discipline (the
    MORPH-style fully-lazy mode).  Raises ``ValueError`` when the requested
    depth exceeds the analytic κ_max — this is the trace-time overflow assert:
    a window the discipline cannot prove exact never traces.
    """
    if n_passes < 1:
        raise ValueError(f"need >= 1 staging pass, got {n_passes}")
    k_eff = n_passes if kappa is None else kappa
    if k_eff < 1:
        raise ValueError(f"kappa must be >= 1, got {kappa}")
    if k_eff > k_max:
        raise ValueError(
            f"deferral depth kappa={k_eff} exceeds kappa_max={k_max} for this "
            f"accumulator discipline — the unreduced window would overflow")
    n_windows = math.ceil(n_passes / k_eff)
    sizes = [k_eff] * (n_passes // k_eff)
    if n_passes % k_eff:
        sizes.append(n_passes % k_eff)
    assert len(sizes) == n_windows and sum(sizes) == n_passes
    return tuple(sizes)


class LazyWindowAccumulator:
    """Trace-time κ-window deferred-reduction accumulator.

    Sums unreduced int32 diagonal planes across up to κ staging passes and
    folds once per window.  Every ``add`` re-checks the analytic magnitude
    bound (covering ragged final tiles, whose true bound is smaller than the
    uniform κ·d_tile estimate), so an overflow-unsafe trace fails loudly at
    trace time instead of silently rounding on device.
    """

    def __init__(self, modulus: int, accum: AccumModel, c: int, *,
                 kappa: int, fold_fn=None):
        self.modulus = modulus
        self.accum = accum
        self.c = c
        self.kappa = kappa
        self.window_limit = accumulator_window(accum)
        self.fold_fn = fold_fn
        self._acc = None
        self._bound = 0          # worst-case |entry| of the pending window
        self._n_pending = 0      # passes accumulated since the last fold
        self.window_index = 0    # folds emitted so far (scopes the HLO)
        self.n_folds = 0

    def add(self, diag, d_tile: int):
        """Accumulate one pass's diagonals (int32 (N, d, n_diag))."""
        new_bound = self._bound + pass_bound(d_tile, self.c)
        if new_bound > self.window_limit:
            raise ValueError(
                f"lazy window overflow: accumulating a d_tile={d_tile} pass "
                f"would raise the unreduced bound to {new_bound} > "
                f"{self.window_limit} ({self.accum} window)")
        if self._n_pending >= self.kappa:
            raise ValueError(
                f"window already holds kappa={self.kappa} passes — fold first")
        self._acc = diag if self._acc is None else self._acc + diag
        self._bound = new_bound
        self._n_pending += 1

    @property
    def pending(self) -> int:
        return self._n_pending

    def ready(self) -> bool:
        return self._n_pending >= self.kappa

    def fold(self):
        """Fold the pending window to a canonical residue; resets the window."""
        from repro.core import montgomery as MONT
        if self._acc is None:
            raise ValueError("fold() on an empty window")
        y = MONT.deferred_fold(self._acc, self.modulus,
                               window_index=self.window_index,
                               fold_fn=self.fold_fn)
        self._acc = None
        self._bound = 0
        self._n_pending = 0
        self.window_index += 1
        self.n_folds += 1
        return y


# --- Table 1 probes (paper Table 1) -------------------------------------------


def _operands_for_target(s: int) -> tuple[np.ndarray, np.ndarray]:
    """u8/s8 operand pair whose exact dot product equals -s (s >= 0).

    The probe accumulates toward the negative target so every rhs entry is
    s8-representable.  Steps use the *odd* pixel product 253·127 = 32,131 so
    partial sums land on generic (odd) integers — an aligned all-(255·128)
    pattern would stay fp32-exact by 2-adic alignment and mask the mantissa
    ceiling the paper probes.
    """
    step = 253 * 127
    n_full, rem = divmod(s, step)
    lhs = [253] * n_full
    rhs = [-127] * n_full
    if rem:
        q, r = divmod(rem, 253)
        if q:
            lhs.append(253)
            rhs.append(-q)
        if r:
            lhs.append(r)
            rhs.append(-1)
    lhs_a = np.asarray(lhs, np.uint8)[None, :]
    rhs_a = np.asarray(rhs, np.int8)[:, None]
    return lhs_a, rhs_a


def probe_exact(s: int, accum: AccumModel) -> bool:
    """True iff the accumulator path reproduces the exact partial sum |S|."""
    lhs, rhs = _operands_for_target(s)
    if accum == "fp32_mantissa":
        out = jnp.dot(jnp.asarray(lhs, jnp.float32), jnp.asarray(rhs, jnp.float32),
                      preferred_element_type=jnp.float32)
        return float(out[0, 0]) == float(-s)
    out = jnp.dot(jnp.asarray(lhs, jnp.int32), jnp.asarray(rhs, jnp.int32),
                  preferred_element_type=jnp.int32)
    return int(out[0, 0]) == -s


# Paper Table 1 probe targets.
TABLE1_TARGETS = (2**23, 2**24 - 1, 2**24, 2**24 + 1, 2**25 - 1, 2**28, 2**30)


def table1_rows() -> dict[str, list[bool]]:
    return {
        "tpu_v4_fp32_mantissa": [probe_exact(s, "fp32_mantissa") for s in TABLE1_TARGETS],
        "tpu_v5_int32_native": [probe_exact(s, "int32_native") for s in TABLE1_TARGETS],
    }
