"""Accumulator exactness probes (paper Table 1).

Constructs a DotGeneral whose true integer partial sum equals a target S and
checks bit-exactness under the two accumulator models:

* ``fp32_mantissa`` (TPU v4 path) — exact iff S <= 2**24;
* ``int32_native`` (v5e/v5p path) — exact through 2**31 - 1.

On CPU the float32 matmul reproduces the v4 rounding behaviour bit-exactly
(2**24 + 1 is not representable in binary32 regardless of summation order).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.limb_gemm import MAX_PIXEL_PRODUCT, AccumModel


def _operands_for_target(s: int) -> tuple[np.ndarray, np.ndarray]:
    """u8/s8 operand pair whose exact dot product equals -s (s >= 0).

    The probe accumulates toward the negative target so every rhs entry is
    s8-representable.  Steps use the *odd* pixel product 253·127 = 32,131 so
    partial sums land on generic (odd) integers — an aligned all-(255·128)
    pattern would stay fp32-exact by 2-adic alignment and mask the mantissa
    ceiling the paper probes.
    """
    step = 253 * 127
    n_full, rem = divmod(s, step)
    lhs = [253] * n_full
    rhs = [-127] * n_full
    if rem:
        q, r = divmod(rem, 253)
        if q:
            lhs.append(253)
            rhs.append(-q)
        if r:
            lhs.append(r)
            rhs.append(-1)
    lhs_a = np.asarray(lhs, np.uint8)[None, :]
    rhs_a = np.asarray(rhs, np.int8)[:, None]
    return lhs_a, rhs_a


def probe_exact(s: int, accum: AccumModel) -> bool:
    """True iff the accumulator path reproduces the exact partial sum |S|."""
    lhs, rhs = _operands_for_target(s)
    if accum == "fp32_mantissa":
        out = jnp.dot(jnp.asarray(lhs, jnp.float32), jnp.asarray(rhs, jnp.float32),
                      preferred_element_type=jnp.float32)
        return float(out[0, 0]) == float(-s)
    out = jnp.dot(jnp.asarray(lhs, jnp.int32), jnp.asarray(rhs, jnp.int32),
                  preferred_element_type=jnp.int32)
    return int(out[0, 0]) == -s


# Paper Table 1 probe targets.
TABLE1_TARGETS = (2**23, 2**24 - 1, 2**24, 2**24 + 1, 2**25 - 1, 2**28, 2**30)


def table1_rows() -> dict[str, list[bool]]:
    return {
        "tpu_v4_fp32_mantissa": [probe_exact(s, "fp32_mantissa") for s in TABLE1_TARGETS],
        "tpu_v5_int32_native": [probe_exact(s, "int32_native") for s in TABLE1_TARGETS],
    }
