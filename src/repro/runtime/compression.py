"""int8 gradient compression with error feedback for cross-pod (DCN) reduce.

At 1000+ nodes the scarce resource is the inter-pod data-centre network, not
ICI: compressing the cross-pod gradient all-reduce 4× (f32→int8) with error
feedback (residual carried to the next step — Seide et al. / EF-SGD) retains
convergence while cutting DCN bytes 4×.  The quantiser is per-tensor
symmetric; ``compressed_grad_sync`` wraps the psum in shard_map over the
"pod" mesh axis so XLA emits an int8 all-reduce on the pod network.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.shardmap_compat import shard_map


def quantize_int8(x):
    """f32/bf16 tensor -> (int8 codes, f32 scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_int8(codes, scale):
    return codes.astype(jnp.float32) * scale


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _ef_quantize(g, err):
    target = g.astype(jnp.float32) + err
    codes, scale = quantize_int8(target)
    recon = dequantize_int8(codes, scale)
    return codes, scale, target - recon   # new residual


def compressed_grad_sync(grads, error_state, *, mesh, axis: str = "pod"):
    """Error-feedback int8 all-reduce of `grads` over `axis`.

    grads are assumed identical-sharded within the remaining axes (the usual
    post-pjit state); returns (synced_grads, new_error_state).
    """

    def sync_leaf(g, err):
        def inner(gl, el):
            codes, scale, new_err = _ef_quantize(gl, el)
            summed = jax.lax.psum(codes.astype(jnp.int32), axis)
            scale_max = jax.lax.pmax(scale, axis)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
            # average of dequantised contributions (common scale bound)
            synced = summed.astype(jnp.float32) * scale_max / n
            return synced.astype(g.dtype), new_err

        other = tuple(a for a in mesh.axis_names if a != axis)
        spec = P()  # replicated leaves across the pod axis
        fn = shard_map(inner, mesh=mesh, in_specs=(spec, spec),
                       out_specs=(spec, spec))
        return fn(g, err)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [sync_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    synced = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
    return synced, new_err
