"""GPipe-style pipeline parallelism over a mesh axis (PP).

The layer stack is split into S contiguous stages; each stage's parameters
live on one device group along the ``stage`` mesh axis.  Microbatches stream
through the pipeline with ``lax.ppermute`` boundary transfers — the classic
(M + S − 1)-tick schedule with bubble fraction (S−1)/(M+S−1).

Implementation notes:
* runs inside ``jax.shard_map`` over the stage axis: every device executes the
  same program on its own stage params; activations hop stages by ppermute;
* tick t computes microbatch (t − stage_id) — inactive (bubble) ticks compute
  on garbage and are masked out of the output gather;
* forward-only here (serving / evaluation); the training path composes with
  DP/TP on the remaining mesh axes.  Used by tests on an 8-device fake mesh
  and available to the launcher via ``stage_axis="pod"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.shardmap_compat import shard_map


def pipeline_forward(stage_fn, stage_params, x_microbatches, *, mesh,
                     axis: str = "pod"):
    """stage_fn(params_stage, x) -> y; all stages shape-preserving.

    stage_params: pytree with leading axis S (== mesh axis size), sharded
    over `axis`.  x_microbatches: (M, mb, ...) replicated.  Returns (M, mb,
    ...) outputs after all S stages.
    """
    s = mesh.shape[axis]
    m = x_microbatches.shape[0]
    n_ticks = m + s - 1

    def per_device(params_stage, xs):
        # params_stage: (1, ...) local slice; xs: (M, mb, ...) replicated
        stage_id = jax.lax.axis_index(axis)
        params_local = jax.tree.map(lambda p: p[0], params_stage)
        mb_shape = xs.shape[1:]
        carry_in = jnp.zeros(mb_shape, xs.dtype)
        outputs = jnp.zeros((m,) + mb_shape, xs.dtype)

        def tick(t, state):
            carry, outs = state
            # stage 0 ingests microbatch t; others take the permuted carry
            mb_idx = jnp.clip(t - stage_id, 0, m - 1)
            x_in = jnp.where(stage_id == 0,
                             xs[jnp.clip(t, 0, m - 1)], carry)
            y = stage_fn(params_local, x_in)
            # last stage emits microbatch (t - (S-1)) when valid
            emit = (t - (s - 1) >= 0) & (t - (s - 1) < m) & (stage_id == s - 1)
            out_idx = jnp.clip(t - (s - 1), 0, m - 1)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0),
                lambda o: o, outs)
            # hop: stage i -> stage i+1 (ring permute; last wraps, ignored)
            carry = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s) for i in range(s)])
            return carry, outs

        _, outputs = jax.lax.fori_loop(0, n_ticks, tick,
                                       (carry_in, outputs))
        # gather the last stage's outputs to everyone
        outputs = jax.lax.psum(
            jnp.where(stage_id == s - 1, outputs, jnp.zeros_like(outputs)),
            axis)
        return outputs

    other = tuple(a for a in mesh.axis_names if a != axis)
    fn = shard_map(
        per_device, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P())
    return fn(stage_params, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
