"""Fault-tolerant training loop + straggler watchdog.

* checkpoint/restart: every K steps through CheckpointManager (async,
  rotated, integrity-hashed); on ANY step failure the loop restores the last
  checkpoint — including the data-iterator cursor — and resumes.  Injected
  faults in tests prove bit-identical recovery.
* straggler mitigation: per-step wall-clock watchdog flags outlier steps
  (p50 × factor); at scale the flagged host would be cordoned and its data
  shard re-issued — re-issue is free here because the pipeline is
  counter-based (see repro.data.pipeline).
* elastic scaling: restore accepts a different device topology; parameters
  are re-placed with jax.device_put under the new mesh's shardings and the
  data stream re-shards by host count.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.checkpoint import CheckpointManager


class StepWatchdog:
    def __init__(self, factor: float = 3.0, warmup: int = 3):
        self.durations: list[float] = []
        self.factor = factor
        self.warmup = warmup
        self.flagged: list[int] = []

    def record(self, step: int, seconds: float):
        self.durations.append(seconds)
        if len(self.durations) > self.warmup:
            p50 = float(np.median(self.durations[:-1]))
            if seconds > self.factor * p50:
                self.flagged.append(step)

    @property
    def median(self) -> float:
        return float(np.median(self.durations)) if self.durations else 0.0


class FaultTolerantLoop:
    """Run (train_step, stream) to `total_steps` surviving injected faults."""

    def __init__(self, train_step, stream, params, opt_state, *,
                 ckpt_dir: str, ckpt_every: int = 10, keep: int = 3,
                 fault_hook=None, max_restarts: int = 10):
        self.train_step = train_step
        self.stream = stream
        self.params = params
        self.opt_state = opt_state
        self.manager = CheckpointManager(ckpt_dir, keep=keep, async_save=False)
        self.ckpt_every = ckpt_every
        self.fault_hook = fault_hook
        self.max_restarts = max_restarts
        self.watchdog = StepWatchdog()
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def _save(self, step: int):
        self.manager.save(step, {"params": self.params,
                                 "opt": self.opt_state},
                          extra={"data": self.stream.state(), "step": step})

    def _restore(self):
        like = {"params": self.params, "opt": self.opt_state}
        tree, extra = self.manager.restore_latest(like)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.stream.restore(extra["data"])
        return int(extra["step"])

    def run(self, total_steps: int):
        self._save(0)
        step = 0
        while step < total_steps:
            try:
                t0 = time.monotonic()
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = next(self.stream)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                self.watchdog.record(step, time.monotonic() - t0)
                self.metrics_log.append(
                    {"step": step, "loss": loss,
                     "grad_norm": float(metrics["grad_norm"])})
                step += 1
                if step % self.ckpt_every == 0:
                    self._save(step)
            except (Exception, KeyboardInterrupt) as e:  # noqa: BLE001
                if isinstance(e, KeyboardInterrupt):
                    raise
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                step = self._restore()
        self._save(total_steps)
        return self.params, self.opt_state
