from repro.runtime.compression import (quantize_int8, dequantize_int8,
                                       compressed_grad_sync, init_error_state)
from repro.runtime.fault_tolerance import FaultTolerantLoop, StepWatchdog
