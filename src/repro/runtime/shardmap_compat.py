"""Version-portable ``shard_map``.

``jax.shard_map`` (with ``check_vma``) only exists in newer releases; the
pinned jaxlib in the accelerator image ships the experimental spelling with
the ``check_rep`` keyword.  Callers use :func:`shard_map` here and never
touch the version split.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(fn, *, mesh, in_specs, out_specs, check: bool = False):
    # The keyword rename (check_rep → check_vma) and the promotion to the
    # top-level namespace happened in different releases, so probe the
    # keyword rather than tying it to where the function lives.
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check)
    except TypeError:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check)
