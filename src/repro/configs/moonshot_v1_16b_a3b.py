"""Moonlight-16B-A3B (kimi/moonshot) — 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot_v1_16b_a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab_size=163840,
    norm="rmsnorm", activation="swiglu", rope=True,
    n_experts=64, top_k=6, n_shared_experts=2,
)
