"""Hymba-1.5B — parallel attention + mamba heads, sliding-window attention
[arXiv:2411.13676; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba_1_5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab_size=32001,
    norm="rmsnorm", activation="swiglu", rope=True,
    ssm_state=16, ssm_heads=25, ssm_expand=1,
    attn_window=1024, subquadratic=True,
)
