"""Whisper large-v3 — encoder-decoder; conv/audio frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_large_v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_head=64,
    d_ff=5120, vocab_size=51866,
    norm="layernorm", activation="gelu", rope=False,
    max_position_embeddings=448, encoder_layers=32,
    frontend="audio_stub", frontend_len=1500,
    tie_embeddings=False,
)
