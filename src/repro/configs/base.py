"""Architecture config schema + shape suite for the assigned model pool."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str              # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int             # 0 => attention-free
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    norm: str = "rmsnorm"          # rmsnorm | layernorm | layernorm_np (OLMo)
    activation: str = "swiglu"     # swiglu | gelu
    rope: bool = True
    rope_theta: float = 10_000.0
    max_position_embeddings: int = 0   # learned abs-pos (whisper) if > 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # hybrid / local attention
    attn_window: int = 0               # sliding-window size (0 = full)
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontend (STUB per assignment: precomputed embeddings)
    frontend: str = ""                 # "" | audio_stub | vision_stub
    frontend_len: int = 0              # frames / patches in input_specs
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    subquadratic: bool = False         # eligible for long_500k
    # training
    remat: bool = True
    blockwise_attn_threshold: int = 4096
    attn_block_size: int = 1024
    # §Perf ablation switches (defaults = optimized; baseline via overrides)
    gqa_repeat_kv: bool = False     # True: materialise repeated KV (baseline)
    scan_staging: bool = False      # crypto cells: lax.scan over passes
    remat_policy: str = "dots"      # dots | nothing (full recompute)
    grad_accum: int = 1             # microbatched gradient accumulation

    @property
    def qkv_dims(self) -> tuple[int, int]:
        return self.n_heads * self.d_head, self.n_kv_heads * self.d_head

    def params_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.n_heads:
            q, kv = self.qkv_dims
            per_layer += d * q + 2 * d * kv + q * d
        if self.n_experts:
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * dff
        elif dff:
            n_mats = 3 if self.activation == "swiglu" else 2
            per_layer += n_mats * d * dff
        if self.ssm_state:
            d_in = self.ssm_expand * d
            per_layer += 2 * d * d_in + d_in * d  # in/out projections
            per_layer += d_in * 2 * self.ssm_state  # B,C projections (approx)
        total = emb + self.n_layers * per_layer
        if self.encoder_layers:
            enc_per = 4 * d * d + (3 if self.activation == "swiglu" else 2) * d * dff
            total += self.encoder_layers * enc_per
            total += self.n_layers * 4 * d * d  # cross-attention
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """Assignment skip rules (documented in DESIGN.md §Arch-applicability)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("skip: long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is full-attention")
    return True, ""
