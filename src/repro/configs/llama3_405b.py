"""Llama-3 405B — dense GQA, 128k vocab [arXiv:2407.21783; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3_405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
    d_ff=53248, vocab_size=128256,
    norm="rmsnorm", activation="swiglu", rope=True, rope_theta=5e5,
    tie_embeddings=False,
)
