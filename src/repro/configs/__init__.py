"""Config registry: the 10 assigned architectures + the paper's own workloads.

``get_config(name)`` returns the full published config; ``smoke_config(name)``
returns a reduced same-family config for CPU smoke tests (small layers/width,
few experts, tiny vocab) — the full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shape_applicable

from repro.configs.internlm2_20b import CONFIG as internlm2_20b
from repro.configs.starcoder2_7b import CONFIG as starcoder2_7b
from repro.configs.llama3_405b import CONFIG as llama3_405b
from repro.configs.olmo_1b import CONFIG as olmo_1b
from repro.configs.granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from repro.configs.moonshot_v1_16b_a3b import CONFIG as moonshot_v1_16b_a3b
from repro.configs.hymba_1_5b import CONFIG as hymba_1_5b
from repro.configs.whisper_large_v3 import CONFIG as whisper_large_v3
from repro.configs.mamba2_370m import CONFIG as mamba2_370m
from repro.configs.internvl2_1b import CONFIG as internvl2_1b

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        internlm2_20b, starcoder2_7b, llama3_405b, olmo_1b,
        granite_moe_3b_a800m, moonshot_v1_16b_a3b, hymba_1_5b,
        whisper_large_v3, mamba2_370m, internvl2_1b,
    ]
}


def get_config(name: str) -> ArchConfig:
    return ARCHS[name.replace("-", "_")]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config: 2 layers, narrow width, tiny vocab."""
    cfg = get_config(name)
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    n_kv = min(cfg.n_kv_heads, n_heads) if n_heads else 0
    if n_heads and n_kv and n_heads % n_kv:
        n_kv = 1
    d_head = 32 if cfg.n_heads else 0
    d_model = max(64, n_heads * d_head) if n_heads else 128
    return dataclasses.replace(
        cfg,
        name=cfg.name + "_smoke",
        n_layers=2,
        encoder_layers=min(cfg.encoder_layers, 2),
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=d_head,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        moe_capacity_factor=8.0,   # drop-free in smoke (decode==prefill)
        ssm_state=min(cfg.ssm_state, 16),
        ssm_heads=min(cfg.ssm_heads, 4) if cfg.ssm_heads else 0,
        ssm_chunk=16,
        attn_window=min(cfg.attn_window, 64) if cfg.attn_window else 0,
        frontend_len=min(cfg.frontend_len, 8) if cfg.frontend_len else 0,
        max_position_embeddings=min(cfg.max_position_embeddings, 512)
        if cfg.max_position_embeddings else 0,
        blockwise_attn_threshold=64,
        attn_block_size=32,
        dtype="float32",
    )
