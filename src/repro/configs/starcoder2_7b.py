"""StarCoder2-7B — dense GQA + RoPE code model [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2_7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_head=128,
    d_ff=18432, vocab_size=49152,
    norm="layernorm", activation="gelu", rope=True, rope_theta=1e5,
    tie_embeddings=False,
)
