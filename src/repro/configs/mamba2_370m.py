"""Mamba2-370m — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2_370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=50280,
    norm="rmsnorm", activation="swiglu", rope=False,
    ssm_state=128, ssm_heads=32, ssm_expand=2,
    subquadratic=True,
)
