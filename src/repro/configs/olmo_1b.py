"""OLMo-1B — dense, non-parametric LayerNorm [arXiv:2402.00838; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo_1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=8192, vocab_size=50304,
    norm="layernorm_np", activation="swiglu", rope=True,
)
