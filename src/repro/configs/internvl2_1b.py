"""InternVL2-1B — InternViT frontend (STUB: precomputed patch embeddings) +
InternLM2-tier LM backbone [arXiv:2404.16821; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2_1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab_size=151655,
    norm="rmsnorm", activation="swiglu", rope=True,
    frontend="vision_stub", frontend_len=256,
)
