"""Granite-3.0 MoE 3B-a800m — 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite_moe_3b_a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
    d_ff=512, vocab_size=49155,
    norm="rmsnorm", activation="swiglu", rope=True,
    n_experts=40, top_k=8,
)
