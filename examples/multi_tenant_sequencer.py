"""End-to-end multi-tenant sequencer (the paper's system, serving mode):

Poisson ingress → per-class queues → Tier-1 rectangular stacking →
HLO validation → Tier-2 co-scheduled dispatch → per-tenant results, verified
against isolated bignum evaluation.

  PYTHONPATH=src python examples/multi_tenant_sequencer.py [--duration 0.05]
"""
import argparse

import numpy as np

from repro.launch.serve import serve_crypto
from repro.core import workloads as WK
from repro.core import ntt as NTT
from repro.core import field as F

ap = argparse.ArgumentParser()
ap.add_argument("--duration", type=float, default=0.03)
ap.add_argument("--rate", type=float, default=2048)
args = ap.parse_args()

results, n_ops, dt = serve_crypto(duration_s=args.duration, rate_hz=args.rate)
print(f"dispatched {n_ops} tenant ops in {len(results)} stacked batches "
      f"in {dt:.2f}s ({n_ops/dt:.0f} ops/s this-hardware)")

# verify a Dilithium batch end-to-end against isolated evaluation
checked = 0
for res in results:
    if res.batch.workload != "dilithium" or checked:
        continue
    eng = WK.DilithiumEngine(res.batch.d_bucket)
    for r in res.batch.requests[:4]:
        iso = np.zeros((1, res.batch.d_bucket), np.uint32)
        iso[0, : r.degree] = r.coeffs
        want = eng.oracle_np(iso)[0]
        got = res.outputs[r.tenant_id]
        assert np.array_equal(got, want), f"tenant {r.tenant_id} corrupted!"
        checked += 1
print(f"isolation check: {checked} tenants' batched results are isomorphic "
      f"to isolated evaluation ✓ (Property 5.1)")

fills = [len(r.batch.requests) for r in results]
print(f"batch fill: mean N_c={np.mean(fills):.1f}, "
      f"workloads={sorted({r.batch.workload for r in results})}")
