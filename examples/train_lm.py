"""Train a small LM end-to-end with the full production stack: sharded data
pipeline, AdamW, fault-tolerant loop with rotating checkpoints, straggler
watchdog.

Default is a CPU-budget ~5M-param OLMo-family model for 100 steps (~2 min);
``--preset 100m --steps 300`` runs the ~100M configuration the deliverable
names (several hours on this CPU container; the default demonstrates the
same code path).

  PYTHONPATH=src python examples/train_lm.py [--steps 100]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.launch.train import build
from repro.runtime import FaultTolerantLoop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=100)
ap.add_argument("--preset", choices=["demo", "100m"], default="demo")
ap.add_argument("--seq-len", type=int, default=128)
ap.add_argument("--global-batch", type=int, default=4)
ap.add_argument("--inject-fault", action="store_true",
                help="kill step 37 once to demonstrate checkpoint/restart")
args = ap.parse_args()

base = get_config("olmo_1b")
if args.preset == "demo":
    cfg = dataclasses.replace(
        base, name="olmo_demo_5m", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=4, d_head=64, d_ff=1024, vocab_size=4096, dtype="float32",
        blockwise_attn_threshold=4096)
else:
    cfg = dataclasses.replace(
        base, name="olmo_100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_head=64, d_ff=3072, vocab_size=32768,
        dtype="float32")
print(f"training {cfg.name}: ~{cfg.params_count()/1e6:.1f}M params, "
      f"seq={args.seq_len}, batch={args.global_batch}, steps={args.steps}")

params, opt_state, step, stream = build(
    cfg, seq_len=args.seq_len, global_batch=args.global_batch,
    total_steps=args.steps)

crashed = {"done": False}


def fault(step_idx):
    if args.inject_fault and step_idx == 37 and not crashed["done"]:
        crashed["done"] = True
        raise RuntimeError("injected node failure (demo)")


with tempfile.TemporaryDirectory() as ckpt:
    loop = FaultTolerantLoop(step, stream, params, opt_state, ckpt_dir=ckpt,
                             ckpt_every=20, fault_hook=fault)
    loop.run(args.steps)
    losses = [m["loss"] for m in loop.metrics_log]
    k = max(len(losses) // 10, 1)
    print(f"loss: first10={sum(losses[:k])/k:.4f} "
          f"last10={sum(losses[-k:])/k:.4f} "
          f"(decreased: {sum(losses[-k:]) < sum(losses[:k])})")
    print(f"median step {loop.watchdog.median*1e3:.0f}ms, "
          f"restarts={loop.restarts}, stragglers={loop.watchdog.flagged}")
