"""Online multi-tenant serving demo (the paper's system with live ingress):

load generator → admission control → continuous rectangular batcher →
co-scheduled dispatch → per-tenant results + telemetry, including a
deliberately overloaded tenant to show rate limiting and backpressure.

  PYTHONPATH=src python examples/online_serving.py [--duration 0.02]
"""
import argparse

import numpy as np

from repro.core import workloads as WK
from repro.core.scheduler import PoissonTrace
from repro.serve import CryptoServer, LoadGenerator, ServeConfig
from repro.serve.client import attach_payloads

ap = argparse.ArgumentParser()
ap.add_argument("--duration", type=float, default=0.02)
ap.add_argument("--rate", type=float, default=1024)
args = ap.parse_args()

# --- serve a Poisson trace through the online runtime --------------------------
server = CryptoServer(ServeConfig(n_c=8, max_age_s=0.005, validate=False))
gen = LoadGenerator(PoissonTrace(rate_hz=args.rate, duration_s=args.duration,
                                 seed=7))
load = gen.run(server)
snap = server.telemetry.snapshot()
print(f"served {load.n_served}/{len(load.handles)} requests in "
      f"{snap['batches']} batches "
      f"(close reasons: {snap['close_reasons']})")
print(f"occupancy K={snap['k_occupancy_mean']:.3f} "
      f"M={snap['m_occupancy_mean']:.3f}; "
      f"p50={snap['latency']['p50_s']*1e3:.2f}ms "
      f"p99={snap['latency']['p99_s']*1e3:.2f}ms")

# --- verify one tenant against isolated evaluation -----------------------------
done = [h for h in load.handles if h.done() and not h.rejected
        and h.request.workload == "dilithium"]
h = done[0]
eng = WK.DilithiumEngine(server.batcher.bucket_for(h.request.degree))
iso = np.zeros((1, eng.d), np.uint32)
iso[0, : h.request.degree] = h.request.coeffs
assert np.array_equal(h.result(), eng.oracle_np(iso)[0])
print("isolation check: online batched result == isolated evaluation ✓")

# --- overload one tenant to trip the rate limiter ------------------------------
server2 = CryptoServer(ServeConfig(n_c=8, max_age_s=0.005, validate=False,
                                   tenant_rate_hz=100.0, tenant_burst=4))
trace = [r for r in PoissonTrace(rate_hz=512, duration_s=0.05,
                                 seed=11).generate()]
for r in trace:
    r.tenant_id = 0                        # one noisy tenant hammers the API
attach_payloads(trace, seed=11)
rejections = 0
for r in trace:
    h = server2.submit(r, now=r.arrival_time)
    rejections += h.rejected
server2.drain(trace[-1].arrival_time if trace else 0.0)
counts = server2.telemetry.admission_counts
print(f"noisy tenant: {counts.get('ok', 0)} admitted, "
      f"{counts.get('rate_limited', 0)} rate-limited "
      f"(token bucket 100 req/s, burst 4) — neighbours stay unharmed")
