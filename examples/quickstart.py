"""Quickstart: the paper's core objects in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Exact Dilithium NTT on the modelled MXU path (3-limb u8×s8, fp32-mantissa
   staging at d_max=171 → two passes for d=256) — validated against bignums.
2. BN254 ERNS evaluation + Montgomery reduction (9 channels, in-envelope).
3. The accumulator exactness probe (paper Table 1).
4. Post-hoc HLO structural validation (Invariant 5.1 + barriers).
"""
import numpy as np
import jax.numpy as jnp

from repro.core import accumulator as ACC
from repro.core import validator as V
from repro.core import workloads as WK
from repro.core import wordarith as W

# 1 — Dilithium forward NTT through the staged limb pipeline
eng = WK.make_engine("dilithium", 256)
print(f"Dilithium d=256: {eng.n_passes} staging passes "
      f"(d_max={eng.plan.d_max}, paper: 171+85)")
rng = np.random.default_rng(0)
a = np.asarray(rng.integers(0, 8380417, (4, 256), dtype=np.uint64), np.uint32)
y = np.asarray(eng.evaluate(jnp.asarray(a)))
assert np.array_equal(y, eng.oracle_np(a))
print("   forward NTT == bignum oracle for all 4 tenant rows ✓")

# 2 — BN254: 9-channel ERNS + Shenoy–Kumaresan/Montgomery reduction
d = 32
omega = np.array([[int.from_bytes(rng.bytes(11), "little") for _ in range(d)]
                  for _ in range(d)], object)
bn = WK.BN254Engine(d, evaluation_matrix=omega)
coeffs = np.array([[int.from_bytes(rng.bytes(16), "little") for _ in range(d)]
                   for _ in range(2)], object)
digits = np.asarray(bn.e2e(bn.ingest(coeffs)))
want = bn.oracle_eval_np(coeffs) % bn.chain.p
assert all(W.digits_to_int(digits[i, j]) == want[i, j]
           for i in range(2) for j in range(d))
print(f"   BN254 e2e op (144 pointwise cross-products + "
      f"Montgomery reduction) exact in the {bn.chain.M.bit_length()}-bit "
      f"CRT envelope ✓")

# 3 — Table 1 accumulator probes
rows = ACC.table1_rows()
print(f"   accumulator probes fp32={rows['tpu_v4_fp32_mantissa']} "
      f"int32={rows['tpu_v5_int32_native']}")

# 4 — HLO structural validation
rep = V.validate_fn(eng.e2e, jnp.asarray(a), expected_passes=eng.n_passes)
rep.raise_if_failed()
print(f"   HLO validator: {rep.n_barriers} barriers, Invariant 5.1 holds, "
      f"zones={sorted(rep.zones)} ✓")
