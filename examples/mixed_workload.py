"""Heterogeneous co-scheduling (paper §7.4) + compiler-separation audit:

* Dilithium and BN254 batches dispatched concurrently through Tier-2;
* a single mixed-precision program compiled WITH zone scopes and barriers —
  validator passes;
* the same program with the separation discipline removed — the validator
  catches the cross-zone fusion XLA performs (the class of bug §6.3 exists
  to stop).

  PYTHONPATH=src python examples/mixed_workload.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import validator as V
from repro.core import workloads as WK
from repro.core.scheduler import TenantRequest, RectangularScheduler
from repro.core.scheduler.coscheduler import SliceCoScheduler

rng = np.random.default_rng(0)

# --- concurrent heterogeneous dispatch -----------------------------------------
cos = SliceCoScheduler()
dil_reqs = [TenantRequest(i, "dilithium", 256, 0.0,
                          np.asarray(rng.integers(0, 8380417, 256,
                                                  dtype=np.uint64), np.uint32))
            for i in range(4)]
bn_eng = cos.engine_for("bn254", 64)
bn_reqs = []
for i in range(2):
    vals = np.array([int(x) for x in rng.integers(0, 2**31, 64)], object)
    bn_reqs.append(TenantRequest(100 + i, "bn254", 64, 0.0,
                                 np.asarray(bn_eng.ingest(vals))))
sched = RectangularScheduler(n_c=4, bucket_granularity=64)
results = cos.dispatch_mixed(sched.plan_batches(dil_reqs + bn_reqs))
print(f"co-scheduled {len(results)} heterogeneous batches: "
      f"{[r.batch.workload for r in results]} ✓")

# --- separated mixed program passes validation ----------------------------------
dil = WK.DilithiumEngine(256)


def separated(a, b):
    y1 = dil.evaluate(a)
    y1, b = jax.lax.optimization_barrier((y1, b))
    with jax.named_scope("wzone_bn254"), jax.named_scope("pzone_4limb"):
        y2 = b * jnp.uint32(3)
    return y1, y2


a = jnp.zeros((4, 256), jnp.uint32)
b = jnp.zeros((4, 256), jnp.uint32)
rep = V.validate_fn(separated, a, b, expected_passes=dil.n_passes)
rep.raise_if_failed()
print(f"separated mixed program: validation PASSED "
      f"(zones={sorted(rep.zones)}, barriers={rep.n_barriers}) ✓")


# --- un-separated program: XLA fuses across zones; the validator aborts ---------
def unseparated(x):
    with jax.named_scope("wzone_dilithium"):
        u = x * jnp.float32(2.0) + jnp.float32(1.0)
    with jax.named_scope("wzone_bn254"):
        v = x * jnp.float32(3.0) - jnp.float32(4.0)
    return u + v


rep2 = V.validate_fn(unseparated, jnp.zeros((256, 256), jnp.float32),
                     expect_eager=False)
assert not rep2.ok
print("un-separated program: validator ABORTS dispatch with "
      f"{[v[0] for v in rep2.violations]} — offending subgraph:\n   "
      + rep2.violations[0][1][:120])
