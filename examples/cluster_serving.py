"""Multi-host sharded serving demo (the paper's fleet economics, live):

tenant-hash ingress → per-host admission (gossip-informed SLO gate) →
per-host continuous batching → co-scheduled dispatch → two-phase drain
barrier → merged cluster telemetry.  Ends with the adversarial single-
hot-tenant trace that collapses the whole load onto one host.

  PYTHONPATH=src python examples/cluster_serving.py [--hosts 3]
"""
import argparse

import numpy as np

from repro.cluster import ClusterConfig, ClusterServer
from repro.core.scheduler import PoissonTrace
from repro.core.scheduler.coscheduler import SliceCoScheduler
from repro.serve import LoadGenerator, ServeConfig

ap = argparse.ArgumentParser()
ap.add_argument("--hosts", type=int, default=3)
ap.add_argument("--duration", type=float, default=0.02)
ap.add_argument("--rate", type=float, default=1024)
args = ap.parse_args()

# One compiled-program cache shared across the simulated hosts keeps this
# demo fast; production gives each host its own co-scheduler (the default).
shared = SliceCoScheduler()
factory = lambda h: shared  # noqa: E731

# --- a Poisson trace across the cluster ----------------------------------------
cluster = ClusterServer(
    ClusterConfig(n_hosts=args.hosts, gossip_period_s=0.002,
                  serve=ServeConfig(n_c=8, max_age_s=0.005, validate=False)),
    coscheduler_factory=factory)
gen = LoadGenerator(PoissonTrace(rate_hz=args.rate, duration_s=args.duration,
                                 seed=7))
load = gen.run(cluster)
snap = cluster.snapshot()
m = snap["merged"]
imb = m["load_imbalance"]
print(f"cluster[{args.hosts} hosts]: served {load.n_served}/"
      f"{len(load.handles)} requests in {m['batches']} batches; "
      f"per-host {imb['per_host_requests']} "
      f"(max/mean {imb['max_over_mean']:.2f})")
g = snap["gossip"]
print(f"gossip: {g['publishes']} publishes, used staleness "
      f"max {g['used_staleness_max_s']*1e3:.2f}ms "
      f"≤ bound {g['staleness_bound_s']*1e3:.2f}ms")
bar = snap["drain_barrier"]
print(f"drain barrier: quiesced {bar['hosts']} hosts → flushed "
      f"{bar['batches_flushed']} batches (complete={bar['complete']})")

# --- cross-host isolation check ------------------------------------------------
from repro.core import workloads as WK  # noqa: E402

done = [h for h in load.handles if h.done() and not h.rejected
        and h.request.workload == "dilithium"]
if done:
    h = done[0]
    host = cluster.router.host_for(h.request.tenant_id)
    eng = WK.DilithiumEngine(cluster.hosts[host].batcher.bucket_for(
        h.request.degree))
    iso = np.zeros((1, eng.d), np.uint32)
    iso[0, : h.request.degree] = h.request.coeffs
    assert np.array_equal(h.result(), eng.oracle_np(iso)[0])
    print(f"isolation check: tenant {h.request.tenant_id} (host {host}) "
          f"== isolated evaluation ✓")
else:
    print("isolation check skipped: no dilithium request served "
          "(trace too short — raise --duration/--rate)")

# --- adversarial hot tenant: the fleet's capacity is unreachable ---------------
hot = ClusterServer(
    ClusterConfig(n_hosts=args.hosts,
                  serve=ServeConfig(n_c=8, max_age_s=0.005, validate=False)),
    coscheduler_factory=factory)
trace = PoissonTrace(rate_hz=args.rate, duration_s=args.duration,
                     seed=11).generate()
for r in trace:
    r.tenant_id = 0                     # every request from one hot tenant
hot_load = LoadGenerator(trace, seed=11).run(hot)
hot_imb = hot.snapshot()["merged"]["load_imbalance"]
print(f"hot tenant: per-host {hot_imb['per_host_requests']} — "
      f"max/mean {hot_imb['max_over_mean']:.2f} "
      f"({args.hosts - 1} hosts idle while one absorbs the storm)")
