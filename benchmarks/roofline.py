"""§Roofline — aggregate the dry-run artifacts into the roofline table.

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and prints,
per (arch × shape × mesh): the three roofline terms, the dominant bottleneck,
MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row
from repro.configs import ARCHS, SHAPES

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")


def active_params(cfg) -> int:
    total = cfg.params_count()
    if not cfg.n_experts:
        return total
    expert = cfg.n_experts * 3 * cfg.d_model * cfg.d_ff * cfg.n_layers
    active = cfg.top_k * 3 * cfg.d_model * cfg.d_ff * cfg.n_layers
    return total - expert + active


def model_flops_for(rec) -> float:
    arch = rec["arch"]
    if arch.startswith("aegis_"):
        # matrix-form transform: 2·d²·rows limb-level MACs aren't "model
        # flops"; use the algorithmic O(d log d) useful work as the reference
        import math
        d, rows = rec["d"], rec["rows"]
        chans = 9 if "bn254" in arch else 1
        return 2.0 * rows * d * math.log2(d) * chans
    cfg = ARCHS[arch]
    n = active_params(cfg)
    mult = 6.0 if rec.get("kind") == "train" else 2.0
    return mult * n * rec.get("tokens", 0)


def rows(pattern: str = "*.json") -> list[str]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, pattern))):
        rec = json.load(open(path))
        name = f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        if rec["status"] == "skipped":
            out.append(csv_row(name, 0.0, f"SKIP [{rec['reason'][:60]}]"))
            continue
        if rec["status"] != "ok":
            out.append(csv_row(name, 0.0, f"ERROR {rec.get('error','')[:80]}"))
            continue
        r = rec["roofline"]
        n_chips = 1
        for x in rec["mesh"].split("x"):
            n_chips *= int(x)
        mf = model_flops_for(rec)
        hlo_total = r["flops_per_chip"] * n_chips
        ratio = mf / hlo_total if hlo_total else 0.0
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        frac = r["t_compute_s"] / bound if bound else 0.0
        out.append(csv_row(
            name, bound * 1e6,
            f"t_comp={r['t_compute_s']:.3e} t_mem={r['t_memory_s']:.3e} "
            f"t_coll={r['t_collective_s']:.3e} dom={r['dominant']} "
            f"roofline_frac={frac:.3f} model/hlo_flops={ratio:.3f} "
            f"bytes_per_dev={rec.get('bytes_per_device',0)/1e9:.1f}GB"))
    return out


def run() -> list[str]:
    got = rows()
    if not got:
        return [csv_row("roofline.missing", 0.0,
                        "no dry-run artifacts; run repro.launch.dryrun first")]
    return got


if __name__ == "__main__":
    print("\n".join(run()))
