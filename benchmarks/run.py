"""Benchmark aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--only table2`` selects a subset;
``--fast`` trims the heavy sweeps (crossover capped at d=2048).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args, _ = ap.parse_known_args()

    from benchmarks import (table1_accumulator, table2_throughput,
                            table3_temporal, table4_ablation,
                            table5_heterogeneous, fig2_concurrency,
                            fig3_crossover, roofline)

    suites = {
        "table1": table1_accumulator.run,
        "table2": table2_throughput.run,
        "table3": table3_temporal.run,
        "table4": table4_ablation.run,
        "table5": table5_heterogeneous.run,
        "fig2": fig2_concurrency.run,
        "fig3": (lambda: fig3_crossover.run(max_log2_d=11)) if args.fast
                else fig3_crossover.run,
        "roofline": roofline.run,
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if args.only and name not in args.only.split(","):
            continue
        try:
            for row in fn():
                print(row, flush=True)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{name}.FAILED,0,{traceback.format_exc(limit=1).splitlines()[-1]}",
                  flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
