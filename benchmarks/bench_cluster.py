"""BENCH_cluster — multi-host sharded serving sweep.

Drives the :mod:`repro.cluster` runtime over a grid of arrival rates ×
host counts × tenant-id distributions and emits one JSON point per cell:
merged p50/p95/p99 latency, per-host occupancy, load-imbalance (max/mean,
cv), gossip staleness audit, and the drain-barrier record.  The tenant
distributions are the interesting axis — ``unique`` spreads load
hash-uniformly, ``zipf`` models realistic skew, and ``hot`` is the
adversarial single-hot-tenant case where the whole offered load lands on
one host and the fleet's spare capacity is unreachable by design (the
paper's §7 economics measured at cluster scale).

  PYTHONPATH=src python benchmarks/bench_cluster.py [--rates 512,1024]
      [--hosts 1,2,4] [--dists unique,zipf,hot] [--duration 0.02]
      [--out bench_cluster.json] [--dry-run]

Also exposes ``run()`` yielding the aggregator's CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# repo root, cwd-independent (benchmarks/ run as a script)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import (RATE_LADDER_FAST, make_trace,  # noqa: E402
                               parse_rate_ladder)

HOST_LADDER = (1, 2, 4)
DISTRIBUTIONS = ("unique", "zipf", "hot")


def sweep(rates=RATE_LADDER_FAST, hosts=HOST_LADDER, dists=DISTRIBUTIONS, *,
          duration_s=0.02, n_c=8, max_age_s=0.005, d_uniform=256, seed=0,
          n_tenants=64, gossip_period_s=0.002,
          coscheduler_factory=None, trace_out=None) -> list[dict]:
    from repro.launch.serve import serve_crypto_cluster

    points = []
    for dist in dists:
        for rate in rates:
            trace = make_trace(rate, duration_s, d_uniform=d_uniform,
                               seed=seed, tenants=dist, n_tenants=n_tenants)
            for n_hosts in hosts:
                # one representative fleet trace per sweep: the widest host
                # count of the first (dist, rate) cell
                traced = (trace_out if (dist == dists[0] and rate == rates[0]
                                        and n_hosts == hosts[-1]) else None)
                t0 = time.time()
                load, snap, dt = serve_crypto_cluster(
                    hosts=n_hosts, n_c=n_c, max_age_s=max_age_s, seed=seed,
                    validate=False,      # HLO validation is tested elsewhere;
                                         # this sweep measures the fleet path
                    gossip_period_s=gossip_period_s, trace=trace,
                    trace_out=traced,
                    coscheduler_factory=coscheduler_factory)
                served = sum(1 for h in load.handles
                             if h.done() and not h.rejected)
                m = snap["merged"]
                points.append({
                    "config": f"h{n_hosts}.{dist}.rate{rate}",
                    "rate_hz": rate,
                    "hosts": n_hosts,
                    "tenant_dist": dist,
                    "duration_s": duration_s,
                    "n_c": n_c,
                    "wall_s": dt,
                    "rows_per_s": served / dt if dt > 0 else 0.0,
                    "served": served,
                    "rejected": len(load.rejected),
                    "batches": m["batches"],
                    "close_reasons": m["close_reasons"],
                    "k_occupancy_mean": m["k_occupancy_mean"],
                    "m_occupancy_mean": m["m_occupancy_mean"],
                    "dispatches": m["dispatch"]["dispatches"],
                    "merged_dispatches": m["dispatch"]["merged_dispatches"],
                    "dispatch_m_fill_mean": m["dispatch"]["m_fill_mean"],
                    "queue_depth_max": m["queue_depth_max"],
                    "p50_s": m["latency"]["p50_s"],
                    "p95_s": m["latency"]["p95_s"],
                    "p99_s": m["latency"]["p99_s"],
                    "imbalance_max_over_mean":
                        m["load_imbalance"]["max_over_mean"],
                    "imbalance_cv": m["load_imbalance"]["cv"],
                    "per_host_requests":
                        m["load_imbalance"]["per_host_requests"],
                    "gossip": snap["gossip"],
                    "drain_barrier": snap["drain_barrier"],
                    "setup_wall_s": time.time() - t0,
                })
    return points


def run(fast: bool = True):
    """Aggregator entry point: ``name,us_per_call,derived`` CSV rows."""
    from repro.core.scheduler.coscheduler import SliceCoScheduler

    hosts = (1, 2) if fast else HOST_LADDER
    rates = RATE_LADDER_FAST if fast else RATE_LADDER_FAST + (2048,)
    shared = SliceCoScheduler()      # one compiled-program cache per sweep —
                                     # latency is virtual-clock, so per-cell
                                     # recompiles would only burn wall time
    for pt in sweep(rates, hosts, coscheduler_factory=lambda h: shared):
        yield (f"cluster.h{pt['hosts']}.{pt['tenant_dist']}"
               f".rate{pt['rate_hz']},"
               f"{pt['p50_s'] * 1e6:.2f},"
               f"p99={pt['p99_s'] * 1e6:.0f}us"
               f";imbalance={pt['imbalance_max_over_mean']:.2f}"
               f";k_occ={pt['k_occupancy_mean']:.3f}"
               f";served={pt['served']};rejected={pt['rejected']}")


def dry_run(trace_out=None) -> dict:
    """CI smoke: one tiny grid cell per distribution on a 3-host cluster;
    asserts the fleet invariants (everything served, barrier complete,
    staleness bound honored, hot tenant collapses onto one host) and that
    the merged fleet trace is schema-valid with per-host process tracks."""
    import json as _json
    import tempfile

    from repro.core.scheduler.coscheduler import SliceCoScheduler
    from repro.obs import validate_chrome_trace

    shared = SliceCoScheduler()          # one compiled-program cache for all
    path = trace_out or os.path.join(
        tempfile.mkdtemp(prefix="bench_cluster_"), "trace.json")
    points = sweep(rates=(512,), hosts=(3,), dists=("unique", "hot"),
                   duration_s=0.005, max_age_s=0.002,
                   coscheduler_factory=lambda h: shared, trace_out=path)
    with open(path) as f:
        fleet = _json.load(f)
    stats = validate_chrome_trace(fleet)
    assert stats["requests"] > 0 and stats["launches"] > 0, stats
    # every host plus the cluster-control track gets its own process
    pids = {ev["pid"] for ev in fleet["traceEvents"] if ev["ph"] != "M"}
    assert len(pids) >= 2, pids
    for pt in points:
        assert pt["served"] > 0 and pt["rejected"] == 0, pt
        assert pt["drain_barrier"]["complete"], pt
        assert pt["dispatches"] > 0, pt
        assert 0.0 < pt["dispatch_m_fill_mean"] <= 1.0, pt
        g = pt["gossip"]
        assert g["used_staleness_max_s"] <= g["staleness_bound_s"], g
    hot = next(pt for pt in points if pt["tenant_dist"] == "hot")
    per_host = hot["per_host_requests"]
    assert sorted(per_host)[:-1] == [0, 0], per_host   # one hot host only
    assert hot["imbalance_max_over_mean"] > 2.5, hot
    return {"points": points, "trace_path": path, "trace_stats": stats}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="512,1024")
    ap.add_argument("--hosts", default="1,2,4")
    ap.add_argument("--dists", default="unique,zipf,hot")
    ap.add_argument("--duration", type=float, default=0.02)
    ap.add_argument("--n-c", type=int, default=8)
    ap.add_argument("--max-age-ms", type=float, default=5.0)
    ap.add_argument("--d-uniform", type=int, default=256)
    ap.add_argument("--n-tenants", type=int, default=64)
    ap.add_argument("--gossip-period-ms", type=float, default=2.0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="record one representative fleet trace (widest "
                         "host count of the first grid cell) and write the "
                         "Perfetto JSON here")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny 3-host grid + fleet-invariant and trace-"
                         "schema asserts (CI)")
    args = ap.parse_args()

    if args.dry_run:
        doc = dry_run(trace_out=args.trace_out)
        stats = doc["trace_stats"]
        print(f"dry run ok: {len(doc['points'])} points, "
              f"hot-tenant imbalance "
              f"{doc['points'][-1]['imbalance_max_over_mean']:.2f}; "
              f"fleet trace schema-valid ({stats['requests']} requests, "
              f"{stats['events']} events) → {doc['trace_path']}")
        return

    from repro.core.scheduler.coscheduler import SliceCoScheduler

    hosts = tuple(int(h) for h in args.hosts.split(","))
    rates = parse_rate_ladder(args.rates)
    shared = SliceCoScheduler()   # one compiled-program cache per sweep —
                                  # latency is virtual-clock; per-cell
                                  # recompiles would only pollute wall_s
    kw = dict(duration_s=args.duration, n_c=args.n_c,
              max_age_s=args.max_age_ms / 1e3, d_uniform=args.d_uniform,
              n_tenants=args.n_tenants,
              gossip_period_s=args.gossip_period_ms / 1e3,
              coscheduler_factory=lambda h: shared)
    dists = tuple(args.dists.split(","))
    # warm pre-run: an identical (untraced) grid off the record — the
    # deterministic trace seed replays the same batch shapes, so every
    # program class the recorded grid launches is already compiled and
    # rows_per_s measures the fleet path, not XLA
    sweep(rates, hosts, dists, **kw)
    points = sweep(rates, hosts, dists, trace_out=args.trace_out, **kw)
    from benchmarks.common import perf_record
    doc = perf_record("cluster", points)
    text = json.dumps(doc, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {len(points)} points → {args.out}")
    else:
        print(text)


if __name__ == "__main__":
    main()
