"""BENCH_cluster — multi-host sharded serving sweep.

Drives the :mod:`repro.cluster` runtime over a grid of arrival rates ×
host counts × tenant-id distributions and emits one JSON point per cell:
merged p50/p95/p99 latency, per-host occupancy, load-imbalance (max/mean,
cv), gossip staleness audit, and the drain-barrier record.  The tenant
distributions are the interesting axis — ``unique`` spreads load
hash-uniformly, ``zipf`` models realistic skew, and ``hot`` is the
adversarial single-hot-tenant case where the whole offered load lands on
one host and the fleet's spare capacity is unreachable by design (the
paper's §7 economics measured at cluster scale).

  PYTHONPATH=src python benchmarks/bench_cluster.py [--rates 512,1024]
      [--hosts 1,2,4] [--dists unique,zipf,hot] [--duration 0.02]
      [--out bench_cluster.json] [--fault-plan kill@0.5:h1] [--dry-run]

``--fault-plan`` (times are fractions of the run) prices the failover
transient: without ``--dry-run`` it appends one ``*.fault`` point to the
record; with ``--dry-run`` it runs the chaos smoke instead — exactly-once
rid audit, zero lost requests, and ``gossip_silence`` firing *and*
resolving in the exported fleet trace.  A plan that kills a host without
recovering it gets ``recover@0.9:hN`` appended (the smoke must observe the
rejoin side too); the addition is printed.

Also exposes ``run()`` yielding the aggregator's CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# repo root, cwd-independent (benchmarks/ run as a script)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src"))

# --device-parallel needs N host devices, and jax locks the device count on
# first init — so the env bootstrap must run before anything below imports
# jax (benchmarks.common → repro.*).  A CI job env-set XLA_FLAGS wins: the
# helper rewrites the same flag to the same value.
if "--device-parallel" in sys.argv:
    from repro.launch.xla_env import maybe_force_host_device_count
    maybe_force_host_device_count(
        int(sys.argv[sys.argv.index("--devices") + 1])
        if "--devices" in sys.argv else 4)

from benchmarks.common import (RATE_LADDER_FAST, make_trace,  # noqa: E402
                               parse_rate_ladder)

HOST_LADDER = (1, 2, 4)
DISTRIBUTIONS = ("unique", "zipf", "hot")


def sweep(rates=RATE_LADDER_FAST, hosts=HOST_LADDER, dists=DISTRIBUTIONS, *,
          duration_s=0.02, n_c=8, max_age_s=0.005, d_uniform=256, seed=0,
          n_tenants=64, gossip_period_s=0.002, fault_plan=None,
          shed_watermark=None, coscheduler_factory=None,
          trace_out=None) -> list[dict]:
    """Grid sweep; ``fault_plan`` is a fraction-of-duration spec *string*
    (``kill@0.5:h1,...``) so each grid cell materialises its own
    consumed-once plan."""
    from repro.launch.serve import serve_crypto_cluster

    points = []
    for dist in dists:
        for rate in rates:
            trace = make_trace(rate, duration_s, d_uniform=d_uniform,
                               seed=seed, tenants=dist, n_tenants=n_tenants)
            for n_hosts in hosts:
                # one representative fleet trace per sweep: the widest host
                # count of the first (dist, rate) cell
                traced = (trace_out if (dist == dists[0] and rate == rates[0]
                                        and n_hosts == hosts[-1]) else None)
                t0 = time.time()
                load, snap, dt = serve_crypto_cluster(
                    hosts=n_hosts, n_c=n_c, max_age_s=max_age_s, seed=seed,
                    validate=False,      # HLO validation is tested elsewhere;
                                         # this sweep measures the fleet path
                    gossip_period_s=gossip_period_s, trace=trace,
                    trace_out=traced, fault_plan=fault_plan,
                    shed_watermark=shed_watermark,
                    coscheduler_factory=coscheduler_factory)
                served = sum(1 for h in load.handles
                             if h.done() and not h.rejected)
                m = snap["merged"]
                suffix = ".fault" if fault_plan else ""
                points.append({
                    "config": f"h{n_hosts}.{dist}.rate{rate}{suffix}",
                    "rate_hz": rate,
                    "hosts": n_hosts,
                    "tenant_dist": dist,
                    "duration_s": duration_s,
                    "n_c": n_c,
                    "wall_s": dt,
                    "rows_per_s": served / dt if dt > 0 else 0.0,
                    "served": served,
                    "rejected": len(load.rejected),
                    "batches": m["batches"],
                    "close_reasons": m["close_reasons"],
                    "k_occupancy_mean": m["k_occupancy_mean"],
                    "m_occupancy_mean": m["m_occupancy_mean"],
                    "dispatches": m["dispatch"]["dispatches"],
                    "merged_dispatches": m["dispatch"]["merged_dispatches"],
                    "dispatch_m_fill_mean": m["dispatch"]["m_fill_mean"],
                    "queue_depth_max": m["queue_depth_max"],
                    "p50_s": m["latency"]["p50_s"],
                    "p95_s": m["latency"]["p95_s"],
                    "p99_s": m["latency"]["p99_s"],
                    "imbalance_max_over_mean":
                        m["load_imbalance"]["max_over_mean"],
                    "imbalance_cv": m["load_imbalance"]["cv"],
                    "per_host_requests":
                        m["load_imbalance"]["per_host_requests"],
                    "gossip": snap["gossip"],
                    "drain_barrier": snap["drain_barrier"],
                    "setup_wall_s": time.time() - t0,
                })
                if fault_plan:
                    fo = snap["failover"]
                    points[-1]["fault_plan"] = fault_plan
                    points[-1]["failover"] = {
                        **fo["summary"], "lost": fo["lost"],
                        "sheds": fo["sheds"], "diverted": fo["diverted"],
                        "ingress": fo["ingress"],
                        # detection latency: gossip silence the fleet sat on
                        # before each cordon (the transient the shed
                        # watermark prices)
                        "detection_silence_s": [
                            ev["silence_s"] for ev in fo["events"]
                            if ev["kind"] == "cordon"],
                    }
    return points


def _ensure_recovery(spec: str) -> tuple[str, list[str]]:
    """Append ``recover@0.9:hN`` for every killed-but-never-recovered host
    so a chaos run always exercises the rejoin side (silence-alert resolve,
    router restore).  Returns the effective spec and what was added."""
    from repro.cluster import FaultPlan

    plan = FaultPlan.parse(spec)
    recovered = {e.host for e in plan.events if e.kind == "recover"}
    added = [f"recover@0.9:h{h}"
             for h in dict.fromkeys(e.host for e in plan.events
                                    if e.kind == "kill")
             if h not in recovered]
    return (",".join([spec] + added) if added else spec), added


def chaos_smoke(fault_plan: str, *, hosts=3, rate=1024, duration_s=0.02,
                coscheduler_factory=None, trace_out=None) -> dict:
    """One chaos cell under the smoke invariants: fleet-unique rids, every
    handle terminal exactly once, nothing lost or double-served, and the
    ``gossip_silence`` alert both firing and resolving in the exported
    fleet trace.  Returns a BENCH-schema point plus the audit artifacts."""
    import tempfile

    from repro.launch.serve import serve_crypto_cluster
    from repro.obs import validate_chrome_trace

    spec, added = _ensure_recovery(fault_plan)
    outdir = tempfile.mkdtemp(prefix="bench_cluster_chaos_")
    trace_path = trace_out or os.path.join(outdir, "chaos_trace.json")
    metrics_path = os.path.join(outdir, "chaos_metrics.prom")
    t0 = time.time()
    load, snap, dt = serve_crypto_cluster(
        hosts=hosts, n_c=8, max_age_s=0.002, duration_s=duration_s,
        rate_hz=rate, d_uniform=256, seed=0, validate=False,
        fault_plan=spec, trace_out=trace_path, metrics_out=metrics_path,
        coscheduler_factory=coscheduler_factory)
    # exactly-once audit: fleet-unique rids, one terminal state per handle
    rids = [h.request.request_id for h in load.handles]
    assert len(set(rids)) == len(rids), "duplicate request ids at ingress"
    assert all(h.done() for h in load.handles), "non-terminal handle"
    served = sum(1 for h in load.handles if not h.rejected)
    assert served + len(load.rejected) == len(load.handles)
    fo = snap["failover"]
    assert fo["lost"] == 0 and fo["limbo_pending"] == 0, fo
    assert fo["summary"]["deduped"] == 0, fo["summary"]
    assert fo["summary"]["cordons"] >= 1, fo["summary"]
    by = snap["merged"]["admission"]["by_reason"]
    assert by.get("duplicate", 0) == 0, by
    with open(trace_path) as f:
        fleet = json.load(f)
    stats = validate_chrome_trace(fleet)
    names = {ev["name"] for ev in fleet["traceEvents"]}
    assert "alert_firing:gossip_silence" in names, \
        "dead host never tripped the silence alert"
    assert "alert_resolved:gossip_silence" in names, \
        "silence alert never resolved after rejoin"
    m = snap["merged"]
    point = {
        "config": f"h{hosts}.unique.rate{rate}.fault",
        "rate_hz": rate, "hosts": hosts, "duration_s": duration_s,
        "n_c": 8, "wall_s": dt,
        "rows_per_s": served / dt if dt > 0 else 0.0,
        "served": served, "rejected": len(load.rejected),
        "fault_plan": spec,
        "p50_s": m["latency"]["p50_s"],
        "p95_s": m["latency"]["p95_s"],
        "p99_s": m["latency"]["p99_s"],
        "failover": {
            **fo["summary"], "lost": fo["lost"], "sheds": fo["sheds"],
            "diverted": fo["diverted"], "ingress": fo["ingress"],
            "detection_silence_s": [ev["silence_s"] for ev in fo["events"]
                                    if ev["kind"] == "cordon"],
        },
        "drain_barrier": snap["drain_barrier"],
        "setup_wall_s": time.time() - t0,
    }
    return {"point": point, "added_recovery": added,
            "trace_path": trace_path, "trace_stats": stats,
            "metrics_path": metrics_path}


def _pinned_factory(cache: dict):
    """Host h → co-scheduler pinned to device ``h mod D``, one shared
    compiled-program cache *per device* (compile time stays linear in
    devices, not hosts; sharing across same-device hosts is bit-neutral —
    row semantics make results batch-composition-independent)."""
    import jax
    from repro.core.scheduler.coscheduler import SliceCoScheduler

    n_dev = jax.device_count()

    def factory(h):
        dev = h % n_dev
        if dev not in cache:
            cache[dev] = SliceCoScheduler(devices=[dev])
        return cache[dev]
    return factory


def device_scaling(rates=(8192,), hosts=HOST_LADDER, *, duration_s=0.05,
                   n_c=8, max_age_s=0.005, d_uniform=512, seed=0,
                   n_tenants=64, warm=True) -> list[dict]:
    """Fleet rows/s vs N devices: each host slice pinned to its own device
    (host h → device h, so N hosts exercise exactly N devices).

    **Methodology (single-core CI honesty).**  This process is one Python
    event loop; on a 1-core runner N devices cannot reduce wall time, so
    each point reports two throughputs.  ``rows_per_s_wall`` is raw
    ``served / wall``.  ``rows_per_s`` is ``served / makespan`` where the
    makespan recomposes the *measured* per-launch service components on
    the device-critical path: per-device busy time is the sum of the
    blocking launch+gather seconds of the launches pinned to that device
    (synchronous dispatch, so the measurement window covers exactly that
    launch's compute), host serial time is ``wall − Σ busy``, and
    ``makespan = host_serial + max_device_busy`` — what the same launch
    schedule costs when each device's queue runs concurrently (the async
    ring's behaviour on real parallel hardware; the dispatch-overlap audit
    and the device-mode parity tests cover that path).  At N=1 the two
    throughputs coincide by construction.

    The default rate keeps every host's batcher saturated at N=4: at low
    offered load, splitting the trace over more hosts fragments launches
    (more, shorter batches per host) and per-launch fixed overhead eats
    the projected speedup."""
    import jax
    from repro.launch.serve import serve_crypto_cluster

    cache: dict = {}
    points = []
    for rate in rates:
        trace = make_trace(rate, duration_s, d_uniform=d_uniform, seed=seed,
                           tenants="unique", n_tenants=n_tenants)
        base_rows_per_s = None
        for n_hosts in hosts:
            kw = dict(hosts=n_hosts, n_c=n_c, max_age_s=max_age_s,
                      seed=seed, validate=False, trace=trace,
                      device_parallel=True,
                      coscheduler_factory=_pinned_factory(cache))
            if warm:
                serve_crypto_cluster(**kw)   # compile + plane upload off
                                             # the record
            t0 = time.time()
            load, snap, dt = serve_crypto_cluster(**kw)
            served = sum(1 for h in load.handles
                         if h.done() and not h.rejected)
            per_host_service = [s["service_s_total"]
                                for s in snap["per_host"]]
            busy: dict[tuple, float] = {}
            for devs, svc in zip(snap["devices"]["per_host"],
                                 per_host_service):
                key = tuple(devs)
                busy[key] = busy.get(key, 0.0) + svc
            busy_total = sum(per_host_service)
            host_serial_s = max(0.0, dt - busy_total)
            makespan_s = host_serial_s + (max(busy.values()) if busy
                                          else 0.0)
            rows_per_s = served / makespan_s if makespan_s > 0 else 0.0
            if base_rows_per_s is None:
                base_rows_per_s = rows_per_s or 1.0
            ov = snap["dispatch_overlap"]
            points.append({
                "config": f"dev{n_hosts}.unique.rate{rate}",
                "device_parallel": True,
                "rate_hz": rate,
                "hosts": n_hosts,
                "device_count": jax.device_count(),
                "devices_per_host": snap["devices"]["per_host"],
                "distinct_devices": snap["devices"]["distinct"],
                "duration_s": duration_s,
                "n_c": n_c,
                "d_uniform": d_uniform,
                "wall_s": dt,
                "served": served,
                "rejected": len(load.rejected),
                "rows_per_s": rows_per_s,
                "rows_per_s_wall": served / dt if dt > 0 else 0.0,
                "host_serial_s": host_serial_s,
                "device_busy_s": {",".join(map(str, k)): v
                                  for k, v in sorted(busy.items())},
                "device_busy_total_s": busy_total,
                "makespan_s": makespan_s,
                "scaling_vs_1": rows_per_s / base_rows_per_s,
                "scaling_efficiency": rows_per_s / base_rows_per_s / n_hosts,
                "dispatch_overlap": ov,
                "drain_barrier": snap["drain_barrier"],
                "setup_wall_s": time.time() - t0,
            })
    return points


def device_dry_run(fault_plan=None) -> dict:
    """CI smoke for ``--device-parallel``: a 4-host device-partitioned
    cluster (ClusterServer's own ``partition_devices`` path, no factory)
    must produce bit-for-bit the per-tenant outputs of the simulated
    shared-device oracle on the same trace, pin each host to a distinct
    device (when the process has ≥4), keep the cross-host queue-gap share
    at exactly 0.0, and complete the drain barrier.  With ``fault_plan``,
    a kill/recover cell re-proves parity when the dead host's in-flight
    arrays live on its own device."""
    import jax
    import numpy as np
    from repro.core.scheduler.coscheduler import SliceCoScheduler
    from repro.launch.serve import serve_crypto_cluster

    n_dev = jax.device_count()
    n_hosts = 4
    kw = dict(hosts=n_hosts, n_c=8, max_age_s=0.002, duration_s=0.01,
              rate_hz=4096, d_uniform=64, seed=0, validate=False)
    load_dev, snap_dev, _ = serve_crypto_cluster(device_parallel=True, **kw)
    shared = SliceCoScheduler()
    load_sim, snap_sim, _ = serve_crypto_cluster(
        coscheduler_factory=lambda h: shared, **kw)
    assert set(load_dev.outputs) == set(load_sim.outputs)
    for tid, row in load_sim.outputs.items():
        np.testing.assert_array_equal(load_dev.outputs[tid], row)
    dv, ov = snap_dev["devices"], snap_dev["dispatch_overlap"]
    assert dv["device_parallel"] and len(dv["per_host"]) == n_hosts, dv
    assert ov["launches"] > 0 and snap_dev["drain_barrier"]["complete"]
    if n_dev >= n_hosts:
        assert dv["distinct"] == n_hosts, dv
        assert all(len(p) == 1 for p in dv["per_host"]), dv
        assert ov["cross_host_queue_share"] == 0.0, ov
    doc = {"hosts": n_hosts, "device_count": n_dev,
           "per_host_devices": dv["per_host"],
           "parity_tenants": len(load_sim.outputs),
           "dispatch_overlap": ov}
    if fault_plan:
        spec, added = _ensure_recovery(fault_plan)
        load_f, snap_f, _ = serve_crypto_cluster(
            device_parallel=True, fault_plan=spec, **kw)
        fo = snap_f["failover"]
        assert fo["lost"] == 0 and fo["limbo_pending"] == 0, fo
        assert all(h.done() for h in load_f.handles)
        assert fo["summary"]["cordons"] >= 1, fo["summary"]
        for tid, row in load_sim.outputs.items():
            np.testing.assert_array_equal(load_f.outputs[tid], row)
        doc["chaos"] = {"fault_plan": spec, "added_recovery": added,
                        **{k: fo[k] for k in ("lost", "recovered",
                                              "replayed")},
                        "cordons": fo["summary"]["cordons"]}
    return doc


def run(fast: bool = True):
    """Aggregator entry point: ``name,us_per_call,derived`` CSV rows."""
    from repro.core.scheduler.coscheduler import SliceCoScheduler

    hosts = (1, 2) if fast else HOST_LADDER
    rates = RATE_LADDER_FAST if fast else RATE_LADDER_FAST + (2048,)
    shared = SliceCoScheduler()      # one compiled-program cache per sweep —
                                     # latency is virtual-clock, so per-cell
                                     # recompiles would only burn wall time
    for pt in sweep(rates, hosts, coscheduler_factory=lambda h: shared):
        yield (f"cluster.h{pt['hosts']}.{pt['tenant_dist']}"
               f".rate{pt['rate_hz']},"
               f"{pt['p50_s'] * 1e6:.2f},"
               f"p99={pt['p99_s'] * 1e6:.0f}us"
               f";imbalance={pt['imbalance_max_over_mean']:.2f}"
               f";k_occ={pt['k_occupancy_mean']:.3f}"
               f";served={pt['served']};rejected={pt['rejected']}")


def dry_run(trace_out=None, fault_plan=None) -> dict:
    """CI smoke: one tiny grid cell per distribution on a 3-host cluster;
    asserts the fleet invariants (everything served, barrier complete,
    staleness bound honored, hot tenant collapses onto one host) and that
    the merged fleet trace is schema-valid with per-host process tracks.
    With ``fault_plan``, also runs the :func:`chaos_smoke` audit."""
    import json as _json
    import tempfile

    from repro.core.scheduler.coscheduler import SliceCoScheduler
    from repro.obs import validate_chrome_trace

    shared = SliceCoScheduler()          # one compiled-program cache for all
    path = trace_out or os.path.join(
        tempfile.mkdtemp(prefix="bench_cluster_"), "trace.json")
    points = sweep(rates=(512,), hosts=(3,), dists=("unique", "hot"),
                   duration_s=0.005, max_age_s=0.002,
                   coscheduler_factory=lambda h: shared, trace_out=path)
    with open(path) as f:
        fleet = _json.load(f)
    stats = validate_chrome_trace(fleet)
    assert stats["requests"] > 0 and stats["launches"] > 0, stats
    # every host plus the cluster-control track gets its own process
    pids = {ev["pid"] for ev in fleet["traceEvents"] if ev["ph"] != "M"}
    assert len(pids) >= 2, pids
    for pt in points:
        assert pt["served"] > 0 and pt["rejected"] == 0, pt
        assert pt["drain_barrier"]["complete"], pt
        assert pt["dispatches"] > 0, pt
        assert 0.0 < pt["dispatch_m_fill_mean"] <= 1.0, pt
        g = pt["gossip"]
        assert g["used_staleness_max_s"] <= g["staleness_bound_s"], g
    hot = next(pt for pt in points if pt["tenant_dist"] == "hot")
    per_host = hot["per_host_requests"]
    assert sorted(per_host)[:-1] == [0, 0], per_host   # one hot host only
    assert hot["imbalance_max_over_mean"] > 2.5, hot
    doc = {"points": points, "trace_path": path, "trace_stats": stats}
    if fault_plan:
        chaos = chaos_smoke(fault_plan,
                            coscheduler_factory=lambda h: shared)
        points.append(chaos["point"])
        doc["chaos"] = chaos
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="512,1024")
    ap.add_argument("--hosts", default="1,2,4")
    ap.add_argument("--dists", default="unique,zipf,hot")
    ap.add_argument("--duration", type=float, default=0.02)
    ap.add_argument("--n-c", type=int, default=8)
    ap.add_argument("--max-age-ms", type=float, default=5.0)
    ap.add_argument("--d-uniform", type=int, default=256)
    ap.add_argument("--n-tenants", type=int, default=64)
    ap.add_argument("--gossip-period-ms", type=float, default=2.0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="record one representative fleet trace (widest "
                         "host count of the first grid cell) and write the "
                         "Perfetto JSON here")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic host-failure injection, times as "
                         "fractions of the run (e.g. kill@0.5:h1); adds a "
                         "*.fault transient point (chaos smoke under "
                         "--dry-run)")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny 3-host grid + fleet-invariant and trace-"
                         "schema asserts (CI)")
    ap.add_argument("--device-parallel", action="store_true",
                    help="add device-scaling points (host h pinned to "
                         "device h; fleet rows/s vs N devices) — forces "
                         "--devices host CPU devices before jax init; with "
                         "--dry-run, runs the device-partition parity smoke")
    ap.add_argument("--devices", type=int, default=4,
                    help="host device count the --device-parallel bootstrap "
                         "forces (consumed before jax init; an env-set "
                         "XLA_FLAGS with the same flag is rewritten)")
    args = ap.parse_args()

    if args.dry_run and args.device_parallel:
        doc = device_dry_run(fault_plan=args.fault_plan)
        print(f"device dry run ok: {doc['hosts']} hosts over "
              f"{doc['device_count']} device(s) "
              f"{doc['per_host_devices']}; bit-parity vs simulated oracle "
              f"on {doc['parity_tenants']} tenants; cross-host queue share "
              f"{doc['dispatch_overlap']['cross_host_queue_share']:.3f}")
        if args.fault_plan:
            ch = doc["chaos"]
            print(f"device chaos ok: plan {ch['fault_plan']} → "
                  f"{ch['cordons']} cordon(s), recovered={ch['recovered']} "
                  f"replayed={ch['replayed']} lost={ch['lost']}; outputs "
                  f"still bit-equal to the oracle")
        return

    if args.dry_run:
        doc = dry_run(trace_out=args.trace_out, fault_plan=args.fault_plan)
        stats = doc["trace_stats"]
        hot = next(pt for pt in doc["points"]
                   if pt.get("tenant_dist") == "hot")
        print(f"dry run ok: {len(doc['points'])} points, "
              f"hot-tenant imbalance "
              f"{hot['imbalance_max_over_mean']:.2f}; "
              f"fleet trace schema-valid ({stats['requests']} requests, "
              f"{stats['events']} events) → {doc['trace_path']}")
        if args.fault_plan:
            chaos = doc["chaos"]
            if chaos["added_recovery"]:
                print("fault plan had no recovery for killed hosts — "
                      f"appended {','.join(chaos['added_recovery'])}")
            f = chaos["point"]["failover"]
            print(f"chaos smoke ok: plan {chaos['point']['fault_plan']} → "
                  f"{f['cordons']} cordon(s) "
                  f"({f['cordons_by_cause']}), replayed={f['replayed']} "
                  f"recovered={f['recovered']} deduped={f['deduped']} "
                  f"lost={f['lost']}; gossip_silence fired and resolved "
                  f"→ {chaos['trace_path']}")
        return

    from repro.core.scheduler.coscheduler import SliceCoScheduler

    hosts = tuple(int(h) for h in args.hosts.split(","))
    rates = parse_rate_ladder(args.rates)
    shared = SliceCoScheduler()   # one compiled-program cache per sweep —
                                  # latency is virtual-clock; per-cell
                                  # recompiles would only pollute wall_s
    kw = dict(duration_s=args.duration, n_c=args.n_c,
              max_age_s=args.max_age_ms / 1e3, d_uniform=args.d_uniform,
              n_tenants=args.n_tenants,
              gossip_period_s=args.gossip_period_ms / 1e3,
              coscheduler_factory=lambda h: shared)
    dists = tuple(args.dists.split(","))
    # warm pre-run: an identical (untraced) grid off the record — the
    # deterministic trace seed replays the same batch shapes, so every
    # program class the recorded grid launches is already compiled and
    # rows_per_s measures the fleet path, not XLA
    sweep(rates, hosts, dists, **kw)
    points = sweep(rates, hosts, dists, trace_out=args.trace_out, **kw)
    if args.fault_plan:
        # one failover-transient point rides along with the healthy grid:
        # same schema, ``.fault`` config suffix, plus the failover summary
        # and per-cordon detection silence
        chaos = chaos_smoke(args.fault_plan,
                            coscheduler_factory=lambda h: shared)
        if chaos["added_recovery"]:
            print("fault plan had no recovery for killed hosts — "
                  f"appended {','.join(chaos['added_recovery'])}")
        points.append(chaos["point"])
    if args.device_parallel:
        dev_points = device_scaling()
        for pt in dev_points:
            print(f"  {pt['config']}: rows/s {pt['rows_per_s']:.0f} "
                  f"(wall {pt['rows_per_s_wall']:.0f}), scaling "
                  f"{pt['scaling_vs_1']:.2f}x, efficiency "
                  f"{pt['scaling_efficiency']:.2f}, devices "
                  f"{pt['devices_per_host']}")
        points.extend(dev_points)
    from benchmarks.common import perf_record
    doc = perf_record("cluster", points)
    text = json.dumps(doc, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {len(points)} points → {args.out}")
    else:
        print(text)


if __name__ == "__main__":
    main()
