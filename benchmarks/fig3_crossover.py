"""Fig 3 — algorithmic crossover scan: O(d²) matrix-form NTT vs O(d log d)
Cooley–Tukey, plus the U_eff utilisation model (paper §5.2).

Both algorithms run live in JAX on a single 31-bit BN254 ERNS channel (the
per-channel cost is identical across the 9 channels, so per-channel timing ×9
is the full-pipeline pointwise cost — noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core import limb_gemm as G
from repro.core import ntt as NTT
from repro.core import primes as P


def run(max_log2_d: int = 12) -> list[str]:
    m = P.ntt_friendly_primes(9, 17)[0]
    out = []
    rng = np.random.default_rng(0)
    t_mat_by_d = {}
    for ld in range(8, max_log2_d + 1):
        d = 1 << ld
        a = jnp.asarray(np.asarray(
            rng.integers(0, m, (1, d), dtype=np.uint64), np.uint32))
        # matrix-form via the limb pipeline (per-plane mode for big d)
        w = NTT.ntt_matrix(d, m)
        plan = G.make_channel_plan(w, m, data_limbs=4, tw_limbs=4,
                                   fuse_below=1025)
        mat = jax.jit(lambda x, p=plan: G.staged_transform(x, p)[0])
        t_mat = time_fn(mat, a, warmup=1, repeats=3)["median_s"]
        # Cooley–Tukey O(d log d)
        ct = jax.jit(lambda x: NTT.cooley_tukey_ntt(x, m))
        t_ct = time_fn(ct, a, warmup=1, repeats=3)["median_s"]
        p_algo = math.log2(d) / d
        t_mat_by_d[d] = t_mat
        out.append(csv_row(
            f"fig3.crossover_d{d}", t_mat * 1e6,
            f"matrix_ops={1/t_mat:.1f} ct_ops={1/t_ct:.1f} "
            f"ratio={t_mat/t_ct:.1f}x P_algo={p_algo:.4f} "
            f"U_eff={0.92*p_algo*100:.2f}%"))
    # O(d²) scaling-law extrapolation to the paper's 2^14 endpoint, with the
    # law validated on the measured range first:
    ds = sorted(t_mat_by_d)
    if len(ds) >= 3:
        ratio = t_mat_by_d[ds[-1]] / t_mat_by_d[ds[-2]]
        out.append(csv_row(
            "fig3.scaling_law_check", 0.0,
            f"t(d)/t(d/2)={ratio:.2f} (O(d²) predicts 4.0)"))
        d_top = ds[-1]
        for d in (2 * d_top, 4 * d_top):
            if d > 16384:
                break
            t_ext = t_mat_by_d[d_top] * (d / d_top) ** 2
            out.append(csv_row(
                f"fig3.crossover_d{d}_extrapolated", t_ext * 1e6,
                f"matrix_ops={1/t_ext:.2f} P_algo={math.log2(d)/d:.5f} "
                f"(O(d²) law extension; no crossover — gap widens)"))
    # the paper's d=256 headline utilisation model
    out.append(csv_row("fig3.ueff_model_d256", 0.0,
                       f"P_algo={8/256:.4f} S_mxu>=0.92 "
                       f"U_eff={0.92*8/256*100:.1f}% paper=2.8%"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
