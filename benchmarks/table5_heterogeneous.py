"""Table 5 — heterogeneous trace replay: batch fill, padding waste, staging
overhead and throughput sensitivity to workload mixture."""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import csv_row, time_fn
from repro.core import limb_gemm as G
from repro.core import workloads as WK
from repro.core.scheduler import PoissonTrace, RectangularScheduler, packing_metrics
from benchmarks.table2_throughput import _rand_dil


def _replay_metrics(trace, d_max_by_class, n_c=8):
    sched = RectangularScheduler(n_c=n_c, bucket_granularity=64)
    batches = sched.plan_batches(trace)
    per_class: dict[str, list] = {}
    for b in batches:
        m = packing_metrics(b.degrees, b.d_bucket,
                            d_max_by_class[b.workload])
        per_class.setdefault(b.workload, []).append(m)
    out = {}
    for w, ms in per_class.items():
        out[w] = {
            "batch_fill": float(np.mean([m.batch_fill for m in ms])),
            "padding_waste": float(np.mean([m.padding_waste for m in ms])),
            "staging_overhead": float(np.mean([m.staging_overhead for m in ms])),
            "n_batches": len(ms),
        }
    return out


def run() -> list[str]:
    d_max = {"dilithium": G.staging_d_max(3, 3, "fp32_mantissa"),
             "bn254": G.staging_d_max(4, 4, "fp32_mantissa")}
    out = []

    # uniform d=256 traces (the headline operating point)
    for wl in ("bn254", "dilithium"):
        trace = PoissonTrace(rate_hz=512, duration_s=0.5, seed=2,
                             mixture=((wl, 1.0),),
                             uniform_degree=256).generate()
        m = _replay_metrics(trace, d_max)[wl]
        paper = ("fill=100% waste=0%" if wl == "bn254"
                 else "fill=100% waste=25%")
        out.append(csv_row(
            f"table5.uniform256_{wl}", 0.0,
            f"fill={m['batch_fill']*100:.0f}% waste={m['padding_waste']*100:.0f}% "
            f"staging={m['staging_overhead']*100:.0f}% paper[{paper}]"))

    # mixed-degree BN254 trace (degrees uniform in [64, 512])
    trace = PoissonTrace(rate_hz=512, duration_s=0.5, seed=3,
                         mixture=(("bn254", 1.0),)).generate()
    m = _replay_metrics(trace, d_max)["bn254"]
    out.append(csv_row(
        "table5.mixed_degree_bn254", 0.0,
        f"fill={m['batch_fill']*100:.0f}% waste={m['padding_waste']*100:.0f}% "
        f"paper[fill=87% waste=13%]"))

    # balanced 50:50 trace
    trace = PoissonTrace(rate_hz=1024, duration_s=0.5, seed=4).generate()
    ms = _replay_metrics(trace, d_max)
    for wl, m in sorted(ms.items()):
        out.append(csv_row(
            f"table5.balanced_{wl}", 0.0,
            f"fill={m['batch_fill']*100:.0f}% waste={m['padding_waste']*100:.0f}% "
            f"batches={m['n_batches']} paper[fill=96% waste=12%]"))

    # measured co-scheduling interference (this hardware): dilithium solo vs
    # alongside a BN254 stream on the same device
    dil = WK.make_engine("dilithium", 256)
    bn = WK.make_engine("bn254", 64)
    a_d = _rand_dil(8, 256)
    rng = np.random.default_rng(0)
    a_b = np.zeros((4, 64, 9), np.uint32)
    for ci, mm in enumerate(bn.chain.moduli):
        a_b[..., ci] = rng.integers(0, mm, (4, 64), dtype=np.uint64).astype(np.uint32)
    e2e_d = jax.jit(dil.e2e)
    e2e_b = jax.jit(bn.e2e)
    t_solo = time_fn(e2e_d, a_d)["median_s"]

    def mixed():
        return e2e_d(a_d), e2e_b(jax.numpy.asarray(a_b))

    t_mixed = time_fn(mixed)["median_s"]
    t_b = time_fn(e2e_b, jax.numpy.asarray(a_b))["median_s"]
    interference = t_mixed / (t_solo + t_b)
    out.append(csv_row("table5.cosched_interference", t_mixed * 1e6,
                       f"serialised_ratio={interference:.2f} "
                       f"paper[dil_-8.4%_bn_-5.7%_on_shared_HBM]"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
