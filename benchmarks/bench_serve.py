"""BENCH_serve — online serving latency/occupancy sweep.

Drives the :mod:`repro.serve` runtime with Poisson traces at a ladder of
arrival rates and emits one JSON document per rate: p50/p95/p99 latency,
K/M occupancy, queue depth, close-reason mix, and admission counts.  This is
the online counterpart of Table 5's static packing sweep — it shows where
the latency knee sits relative to the occupancy the batcher can sustain.

  PYTHONPATH=src python benchmarks/bench_serve.py [--rates 512,1024,2048]
      [--duration 0.02] [--out bench_serve.json] [--trace-out trace.json]
      [--controller [--holdback-lambda 1.5] [--inflight-depth 2]]
      [--dry-run]

``--tenant-frontier`` switches to the ingress-scale benchmark instead: the
sustained admitted-requests/s × tenant-count frontier (10⁴–10⁶ distinct
tenants) of the columnar vectorised admission edge vs the scalar oracle,
with bit-identical decisions asserted (``tenant_frontier()``).

Also exposes ``run()`` yielding the aggregator's CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# repo root, cwd-independent (benchmarks/ run as a script)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def sweep(rates=(512, 1024, 2048), *, duration_s=0.02, n_c=8,
          max_age_s=0.005, d_uniform=256, seed=0, merge_dispatch=True,
          row_ladder_max=None, donate=False,
          async_pipeline=False, controller=False, holdback_lambda=0.0,
          inflight_depth=1, coscheduler=None,
          trace_out=None, metrics_out=None) -> list[dict]:
    from repro.launch.serve import serve_crypto_online

    points = []
    for rate in rates:
        t0 = time.time()
        load, snap, dt = serve_crypto_online(
            duration_s=duration_s, rate_hz=rate, n_c=n_c,
            max_age_s=max_age_s, d_uniform=d_uniform, seed=seed,
            merge_dispatch=merge_dispatch, row_ladder_max=row_ladder_max,
            donate=donate, async_pipeline=async_pipeline,
            controller=controller, holdback_lambda=holdback_lambda,
            inflight_depth=inflight_depth, coscheduler=coscheduler,
            # one representative traced/scraped run per sweep — tracing
            # every rate would make the output a concatenation of
            # unrelated runs
            trace_out=trace_out if rate == rates[0] else None,
            metrics_out=metrics_out if rate == rates[0] else None,
            validate=False)      # HLO validation is tested elsewhere; this
                                 # sweep measures the serving path itself
        lat = snap["latency"]
        disp = snap["dispatch"]
        points.append({
            "config": f"rate{rate}",
            "rate_hz": rate,
            "duration_s": duration_s,
            "n_c": n_c,
            "max_age_s": max_age_s,
            "fast_path": {"merge": merge_dispatch,
                          "row_ladder_max": row_ladder_max,
                          "donate": donate, "async": async_pipeline,
                          "controller": controller,
                          "holdback_lambda": holdback_lambda,
                          "inflight_depth": inflight_depth},
            "wall_s": dt,
            "rows_per_s": load.n_served / dt if dt > 0 else 0.0,
            "served": load.n_served,
            "rejected": len(load.rejected),
            "batches": snap["batches"],
            "close_reasons": snap["close_reasons"],
            "k_occupancy_mean": snap["k_occupancy_mean"],
            "m_occupancy_mean": snap["m_occupancy_mean"],
            # achieved per-launch M fill after super-batching + ladder
            # padding — the recovered M-occupancy this PR tracks
            "dispatches": disp["dispatches"],
            "merged_dispatches": disp["merged_dispatches"],
            "batches_per_dispatch_mean": disp["batches_per_dispatch_mean"],
            "dispatch_m_occupancy_mean": disp["m_occupancy_mean"],
            "dispatch_m_fill_mean": disp["m_fill_mean"],
            "holdback": snap.get("holdback"),
            "controller_updates": (snap["controller"]["updates"]
                                   if controller else 0),
            "queue_depth_mean": snap["queue_depth_mean"],
            "queue_depth_max": snap["queue_depth_max"],
            "p50_s": lat["p50_s"], "p95_s": lat["p95_s"],
            "p99_s": lat["p99_s"],
            "penalty": snap.get("penalty"),
            "setup_wall_s": time.time() - t0,
        })
    return points


def tenant_frontier(tenant_counts=(10_000, 100_000, 1_000_000), *,
                    arrival_batch=8192, revisit_fraction=0.25,
                    tenant_rate_hz=4.0, tenant_burst=2.0,
                    slo_deadline_s=0.25, service_rate_init=1e6,
                    seed=0) -> list[dict]:
    """Sustained admitted-requests/s × tenant-count frontier (10⁴–10⁶
    distinct tenants): the columnar vectorised admission edge vs the scalar
    per-request oracle on the same trace, with bit-identical decisions
    asserted point by point.

    Each point replays ``n_tenants × (1 + revisit_fraction)`` arrivals
    (every tenant once, plus a uniform revisit tail so the duplicate-tenant
    rounds of the vector path are exercised) through ``admit_batch`` in
    ``arrival_batch``-sized chunks against a drained queue — the steady
    state where admission itself, not dispatch, is the contended resource
    (the paper's §7.4 overload regime at production tenant counts).  All
    three gates stay armed; the token bucket does the per-tenant work.
    """
    import numpy as np

    from repro.serve.admission import AdmissionController

    points = []
    for nt in tenant_counts:
        rng = np.random.default_rng(seed)
        n = int(nt * (1.0 + revisit_fraction))
        ids = np.concatenate([rng.permutation(nt),
                              rng.integers(0, nt, n - nt)])
        rng.shuffle(ids)
        ts = np.linspace(0.0, 1.0, n)
        kw = dict(max_pending=2 * arrival_batch,
                  tenant_rate_hz=tenant_rate_hz, tenant_burst=tenant_burst,
                  slo_deadline_s=slo_deadline_s,
                  service_rate_init=service_rate_init)
        runs = {}
        for columnar in (False, True):
            ctl = AdmissionController(columnar=columnar, **kw)
            chunks = []
            t0 = time.perf_counter()
            for lo in range(0, n, arrival_batch):
                chunks.append(ctl.admit_batch(ids[lo:lo + arrival_batch],
                                              ts[lo:lo + arrival_batch],
                                              pending=0))
            runs[columnar] = (time.perf_counter() - t0, chunks)
        wall_s, dec_s = runs[False]
        wall_v, dec_v = runs[True]
        equal = all(
            np.array_equal(a.admitted, b.admitted)
            and np.array_equal(a.reason_codes, b.reason_codes)
            and np.array_equal(a.retry_after_s, b.retry_after_s)
            for a, b in zip(dec_s, dec_v))
        admitted = sum(d.n_admitted for d in dec_v)
        points.append({
            "config": f"frontier_nt{nt}",
            "n_tenants": nt,
            "n_requests": n,
            "arrival_batch": arrival_batch,
            "revisit_fraction": revisit_fraction,
            "tenant_rate_hz": tenant_rate_hz,
            "tenant_burst": tenant_burst,
            "admitted": admitted,
            "rejected": n - admitted,
            "decisions_equal": bool(equal),
            "scalar_wall_s": wall_s,
            "columnar_wall_s": wall_v,
            "scalar_admitted_per_s": admitted / wall_s if wall_s > 0 else 0.0,
            "admitted_per_s": admitted / wall_v if wall_v > 0 else 0.0,
            "speedup": wall_s / wall_v if wall_v > 0 else 0.0,
        })
    return points


def frontier_dry_run() -> list[dict]:
    """CI smoke for the tenant frontier: one tiny point; asserts the
    columnar path emitted bit-identical decisions and actually beat the
    scalar oracle (any margin — the committed-record floor is the real
    gate, this catches wiring rot)."""
    points = tenant_frontier(tenant_counts=(2000,), arrival_batch=512)
    pt = points[0]
    assert pt["decisions_equal"], pt
    assert pt["admitted"] > 0, pt
    assert pt["speedup"] > 1.0, pt
    return points


def _make_warm_coscheduler(*, n_c, merge_dispatch, row_ladder_max, donate,
                           async_pipeline):
    """One co-scheduler shared across the sweep, pre-warmed so the recorded
    points measure serving, not XLA compiles (latency is virtual-clock; the
    compile cost would only pollute wall_s / rows_per_s)."""
    from repro.serve.server import ServeConfig, coscheduler_from_config

    cfg = ServeConfig(n_c=n_c, merge_dispatch=merge_dispatch,
                      row_ladder_max=row_ladder_max, donate=donate,
                      async_pipeline=async_pipeline, validate=False)
    return coscheduler_from_config(cfg)


def dry_run(trace_out=None, metrics_out=None) -> dict:
    """CI smoke: one tiny traced + scraped sweep point; asserts the trace
    file is schema-valid with a full submit → batch → launch → complete
    chain per admitted request, that the OpenMetrics exposition validates,
    and that penalty shares conserve."""
    import tempfile

    from repro.obs import validate_chrome_trace, validate_openmetrics

    tmp = tempfile.mkdtemp(prefix="bench_serve_")
    path = trace_out or os.path.join(tmp, "trace.json")
    mpath = metrics_out or os.path.join(tmp, "metrics.om")
    points = sweep(rates=(512,), duration_s=0.005, max_age_s=0.002,
                   trace_out=path, metrics_out=mpath)
    pt = points[0]
    assert pt["served"] > 0 and pt["rejected"] == 0, pt
    with open(path) as f:
        trace = json.load(f)
    stats = validate_chrome_trace(trace)
    assert stats["requests"] == pt["served"], (stats, pt["served"])
    assert stats["batches"] > 0 and stats["launches"] > 0, stats
    mstats = validate_openmetrics(mpath)
    assert mstats["samples"] > 0, mstats
    assert pt["penalty"], pt
    for w, sec in pt["penalty"].items():
        total = sum(sec["shares"].values())
        assert abs(total - 1.0) <= 1e-9, (w, sec["shares"])
    return {"points": points, "trace_path": path, "trace_stats": stats,
            "metrics_path": mpath, "metrics_stats": mstats}


def run(fast: bool = True):
    """Aggregator entry point: ``name,us_per_call,derived`` CSV rows."""
    from benchmarks.common import RATE_LADDER_FAST, RATE_LADDER_FULL

    rates = RATE_LADDER_FAST if fast else RATE_LADDER_FULL
    for pt in sweep(rates):
        yield (f"serve.online.rate{pt['rate_hz']},"
               f"{pt['p50_s'] * 1e6:.2f},"
               f"p99={pt['p99_s'] * 1e6:.0f}us"
               f";k_occ={pt['k_occupancy_mean']:.3f}"
               f";m_occ={pt['m_occupancy_mean']:.3f}"
               f";m_fill={pt['dispatch_m_fill_mean']:.3f}"
               f";served={pt['served']};rejected={pt['rejected']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="512,1024,2048")
    ap.add_argument("--duration", type=float, default=0.02)
    ap.add_argument("--n-c", type=int, default=8)
    ap.add_argument("--max-age-ms", type=float, default=5.0)
    ap.add_argument("--d-uniform", type=int, default=256)
    ap.add_argument("--no-merge", action="store_true")
    ap.add_argument("--row-ladder-max", type=int, default=None)
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--async-pipeline", action="store_true")
    ap.add_argument("--controller", action="store_true",
                    help="closed-loop close policy (adaptive occupancy "
                         "controller) instead of the static config")
    ap.add_argument("--holdback-lambda", type=float, default=0.0)
    ap.add_argument("--inflight-depth", type=int, default=1)
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="record request-lifecycle tracing on one sweep "
                         "point and write the Perfetto JSON here")
    ap.add_argument("--metrics-out", default=None,
                    help="scrape continuous metrics on one sweep point and "
                         "write the OpenMetrics exposition here")
    ap.add_argument("--tenant-frontier", action="store_true",
                    help="measure the admitted-requests/s × tenant-count "
                         "frontier of the columnar admission edge instead "
                         "of the serving-rate sweep")
    ap.add_argument("--tenant-counts", default="10000,100000,1000000",
                    help="tenant-count ladder for --tenant-frontier")
    ap.add_argument("--arrival-batch", type=int, default=8192,
                    help="submit_many chunk size for --tenant-frontier")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny traced sweep + trace-schema / penalty-"
                         "conservation asserts (CI); with --tenant-frontier, "
                         "a tiny frontier point with parity + speedup "
                         "asserts instead")
    args = ap.parse_args()

    from benchmarks.common import parse_rate_ladder, perf_record

    if args.tenant_frontier:
        if args.dry_run:
            points = frontier_dry_run()
            pt = points[0]
            print(f"frontier dry run ok: {pt['n_tenants']} tenants, "
                  f"{pt['admitted']}/{pt['n_requests']} admitted, "
                  f"decisions bit-identical, speedup {pt['speedup']:.1f}x "
                  f"({pt['admitted_per_s']:,.0f} admitted/s columnar vs "
                  f"{pt['scalar_admitted_per_s']:,.0f}/s scalar)")
            return
        counts = parse_rate_ladder(args.tenant_counts)
        # warm pre-run on the smallest count: numpy/interpreter warm-up off
        # the record, same as the sweep's compile warm-up
        tenant_frontier(tenant_counts=counts[:1],
                        arrival_batch=args.arrival_batch)
        points = tenant_frontier(tenant_counts=counts,
                                 arrival_batch=args.arrival_batch)
        doc = perf_record("serve", points)
        text = json.dumps(doc, indent=2, sort_keys=True)
        if args.out:
            with open(args.out, "w") as f:
                f.write(text)
            print(f"wrote {len(points)} frontier points → {args.out}")
        else:
            print(text)
        return

    if args.dry_run:
        doc = dry_run(trace_out=args.trace_out, metrics_out=args.metrics_out)
        stats = doc["trace_stats"]
        ms = doc["metrics_stats"]
        print(f"dry run ok: {stats['requests']} requests traced through "
              f"{stats['batches']} batches / {stats['launches']} launches "
              f"({stats['events']} events, schema-valid); metrics "
              f"{ms['families']} families / {ms['series']} series / "
              f"{ms['samples']} samples (OpenMetrics-valid); penalty "
              f"shares conserve — trace → {doc['trace_path']}, "
              f"metrics → {doc['metrics_path']}")
        return

    shared = _make_warm_coscheduler(
        n_c=args.n_c, merge_dispatch=not args.no_merge,
        row_ladder_max=args.row_ladder_max, donate=args.donate,
        async_pipeline=args.async_pipeline)
    kw = dict(duration_s=args.duration, n_c=args.n_c,
              max_age_s=args.max_age_ms / 1e3, d_uniform=args.d_uniform,
              merge_dispatch=not args.no_merge,
              row_ladder_max=args.row_ladder_max, donate=args.donate,
              async_pipeline=args.async_pipeline,
              controller=args.controller,
              holdback_lambda=args.holdback_lambda,
              inflight_depth=args.inflight_depth, coscheduler=shared)
    rates = parse_rate_ladder(args.rates)
    # warm pre-run: an identical (untraced) sweep off the record — the
    # deterministic Poisson seed replays the exact same batch shapes, so
    # every merged-dispatch program class the recorded sweep launches is
    # already compiled and rows_per_s measures serving, not XLA
    sweep(rates, **kw)
    points = sweep(rates, trace_out=args.trace_out,
                   metrics_out=args.metrics_out, **kw)
    doc = perf_record("serve", points)
    text = json.dumps(doc, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {len(points)} points → {args.out}")
    else:
        print(text)


if __name__ == "__main__":
    main()
