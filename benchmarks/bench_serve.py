"""BENCH_serve — online serving latency/occupancy sweep.

Drives the :mod:`repro.serve` runtime with Poisson traces at a ladder of
arrival rates and emits one JSON document per rate: p50/p95/p99 latency,
K/M occupancy, queue depth, close-reason mix, and admission counts.  This is
the online counterpart of Table 5's static packing sweep — it shows where
the latency knee sits relative to the occupancy the batcher can sustain.

  PYTHONPATH=src python benchmarks/bench_serve.py [--rates 512,1024,2048]
      [--duration 0.02] [--out bench_serve.json] [--trace-out trace.json]
      [--controller [--holdback-lambda 1.5] [--inflight-depth 2]]
      [--dry-run]

Also exposes ``run()`` yielding the aggregator's CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# repo root, cwd-independent (benchmarks/ run as a script)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def sweep(rates=(512, 1024, 2048), *, duration_s=0.02, n_c=8,
          max_age_s=0.005, d_uniform=256, seed=0, merge_dispatch=True,
          row_ladder_max=None, donate=False,
          async_pipeline=False, controller=False, holdback_lambda=0.0,
          inflight_depth=1, coscheduler=None,
          trace_out=None) -> list[dict]:
    from repro.launch.serve import serve_crypto_online

    points = []
    for rate in rates:
        t0 = time.time()
        load, snap, dt = serve_crypto_online(
            duration_s=duration_s, rate_hz=rate, n_c=n_c,
            max_age_s=max_age_s, d_uniform=d_uniform, seed=seed,
            merge_dispatch=merge_dispatch, row_ladder_max=row_ladder_max,
            donate=donate, async_pipeline=async_pipeline,
            controller=controller, holdback_lambda=holdback_lambda,
            inflight_depth=inflight_depth, coscheduler=coscheduler,
            # one representative traced run per sweep — tracing every rate
            # would make the trace file a concatenation of unrelated runs
            trace_out=trace_out if rate == rates[0] else None,
            validate=False)      # HLO validation is tested elsewhere; this
                                 # sweep measures the serving path itself
        lat = snap["latency"]
        disp = snap["dispatch"]
        points.append({
            "config": f"rate{rate}",
            "rate_hz": rate,
            "duration_s": duration_s,
            "n_c": n_c,
            "max_age_s": max_age_s,
            "fast_path": {"merge": merge_dispatch,
                          "row_ladder_max": row_ladder_max,
                          "donate": donate, "async": async_pipeline,
                          "controller": controller,
                          "holdback_lambda": holdback_lambda,
                          "inflight_depth": inflight_depth},
            "wall_s": dt,
            "rows_per_s": load.n_served / dt if dt > 0 else 0.0,
            "served": load.n_served,
            "rejected": len(load.rejected),
            "batches": snap["batches"],
            "close_reasons": snap["close_reasons"],
            "k_occupancy_mean": snap["k_occupancy_mean"],
            "m_occupancy_mean": snap["m_occupancy_mean"],
            # achieved per-launch M fill after super-batching + ladder
            # padding — the recovered M-occupancy this PR tracks
            "dispatches": disp["dispatches"],
            "merged_dispatches": disp["merged_dispatches"],
            "batches_per_dispatch_mean": disp["batches_per_dispatch_mean"],
            "dispatch_m_occupancy_mean": disp["m_occupancy_mean"],
            "dispatch_m_fill_mean": disp["m_fill_mean"],
            "holdback": snap.get("holdback"),
            "controller_updates": (snap["controller"]["updates"]
                                   if controller else 0),
            "queue_depth_mean": snap["queue_depth_mean"],
            "queue_depth_max": snap["queue_depth_max"],
            "p50_s": lat["p50_s"], "p95_s": lat["p95_s"],
            "p99_s": lat["p99_s"],
            "penalty": snap.get("penalty"),
            "setup_wall_s": time.time() - t0,
        })
    return points


def _make_warm_coscheduler(*, n_c, merge_dispatch, row_ladder_max, donate,
                           async_pipeline):
    """One co-scheduler shared across the sweep, pre-warmed so the recorded
    points measure serving, not XLA compiles (latency is virtual-clock; the
    compile cost would only pollute wall_s / rows_per_s)."""
    from repro.serve.server import ServeConfig, coscheduler_from_config

    cfg = ServeConfig(n_c=n_c, merge_dispatch=merge_dispatch,
                      row_ladder_max=row_ladder_max, donate=donate,
                      async_pipeline=async_pipeline, validate=False)
    return coscheduler_from_config(cfg)


def dry_run(trace_out=None) -> dict:
    """CI smoke: one tiny traced sweep point; asserts the trace file is
    schema-valid with a full submit → batch → launch → complete chain per
    admitted request, and that penalty shares conserve."""
    import tempfile

    from repro.obs import validate_chrome_trace

    path = trace_out or os.path.join(tempfile.mkdtemp(prefix="bench_serve_"),
                                     "trace.json")
    points = sweep(rates=(512,), duration_s=0.005, max_age_s=0.002,
                   trace_out=path)
    pt = points[0]
    assert pt["served"] > 0 and pt["rejected"] == 0, pt
    with open(path) as f:
        trace = json.load(f)
    stats = validate_chrome_trace(trace)
    assert stats["requests"] == pt["served"], (stats, pt["served"])
    assert stats["batches"] > 0 and stats["launches"] > 0, stats
    assert pt["penalty"], pt
    for w, sec in pt["penalty"].items():
        total = sum(sec["shares"].values())
        assert abs(total - 1.0) <= 1e-9, (w, sec["shares"])
    return {"points": points, "trace_path": path, "trace_stats": stats}


def run(fast: bool = True):
    """Aggregator entry point: ``name,us_per_call,derived`` CSV rows."""
    from benchmarks.common import RATE_LADDER_FAST, RATE_LADDER_FULL

    rates = RATE_LADDER_FAST if fast else RATE_LADDER_FULL
    for pt in sweep(rates):
        yield (f"serve.online.rate{pt['rate_hz']},"
               f"{pt['p50_s'] * 1e6:.2f},"
               f"p99={pt['p99_s'] * 1e6:.0f}us"
               f";k_occ={pt['k_occupancy_mean']:.3f}"
               f";m_occ={pt['m_occupancy_mean']:.3f}"
               f";m_fill={pt['dispatch_m_fill_mean']:.3f}"
               f";served={pt['served']};rejected={pt['rejected']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="512,1024,2048")
    ap.add_argument("--duration", type=float, default=0.02)
    ap.add_argument("--n-c", type=int, default=8)
    ap.add_argument("--max-age-ms", type=float, default=5.0)
    ap.add_argument("--d-uniform", type=int, default=256)
    ap.add_argument("--no-merge", action="store_true")
    ap.add_argument("--row-ladder-max", type=int, default=None)
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--async-pipeline", action="store_true")
    ap.add_argument("--controller", action="store_true",
                    help="closed-loop close policy (adaptive occupancy "
                         "controller) instead of the static config")
    ap.add_argument("--holdback-lambda", type=float, default=0.0)
    ap.add_argument("--inflight-depth", type=int, default=1)
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="record request-lifecycle tracing on one sweep "
                         "point and write the Perfetto JSON here")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny traced sweep + trace-schema / penalty-"
                         "conservation asserts (CI)")
    args = ap.parse_args()

    from benchmarks.common import parse_rate_ladder, perf_record

    if args.dry_run:
        doc = dry_run(trace_out=args.trace_out)
        stats = doc["trace_stats"]
        print(f"dry run ok: {stats['requests']} requests traced through "
              f"{stats['batches']} batches / {stats['launches']} launches "
              f"({stats['events']} events, schema-valid); penalty shares "
              f"conserve — trace → {doc['trace_path']}")
        return

    shared = _make_warm_coscheduler(
        n_c=args.n_c, merge_dispatch=not args.no_merge,
        row_ladder_max=args.row_ladder_max, donate=args.donate,
        async_pipeline=args.async_pipeline)
    kw = dict(duration_s=args.duration, n_c=args.n_c,
              max_age_s=args.max_age_ms / 1e3, d_uniform=args.d_uniform,
              merge_dispatch=not args.no_merge,
              row_ladder_max=args.row_ladder_max, donate=args.donate,
              async_pipeline=args.async_pipeline,
              controller=args.controller,
              holdback_lambda=args.holdback_lambda,
              inflight_depth=args.inflight_depth, coscheduler=shared)
    rates = parse_rate_ladder(args.rates)
    # warm pre-run: an identical (untraced) sweep off the record — the
    # deterministic Poisson seed replays the exact same batch shapes, so
    # every merged-dispatch program class the recorded sweep launches is
    # already compiled and rows_per_s measures serving, not XLA
    sweep(rates, **kw)
    points = sweep(rates, trace_out=args.trace_out, **kw)
    doc = perf_record("serve", points)
    text = json.dumps(doc, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {len(points)} points → {args.out}")
    else:
        print(text)


if __name__ == "__main__":
    main()
