"""Shared benchmark utilities + the paper's recorded external baselines."""
from __future__ import annotations

import json
import platform
import time

import numpy as np
import jax


def time_fn(fn, *args, warmup: int = 2, repeats: int = 5) -> dict:
    """Median wall time of a jitted callable (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    arr = np.asarray(times)
    return {"median_s": float(np.median(arr)), "p99_s": float(np.max(arr)),
            "mean_s": float(arr.mean()), "n": repeats}


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"


# --- BENCH_* perf records (the repo's tracked perf trajectory) ----------------

PERF_SCHEMA = 1


def perf_record(bench: str, points: list, meta: dict | None = None) -> dict:
    """One ``BENCH_<name>.json`` document: a stable envelope around a list of
    measurement points, stamped with enough environment to compare runs
    across commits (the perf-trajectory contract shared by every bench)."""
    doc = {
        "bench": bench,
        "schema": PERF_SCHEMA,
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "env": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "jax": jax.__version__,
            "platform": platform.platform(),
            "python": platform.python_version(),
        },
        "points": points,
    }
    if meta:
        doc["meta"] = meta
    return doc


def write_perf_record(path: str, bench: str, points: list,
                      meta: dict | None = None) -> dict:
    """Assemble + write a perf record; returns the document."""
    doc = perf_record(bench, points, meta)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    return doc


# --- Rate ladders + trace generation (shared by bench_serve / bench_cluster) --
RATE_LADDER_FAST = (512, 1024)
RATE_LADDER_FULL = (512, 1024, 2048, 4096)


def parse_rate_ladder(spec: str) -> tuple[int, ...]:
    """'512,1024,2048' → (512, 1024, 2048) — the CLI rate-ladder format."""
    return tuple(int(r) for r in spec.split(","))


def make_trace(rate_hz: float, duration_s: float, *, d_uniform: int | None = None,
               seed: int = 0, tenants: str = "unique", n_tenants: int = 64,
               zipf_a: float = 1.5, accum: str = "fp32_mantissa") -> list:
    """Poisson trace with payloads and a chosen tenant-id distribution.

    ``tenants`` shapes how requests map to tenant ids — the lever that
    stresses a tenant-hash ingress:

    * ``"unique"`` — every request its own tenant (the PoissonTrace default;
      hash routing spreads load near-uniformly);
    * ``"zipf"``   — requests drawn from ``n_tenants`` tenants with Zipf
      exponent ``zipf_a`` (realistic skew: a few tenants dominate);
    * ``"hot"``    — adversarial single hot tenant owning every request
      (worst-case: the whole load lands on one host).

    Payloads are attached per request in arrival order, so two traces that
    differ only in tenant assignment carry identical coefficient streams.
    """
    from repro.core.scheduler import PoissonTrace
    from repro.serve.client import attach_payloads

    trace = PoissonTrace(rate_hz=rate_hz, duration_s=duration_s,
                         uniform_degree=d_uniform, seed=seed).generate()
    if tenants == "zipf":
        rng = np.random.default_rng(seed + 1)
        ranks = (rng.zipf(zipf_a, len(trace)) - 1) % n_tenants
        for req, rank in zip(trace, ranks):
            req.tenant_id = int(rank)
    elif tenants == "hot":
        for req in trace:
            req.tenant_id = 0
    elif tenants != "unique":
        raise ValueError(f"unknown tenant distribution {tenants!r} "
                         f"(want unique | zipf | hot)")
    attach_payloads(trace, seed=seed, accum=accum)
    return trace


def make_drifting_trace(rates, seg_duration_s: float, *,
                        d_uniform: int | None = 64, seed: int = 0,
                        workload: str = "dilithium",
                        accum: str = "fp32_mantissa") -> list:
    """Piecewise-Poisson trace whose rate *drifts* across segments — the
    harness for the closed-loop controller benchmarks.

    Each entry of ``rates`` owns one ``seg_duration_s``-long segment; a
    static close policy tuned for any one segment is mistuned for the
    others, which is exactly the regime the adaptive controller is supposed
    to survive.  Tenant ids are re-assigned sequentially across the whole
    trace (unique per request) so per-tenant output maps are directly
    comparable across serving configurations, and payloads are attached
    once, in arrival order, from one rng stream — two calls with the same
    arguments produce byte-identical traces.
    """
    from repro.core.scheduler import PoissonTrace
    from repro.serve.client import attach_payloads

    trace, t0 = [], 0.0
    for i, rate in enumerate(rates):
        seg = PoissonTrace(rate_hz=float(rate), duration_s=seg_duration_s,
                           uniform_degree=d_uniform, seed=seed + i,
                           mixture=((workload, 1.0),)).generate()
        for r in seg:
            r.arrival_time += t0
        trace.extend(seg)
        t0 += seg_duration_s
    for i, r in enumerate(trace):
        r.tenant_id = i
    attach_payloads(trace, seed=seed, accum=accum)
    return trace


# --- Recorded constants from the paper (GPU baselines + cloud pricing) --------
# These are *external reference points* (paper §7.1, Table 2) — the deficit
# reproduction is derived arithmetic over them + our measured structure.
PAPER = {
    "a100_cuzk_bn254_ops": 7.2e6,
    "a100_sppark_bn254_ops": 18.4e6,
    "a100_icicle_m31_ops": 62.15e6,
    "a100_cudilithium_ntt_ops": 18.3e6,
    "a100_price": 3.67,
    "tpu_v4_price_chip": 3.22, "tpu_v4_chips": 4,
    "tpu_v5e_price_chip": 1.20, "tpu_v5e_chips": 8,
    "tpu_v5p_price_chip": 4.20, "tpu_v5p_chips": 4,
    # the paper's measured TPU throughputs (recorded for derived columns)
    "tpu_v4_bn254_ops": 3663.0,
    "tpu_v5e_bn254_ops": 2704.0,
    "tpu_v5p_bn254_ops": 5931.0,
    "tpu_v5p_bn254_int32_ops": 7014.0,
    "tpu_v4_dil_ops": 110435.0,
    "tpu_v5e_dil_ops": 85231.0,
    "tpu_v5p_dil_ops": 164822.0,
    "tpu_v4_pointwise_ops": 63000.0,
    "tpu_v4_vpu_only_ops": 4400.0,
    # §7.2.1: projected spatial collapse of eager (strict-isolation) folding
    # vs the κ-amortised deferred schedule.
    "kappa_spatial_collapse": 5.19,
}
