"""BENCH_dispatch — dispatch hot-path microbenchmark (fast-path levers).

Measures the Tier-2 dispatch loop itself — the per-launch overhead the
paper's measurement vehicle adds on top of the architectural deficit — over
a grid of fast-path configurations:

* **per-batch** (the pre-fast-path baseline): every stacked batch is its own
  launch, padded to N_c rows, materialised with a blocking host sync before
  the next launch;
* **merge** on/off — M-axis super-batching of same-(workload, d_bucket)
  batches into tall operands;
* **ladder** on/off — row-ladder compile cache (launch heights padded to
  geometric rungs, so XLA traces are bounded by the ladder size);
* **donate** on/off — operand buffers donated to the e2e programs;
* **async** on/off — two-phase launch → copy_to_host_async → gather with one
  launch group kept in flight.

``--controller`` adds the closed-loop axis: the **drifting-rate ladder**
drives the full serving stack (admission → continuous batcher → dispatch)
with a piecewise-Poisson trace whose rate jumps across segments, once with
the static close policy (the PR-4 fast path) and once with the adaptive
occupancy controller + λ-holdback + depth-2 launch ring.  Both runs must be
bit-for-bit equal per tenant (and a static re-run bounds the noise floor);
the acceptance bar is adaptive ≥ 1.2× static rows/s.

Every configuration is checked **bit-for-bit against the per-batch baseline**
before its timing counts, and the trace counters are asserted against the
ladder bound — throughput claims at unequal correctness are worthless.
Writes a ``BENCH_dispatch.json`` perf record via the shared helper in
:mod:`benchmarks.common`.

  PYTHONPATH=src python benchmarks/bench_dispatch.py [--batches 200]
      [--repeats 3] [--out BENCH_dispatch.json] [--controller] [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

# repo root, cwd-independent (benchmarks/ run as a script)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import write_perf_record  # noqa: E402

LADDER = (8, 16, 32, 64, 128)
N_C = 8          # baseline pad target (the serve default)

# Drifting-rate ladder (req/s per segment) for the closed-loop axis: a 16×
# swing in offered load, so any single static tuning point is mistuned for
# most of the trace.
DRIFT_RATES = (512, 4096, 1024, 8192)
DRIFT_SEG_S = 0.05
ADAPTIVE_FLOOR = 1.2     # acceptance: adaptive ≥ 1.2× static rows/s
TRACE_OVERHEAD_MAX = 0.05   # acceptance: tracing costs ≤ 5% rows/s vs off
METRICS_OVERHEAD_MAX = 0.05  # acceptance: metrics scrape ≤ 5% rows/s vs off


def make_batches(n_batches: int, *, seed: int = 0, d_buckets=(64, 128),
                 with_bn254: bool = False) -> list:
    """Adversarially mixed-height stacked batches: every height in
    [1, N_C] appears, in an order that defeats shape caching without a
    ladder.  Live rows only (mergeable emission)."""
    from repro.core import field as F
    from repro.core.scheduler import TenantRequest
    from repro.core.scheduler.rectangular import StackedBatch, stack_rows
    from repro.core import workloads as WK

    rng = np.random.default_rng(seed)
    batches = []
    for i in range(n_batches):
        workload = ("bn254" if (with_bn254 and i % 5 == 4) else "dilithium")
        d = int(rng.choice(d_buckets)) if workload == "dilithium" else 64
        rows = int(rng.integers(1, N_C + 1))
        reqs = []
        for r in range(rows):
            tid = i * 1000 + r
            if workload == "dilithium":
                coeffs = np.asarray(rng.integers(0, F.DILITHIUM_Q, d,
                                                 dtype=np.uint64), np.uint32)
            else:
                eng = WK.make_engine("bn254", d)
                vals = np.array([int(x) for x in rng.integers(0, 2**31, d)],
                                object)
                coeffs = np.asarray(eng.ingest(vals))
            reqs.append(TenantRequest(tid, workload, d, 0.0, coeffs))
        batches.append(StackedBatch(workload=workload, d_bucket=d,
                                    requests=reqs,
                                    operand=stack_rows(reqs, d)))
    return batches


def _pad_batch(b, n_rows: int):
    """The pre-fast-path batcher behaviour: pad every operand to N_c rows so
    the per-batch path hits one compiled shape per class."""
    from repro.core.scheduler.rectangular import StackedBatch, stack_rows
    return StackedBatch(workload=b.workload, d_bucket=b.d_bucket,
                        requests=b.requests,
                        operand=stack_rows(b.requests, b.d_bucket,
                                           n_rows=n_rows))


def _rows_of(results) -> list:
    return [np.asarray(r.rows[:r.batch.n_c]) for r in results]


def run_baseline(batches, repeats: int):
    """Pre-PR per-batch path: one padded launch + blocking materialise per
    batch, no merge, no ladder, no donation."""
    from repro.core.scheduler.coscheduler import SliceCoScheduler

    cos = SliceCoScheduler(merge=False)
    padded = [_pad_batch(b, N_C) for b in batches]
    for b in padded[: min(8, len(padded))]:          # warm the jit caches
        cos.dispatch(b)
    best, results = None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        results = [cos.dispatch(b) for b in padded]
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return _rows_of(results), best, dict(cos.trace_counts)


def run_fastpath(batches, repeats: int, *, merge: bool, ladder: bool,
                 donate: bool, async_pipeline: bool, chunk: int = 16):
    """The dispatch fast path at one lever setting.  Batches arrive in
    ``chunk``-sized waves (the shape a pump loop hands dispatch_mixed); the
    async variant keeps one wave in flight while the next launches."""
    from repro.core.scheduler.coscheduler import SliceCoScheduler

    cos = SliceCoScheduler(merge=merge,
                           row_ladder=LADDER if ladder else None,
                           donate=donate)
    if ladder:
        programs = sorted({(b.workload, b.d_bucket) for b in batches})
        cos.precompile(programs, N_C)
    chunks = [batches[i:i + chunk] for i in range(0, len(batches), chunk)]
    for c in chunks[:1]:                             # warm remaining shapes
        cos.dispatch_mixed(c)
    best, results = None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        results = []
        if async_pipeline:
            prev = None
            for c in chunks:
                flight = cos.launch_mixed(c)
                if prev is not None:
                    results.extend(cos.gather(prev))
                prev = flight
            results.extend(cos.gather(prev))
        else:
            for c in chunks:
                results.extend(cos.dispatch_mixed(c))
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return _rows_of(results), best, dict(cos.trace_counts)


def sweep(n_batches: int = 200, repeats: int = 3, seed: int = 0,
          with_bn254: bool = False) -> dict:
    batches = make_batches(n_batches, seed=seed, with_bn254=with_bn254)
    live_rows = sum(b.n_c for b in batches)
    base_rows, base_s, base_traces = run_baseline(batches, repeats)

    points = [{
        "config": "per-batch", "merge": False, "ladder": False,
        "donate": False, "async": False, "wall_s": base_s,
        "rows_per_s": live_rows / base_s, "speedup": 1.0,
        "trace_counts": {f"{w}/{d}": n for (w, d), n in base_traces.items()},
        "bitexact_vs_baseline": True,
    }]
    grid = [
        dict(merge=True, ladder=False, donate=False, async_pipeline=False),
        dict(merge=False, ladder=True, donate=False, async_pipeline=False),
        dict(merge=True, ladder=True, donate=False, async_pipeline=False),
        dict(merge=True, ladder=True, donate=True, async_pipeline=False),
        dict(merge=True, ladder=True, donate=True, async_pipeline=True),
    ]
    for g in grid:
        rows, dt, traces = run_fastpath(batches, repeats, **g)
        exact = all(np.array_equal(a, b) for a, b in zip(rows, base_rows))
        if not exact:
            raise AssertionError(f"fast path {g} diverged from the per-batch "
                                 f"baseline — refusing to record its timing")
        if g["ladder"]:
            over = {k: n for k, n in traces.items() if n > len(LADDER)}
            assert not over, f"row ladder failed to bound traces: {over}"
        points.append({
            "config": "+".join(k for k, v in g.items() if v) or "plain",
            "merge": g["merge"], "ladder": g["ladder"],
            "donate": g["donate"], "async": g["async_pipeline"],
            "wall_s": dt, "rows_per_s": live_rows / dt,
            "speedup": base_s / dt,
            "trace_counts": {f"{w}/{d}": n for (w, d), n in traces.items()},
            "bitexact_vs_baseline": True,
        })
    return {"batches": n_batches, "live_rows": live_rows,
            "ladder": list(LADDER), "n_c": N_C, "points": points}


def controller_ladder(rates=DRIFT_RATES, seg_duration_s=DRIFT_SEG_S,
                      repeats: int = 3, seed: int = 0,
                      d_uniform: int = 64) -> dict:
    """The closed-loop axis: static vs adaptive close policy over the
    drifting-rate ladder, through the full online serving stack.

    All runs share one pre-compiled co-scheduler (every ladder rung warmed)
    so the timings measure the dispatch loop, not XLA compiles, and every
    run's per-tenant outputs are asserted bit-for-bit equal before any
    timing is recorded."""
    from benchmarks.common import make_drifting_trace
    from repro.core.scheduler.coscheduler import (SliceCoScheduler,
                                                  default_row_ladder)
    from repro.core.scheduler.rectangular import select_bucket
    from repro.serve import CryptoServer, LoadGenerator, ServeConfig

    ladder = default_row_ladder(LADDER[-1])
    cos = SliceCoScheduler(merge=True, row_ladder=ladder)
    d_bucket = select_bucket(d_uniform)
    cos.precompile([("dilithium", d_bucket)], N_C)
    base = dict(n_c=N_C, max_age_s=0.002, validate=False,
                merge_dispatch=True, row_ladder_max=LADDER[-1],
                async_pipeline=True)

    def run_once(extra):
        trace = make_drifting_trace(rates, seg_duration_s,
                                    d_uniform=d_uniform, seed=seed)
        server = CryptoServer(ServeConfig(**base, **extra), coscheduler=cos)
        gen = LoadGenerator(trace, attach=False)
        t0 = time.perf_counter()
        load = gen.run(server)
        dt = time.perf_counter() - t0
        assert not load.rejected, "drift ladder must serve every request"
        return load.outputs, dt, server.telemetry.snapshot()

    def best_of(extra):
        outputs = best_dt = snap = None
        for _ in range(repeats):
            out, dt, s = run_once(extra)
            if best_dt is None or dt < best_dt:
                outputs, best_dt, snap = out, dt, s
        return outputs, best_dt, snap

    adaptive_cfg = dict(controller=True, holdback_lambda=1.5,
                        inflight_depth=2)
    static_out, static_s, static_snap = best_of({})
    rerun_out, rerun_s, rerun_snap = best_of({})
    adapt_out, adapt_s, adapt_snap = best_of(adaptive_cfg)

    # Replay parity: the closed loop may only change grouping and timing,
    # never a single tenant's bits.
    assert set(adapt_out) == set(static_out) == set(rerun_out)
    for tid, row in static_out.items():
        if not (np.array_equal(row, adapt_out[tid])
                and np.array_equal(row, rerun_out[tid])):
            raise AssertionError(
                f"controller serving diverged from the static fast path at "
                f"tenant {tid} — refusing to record its timing")

    rows = len(static_out)

    def point(config, wall_s, snap, **extra):
        disp = snap["dispatch"]
        return {
            "config": config, "axis": "controller-drift",
            "rates": list(rates), "seg_duration_s": seg_duration_s,
            "rows": rows, "wall_s": wall_s, "rows_per_s": rows / wall_s,
            "dispatches": disp["dispatches"],
            "dispatch_m_occupancy_mean": disp["m_occupancy_mean"],
            "dispatch_m_fill_mean": disp["m_fill_mean"],
            "bitexact_vs_static": True, **extra,
        }

    ctl = adapt_snap["controller"]
    points = [
        point("drift-static", static_s, static_snap, controller=False),
        point("drift-static-rerun", rerun_s, rerun_snap, controller=False,
              noise_vs_static=rerun_s / static_s),
        point("drift-adaptive", adapt_s, adapt_snap, controller=True,
              holdback_lambda=adaptive_cfg["holdback_lambda"],
              inflight_depth=adaptive_cfg["inflight_depth"],
              speedup_vs_static=static_s / adapt_s,
              controller_updates=ctl["updates"],
              target_rows={k: c["target_rows"]
                           for k, c in ctl["classes"].items()},
              holdback=adapt_snap["holdback"]),
    ]
    return {"rates": list(rates), "seg_duration_s": seg_duration_s,
            "rows": rows, "points": points}


def tracing_overhead(repeats: int = 8, seed: int = 0, rate_hz: float = 4096,
                     duration_s: float = 0.2, d_uniform: int = 256,
                     trace_out=None) -> dict:
    """The observability axis: the full online serving stack at a fixed
    Poisson rate, once with tracing off and once with the ring-buffer
    tracer on.  The traced run's buffer must render to a schema-valid
    Chrome trace whose causal chains cover every served request; full runs
    additionally assert rows/s lags the untraced run by at most
    ``TRACE_OVERHEAD_MAX`` (dry runs skip the timing claim — CI wall
    clocks are noise).  Measured at the serving default d=256: overhead is
    a ratio to real per-request work, so an artificially tiny bucket would
    measure Python call dispatch against itself rather than tracing
    against serving."""
    from repro.core.scheduler import PoissonTrace
    from repro.core.scheduler.coscheduler import (SliceCoScheduler,
                                                  default_row_ladder)
    from repro.core.scheduler.rectangular import select_bucket
    from repro.obs import chrome_trace, validate_chrome_trace
    from repro.serve import CryptoServer, LoadGenerator, ServeConfig

    cos = SliceCoScheduler(merge=True,
                           row_ladder=default_row_ladder(LADDER[-1]))
    cos.precompile([("dilithium", select_bucket(d_uniform))], N_C)
    base = dict(n_c=N_C, max_age_s=0.002, validate=False,
                merge_dispatch=True, row_ladder_max=LADDER[-1],
                async_pipeline=True)

    import gc

    def one(tracing: bool):
        srv = CryptoServer(ServeConfig(**base, tracing=tracing),
                           coscheduler=cos)
        gen = LoadGenerator(
            PoissonTrace(rate_hz=rate_hz, duration_s=duration_s,
                         uniform_degree=d_uniform, seed=seed,
                         mixture=(("dilithium", 1.0),)),
            seed=seed)
        # Collector pauses would land on whichever run happens to cross a
        # gen-0 threshold — freeze them out of the timed region entirely.
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            load = gen.run(srv)
            dt = time.perf_counter() - t0
        finally:
            gc.enable()
        assert not load.rejected, "overhead axis must serve everything"
        return load.n_served, dt, srv

    one(False)
    one(True)                        # warm both paths off the clock
    # Interleave the off/on pairs (process state — heap, allocator, jax
    # caches — drifts monotonically; back-to-back blocks would charge that
    # drift entirely to whichever variant runs second) and take best-of.
    rows_off = rows_on = traced = None
    off_s = on_s = float("inf")
    for _ in range(repeats):
        served, dt, _ = one(False)
        if dt < off_s:
            rows_off, off_s = served, dt
        served, dt, srv = one(True)
        if dt < on_s:
            rows_on, on_s, traced = served, dt, srv
    assert rows_on == rows_off, (rows_on, rows_off)
    stats = validate_chrome_trace(chrome_trace(traced.trace_events()))
    assert stats["requests"] == rows_on, (stats, rows_on)
    if trace_out:
        traced.write_trace(trace_out)
    overhead = on_s / off_s - 1.0
    points = [
        {"config": "trace-off", "axis": "tracing-overhead",
         "rows": rows_off, "wall_s": off_s, "rows_per_s": rows_off / off_s},
        {"config": "trace-on", "axis": "tracing-overhead",
         "rows": rows_on, "wall_s": on_s, "rows_per_s": rows_on / on_s,
         "overhead_vs_off": overhead, "trace_events": stats["events"],
         "trace_dropped": traced.tracer.dropped},
    ]
    return {"rate_hz": rate_hz, "duration_s": duration_s,
            "overhead_vs_off": overhead, "trace_stats": stats,
            "points": points}


def metrics_overhead(repeats: int = 8, seed: int = 0, rate_hz: float = 4096,
                     duration_s: float = 0.2, d_uniform: int = 256,
                     metrics_out=None) -> dict:
    """The continuous-metrics axis: the same fixed-rate serving loop as
    :func:`tracing_overhead`, once with the metrics scrape + alert engine
    off and once on (default 5 ms cadence).  The on run's exposition must
    validate as OpenMetrics; full runs additionally assert rows/s lags the
    off run by at most ``METRICS_OVERHEAD_MAX`` (dry runs skip the timing
    claim — CI wall clocks are noise)."""
    from repro.core.scheduler import PoissonTrace
    from repro.core.scheduler.coscheduler import (SliceCoScheduler,
                                                  default_row_ladder)
    from repro.core.scheduler.rectangular import select_bucket
    from repro.obs import validate_openmetrics
    from repro.serve import CryptoServer, LoadGenerator, ServeConfig

    cos = SliceCoScheduler(merge=True,
                           row_ladder=default_row_ladder(LADDER[-1]))
    cos.precompile([("dilithium", select_bucket(d_uniform))], N_C)
    base = dict(n_c=N_C, max_age_s=0.002, validate=False,
                merge_dispatch=True, row_ladder_max=LADDER[-1],
                async_pipeline=True)

    import gc

    def one(metrics: bool):
        srv = CryptoServer(ServeConfig(**base, metrics=metrics),
                           coscheduler=cos)
        gen = LoadGenerator(
            PoissonTrace(rate_hz=rate_hz, duration_s=duration_s,
                         uniform_degree=d_uniform, seed=seed,
                         mixture=(("dilithium", 1.0),)),
            seed=seed)
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            load = gen.run(srv)
            dt = time.perf_counter() - t0
        finally:
            gc.enable()
        assert not load.rejected, "overhead axis must serve everything"
        return load.n_served, dt, srv

    one(False)
    one(True)                        # warm both paths off the clock
    rows_off = rows_on = scraped = None
    off_s = on_s = float("inf")
    for _ in range(repeats):
        served, dt, _ = one(False)
        if dt < off_s:
            rows_off, off_s = served, dt
        served, dt, srv = one(True)
        if dt < on_s:
            rows_on, on_s, scraped = served, dt, srv
    assert rows_on == rows_off, (rows_on, rows_off)
    stats = validate_openmetrics(scraped.metrics_text())
    assert scraped.metrics.scrapes > 0, "metrics run never scraped"
    if metrics_out:
        scraped.write_metrics(metrics_out)
    overhead = on_s / off_s - 1.0
    points = [
        {"config": "metrics-off", "axis": "metrics-overhead",
         "rows": rows_off, "wall_s": off_s, "rows_per_s": rows_off / off_s},
        {"config": "metrics-on", "axis": "metrics-overhead",
         "rows": rows_on, "wall_s": on_s, "rows_per_s": rows_on / on_s,
         "overhead_vs_off": overhead, "scrapes": scraped.metrics.scrapes,
         "metrics_series": stats["series"],
         "alert_events": scraped.alerts.events_total},
    ]
    return {"rate_hz": rate_hz, "duration_s": duration_s,
            "overhead_vs_off": overhead, "metrics_stats": stats,
            "points": points}


def dry_run(controller: bool = False) -> dict:
    """CI smoke: tiny stream, parity + retrace-guard asserts, no timing
    claims (CI wall clocks are noise)."""
    doc = sweep(n_batches=12, repeats=1)
    full = next(p for p in doc["points"]
                if p["merge"] and p["ladder"] and p["async"])
    assert full["bitexact_vs_baseline"]
    assert all(n <= len(LADDER) for n in full["trace_counts"].values()), doc
    tdoc = tracing_overhead(repeats=1, rate_hz=1024, duration_s=0.01)
    doc["tracing_dry"] = {"trace_stats": tdoc["trace_stats"],
                          "overhead_vs_off": tdoc["overhead_vs_off"]}
    mdoc = metrics_overhead(repeats=1, rate_hz=1024, duration_s=0.01)
    doc["metrics_dry"] = {"metrics_stats": mdoc["metrics_stats"],
                          "overhead_vs_off": mdoc["overhead_vs_off"]}
    if controller:
        cdoc = controller_ladder(rates=(256, 2048), seg_duration_s=0.02,
                                 repeats=1)
        adapt = next(p for p in cdoc["points"]
                     if p["config"] == "drift-adaptive")
        assert adapt["bitexact_vs_static"]
        assert adapt["controller_updates"] > 0, adapt
        doc["controller_dry"] = cdoc
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=200)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--with-bn254", action="store_true",
                    help="mix BN254 batches into the stream (slower)")
    ap.add_argument("--controller", action="store_true",
                    help="also run the closed-loop axis: static vs adaptive "
                         "close policy over the drifting-rate ladder")
    ap.add_argument("--tracing", action="store_true",
                    help="also run the observability axis: rows/s with the "
                         "ring-buffer tracer on vs off (≤ 5% acceptance)")
    ap.add_argument("--trace-out", default=None,
                    help="write the traced run's Perfetto JSON here "
                         "(with --tracing)")
    ap.add_argument("--metrics", action="store_true",
                    help="also run the continuous-metrics axis: rows/s with "
                         "the metrics scrape + alert engine on vs off "
                         "(≤ 5% acceptance)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the scraped run's OpenMetrics exposition "
                         "here (with --metrics)")
    ap.add_argument("--out", default="BENCH_dispatch.json")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny stream + parity/retrace asserts (CI)")
    args = ap.parse_args()

    if args.dry_run:
        doc = dry_run(controller=args.controller)
        full = next(p for p in doc["points"]
                    if p["merge"] and p["ladder"] and p["async"])
        print(f"dry run ok: {len(doc['points'])} configs bit-exact, "
              f"traces bounded by ladder({len(doc['ladder'])}); "
              f"merge+ladder+donate+async speedup {full['speedup']:.2f}x "
              f"(untracked — timing asserts are for full runs)")
        ts = doc["tracing_dry"]["trace_stats"]
        print(f"tracing dry ok: {ts['requests']} requests traced through "
              f"{ts['batches']} batches / {ts['launches']} launches, "
              f"trace schema-valid (overhead untracked in dry runs)")
        ms = doc["metrics_dry"]["metrics_stats"]
        print(f"metrics dry ok: {ms['families']} families / "
              f"{ms['series']} series / {ms['samples']} samples, "
              f"exposition OpenMetrics-valid (overhead untracked in "
              f"dry runs)")
        if args.controller:
            adapt = next(p for p in doc["controller_dry"]["points"]
                         if p["config"] == "drift-adaptive")
            print(f"controller dry ok: adaptive bit-exact vs static, "
                  f"{adapt['controller_updates']} control updates, "
                  f"target rungs {adapt['target_rows']}")
        return

    doc = sweep(args.batches, args.repeats, seed=args.seed,
                with_bn254=args.with_bn254)
    if args.controller:
        cdoc = controller_ladder(repeats=args.repeats, seed=args.seed)
        doc["points"].extend(cdoc["points"])
        doc["controller_ladder"] = {k: v for k, v in cdoc.items()
                                    if k != "points"}
    if args.tracing:
        tdoc = tracing_overhead(repeats=args.repeats, seed=args.seed,
                                trace_out=args.trace_out)
        doc["points"].extend(tdoc["points"])
        doc["tracing_overhead"] = {k: v for k, v in tdoc.items()
                                   if k != "points"}
    if args.metrics:
        mdoc = metrics_overhead(repeats=args.repeats, seed=args.seed,
                                metrics_out=args.metrics_out)
        doc["points"].extend(mdoc["points"])
        doc["metrics_overhead"] = {k: v for k, v in mdoc.items()
                                   if k != "points"}
    record = write_perf_record(
        args.out, "dispatch",
        doc["points"], meta={k: v for k, v in doc.items() if k != "points"})
    for p in doc["points"]:
        ratio = p.get("speedup", p.get("speedup_vs_static",
                                       p.get("noise_vs_static", 1.0)))
        print(f"{p['config']:<28} {p['wall_s']*1e3:8.1f} ms "
              f"{p['rows_per_s']:10.0f} rows/s  {ratio:.2f}x")
    full = next(p for p in doc["points"]
                if p.get("merge") and p.get("ladder") and p.get("async"))
    print(f"\nmerge+async speedup over per-batch: {full['speedup']:.2f}x "
          f"(acceptance floor 1.3x); wrote {args.out}")
    if args.controller:
        adapt = next(p for p in doc["points"]
                     if p["config"] == "drift-adaptive")
        print(f"adaptive vs static on the drifting-rate ladder: "
              f"{adapt['speedup_vs_static']:.2f}x "
              f"(acceptance floor {ADAPTIVE_FLOOR}x)")
        if adapt["speedup_vs_static"] < ADAPTIVE_FLOOR:
            raise AssertionError(
                f"adaptive {adapt['speedup_vs_static']:.2f}x < "
                f"{ADAPTIVE_FLOOR}x acceptance floor on the drifting-rate "
                f"ladder")
    if args.tracing:
        over = doc["tracing_overhead"]["overhead_vs_off"]
        print(f"tracing overhead vs off: {over:+.1%} "
              f"(acceptance ceiling {TRACE_OVERHEAD_MAX:.0%})")
        if over > TRACE_OVERHEAD_MAX:
            raise AssertionError(
                f"tracing overhead {over:+.1%} exceeds the "
                f"{TRACE_OVERHEAD_MAX:.0%} acceptance ceiling")
    if args.metrics:
        over = doc["metrics_overhead"]["overhead_vs_off"]
        print(f"metrics overhead vs off: {over:+.1%} "
              f"(acceptance ceiling {METRICS_OVERHEAD_MAX:.0%})")
        if over > METRICS_OVERHEAD_MAX:
            raise AssertionError(
                f"metrics overhead {over:+.1%} exceeds the "
                f"{METRICS_OVERHEAD_MAX:.0%} acceptance ceiling")
    print(json.dumps(record["env"], sort_keys=True))


if __name__ == "__main__":
    main()
