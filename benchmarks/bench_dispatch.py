"""BENCH_dispatch — dispatch hot-path microbenchmark (fast-path levers).

Measures the Tier-2 dispatch loop itself — the per-launch overhead the
paper's measurement vehicle adds on top of the architectural deficit — over
a grid of fast-path configurations:

* **per-batch** (the pre-fast-path baseline): every stacked batch is its own
  launch, padded to N_c rows, materialised with a blocking host sync before
  the next launch;
* **merge** on/off — M-axis super-batching of same-(workload, d_bucket)
  batches into tall operands;
* **ladder** on/off — row-ladder compile cache (launch heights padded to
  geometric rungs, so XLA traces are bounded by the ladder size);
* **donate** on/off — operand buffers donated to the e2e programs;
* **async** on/off — two-phase launch → copy_to_host_async → gather with one
  launch group kept in flight.

Every configuration is checked **bit-for-bit against the per-batch baseline**
before its timing counts, and the trace counters are asserted against the
ladder bound — throughput claims at unequal correctness are worthless.
Writes a ``BENCH_dispatch.json`` perf record via the shared helper in
:mod:`benchmarks.common`.

  PYTHONPATH=src python benchmarks/bench_dispatch.py [--batches 200]
      [--repeats 3] [--out BENCH_dispatch.json] [--dry-run]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

# repo root, cwd-independent (benchmarks/ run as a script)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import write_perf_record  # noqa: E402

LADDER = (8, 16, 32, 64, 128)
N_C = 8          # baseline pad target (the serve default)


def make_batches(n_batches: int, *, seed: int = 0, d_buckets=(64, 128),
                 with_bn254: bool = False) -> list:
    """Adversarially mixed-height stacked batches: every height in
    [1, N_C] appears, in an order that defeats shape caching without a
    ladder.  Live rows only (mergeable emission)."""
    from repro.core import field as F
    from repro.core.scheduler import TenantRequest
    from repro.core.scheduler.rectangular import StackedBatch, stack_rows
    from repro.core import workloads as WK

    rng = np.random.default_rng(seed)
    batches = []
    for i in range(n_batches):
        workload = ("bn254" if (with_bn254 and i % 5 == 4) else "dilithium")
        d = int(rng.choice(d_buckets)) if workload == "dilithium" else 64
        rows = int(rng.integers(1, N_C + 1))
        reqs = []
        for r in range(rows):
            tid = i * 1000 + r
            if workload == "dilithium":
                coeffs = np.asarray(rng.integers(0, F.DILITHIUM_Q, d,
                                                 dtype=np.uint64), np.uint32)
            else:
                eng = WK.make_engine("bn254", d)
                vals = np.array([int(x) for x in rng.integers(0, 2**31, d)],
                                object)
                coeffs = np.asarray(eng.ingest(vals))
            reqs.append(TenantRequest(tid, workload, d, 0.0, coeffs))
        batches.append(StackedBatch(workload=workload, d_bucket=d,
                                    requests=reqs,
                                    operand=stack_rows(reqs, d)))
    return batches


def _pad_batch(b, n_rows: int):
    """The pre-fast-path batcher behaviour: pad every operand to N_c rows so
    the per-batch path hits one compiled shape per class."""
    from repro.core.scheduler.rectangular import StackedBatch, stack_rows
    return StackedBatch(workload=b.workload, d_bucket=b.d_bucket,
                        requests=b.requests,
                        operand=stack_rows(b.requests, b.d_bucket,
                                           n_rows=n_rows))


def _rows_of(results) -> list:
    return [np.asarray(r.rows[:r.batch.n_c]) for r in results]


def run_baseline(batches, repeats: int):
    """Pre-PR per-batch path: one padded launch + blocking materialise per
    batch, no merge, no ladder, no donation."""
    from repro.core.scheduler.coscheduler import SliceCoScheduler

    cos = SliceCoScheduler(merge=False)
    padded = [_pad_batch(b, N_C) for b in batches]
    for b in padded[: min(8, len(padded))]:          # warm the jit caches
        cos.dispatch(b)
    best, results = None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        results = [cos.dispatch(b) for b in padded]
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return _rows_of(results), best, dict(cos.trace_counts)


def run_fastpath(batches, repeats: int, *, merge: bool, ladder: bool,
                 donate: bool, async_pipeline: bool, chunk: int = 16):
    """The dispatch fast path at one lever setting.  Batches arrive in
    ``chunk``-sized waves (the shape a pump loop hands dispatch_mixed); the
    async variant keeps one wave in flight while the next launches."""
    from repro.core.scheduler.coscheduler import SliceCoScheduler

    cos = SliceCoScheduler(merge=merge,
                           row_ladder=LADDER if ladder else None,
                           donate=donate)
    if ladder:
        programs = sorted({(b.workload, b.d_bucket) for b in batches})
        cos.precompile(programs, N_C)
    chunks = [batches[i:i + chunk] for i in range(0, len(batches), chunk)]
    for c in chunks[:1]:                             # warm remaining shapes
        cos.dispatch_mixed(c)
    best, results = None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        results = []
        if async_pipeline:
            prev = None
            for c in chunks:
                flight = cos.launch_mixed(c)
                if prev is not None:
                    results.extend(cos.gather(prev))
                prev = flight
            results.extend(cos.gather(prev))
        else:
            for c in chunks:
                results.extend(cos.dispatch_mixed(c))
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return _rows_of(results), best, dict(cos.trace_counts)


def sweep(n_batches: int = 200, repeats: int = 3, seed: int = 0,
          with_bn254: bool = False) -> dict:
    batches = make_batches(n_batches, seed=seed, with_bn254=with_bn254)
    live_rows = sum(b.n_c for b in batches)
    base_rows, base_s, base_traces = run_baseline(batches, repeats)

    points = [{
        "config": "per-batch", "merge": False, "ladder": False,
        "donate": False, "async": False, "wall_s": base_s,
        "rows_per_s": live_rows / base_s, "speedup": 1.0,
        "trace_counts": {f"{w}/{d}": n for (w, d), n in base_traces.items()},
        "bitexact_vs_baseline": True,
    }]
    grid = [
        dict(merge=True, ladder=False, donate=False, async_pipeline=False),
        dict(merge=False, ladder=True, donate=False, async_pipeline=False),
        dict(merge=True, ladder=True, donate=False, async_pipeline=False),
        dict(merge=True, ladder=True, donate=True, async_pipeline=False),
        dict(merge=True, ladder=True, donate=True, async_pipeline=True),
    ]
    for g in grid:
        rows, dt, traces = run_fastpath(batches, repeats, **g)
        exact = all(np.array_equal(a, b) for a, b in zip(rows, base_rows))
        if not exact:
            raise AssertionError(f"fast path {g} diverged from the per-batch "
                                 f"baseline — refusing to record its timing")
        if g["ladder"]:
            over = {k: n for k, n in traces.items() if n > len(LADDER)}
            assert not over, f"row ladder failed to bound traces: {over}"
        points.append({
            "config": "+".join(k for k, v in g.items() if v) or "plain",
            "merge": g["merge"], "ladder": g["ladder"],
            "donate": g["donate"], "async": g["async_pipeline"],
            "wall_s": dt, "rows_per_s": live_rows / dt,
            "speedup": base_s / dt,
            "trace_counts": {f"{w}/{d}": n for (w, d), n in traces.items()},
            "bitexact_vs_baseline": True,
        })
    return {"batches": n_batches, "live_rows": live_rows,
            "ladder": list(LADDER), "n_c": N_C, "points": points}


def dry_run() -> dict:
    """CI smoke: tiny stream, parity + retrace-guard asserts, no timing
    claims (CI wall clocks are noise)."""
    doc = sweep(n_batches=12, repeats=1)
    full = next(p for p in doc["points"]
                if p["merge"] and p["ladder"] and p["async"])
    assert full["bitexact_vs_baseline"]
    assert all(n <= len(LADDER) for n in full["trace_counts"].values()), doc
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=200)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--with-bn254", action="store_true",
                    help="mix BN254 batches into the stream (slower)")
    ap.add_argument("--out", default="BENCH_dispatch.json")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny stream + parity/retrace asserts (CI)")
    args = ap.parse_args()

    if args.dry_run:
        doc = dry_run()
        full = doc["points"][-1]
        print(f"dry run ok: {len(doc['points'])} configs bit-exact, "
              f"traces bounded by ladder({len(doc['ladder'])}); "
              f"merge+ladder+donate+async speedup {full['speedup']:.2f}x "
              f"(untracked — timing asserts are for full runs)")
        return

    doc = sweep(args.batches, args.repeats, seed=args.seed,
                with_bn254=args.with_bn254)
    record = write_perf_record(
        args.out, "dispatch",
        doc["points"], meta={k: v for k, v in doc.items() if k != "points"})
    for p in doc["points"]:
        print(f"{p['config']:<28} {p['wall_s']*1e3:8.1f} ms "
              f"{p['rows_per_s']:10.0f} rows/s  {p['speedup']:.2f}x")
    full = doc["points"][-1]
    print(f"\nmerge+async speedup over per-batch: {full['speedup']:.2f}x "
          f"(acceptance floor 1.3x); wrote {args.out}")
    print(json.dumps(record["env"], sort_keys=True))


if __name__ == "__main__":
    main()
