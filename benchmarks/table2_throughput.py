"""Table 2 — cost-normalised throughput, Π diagnostics, κ amortisation and the
headline deficit factorisation.

This-hardware numbers are CPU measurements of our pipeline; the GPU/TPU
columns are the paper's recorded constants; the *derived* quantities
(ops/$, deficits, Π, κ, arithmetic-vs-spatial factorisation) reproduce the
paper's arithmetic over both.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import PAPER, csv_row, time_fn
from repro.core import validator as V
from repro.core import workloads as WK

N_C = 8
D = 256


def _rand_dil(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.asarray(
        rng.integers(0, 8380417, (n, d), dtype=np.uint64), np.uint32))


def _rand_bn(eng, n, d, seed=0):
    rng = np.random.default_rng(seed)
    res = np.zeros((n, d, eng.n_channels), np.uint32)
    for ci, m in enumerate(eng.chain.moduli):
        res[..., ci] = rng.integers(0, m, (n, d), dtype=np.uint64).astype(np.uint32)
    return jnp.asarray(res)


def run() -> list[str]:
    out = []

    # --- our measured throughput (this hardware: CPU) -------------------------
    dil = WK.make_engine("dilithium", D)
    a_d = _rand_dil(N_C, D)
    e2e_d = jax.jit(dil.e2e)
    t = time_fn(e2e_d, a_d)
    dil_ops = N_C / t["median_s"]
    out.append(csv_row("table2.dilithium_e2e_cpu", t["median_s"] * 1e6 / N_C,
                       f"ops_per_s={dil_ops:.0f} batch={N_C} d={D}"))

    bn = WK.make_engine("bn254", D)
    a_b = _rand_bn(bn, N_C, D)
    e2e_b = jax.jit(bn.e2e)
    t_total = time_fn(e2e_b, a_b)
    bn_ops = N_C / t_total["median_s"]
    out.append(csv_row("table2.bn254_e2e_cpu", t_total["median_s"] * 1e6 / N_C,
                       f"ops_per_s={bn_ops:.0f} batch={N_C} d={D}"))

    ev_b = jax.jit(bn.evaluate)
    t_gemm = time_fn(ev_b, a_b)
    y = ev_b(a_b)
    red_b = jax.jit(bn.reduce)
    t_red = time_fn(red_b, y)
    pi = t_total["median_s"] / t_gemm["median_s"]
    out.append(csv_row("table2.bn254_pointwise_cpu",
                       t_gemm["median_s"] * 1e6 / N_C,
                       f"ops_per_s={N_C/t_gemm['median_s']:.0f}"))
    out.append(csv_row("table2.pi_vpu_penalty", t_red["median_s"] * 1e6 / N_C,
                       f"PI_ours={pi:.1f} PI_paper=17.2 "
                       f"paper_check={PAPER['tpu_v4_bn254_ops']:.0f}*"
                       f"{1/PAPER['tpu_v4_pointwise_ops']*1e6:.1f}us"))

    # int32-native sensitivity (v5p path)
    bn_i32 = WK.make_engine("bn254", D, accum="int32_native")
    t_i32 = time_fn(jax.jit(bn_i32.e2e), a_b)
    gain = t_total["median_s"] / t_i32["median_s"]
    paper_gain = PAPER["tpu_v5p_bn254_int32_ops"] / PAPER["tpu_v5p_bn254_ops"]
    out.append(csv_row("table2.bn254_int32_native_cpu",
                       t_i32["median_s"] * 1e6 / N_C,
                       f"speedup_ours={gain:.2f} paper=1.183"))

    # --- κ: static fold census, eager vs lazy (MORPH discipline) --------------
    # matched staging windows (d_max=171 both) so only the reduction
    # discipline differs — the paper's 1764-vs-392 node-count experiment.
    from repro.core import field as FLD
    from repro.core import limb_gemm as G
    from repro.core import ntt as NTT
    d_k = 1024
    w_k = NTT.ntt_matrix(d_k, FLD.DILITHIUM_Q, negacyclic=True)
    plan_k = G.make_channel_plan(w_k, FLD.DILITHIUM_Q, data_limbs=3,
                                 tw_limbs=3, accum="int32_native")
    a_k = _rand_dil(2, d_k)
    c_e = V.fold_census(
        lambda x: G.staged_transform(x, plan_k, reduction="eager",
                                     d_max=171)[0], a_k)
    c_l = V.fold_census(
        lambda x: G.staged_transform(x, plan_k, reduction="lazy",
                                     d_max=171)[0], a_k)
    kappa = (c_e["n_fold_scopes"] / max(c_l["n_fold_scopes"], 1))
    out.append(csv_row("table2.kappa_lazy_amortisation", 0.0,
                       f"eager_folds={c_e['n_fold_scopes']} "
                       f"lazy_folds={c_l['n_fold_scopes']} "
                       f"kappa_ours={kappa:.1f} (=n_passes at d=1024) "
                       f"kappa_paper=4.5"))

    # --- recorded-constant cost table + deficits (paper reproduction) ---------
    rows = {
        "a100_bn254": (PAPER["a100_cuzk_bn254_ops"], PAPER["a100_price"]),
        "v4_bn254": (PAPER["tpu_v4_bn254_ops"],
                     PAPER["tpu_v4_price_chip"] * PAPER["tpu_v4_chips"]),
        "v5e_bn254": (PAPER["tpu_v5e_bn254_ops"],
                      PAPER["tpu_v5e_price_chip"] * PAPER["tpu_v5e_chips"]),
        "v5p_bn254": (PAPER["tpu_v5p_bn254_ops"],
                      PAPER["tpu_v5p_price_chip"] * PAPER["tpu_v5p_chips"]),
        "v5p_bn254_int32": (PAPER["tpu_v5p_bn254_int32_ops"],
                            PAPER["tpu_v5p_price_chip"] * PAPER["tpu_v5p_chips"]),
        "a100_dil": (PAPER["a100_cudilithium_ntt_ops"], PAPER["a100_price"]),
        "v4_dil": (PAPER["tpu_v4_dil_ops"],
                   PAPER["tpu_v4_price_chip"] * PAPER["tpu_v4_chips"]),
        "v5p_dil": (PAPER["tpu_v5p_dil_ops"],
                    PAPER["tpu_v5p_price_chip"] * PAPER["tpu_v5p_chips"]),
    }
    eff = {k: ops / price for k, (ops, price) in rows.items()}
    deficits = {
        "v4_bn254": eff["a100_bn254"] / eff["v4_bn254"],        # paper ~6908
        "v5p_bn254": eff["a100_bn254"] / eff["v5p_bn254"],      # paper ~5558
        "v5p_bn254_int32": eff["a100_bn254"] / eff["v5p_bn254_int32"],  # ~4693
        "v4_dil": eff["a100_dil"] / eff["v4_dil"],              # paper ~582
        "v5p_dil": eff["a100_dil"] / eff["v5p_dil"],            # paper ~508
    }
    for k, v in deficits.items():
        out.append(csv_row(f"table2.deficit_{k}", 0.0, f"deficit={v:.0f}x"))

    # analytical factorisation: arithmetic × spatial(5.19) ≈ headline
    spatial = 5.19
    arith_v4 = deficits["v4_bn254"] / spatial     # paper ~1331
    arith_v5p = deficits["v5p_bn254"] / spatial   # paper ~1071
    out.append(csv_row(
        "table2.factorisation", 0.0,
        f"arith_v4={arith_v4:.0f}x arith_v5p={arith_v5p:.0f}x spatial=5.19x "
        f"recompose_v4={arith_v4*spatial:.0f} recompose_v5p={arith_v5p*spatial:.0f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
