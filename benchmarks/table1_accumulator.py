"""Table 1 — empirical accumulator precision limits (fp32-mantissa vs int32)."""
from __future__ import annotations

from repro.core import accumulator as ACC
from benchmarks.common import csv_row


def run() -> list[str]:
    rows = ACC.table1_rows()
    paper_v4 = [True, True, True, False, False, False, False]
    paper_v5 = [True] * 7
    out = []
    v4 = rows["tpu_v4_fp32_mantissa"]
    v5 = rows["tpu_v5_int32_native"]
    out.append(csv_row(
        "table1.fp32_mantissa_model", 0.0,
        f"probes={''.join('T' if x else 'F' for x in v4)} "
        f"matches_paper_v4={v4 == paper_v4}"))
    out.append(csv_row(
        "table1.int32_native_model", 0.0,
        f"probes={''.join('T' if x else 'F' for x in v5)} "
        f"matches_paper_v5={v5 == paper_v5}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
