"""Table 3 — temporal decomposition of a BN254 invocation (phase fractions)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core import workloads as WK
from benchmarks.table2_throughput import _rand_bn, N_C, D


def run() -> list[str]:
    eng = WK.make_engine("bn254", D)
    a = _rand_bn(eng, N_C, D)

    e2e = jax.jit(eng.e2e)
    ev = jax.jit(eng.evaluate)
    red = jax.jit(eng.reduce)
    y = ev(a)

    t_total = time_fn(e2e, a)["median_s"]
    t_gemm = time_fn(ev, a)["median_s"]
    t_red = time_fn(red, y)["median_s"]
    t_dispatch = max(t_total - t_gemm - t_red, 0.0)

    # our evaluate() includes the per-pass folds; split out the pure matmul
    # share via the pointwise-only diagonals
    from repro.core import limb_gemm as G
    plan = eng.plans[0]
    f_tile = jnp.asarray(plan.fused_operand[: plan.d_max * 4])
    pointwise = jax.jit(lambda x: G.tile_diagonals(
        x[:, : plan.d_max], None, f_tile, plan))
    t_mxu_pass = time_fn(pointwise, a[..., 0])["median_s"]
    t_mxu = t_mxu_pass * eng.n_passes * eng.n_channels
    t_fold = max(t_gemm - t_mxu, 0.0)

    rows = {
        "vpu_montgomery_reduction": t_red + t_fold,
        "mxu_systolic": t_mxu,
        "dispatch_gap": t_dispatch,
    }
    total = sum(rows.values())
    out = []
    for k, v in rows.items():
        out.append(csv_row(f"table3.{k}", v * 1e6 / N_C,
                           f"fraction={100*v/total:.2f}% "
                           f"paper_v4_vpu_fraction=98.3%"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
