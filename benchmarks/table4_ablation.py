"""Table 4 — component ablation: sequential-fused vs warm-cache time-sliced vs
Aegis batched, for BN254 and Dilithium."""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import csv_row, time_fn
from repro.core import workloads as WK
from benchmarks.table2_throughput import _rand_bn, _rand_dil, D


def _bench(engine, a_batched, n_c):
    e2e = jax.jit(engine.e2e)
    # sequential-fused: one tenant per fused trace (batch=1)
    t_seq = time_fn(e2e, a_batched[:1])
    seq_ops = 1 / t_seq["median_s"]
    # warm-cache time-sliced: same compiled program dispatched per tenant
    def time_sliced():
        outs = [e2e(a_batched[i:i + 1]) for i in range(n_c)]
        return outs
    t_slice = time_fn(time_sliced)
    slice_ops = n_c / t_slice["median_s"]
    # Aegis batched
    t_batch = time_fn(e2e, a_batched)
    batch_ops = n_c / t_batch["median_s"]
    return seq_ops, slice_ops, batch_ops, t_seq, t_slice, t_batch


def run() -> list[str]:
    out = []
    n_c = 32
    dil = WK.make_engine("dilithium", D)
    seq, sl, bat, t_seq, t_slice, t_batch = _bench(dil, _rand_dil(n_c, D), n_c)
    out.append(csv_row("table4.dil_sequential", 1e6 / seq,
                       f"ops_per_s={seq:.0f} p99={t_seq['p99_s']*1e3:.1f}ms"))
    out.append(csv_row("table4.dil_time_sliced", 1e6 / sl,
                       f"ops_per_s={sl:.0f} speedup={sl/seq:.2f}x"))
    out.append(csv_row("table4.dil_batched", 1e6 / bat,
                       f"ops_per_s={bat:.0f} speedup={bat/sl:.1f}x "
                       f"paper_speedup=32.5x p99={t_batch['p99_s']*1e3:.1f}ms"))

    n_c = 8
    bn = WK.make_engine("bn254", D)
    seq, sl, bat, t_seq, t_slice, t_batch = _bench(bn, _rand_bn(bn, n_c, D), n_c)
    out.append(csv_row("table4.bn254_sequential", 1e6 / seq,
                       f"ops_per_s={seq:.1f} p99={t_seq['p99_s']*1e3:.1f}ms"))
    out.append(csv_row("table4.bn254_time_sliced", 1e6 / sl,
                       f"ops_per_s={sl:.1f} speedup={sl/seq:.2f}x paper=0.98x"))
    out.append(csv_row("table4.bn254_batched", 1e6 / bat,
                       f"ops_per_s={bat:.1f} speedup={bat/sl:.1f}x "
                       f"paper_speedup=29.1x p99={t_batch['p99_s']*1e3:.1f}ms"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
