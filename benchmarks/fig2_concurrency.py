"""Fig 2 — throughput scaling under concurrency (d=256 Dilithium)."""
from __future__ import annotations

import jax

from benchmarks.common import csv_row, time_fn
from repro.core import workloads as WK
from benchmarks.table2_throughput import _rand_dil


def run() -> list[str]:
    eng = WK.make_engine("dilithium", 256)
    e2e = jax.jit(eng.e2e)
    out = []
    base = None
    for n_s in (1, 2, 4, 8, 16, 32, 64, 128):
        a = _rand_dil(n_s, 256, seed=n_s)
        t = time_fn(e2e, a, warmup=1, repeats=3)["median_s"]
        ops = n_s / t
        base = base or ops
        out.append(csv_row(f"fig2.concurrency_ns{n_s}", t * 1e6 / n_s,
                           f"ops_per_s={ops:.0f} scaling={ops/base:.1f}x"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
