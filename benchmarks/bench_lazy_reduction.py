"""κ-sweep of the deferred Montgomery reduction mode (paper §7.2.1).

The paper projects that strict multi-tenant separation (eager per-pass
folding) costs a **5.19× spatial collapse** on the VPU-bound reduction phase,
and that relaxing it — deferring the fold across κ staging passes — recovers
the spatial cycles proportionally (κ-amortisation).  This bench measures that
lever on real compiled programs:

* static structure: fold sites per op from the HLO census (V6-consistent),
  swept over κ ∈ {1, 2, 4, …, κ_max};
* modeled spatial recovery: reduction-stall cycles ∝ fold count, so
  recovery(κ) = eager_folds / lazy_folds(κ) → saturates at n_passes;
* measured wall time of the jitted transform per κ (CPU here; the *shape*
  of the curve — not absolute µs — is the reproducible object);
* trace-time κ_max guard: the sweep proves κ_max traces and κ_max + 1
  raises, so the amortisation claim is bounded by a machine-checked window.

``--dry-run`` keeps CI cheap: tiny degree, no timing claims, but the full
κ-window tracing, census, and guard still execute.

Usage::

    python benchmarks/bench_lazy_reduction.py [--d 1024] [--n 8]
        [--d-tile 171] [--kappas 1,2,4,8] [--dry-run] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import math
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, ".")  # repo root (benchmarks/ run as a script)

from benchmarks.common import PAPER, csv_row, time_fn  # noqa: E402
from repro.core import accumulator as ACC              # noqa: E402
from repro.core import field as F                      # noqa: E402
from repro.core import limb_gemm as G                  # noqa: E402
from repro.core import ntt as NTT                      # noqa: E402
from repro.core import validator as V                  # noqa: E402


def sweep(*, d: int = 1024, n: int = 8, d_tile: int = 171,
          kappas: list[int] | None = None, dry_run: bool = False) -> dict:
    """Run the κ sweep; returns the result dict (also used by tests/CI)."""
    m = F.DILITHIUM_Q
    w = NTT.ntt_matrix(d, m, negacyclic=(m - 1) % (2 * d) == 0)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3,
                               accum="int32_native")
    c = min(plan.data_limbs, plan.tw_limbs)
    n_passes = math.ceil(d / d_tile)
    k_max = ACC.kappa_max("int32_native", min(d_tile, d), c)
    if kappas is None:
        kappas = []
        k = 1
        while k < min(k_max, n_passes):
            kappas.append(k)
            k *= 2
        kappas.append(min(k_max, n_passes))

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, m, (n, d), dtype=np.uint64).astype(np.uint32))

    def eager_fn(x):
        return G.staged_transform(x, plan, reduction="eager", d_max=d_tile)[0]

    def lazy_fn(kappa):
        def fn(x):
            return G.staged_transform(x, plan, reduction="lazy",
                                      kappa=kappa, d_max=d_tile)[0]
        return fn

    census_e = V.fold_census(eager_fn, a)
    eager_folds = census_e["n_fold_scopes"]
    timing_e = None if dry_run else time_fn(jax.jit(eager_fn), a, repeats=3)
    ref = np.asarray(eager_fn(a))

    rows = []
    for kappa in kappas:
        fn = lazy_fn(kappa)
        census = V.fold_census(fn, a)
        folds = census["n_fold_scopes"]
        expected = math.ceil(n_passes / kappa)
        assert folds == expected, (kappa, folds, expected)
        recovery = eager_folds / folds
        timing = None if dry_run else time_fn(jax.jit(fn), a, repeats=3)
        # exactness spot check (the property suite is the real proof)
        np.testing.assert_array_equal(ref, np.asarray(fn(a)))
        rows.append({
            "kappa": kappa, "lazy_folds": folds,
            "fold_recovery": recovery,
            "median_s": timing["median_s"] if timing else None,
            "speedup": (timing_e["median_s"] / timing["median_s"])
                       if timing else None,
        })

    # the κ_max boundary is machine-checked, not assumed
    guard_ok = False
    try:
        G.staged_transform(a, plan, reduction="lazy", kappa=k_max + 1,
                           d_max=d_tile)
    except ValueError:
        guard_ok = True

    return {
        "d": d, "n": n, "d_tile": d_tile, "n_passes": n_passes,
        "kappa_max": k_max, "eager_folds": eager_folds,
        "eager_median_s": timing_e["median_s"] if timing_e else None,
        "kappa_max_guard_raises": guard_ok,
        "paper_spatial_collapse": PAPER["kappa_spatial_collapse"],
        "rows": rows,
        "dry_run": dry_run,
    }


def run(*, dry_run: bool = False, **kw):
    """CSV-row generator (benchmarks/run.py convention)."""
    res = sweep(dry_run=dry_run, **kw)
    out = []
    for row in res["rows"]:
        us = (row["median_s"] or 0.0) * 1e6 / res["n"]
        speed = f"{row['speedup']:.2f}" if row["speedup"] else "n/a"
        out.append(csv_row(
            f"lazy_reduction.kappa_{row['kappa']}", us,
            f"folds={row['lazy_folds']} recovery={row['fold_recovery']:.2f}x "
            f"speedup={speed}"))
    best = max(r["fold_recovery"] for r in res["rows"])
    out.append(csv_row(
        "lazy_reduction.summary", 0.0,
        f"n_passes={res['n_passes']} kappa_max={res['kappa_max']} "
        f"best_recovery={best:.2f}x paper_projection="
        f"{res['paper_spatial_collapse']}x guard_ok="
        f"{res['kappa_max_guard_raises']}"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=1024)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--d-tile", type=int, default=171,
                    help="staging tile (171 = the paper's fp32-era Dilithium "
                         "pass width, kept under int32 so κ can defer)")
    ap.add_argument("--kappas", default=None,
                    help="comma list, e.g. 1,2,4 (default: powers of two to "
                         "min(kappa_max, n_passes))")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny smoke sweep: trace + census + guard, no timing")
    ap.add_argument("--json", default=None, help="dump the result dict here")
    args = ap.parse_args()

    kw = dict(d=args.d, n=args.n, d_tile=args.d_tile,
              kappas=[int(k) for k in args.kappas.split(",")]
              if args.kappas else None)
    if args.dry_run:
        kw.update(d=min(args.d, 128), n=min(args.n, 2), d_tile=min(args.d_tile, 32))
    res = sweep(dry_run=args.dry_run, **kw)

    print(f"# deferred Montgomery reduction sweep: d={res['d']} "
          f"d_tile={res['d_tile']} n_passes={res['n_passes']} "
          f"kappa_max={res['kappa_max']}")
    print(f"# eager baseline: {res['eager_folds']} folds"
          + (f", {res['eager_median_s']*1e3:.2f} ms" if res["eager_median_s"]
             else " (dry run: no timing)"))
    for row in res["rows"]:
        line = (f"kappa={row['kappa']:>4}  folds={row['lazy_folds']:>3}  "
                f"fold_recovery={row['fold_recovery']:5.2f}x")
        if row["median_s"] is not None:
            line += (f"  median={row['median_s']*1e3:8.3f} ms"
                     f"  speedup={row['speedup']:.2f}x")
        print(line)
    best = max(r["fold_recovery"] for r in res["rows"])
    print(f"# spatial-cycle recovery saturates at {best:.2f}x "
          f"(paper §7.2.1 projects {res['paper_spatial_collapse']}x collapse "
          f"for the eager discipline; recovery is bounded by n_passes="
          f"{res['n_passes']} at this degree)")
    print(f"# kappa_max+1 guard raised: {res['kappa_max_guard_raises']}")
    assert res["kappa_max_guard_raises"], "κ_max boundary must be enforced"
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
        print(f"# json → {args.json}")


if __name__ == "__main__":
    main()
