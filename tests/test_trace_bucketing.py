"""PoissonTrace synthesis and degree-bucket boundary behaviour."""
import numpy as np

from repro.core.scheduler import PoissonTrace
from repro.core.scheduler.rectangular import bucket_degree, bucket_pow2


# --- PoissonTrace --------------------------------------------------------------

def test_trace_seed_determinism():
    a = PoissonTrace(rate_hz=1024, duration_s=0.5, seed=42).generate()
    b = PoissonTrace(rate_hz=1024, duration_s=0.5, seed=42).generate()
    assert len(a) == len(b) > 0
    for ra, rb in zip(a, b):
        assert (ra.tenant_id, ra.workload, ra.degree, ra.arrival_time) == \
               (rb.tenant_id, rb.workload, rb.degree, rb.arrival_time)
    c = PoissonTrace(rate_hz=1024, duration_s=0.5, seed=43).generate()
    assert [r.degree for r in c] != [r.degree for r in a]


def test_trace_arrivals_sorted_within_horizon():
    trace = PoissonTrace(rate_hz=2048, duration_s=0.25, seed=1).generate()
    times = [r.arrival_time for r in trace]
    assert times == sorted(times)
    assert 0.0 <= times[0] and times[-1] <= 0.25
    assert all(64 <= r.degree <= 512 for r in trace)


def test_trace_mixture_proportions():
    trace = PoissonTrace(rate_hz=4096, duration_s=1.0, seed=3,
                         mixture=(("dilithium", 0.8), ("bn254", 0.2))).generate()
    frac = np.mean([r.workload == "dilithium" for r in trace])
    assert 0.75 < frac < 0.85
    # unnormalised weights are normalised, not rejected
    trace2 = PoissonTrace(rate_hz=1024, duration_s=0.5, seed=3,
                          mixture=(("dilithium", 3.0), ("bn254", 1.0))).generate()
    frac2 = np.mean([r.workload == "dilithium" for r in trace2])
    assert 0.65 < frac2 < 0.85


def test_trace_uniform_degree_mode():
    trace = PoissonTrace(rate_hz=1024, duration_s=0.5, seed=0,
                         uniform_degree=256).generate()
    assert trace and all(r.degree == 256 for r in trace)


# --- granular buckets (paper Table-5 convention) --------------------------------

def test_bucket_degree_boundaries():
    assert bucket_degree(1) == 64             # floor bucket
    assert bucket_degree(63) == 64
    assert bucket_degree(64) == 64            # exact multiple stays put
    assert bucket_degree(65) == 128
    assert bucket_degree(128) == 128
    assert bucket_degree(192) == 192          # any multiple, not only pow2
    assert bucket_degree(100_000) == 100_032  # large d: next multiple of 64
    assert bucket_degree(33, granularity=32) == 64
    assert bucket_degree(32, granularity=32) == 32


# --- power-of-two buckets (execution path) --------------------------------------

def test_bucket_pow2_boundaries():
    assert bucket_pow2(1) == 64               # floor bucket
    assert bucket_pow2(64) == 64
    assert bucket_pow2(65) == 128             # crossing a boundary doubles
    assert bucket_pow2(4096) == 4096          # exact power stays put
    assert bucket_pow2(4097) == 8192
    assert bucket_pow2(1_000_000) == 1 << 20
    assert bucket_pow2(100, floor=256) == 256


def test_pow2_buckets_are_ntt_transform_sizes():
    # every bucket must divide the 2-adic part of Q−1 for Dilithium (2^13)
    for d in (1, 64, 100, 500, 512):
        b = bucket_pow2(d)
        assert b >= d and (b & (b - 1)) == 0
