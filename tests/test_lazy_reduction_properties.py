"""Property harness for the deferred (κ-amortised) Montgomery reduction mode.

Proves the paper's §7.2.1 lever end-to-end:

* lazy κ-window accumulation is **bit-for-bit** equal to eager per-pass
  folding for random polynomials, moduli drawn from :mod:`repro.core.primes`,
  and every κ in [1, κ_max];
* the κ_max overflow boundary is sharp — κ_max traces, κ_max + 1 raises —
  including under adversarial worst-case operands;
* the HLO validator accepts exactly-one-fold-per-window lazy programs and
  rejects programs that fold more than once per window or fold eagerly under
  a lazy label.

Runs under real hypothesis when installed (CI pins a seed via
``--hypothesis-seed``) and under the deterministic stub in
``tests/conftest.py`` otherwise.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import accumulator as ACC
from repro.core import field as F
from repro.core import limb_gemm as G
from repro.core import montgomery as MONT
from repro.core import primes as P
from repro.core import validator as V
from repro.core import workloads as WK

RNG = np.random.default_rng(42)

# Moduli pool: the Dilithium prime + NTT-friendly 31-bit primes from the ERNS
# generator — 4-limb staging; plus small-2-adicity 23-bit primes — 3-limb.
MODULI_4LIMB = P.ntt_friendly_primes(3, two_adicity=8, max_bits=31)
MODULI_3LIMB = (F.DILITHIUM_Q,) + P.ntt_friendly_primes(2, two_adicity=6,
                                                        max_bits=23)


def _plan_for(m: int, d: int, limbs: int):
    w = np.asarray(RNG.integers(0, m, (d, d), dtype=np.uint64), np.uint32)
    return G.make_channel_plan(w, m, data_limbs=limbs, tw_limbs=limbs,
                               accum="int32_native")


def _rand_rows(m: int, d: int, n: int = 2) -> np.ndarray:
    return np.asarray(RNG.integers(0, m, (n, d), dtype=np.uint64), np.uint32)


# --- lazy == eager, bit for bit, over the whole κ range -----------------------


@settings(max_examples=12, deadline=30_000)
@given(st.integers(0, len(MODULI_3LIMB) + len(MODULI_4LIMB) - 1),
       st.integers(2, 6),      # passes
       st.integers(0, 10_000)  # κ selector, mapped into [1, κ_max]
       )
def test_lazy_equals_eager_bitforbit(mod_idx, n_passes, kappa_sel):
    pool = list(MODULI_3LIMB) + list(MODULI_4LIMB)
    m = pool[mod_idx]
    limbs = 3 if m in MODULI_3LIMB else 4
    d_tile = 8
    d = d_tile * n_passes
    plan = _plan_for(m, d, limbs)
    k_max = ACC.kappa_max("int32_native", d_tile, limbs)
    assert k_max >= n_passes  # tiny tiles: the whole sweep is in-window
    kappa = 1 + kappa_sel % min(k_max, n_passes + 2)
    a = jnp.asarray(_rand_rows(m, d))
    eager, st_e = G.staged_transform(a, plan, reduction="eager", d_max=d_tile)
    lazy, st_l = G.staged_transform(a, plan, reduction="lazy", d_max=d_tile,
                                    kappa=kappa)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(lazy))
    assert st_e["n_folds"] == n_passes
    assert st_l["n_folds"] == -(-n_passes // kappa)  # ⌈passes/κ⌉ windows


@settings(max_examples=8, deadline=30_000)
@given(st.integers(0, len(MODULI_3LIMB) - 1), st.integers(2, 4))
def test_whole_transform_window_and_scan_agree(mod_idx, n_passes):
    """κ=None (single window) and the scan form match eager exactly."""
    m = MODULI_3LIMB[mod_idx]
    d_tile = 8
    d = d_tile * n_passes
    plan = _plan_for(m, d, 3)
    a = jnp.asarray(_rand_rows(m, d))
    eager, _ = G.staged_transform(a, plan, reduction="eager", d_max=d_tile)
    lazy, st_l = G.staged_transform(a, plan, reduction="lazy", d_max=d_tile)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(lazy))
    assert st_l["n_folds"] == 1
    y_scan = G.staged_transform_scan(
        a, jnp.asarray(plan.w_planes), modulus=m, data_limbs=3,
        accum="int32_native", d_max=d_tile, reduction="lazy", kappa=2)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(y_scan))


# --- the κ_max overflow boundary ----------------------------------------------


@pytest.mark.parametrize("limbs,m", [(3, F.DILITHIUM_Q), (4, MODULI_4LIMB[0])])
def test_kappa_boundary_pass_and_raise(limbs, m):
    """κ_max traces and stays exact on adversarial worst-case inputs;
    κ_max + 1 raises at trace time (the analytic overflow assert)."""
    d_tile = 16
    k_max = ACC.kappa_max("int32_native", d_tile, limbs)
    n_passes = min(k_max, 3)
    d = d_tile * max(n_passes, 2)
    plan = _plan_for(m, d, limbs)
    # adversarial rows: every coefficient at the field ceiling maximises the
    # limb magnitudes feeding the unreduced accumulator
    worst = np.full((2, d), m - 1, np.uint32)
    eager, _ = G.staged_transform(jnp.asarray(worst), plan,
                                  reduction="eager", d_max=d_tile)
    lazy, _ = G.staged_transform(jnp.asarray(worst), plan, reduction="lazy",
                                 d_max=d_tile, kappa=n_passes)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(lazy))
    with pytest.raises(ValueError, match="kappa_max"):
        G.staged_transform(jnp.asarray(worst), plan, reduction="lazy",
                           d_max=d_tile, kappa=k_max + 1)


def test_fp32_mantissa_kappa_max_is_one_at_full_tile():
    """The paper's point: at the fp32 staging ceiling the mantissa window
    admits no deferral at all — κ_max == 1 — so multi-pass lazy raises."""
    for la, lw in ((3, 3), (4, 4)):
        d_max = G.staging_d_max(la, lw, "fp32_mantissa")
        assert ACC.kappa_max("fp32_mantissa", d_max, min(la, lw)) == 1
    m, d = F.DILITHIUM_Q, 512
    plan = G.make_channel_plan(
        np.asarray(RNG.integers(0, m, (d, d), dtype=np.uint64), np.uint32),
        m, data_limbs=3, tw_limbs=3)
    with pytest.raises(ValueError):
        G.staged_transform(jnp.zeros((1, d), jnp.uint32), plan,
                           reduction="lazy")


def test_oversized_tile_rejected_on_every_path():
    """A d_tile above the discipline's per-pass ceiling would silently round
    under fp32 — eager and lazy, unrolled/traced/scan, and engine
    construction must all refuse it."""
    m, d = F.DILITHIUM_Q, 512
    plan = G.make_channel_plan(
        np.asarray(RNG.integers(0, m, (d, d), dtype=np.uint64), np.uint32),
        m, data_limbs=3, tw_limbs=3)   # fp32: ceiling 171
    a = jnp.zeros((1, d), jnp.uint32)
    w = jnp.asarray(plan.w_planes)
    with pytest.raises(ValueError, match="per-pass ceiling"):
        G.staged_transform(a, plan, reduction="eager", d_max=512)
    with pytest.raises(ValueError, match="per-pass ceiling"):
        G.staged_transform_traced(a, w, modulus=m, data_limbs=3, d_max=512)
    with pytest.raises(ValueError, match="per-pass ceiling"):
        G.staged_transform_scan(a, w, modulus=m, data_limbs=3, d_max=512)
    with pytest.raises(ValueError, match="per-pass ceiling"):
        WK.DilithiumEngine(512, d_tile=512)


def test_eager_with_kappa_rejected_on_every_variant():
    """kappa only means something under lazy folding; the unrolled, traced,
    and scan forms all refuse the eager+kappa combination instead of
    silently recording a deferral that never happened."""
    m, d = F.DILITHIUM_Q, 64
    plan = _plan_for(m, d, 3)
    a = jnp.zeros((1, d), jnp.uint32)
    w = jnp.asarray(plan.w_planes)
    for call in (
            lambda: G.staged_transform(a, plan, reduction="eager", kappa=8),
            lambda: G.staged_transform_traced(
                a, w, modulus=m, data_limbs=3, accum="int32_native", kappa=8),
            lambda: G.staged_transform_scan(
                a, w, modulus=m, data_limbs=3, accum="int32_native", kappa=8)):
        with pytest.raises(ValueError, match="requires reduction='lazy'"):
            call()


def test_lazy_window_accumulator_guards():
    acc = ACC.LazyWindowAccumulator(97, "int32_native", 3, kappa=2)
    diag = jnp.ones((1, 8, 5), jnp.int32)
    acc.add(diag, 8)
    acc.add(diag, 8)
    with pytest.raises(ValueError, match="fold first"):
        acc.add(diag, 8)
    acc.fold()
    assert acc.n_folds == 1 and acc.pending == 0
    with pytest.raises(ValueError, match="empty window"):
        acc.fold()
    # a single oversized pass trips the magnitude bound directly
    huge = ACC.LazyWindowAccumulator(97, "fp32_mantissa", 3, kappa=1)
    with pytest.raises(ValueError, match="overflow"):
        huge.add(jnp.ones((1, 999, 5), jnp.int32), 999)


# --- engine-level equivalence (what the co-scheduler dispatches) --------------


@settings(max_examples=4, deadline=30_000)
@given(st.integers(0, 3))
def test_dilithium_engine_lazy_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    a = np.asarray(rng.integers(0, F.DILITHIUM_Q, (3, 256), dtype=np.uint64),
                   np.uint32)
    eng = WK.DilithiumEngine(256, accum="int32_native", reduction="lazy",
                             d_tile=171, kappa=2)
    assert eng.fold_profile["n_passes"] == 2
    assert eng.fold_profile["n_folds"] == 1
    np.testing.assert_array_equal(np.asarray(eng.evaluate(jnp.asarray(a))),
                                  eng.oracle_np(a))


@pytest.mark.parametrize("d_bucket,kappa", [(128, 2), (256, 2), (256, 4),
                                            (512, 8)])
def test_dilithium_bucket_sweep_lazy_eq_eager(d_bucket, kappa):
    """Bucket sweep at the serve path's pow2 d̂: lazy κ-window engines match
    eager engines bit-for-bit on random rows (d_tile=64 → d̂/64 passes)."""
    rng = np.random.default_rng(d_bucket)
    a = jnp.asarray(np.asarray(
        rng.integers(0, F.DILITHIUM_Q, (2, d_bucket), dtype=np.uint64),
        np.uint32))
    lazy = WK.DilithiumEngine(d_bucket, accum="int32_native",
                              reduction="lazy", d_tile=64, kappa=kappa)
    eager = WK.DilithiumEngine(d_bucket, accum="int32_native",
                               reduction="eager", d_tile=64)
    np.testing.assert_array_equal(np.asarray(lazy.evaluate(a)),
                                  np.asarray(eager.evaluate(a)))
    assert lazy.fold_profile["n_folds"] == -(-(d_bucket // 64) // kappa)


def test_bn254_engine_lazy_matches_eager():
    d = 32
    rng = np.random.default_rng(5)
    coeffs = np.array([[int.from_bytes(rng.bytes(16), "little")
                        for _ in range(d)] for _ in range(2)], object)
    lazy_eng = WK.BN254Engine(d, accum="int32_native", reduction="lazy")
    eager_eng = WK.BN254Engine(d, accum="int32_native", reduction="eager")
    a = lazy_eng.ingest(coeffs)
    np.testing.assert_array_equal(np.asarray(lazy_eng.e2e(a)),
                                  np.asarray(eager_eng.e2e(a)))
    assert lazy_eng.fold_profile["n_folds"] == lazy_eng.n_channels


# --- HLO validator: one fold per window, no re-fusion back to eager -----------


def _lazy_fn(plan, d_tile, kappa):
    def fn(x):
        y, _ = G.staged_transform(x, plan, reduction="lazy", d_max=d_tile,
                                  kappa=kappa)
        return y
    return fn


def test_validator_accepts_kappa_windows():
    m, d_tile, n_passes = F.DILITHIUM_Q, 32, 4
    plan = _plan_for(m, d_tile * n_passes, 3)
    for kappa, windows in ((1, 4), (2, 2), (4, 1)):
        rep = V.validate_fn(_lazy_fn(plan, d_tile, kappa),
                            jnp.zeros((2, plan.d), jnp.uint32),
                            expect_eager=False, expected_windows=windows,
                            n_diag=plan.n_diag)
        rep.raise_if_failed()


def test_validator_rejects_multifold_window():
    """A lazy-labelled program folding twice inside one window is rejected
    (V7): more than one reduction per window is eager in disguise."""
    m, d = F.DILITHIUM_Q, 64
    plan = _plan_for(m, d, 3)
    mm = jnp.uint32(m)

    def double_fold(x):
        diag = G.tile_diagonals(x, None, jnp.asarray(plan.fused_operand), plan)
        with jax.named_scope("lazy_window_0"), jax.named_scope("vpu_fold_lazy"):
            y1 = MONT.fold_diagonals_lax(diag, mm)
            y2 = MONT.fold_diagonals_lax(diag + jnp.int32(1), mm)
        return F.addmod_u32(y1, y2, mm)

    rep = V.validate_fn(double_fold, jnp.zeros((2, d), jnp.uint32),
                        expect_eager=False, expected_windows=1,
                        n_diag=plan.n_diag)
    assert not rep.ok and any(v[0] == "V7" for v in rep.violations)
    with pytest.raises(V.ValidationError):
        rep.raise_if_failed()


def test_validator_rejects_eager_folds_in_lazy_module():
    """An eager per-pass program audited as lazy fails V6 twice over: the
    expected windows are missing and per-pass folds are present."""
    m, d_tile, n_passes = F.DILITHIUM_Q, 32, 3
    plan = _plan_for(m, d_tile * n_passes, 3)

    def eager_fn(x):
        y, _ = G.staged_transform(x, plan, reduction="eager", d_max=d_tile)
        return y

    rep = V.validate_fn(eager_fn, jnp.zeros((2, plan.d), jnp.uint32),
                        expect_eager=False, expected_windows=1,
                        n_diag=plan.n_diag)
    assert not rep.ok and any(v[0] == "V6" for v in rep.violations)


def test_fold_census_counts_kappa_windows():
    m, d_tile, n_passes = F.DILITHIUM_Q, 32, 4
    plan = _plan_for(m, d_tile * n_passes, 3)
    z = jnp.zeros((2, plan.d), jnp.uint32)
    c2 = V.fold_census(_lazy_fn(plan, d_tile, 2), z)
    assert c2["n_lazy_windows"] == 2 and c2["n_fold_scopes"] == 2
    c4 = V.fold_census(_lazy_fn(plan, d_tile, 4), z)
    assert c4["n_lazy_windows"] == 1
