"""Data pipeline determinism, checkpoint roundtrip/rotation/integrity,
fault-tolerant loop recovery, gradient compression."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint
from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticLMStream
from repro.models import steps as ST
from repro.runtime import (FaultTolerantLoop, StepWatchdog, quantize_int8,
                           dequantize_int8)


# --- data ----------------------------------------------------------------------

def test_stream_deterministic_and_restorable():
    cfg = DataConfig(seq_len=32, global_batch=8, vocab_size=100, seed=3)
    s1 = SyntheticLMStream(cfg)
    batches = [next(s1) for _ in range(5)]
    s2 = SyntheticLMStream(cfg)
    s2.restore({"step": 3, "seed": 3, "host_id": 0, "n_hosts": 1})
    np.testing.assert_array_equal(next(s2)["tokens"], batches[3]["tokens"])


def test_stream_sharding_partitions_batch():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=50, seed=1)
    hosts = [SyntheticLMStream(cfg, host_id=i, n_hosts=4) for i in range(4)]
    batches = [next(h) for h in hosts]
    assert all(b["tokens"].shape == (2, 16) for b in batches)
    # distinct shards
    assert not np.array_equal(batches[0]["tokens"], batches[1]["tokens"])


# --- checkpoint ------------------------------------------------------------------

def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
            "b": {"c": jnp.arange(5, dtype=jnp.int32)},
            "d": [jnp.zeros((2,), jnp.float32)]}
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
    got, extra = restore_checkpoint(str(tmp_path), tree)
    assert extra["note"] == "x"
    assert got["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]), np.arange(5))


def test_checkpoint_rotation_and_integrity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    # corrupt latest payload -> integrity failure
    npz = os.path.join(tmp_path, "step_00000004", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(0)
        f.write(b"XX")
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), tree, step=4)


# --- fault-tolerant loop ----------------------------------------------------------

def _tiny_setup(tmp_path, fault_hook=None, ckpt_every=4):
    cfg = smoke_config("olmo_1b")
    data_cfg = DataConfig(seq_len=16, global_batch=4,
                          vocab_size=cfg.vocab_size, seed=0)
    stream = SyntheticLMStream(data_cfg)
    params, opt_state = ST.init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(ST.make_train_step(cfg))
    return FaultTolerantLoop(step, stream, params, opt_state,
                             ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                             fault_hook=fault_hook)


def test_fault_recovery_matches_clean_run(tmp_path):
    clean = _tiny_setup(tmp_path / "clean")
    p_clean, _ = clean.run(10)

    crashed = {"done": False}

    def hook(step):
        if step == 6 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    faulty = _tiny_setup(tmp_path / "faulty", fault_hook=hook)
    p_faulty, _ = faulty.run(10)
    assert faulty.restarts == 1
    for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_faulty)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-5)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=3.0, warmup=2)
    for i, d in enumerate([1.0, 1.0, 1.0, 1.1, 9.0, 1.0]):
        wd.record(i, d)
    assert wd.flagged == [4]


# --- compression -------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    codes, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(codes, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_compressed_grad_sync_multidevice_subprocess():
    """int8 EF all-reduce over a 4-device 'pod' axis ≈ exact mean."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.runtime import compressed_grad_sync, init_error_state

mesh = Mesh(np.array(jax.devices()), ("pod",))
rng = np.random.default_rng(1)
g = {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)}
err = init_error_state(g)
synced, err2 = compressed_grad_sync(g, err, mesh=mesh, axis="pod")
# all pods contribute the same replicated grad -> mean == grad (within int8 quant err)
d = np.abs(np.asarray(synced["w"]) - np.asarray(g["w"]))
scale = np.abs(np.asarray(g["w"])).max() / 127.0
assert d.max() <= scale * 1.01, (d.max(), scale)
assert np.abs(np.asarray(err2["w"])).max() <= scale
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_grad_accum_matches_full_batch():
    """Microbatched accumulation == full-batch gradients (same update)."""
    import dataclasses
    import jax
    from repro.configs import smoke_config
    from repro.models import steps as ST
    from repro.data import DataConfig, SyntheticLMStream

    cfg = smoke_config("olmo_1b")
    cfg8 = dataclasses.replace(cfg, grad_accum=4)
    data = DataConfig(seq_len=16, global_batch=8, vocab_size=cfg.vocab_size,
                      seed=5)
    batch = {k: jax.numpy.asarray(v)
             for k, v in SyntheticLMStream(data).batch_at(0).items()}
    params, opt = ST.init_train_state(cfg, jax.random.PRNGKey(0))
    p1, _, m1 = jax.jit(ST.make_train_step(cfg))(params, opt, batch)
    p2, _, m2 = jax.jit(ST.make_train_step(cfg8))(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)
