"""Continuous metrics, SLO burn-rate alerting, and the controller flight
recorder.

Everything runs on the deterministic virtual clock.  The acceptance
contract exercised here:

- the registry scrapes on a fixed serving-clock cadence into bounded rings
  and exposes valid OpenMetrics text (gzip-transparent on ``.gz`` paths);
- burn rates match the closed form on synthetic counter series, and the
  alert engine walks pending → firing → resolved (with cancellation);
- an induced-overload serve run fires AND resolves the admission SLO
  burn-rate alert, with the firing instant visible in the exported
  Perfetto trace;
- two identical runs — single host and a 2-host cluster — produce
  bit-identical scrape series and alert logs under
  ``deterministic_timing``;
- every controller setpoint change lands in the flight-recorder ring and
  as a ``setpoint`` instant on the trace.
"""
import gzip
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.cluster import ClusterConfig, ClusterServer
from repro.core import field as F
from repro.core.scheduler import TenantRequest
from repro.core.scheduler.coscheduler import SliceCoScheduler
from repro.obs import (chrome_trace, read_text, validate_chrome_trace,
                       validate_openmetrics, write_text)
from repro.obs.alerts import (AlertEngine, BurnRateRule, ThresholdRule,
                              default_cluster_rules, default_serve_rules,
                              merge_alert_sections)
from repro.obs.metrics import MetricsRegistry, expose_registries
from repro.serve import CryptoServer, ServeConfig

RNG = np.random.default_rng(41)

# Shared compiled-program cache (engines are lru-cached process-wide, so
# this reuses the other serving suites' work).
COS = SliceCoScheduler()


def _dil_request(tid, d, t=0.0):
    coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d, dtype=np.uint64),
                        np.uint32)
    return TenantRequest(tid, "dilithium", d, t, coeffs)


def _cfg(**kw):
    kw.setdefault("validate", False)
    kw.setdefault("n_c", 4)
    kw.setdefault("max_age_s", 0.005)
    kw.setdefault("metrics", True)
    kw.setdefault("metrics_period_s", 0.001)
    kw.setdefault("deterministic_timing", True)
    return ServeConfig(**kw)


# --- registry ------------------------------------------------------------------

def test_registry_cadence_and_monotone_timestamps():
    r = MetricsRegistry(period_s=0.01, capacity=16)
    ticks = []
    r.add_collector(lambda now: ticks.append(now) or [("g", (), 1.0)])
    assert r.scrape(0.0)
    assert not r.maybe_scrape(0.005)          # inside the period: gated
    assert r.maybe_scrape(0.0199999)          # >= period elapsed
    assert not r.scrape(0.0199999)            # same instant: no double sample
    assert not r.scrape(0.01)                 # going backwards: refused
    assert r.scrapes == 2 and len(ticks) == 2
    assert [ts for ts, _ in r.series("g")] == [0.0, 0.0199999]


def test_registry_ring_bounds_and_dropped_points():
    r = MetricsRegistry(period_s=0.001, capacity=4)
    for i in range(9):
        r.observe("c", (), float(i), float(i))
    assert len(r.series("c")) == 4
    assert r.dropped_points == 5
    assert r.series("c")[0] == (5.0, 5.0)     # oldest retained
    snap = r.snapshot()
    assert snap["samples"] == 4 and snap["dropped_points"] == 5


def test_window_delta_clamps_to_oldest_and_needs_two_samples():
    r = MetricsRegistry(period_s=0.001, capacity=16)
    r.observe("c", (), 0.0, 10.0)
    assert r.window_delta("c", (), 0.0, 1.0) is None
    for i in range(1, 5):
        r.observe("c", (), float(i), 10.0 + 2.0 * i)
    assert r.window_delta("c", (), 4.0, 2.0) == (4.0, 2.0)
    # window wider than the ring span: clamped to the oldest point
    assert r.window_delta("c", (), 4.0, 100.0) == (8.0, 4.0)


def test_exposition_is_valid_openmetrics_and_hosts_are_labelled():
    a = MetricsRegistry(period_s=0.001, host=0)
    b = MetricsRegistry(period_s=0.001, host=1)
    for reg, base in ((a, 1.0), (b, 2.0)):
        reg.describe("repro_x_total", kind="counter", help_text="an x")
        for i in range(3):
            reg.observe("repro_x_total", (), float(i), base * i)
    text = expose_registries([a, b])
    stats = validate_openmetrics(text)
    assert stats == {"families": 1, "series": 2, "samples": 6}
    assert text.count("# TYPE repro_x_total counter") == 1
    assert 'host="0"' in text and 'host="1"' in text
    assert text.endswith("# EOF\n")


def test_validate_openmetrics_rejects_bad_documents():
    with pytest.raises(ValueError):
        validate_openmetrics("# TYPE x counter\nx 1 0\n")   # missing EOF
    with pytest.raises(ValueError):                         # counter decrease
        validate_openmetrics("# TYPE x counter\nx 2 0\nx 1 1\n# EOF\n")
    with pytest.raises(ValueError):                         # ts not increasing
        validate_openmetrics("# TYPE x gauge\nx 1 5\nx 2 5\n# EOF\n")


# --- burn-rate math vs closed form ---------------------------------------------

def test_burn_rate_matches_closed_form():
    r = MetricsRegistry(period_s=1.0, capacity=256)
    miss_rate, budget = 0.3, 0.05
    for i in range(61):
        r.observe("den", (), float(i), float(i))
        r.observe("num", (), float(i), miss_rate * i)
    rule = BurnRateRule(name="b", num=("num", ()), den=("den", ()),
                        budget=budget, windows=((30.0, 5.0, 2.0),))
    for w in (5.0, 30.0):
        assert rule.burn(r, 60.0, w) == pytest.approx(miss_rate / budget)
    hit, worst = rule.condition(r, 60.0)
    assert hit and worst == pytest.approx(miss_rate / budget)
    # below the factor on both windows: no hit, worst still reported
    calm = BurnRateRule(name="c", num=("num", ()), den=("den", ()),
                        budget=budget, windows=((30.0, 5.0, 10.0),))
    hit, worst = calm.condition(r, 60.0)
    assert not hit and worst == pytest.approx(miss_rate / budget)


def test_burn_rate_pair_demands_both_windows():
    r = MetricsRegistry(period_s=1.0, capacity=256)
    # heavy historic burn that stopped 10 ticks ago: long window still hot,
    # short window clean — the pair must NOT fire (not burning *now*)
    for i in range(51):
        r.observe("den", (), float(i), float(i))
        r.observe("num", (), float(i), float(min(i, 40)))
    rule = BurnRateRule(name="b", num=("num", ()), den=("den", ()),
                        budget=0.05, windows=((40.0, 5.0, 2.0),))
    assert rule.burn(r, 50.0, 40.0) > 2.0
    assert rule.burn(r, 50.0, 5.0) == 0.0
    hit, _ = rule.condition(r, 50.0)
    assert not hit


# --- alert state machine -------------------------------------------------------

def test_alert_transitions_pending_firing_resolved_and_cancelled():
    r = MetricsRegistry(period_s=0.01, capacity=64)
    rule = ThresholdRule(name="hot", series=("g", ()), op=">", value=5.0,
                         for_s=0.02)
    eng = AlertEngine(r, (rule,))
    # missing series: undefined signal stays inactive
    eng.evaluate(0.0)
    assert eng.state("hot") == "inactive"
    # a blip shorter than for_s: pending then cancelled, never firing
    r.observe("g", (), 0.01, 9.0)
    eng.evaluate(0.01)
    assert eng.state("hot") == "pending"
    r.observe("g", (), 0.02, 1.0)
    eng.evaluate(0.02)
    assert eng.state("hot") == "inactive"
    # sustained breach: pending at onset, firing once for_s has elapsed
    for t in (0.03, 0.04, 0.05, 0.06):
        r.observe("g", (), t, 9.0)
        eng.evaluate(t)
    assert eng.state("hot") == "firing"
    r.observe("g", (), 0.07, 1.0)
    eng.evaluate(0.07)
    assert eng.state("hot") == "inactive"
    kinds = [e["transition"] for e in eng.log]
    assert kinds == ["pending", "cancelled", "pending", "firing", "resolved"]
    snap = eng.snapshot()
    assert snap["rules"]["hot"]["fired"] == 1
    assert snap["rules"]["hot"]["resolved"] == 1
    assert snap["events_total"] == 5


def test_alert_engine_rejects_duplicate_rule_names():
    r = MetricsRegistry(period_s=0.01)
    dup = ThresholdRule(name="x", series=("g", ()), op=">", value=0.0)
    with pytest.raises(ValueError):
        AlertEngine(r, (dup, dup))


def test_default_rule_sets_cover_the_contracted_signals():
    serve = {r.name for r in default_serve_rules(max_age_s=0.005,
                                                 slo_deadline_s=0.01)}
    assert serve == {"slo_burn", "p99_latency", "m_occupancy_floor",
                     "arithmetic_stall_share"}
    cluster = {r.name for r in default_cluster_rules(staleness_bound_s=0.004)}
    assert cluster == {"gossip_silence", "gossip_staleness", "failover_shed"}


def test_merge_alert_sections_counts_firing_hosts():
    mk = lambda state, fired: {"rules": {"slo_burn": {
        "state": state, "fired": fired, "resolved": 0, "severity": "page"}},
        "events_total": fired}
    merged = merge_alert_sections([mk("firing", 2), mk("inactive", 1), None])
    assert merged["hosts"] == 2
    assert merged["rules"]["slo_burn"]["fired"] == 3
    assert merged["rules"]["slo_burn"]["hosts_firing"] == 1
    assert merged["events_total"] == 3
    assert merge_alert_sections([None, {}]) == {}


# --- induced overload: fire AND resolve on a real serve run --------------------

def _overload_rules():
    """One tight window pair so a ~20 ms virtual run can both fire and
    resolve the admission burn alert."""
    return (BurnRateRule(
        name="slo_burn",
        num=("repro_admission_slo_miss_total", ()),
        den=("repro_admission_decisions_total", ()),
        budget=0.05, windows=((0.01, 0.004, 1.0),)),)


def _run_overload(tmp_path=None):
    # n_c far above the offered burst and a long age trigger: admitted
    # requests pool in the open batch, so the SLO gate's predicted wait
    # (pending / service-rate, init 1024 rows/s) crosses the 2 ms deadline
    # after a couple of admits and every later decision is a miss.
    cfg = _cfg(n_c=64, max_age_s=0.05, slo_deadline_s=0.002,
               tracing=True, alert_rules=_overload_rules())
    srv = CryptoServer(cfg, coscheduler=COS)
    t = 0.0
    handles = []
    for i in range(40):
        t = i * 0.0005
        handles.append(srv.submit(_dil_request(i, 64, t), now=t))
    rejected = sum(1 for h in handles if h.rejected)
    # offered load stops; keep the serving clock ticking so scrapes continue,
    # the age trigger flushes the pooled batch, and the alert can resolve
    for k in range(1, 41):
        srv.pump(0.02 + 0.002 * k)
    srv.drain(0.11)
    return srv, rejected


def test_induced_overload_fires_and_resolves_slo_burn():
    srv, rejected = _run_overload()
    assert rejected > 10                      # the overload actually rejected
    snap = srv.alerts.snapshot()
    rule = snap["rules"]["slo_burn"]
    assert rule["fired"] >= 1
    assert rule["resolved"] >= 1
    assert rule["state"] == "inactive"        # resolved by the end
    kinds = [e["transition"] for e in srv.alerts.log]
    assert kinds.index("firing") < kinds.index("resolved")
    # the firing instant is on the Perfetto timeline, on the alerts track
    trace = chrome_trace(srv.trace_events())
    validate_chrome_trace(trace)
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "i"}
    assert "alert_firing:slo_burn" in names
    assert "alert_resolved:slo_burn" in names
    # and the telemetry snapshot carries both sections
    tsnap = srv.telemetry.snapshot()
    assert tsnap["metrics"]["scrapes"] == srv.metrics.scrapes
    assert tsnap["alerts"]["rules"]["slo_burn"]["fired"] == rule["fired"]


# --- virtual-clock determinism -------------------------------------------------

def _deterministic_run(seed=5):
    rng = np.random.default_rng(seed)
    reqs = [(i, TenantRequest(
        i, "dilithium", 64, i * 0.0008,
        np.asarray(rng.integers(0, F.DILITHIUM_Q, 64, dtype=np.uint64),
                   np.uint32))) for i in range(48)]
    cfg = _cfg(controller=True, row_ladder_max=32, slo_deadline_s=0.01,
               max_pending=64)
    srv = CryptoServer(cfg, coscheduler=COS)
    for i, req in reqs:
        srv.submit(req, now=req.arrival_time)
    srv.drain(0.06)
    return srv


def test_two_runs_scrape_bit_identical_series_and_alert_logs():
    a, b = _deterministic_run(), _deterministic_run()
    assert a.metrics.scrapes > 5
    assert a.metrics_text() == b.metrics_text()
    assert list(a.alerts.log) == list(b.alerts.log)
    assert json.dumps(a.alerts.snapshot(), sort_keys=True) == \
        json.dumps(b.alerts.snapshot(), sort_keys=True)


def _deterministic_cluster_run(seed=9):
    rng = np.random.default_rng(seed)
    serve = _cfg(n_c=4, max_age_s=0.004, slo_deadline_s=0.02)
    cluster = ClusterServer(
        ClusterConfig(n_hosts=2, gossip_period_s=0.002, serve=serve),
        coscheduler_factory=lambda h: COS)
    for i in range(48):
        t = i * 0.0008
        coeffs = np.asarray(rng.integers(0, F.DILITHIUM_Q, 64,
                                         dtype=np.uint64), np.uint32)
        cluster.submit(TenantRequest(i, "dilithium", 64, t, coeffs), now=t)
    cluster.drain(0.06)
    return cluster


def test_cluster_scrape_and_alert_logs_bit_identical_across_runs():
    a, b = _deterministic_cluster_run(), _deterministic_cluster_run()
    assert a.metrics is not None and a.metrics.scrapes > 0
    assert a.metrics_text() == b.metrics_text()
    assert list(a.alerts.log) == list(b.alerts.log)
    for ha, hb in zip(a.hosts, b.hosts):
        assert list(ha.alerts.log) == list(hb.alerts.log)
    stats = validate_openmetrics(a.metrics_text())
    assert stats["samples"] > 0
    # gossip sensing series are present at the fleet level
    assert a.metrics.latest("repro_gossip_silence_seconds_max") is not None
    # merged telemetry carries the fleet alert/metrics roll-ups
    merged = a.snapshot()["merged"]
    assert merged["metrics"]["hosts"] == 2
    assert set(merged["alerts"]["rules"]) == {
        r.name for r in default_serve_rules(max_age_s=0.004,
                                            slo_deadline_s=0.02)}


def test_gossip_silence_alert_senses_a_dead_host():
    serve = _cfg(n_c=4, max_age_s=0.004)
    cluster = ClusterServer(
        ClusterConfig(n_hosts=2, gossip_period_s=0.002, serve=serve),
        coscheduler_factory=lambda h: COS)
    # the in-process event loop publishes for every host it still drives, so
    # a dead host is simulated at the bus: both publish once, then host 1
    # goes silent while host 0 keeps its digests fresh
    cluster.gossip.publish(0, 3, 0.0)
    cluster.gossip.publish(1, 3, 0.0)
    bound = cluster.gossip.staleness_bound_s
    for k in range(1, 10):
        t = 0.002 * k
        cluster.gossip.maybe_publish(0, 3, t)
        assert cluster.metrics.scrape(t)
        cluster.alerts.evaluate(t)
        if t <= bound:                     # within the bound: not dead yet
            assert cluster.alerts.state("gossip_silence") == "inactive"
    assert cluster.alerts.state("gossip_silence") == "firing"
    assert cluster.metrics.latest("repro_gossip_silence_seconds_max") > bound
    # the dying host's per-peer silence series carries the evidence
    assert cluster.metrics.latest("repro_gossip_silence_seconds",
                                  (("peer", "1"),)) > bound
    # host 1 resumes publishing: the alert resolves on the next scrape
    cluster.gossip.publish(1, 3, 0.02)
    cluster.metrics.scrape(0.0205)
    cluster.alerts.evaluate(0.0205)
    assert cluster.alerts.state("gossip_silence") == "inactive"
    assert cluster.alerts.snapshot()["rules"]["gossip_silence"]["resolved"] == 1


def test_silence_survives_digest_prune_until_republish():
    """Regression: ``cluster_view``'s staleness prune drops a dead host's
    *digest*, but its publish silence must keep growing — ``gossip_silence``
    stays firing after the prune and resolves only on an actual republish.
    (The bug mode: pruning ``_last_pub`` alongside ``_digests`` would make a
    cordoned host read as healthy one GC later.)"""
    serve = _cfg(n_c=4, max_age_s=0.004)
    cluster = ClusterServer(
        ClusterConfig(n_hosts=2, gossip_period_s=0.002, serve=serve),
        coscheduler_factory=lambda h: COS)
    bus = cluster.gossip
    bus.publish(0, 3, 0.0)
    bus.publish(1, 3, 0.0)
    bound = bus.staleness_bound_s
    # age host 1's digest past the bound and force the prune via a view read
    t = bound + 0.001
    bus.publish(0, 3, t)
    bus.cluster_view(0, 3, t)
    assert bus.pruned_digests == 1
    assert 1 not in bus._digests                  # digest gone...
    assert bus.silence_s(t)[1] == pytest.approx(t)   # ...silence intact
    cluster.metrics.scrape(t)
    cluster.alerts.evaluate(t)
    assert cluster.alerts.state("gossip_silence") == "firing"
    # silence keeps growing across later scrapes — still firing, long after
    # the digest was garbage-collected
    for k in (2.0, 4.0, 8.0):
        tk = bound * k + 0.001
        bus.maybe_publish(0, 3, tk)
        cluster.metrics.scrape(tk)
        cluster.alerts.evaluate(tk)
        assert cluster.alerts.state("gossip_silence") == "firing"
        assert bus.silence_s(tk)[1] == pytest.approx(tk)
    # an actual republish (the rejoin announce) is what resolves it
    t_back = bound * 8.0 + 0.002
    bus.publish(1, 3, t_back)
    assert bus.revives == 1                       # pruned → publishing again
    cluster.metrics.scrape(t_back + 0.001)
    cluster.alerts.evaluate(t_back + 0.001)
    assert cluster.alerts.state("gossip_silence") == "inactive"
    assert cluster.alerts.snapshot()[
        "rules"]["gossip_silence"]["resolved"] == 1
    assert bus.snapshot()["revives"] == 1


# --- controller flight recorder ------------------------------------------------

def test_flight_recorder_captures_setpoint_changes():
    cfg = _cfg(controller=True, row_ladder_max=64, n_c=8, max_age_s=0.002,
               tracing=True, max_pending=4096)
    srv = CryptoServer(cfg, coscheduler=COS)
    # a hard burst then starvation: the controller must move the target
    # rung at least once in each direction
    t = 0.0
    for i in range(120):
        t = i * 0.0001
        srv.submit(_dil_request(i, 64, t), now=t)
    for k in range(1, 30):
        srv.pump(t + 0.002 * k)
    srv.drain(t + 0.08)
    ctl = srv.controller
    assert ctl.decisions >= 1
    assert len(ctl.flight) == min(ctl.decisions, ctl.flight.maxlen)
    for rec in ctl.flight:
        assert rec.reason in ("starving", "overloaded", "queue_model")
        assert (rec.target_rows, rec.max_age_s, rec.occupancy_close) != \
            (rec.target_rows_from, rec.max_age_from_s, rec.occupancy_from)
    fr = ctl.snapshot()["flight_recorder"]
    assert fr["decisions"] == ctl.decisions
    assert len(fr["records"]) == len(ctl.flight)
    assert fr["records"][-1]["ts"] >= fr["records"][0]["ts"]
    # every recorded decision also landed as a setpoint instant on the trace
    trace = chrome_trace(srv.trace_events())
    setpoints = [e for e in trace["traceEvents"]
                 if e["ph"] == "i" and e["name"] == "setpoint"]
    assert len(setpoints) == ctl.decisions
    assert setpoints[0]["args"]["reason"] in ("starving", "overloaded",
                                              "queue_model")


def test_flight_recorder_ring_is_bounded():
    from repro.serve.controller import AdaptiveController
    ctl = AdaptiveController(ladder=(8, 16, 32), n_c=8, max_age_s=0.002,
                             recorder_capacity=4)
    for i in range(12):
        # alternate starvation and overload so every observation moves a
        # setpoint (the age lever oscillates) and appends a record
        depth = 0 if i % 2 == 0 else 10_000
        ctl.observe_dispatch(("dilithium", 64), now=0.01 * (i + 1),
                             live_rows=2, queue_depth=depth)
    assert ctl.decisions > 4
    assert len(ctl.flight) == 4               # ring stays bounded
    assert ctl.snapshot()["flight_recorder"]["capacity"] == 4


# --- gzip transparency ---------------------------------------------------------

def test_trace_and_metrics_gzip_roundtrip(tmp_path):
    srv, _ = _run_overload()
    tpath = str(tmp_path / "trace.json.gz")
    mpath = str(tmp_path / "metrics.om.gz")
    srv.write_trace(tpath)
    srv.write_metrics(mpath)
    with gzip.open(tpath, "rt") as f:      # really gzip on disk
        json.load(f)
    stats = validate_chrome_trace(tpath)   # validator reads .gz directly
    assert stats["requests"] > 0
    mstats = validate_openmetrics(mpath)
    assert mstats["samples"] > 0
    assert read_text(mpath) == srv.metrics_text()
    # plain-path round trip through the same helpers
    plain = str(tmp_path / "metrics.om")
    write_text(plain, srv.metrics_text())
    assert validate_openmetrics(plain) == mstats


# --- perf_report penalty-share drift -------------------------------------------

def _load_perf_report():
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "scripts", "perf_report.py")
    spec = importlib.util.spec_from_file_location("perf_report", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_perf_report_warns_on_penalty_share_drift_without_failing():
    pr = _load_perf_report()
    env = {k: "same" for k in pr.ENV_KEYS}
    mk = lambda shares: {
        "bench": "serve", "schema": 1, "env": env,
        "points": [{"config": "rate512", "rows_per_s": 1000.0,
                    "penalty": {"dilithium": {"shares": shares}}}]}
    base = mk({"mxu_productive": 0.50, "arithmetic_stall": 0.30,
               "spatial_pad": 0.15, "host_gap": 0.05})
    cand = mk({"mxu_productive": 0.42, "arithmetic_stall": 0.38,
               "spatial_pad": 0.15, "host_gap": 0.05})
    report = pr.diff_records(base, cand)
    drift = report["per_config"][0]["penalty_drift"]
    assert {d["bin"] for d in drift} == {"mxu_productive",
                                         "arithmetic_stall"}
    assert not report["regressions"]          # drift is warning-only
    # identical shares (and drift within the band): no warning rows
    same = pr.diff_records(base, base)
    assert "penalty_drift" not in same["per_config"][0]
    small = mk({"mxu_productive": 0.47, "arithmetic_stall": 0.33,
                "spatial_pad": 0.15, "host_gap": 0.05})
    assert "penalty_drift" not in pr.diff_records(
        base, small)["per_config"][0]
