"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
asserting output shapes + no NaNs; decode-vs-prefill consistency; SSD oracle.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, smoke_config, get_config
from repro.models import model as M
from repro.models import ssm as S
from repro.models import steps as ST

ALL_ARCHS = sorted(ARCHS.keys())


def _batch(cfg, rng, b=2, s=32):
    tokens = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens),
             "labels": jnp.asarray(tokens)}
    if cfg.frontend:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, max(cfg.frontend_len, 4), cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    rng = np.random.default_rng(0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, aux, _ = M.forward(cfg, params, batch, mode="train")
    b, s = batch["tokens"].shape
    expect_s = s + (batch["embeds"].shape[1]
                    if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (b, expect_s, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    params2, opt_state = ST.init_train_state(cfg, jax.random.PRNGKey(1))
    step = jax.jit(ST.make_train_step(cfg))
    params2, opt_state, metrics = step(params2, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["olmo_1b", "mamba2_370m", "hymba_1_5b",
                                  "whisper_large_v3", "granite_moe_3b_a800m"])
def test_decode_matches_prefill(arch):
    """Greedy decode against the cache must reproduce full-context logits."""
    cfg = smoke_config(arch)
    rng = np.random.default_rng(1)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    b, s = 2, 16
    batch = _batch(cfg, rng, b=b, s=s)

    prefill = jax.jit(ST.make_prefill(cfg, max_len=s + 8))
    decode = jax.jit(ST.make_decode_step(cfg))
    logits_p, cache = prefill(params, batch)

    # full-context reference for position s (next token after the prompt):
    tok_next = np.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), np.int32)
    _, logits_d, cache = decode(params, cache, jnp.asarray(tok_next),
                                jnp.int32(s))
    full = {"tokens": jnp.concatenate(
        [batch["tokens"], jnp.asarray(tok_next)], axis=1)}
    if "embeds" in batch:
        full["embeds"] = batch["embeds"]
    logits_full, _, _ = M.forward(cfg, params, full, mode="train")
    got = np.asarray(logits_d[:, -1], np.float32)
    want = np.asarray(logits_full[:, -1], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_ssd_chunked_matches_reference():
    cfg = smoke_config("mamba2_370m")
    params = S.ssm_params(cfg, jax.random.PRNGKey(3))
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 64, cfg.d_model)),
                    jnp.float32)
    y_chunk, st_chunk = S.ssd_forward(cfg, params, x)
    y_ref, st_ref = S.ssd_reference(cfg, params, x)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_param_counts_plausible():
    """Full configs land in the advertised parameter-count ballpark."""
    assert 15e9 < get_config("internlm2_20b").params_count() < 25e9
    assert 350e9 < get_config("llama3_405b").params_count() < 480e9
    assert 0.8e9 < get_config("olmo_1b").params_count() < 1.6e9
    assert 5e9 < get_config("starcoder2_7b").params_count() < 9e9
    # assigned config (48L × 64e × d_ff 1408) totals ~28B; active ≈ 3B ("A3B")
    assert 10e9 < get_config("moonshot_v1_16b_a3b").params_count() < 30e9
    assert 0.25e9 < get_config("mamba2_370m").params_count() < 0.6e9


def test_sliding_window_ring_cache():
    """Hymba: decode far past the window keeps only in-window history."""
    cfg = smoke_config("hymba_1_5b")
    assert cfg.attn_window and cfg.attn_window < 128
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(3)
    b, s = 1, 32
    batch = _batch(cfg, rng, b=b, s=s)
    prefill = jax.jit(ST.make_prefill(cfg, max_len=cfg.attn_window))
    decode = jax.jit(ST.make_decode_step(cfg))
    _, cache = prefill(params, batch)
    tok = jnp.asarray([[1]], jnp.int32)
    for i in range(s, s + 4):
        tok, logits, cache = decode(params, cache, tok, jnp.int32(i))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
