"""Two-tier scheduler + HLO validator: isolation isomorphism, metrics, zones."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import field as F
from repro.core import limb_gemm as G
from repro.core import ntt as NTT
from repro.core import validator as V
from repro.core import workloads as WK
from repro.core.scheduler import (IngressQueue, PoissonTrace, TenantRequest,
                                  RectangularScheduler, packing_metrics)
from repro.core.scheduler.rectangular import (block_diagonal_zero_fraction,
                                              bucket_degree)
from repro.core.scheduler.coscheduler import SliceCoScheduler

RNG = np.random.default_rng(0)


# --- Tier 1: rectangular scheduling -------------------------------------------

def _dil_request(tid, d):
    coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d, dtype=np.uint64),
                        np.uint32)
    return TenantRequest(tid, "dilithium", d, 0.0, coeffs)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(1, 256), min_size=1, max_size=12))
def test_batched_isomorphic_to_isolated(degrees):
    """Property 5.1 / Data Correctness: row i of the batched output equals the
    isolated evaluation of tenant i's polynomial (zero-padded)."""
    sched = RectangularScheduler(n_c=8, bucket_granularity=64)
    reqs = [_dil_request(i, d) for i, d in enumerate(degrees)]
    batches = sched.plan_batches(reqs)
    assert sum(b.n_c for b in batches) == len(reqs)
    for batch in batches:
        eng = WK.DilithiumEngine(batch.d_bucket)
        out = np.asarray(eng.evaluate(jnp.asarray(batch.operand)))
        routed = sched.unstack(batch, out)
        for r in batch.requests:
            iso = np.zeros((1, batch.d_bucket), np.uint32)
            iso[0, : r.degree] = r.coeffs
            want = eng.oracle_np(iso)[0]
            np.testing.assert_array_equal(routed[r.tenant_id], want)


def test_packing_metrics_paper_values():
    # Uniform BN254 d=256 (d_max=128): fill 100%, waste 0%, staging 50%
    m = packing_metrics([256] * 8, 256, 128)
    assert m.batch_fill == 1.0 and m.padding_waste == 0.0
    assert m.staging_overhead == 0.5
    assert m.m_occupancy == 8 / 128  # the paper's 6.25% M-dim occupancy
    # Uniform Dilithium d=256 (d_max=171): footprint 342 → ~25% waste
    m = packing_metrics([256] * 8, 256, 171)
    assert abs(m.padding_waste - (342 - 256) / 342) < 1e-9
    assert m.staging_overhead == 0.5


def test_block_diagonal_waste_eliminated():
    degrees = [64, 128, 256, 512]
    bd = block_diagonal_zero_fraction(degrees)
    assert bd > 0.6  # block-diagonal wastes most of the array
    m = packing_metrics(degrees, 512, 128)
    assert m.padding_waste < bd  # rectangular stacking strictly better


def test_bucket_degree():
    assert bucket_degree(1) == 64
    assert bucket_degree(64) == 64
    assert bucket_degree(65) == 128
    assert bucket_degree(512) == 512


# --- ingress + traces ----------------------------------------------------------

def test_poisson_trace_mixture():
    trace = PoissonTrace(rate_hz=2048, duration_s=2.0, seed=1).generate()
    assert 3000 < len(trace) < 5200
    frac_dil = np.mean([r.workload == "dilithium" for r in trace])
    assert 0.45 < frac_dil < 0.55
    q = IngressQueue()
    q.push_trace(trace)
    assert set(q.workloads) == {"dilithium", "bn254"}
    batch = q.pop_batch("dilithium", 8)
    assert len(batch) == 8 and all(r.workload == "dilithium" for r in batch)


# --- Tier 2: co-scheduler ------------------------------------------------------

def test_coscheduler_dispatch_dilithium():
    sched = RectangularScheduler(n_c=4, bucket_granularity=256)
    reqs = [_dil_request(i, 256) for i in range(4)]
    batches = sched.plan_batches(reqs)
    cos = SliceCoScheduler()
    res = cos.dispatch(batches[0])
    eng = cos.engine_for("dilithium", 256)
    for r in reqs:
        want = eng.oracle_np(r.coeffs[None, :])[0]
        np.testing.assert_array_equal(res.outputs[r.tenant_id], want)


def test_coscheduler_compiles_once_per_class():
    """Repeated dispatches of one (workload, d_bucket, shape) class must hit
    the cached executable — the trace counter increments only on retrace."""
    cos = SliceCoScheduler()
    sched = RectangularScheduler(n_c=2, bucket_granularity=64)
    for round_i in range(3):                  # fresh batch objects each round
        reqs = [_dil_request(10 * round_i + i, 64) for i in range(2)]
        cos.dispatch(sched.plan_batches(reqs)[0])
    assert cos.trace_counts[("dilithium", 64)] == 1
    # a different operand shape is a legitimate retrace
    one = sched.plan_batches([_dil_request(99, 64)])[0]
    cos.dispatch(one)
    assert cos.trace_counts[("dilithium", 64)] == 2


def test_dispatch_mixed_order_and_nonblocking(monkeypatch):
    """dispatch_mixed preserves input batch order and launches every program
    before materialising any result (no host sync between launches)."""
    rng = np.random.default_rng(17)
    cos = SliceCoScheduler()
    dil = [_dil_request(i, 256) for i in range(2)]
    eng_b = cos.engine_for("bn254", 64)
    coeffs = np.array([int.from_bytes(rng.bytes(16), "little")
                       for _ in range(64)], object)
    bn = [TenantRequest(200, "bn254", 64, 0.0, np.asarray(eng_b.ingest(coeffs)))]
    sched = RectangularScheduler(n_c=2, bucket_granularity=64)
    batches = sched.plan_batches(dil + bn)
    assert len(batches) == 2

    events = []
    orig_launch = SliceCoScheduler._launch
    orig_mat = SliceCoScheduler._materialise
    monkeypatch.setattr(SliceCoScheduler, "_launch",
                        lambda self, b: (events.append("launch"),
                                         orig_launch(self, b))[1])
    monkeypatch.setattr(SliceCoScheduler, "_materialise",
                        lambda self, *f: (events.append("materialise"),
                                          orig_mat(self, *f))[1])
    results = cos.dispatch_mixed(batches)
    assert events == ["launch", "launch", "materialise", "materialise"]
    assert [r.batch is b for r, b in zip(results, batches)] == [True, True]
    # and the rows are still correct end-to-end
    for r, b in zip(results, batches):
        if b.workload != "dilithium":
            continue
        eng = cos.engine_for("dilithium", b.d_bucket)
        for req in b.requests:
            iso = np.zeros((1, b.d_bucket), np.uint32)
            iso[0, : req.degree] = req.coeffs
            np.testing.assert_array_equal(r.outputs[req.tenant_id],
                                          eng.oracle_np(iso)[0])


def test_coscheduler_rejects_bad_reduction():
    """A typo'd reduction mode must fail construction, not silently trace
    the eager path (the string used to pass through unvalidated)."""
    with pytest.raises(ValueError, match="unknown reduction mode"):
        SliceCoScheduler(reduction="lzay")
    with pytest.raises(ValueError, match="unknown reduction mode"):
        SliceCoScheduler(reduction_by_workload={"dilithium": "Lazy"})
    with pytest.raises(ValueError, match="unknown workload class"):
        SliceCoScheduler(reduction_by_workload={"dilithum": "lazy"})
    # engines and the raw transform guard the same surface
    with pytest.raises(ValueError, match="unknown reduction mode"):
        WK.DilithiumEngine(64, reduction="eagr")
    cos = SliceCoScheduler(reduction="lazy",
                           reduction_by_workload={"bn254": "eager"})
    assert cos.reduction_for("dilithium") == "lazy"
    assert cos.reduction_for("bn254") == "eager"


def test_coscheduler_mixed_dispatch():
    rng = np.random.default_rng(9)
    cos = SliceCoScheduler()
    dil = [_dil_request(i, 256) for i in range(2)]
    eng_b = cos.engine_for("bn254", 64)
    bn_reqs = []
    for i in range(2):
        coeffs = np.array([int.from_bytes(rng.bytes(16), "little")
                           for _ in range(64)], object)
        res = np.asarray(eng_b.ingest(coeffs))
        bn_reqs.append(TenantRequest(100 + i, "bn254", 64, 0.0, res))
    sched = RectangularScheduler(n_c=2, bucket_granularity=64)
    batches = sched.plan_batches(dil + bn_reqs)
    results = cos.dispatch_mixed(batches)
    assert {b.batch.workload for b in results} == {"dilithium", "bn254"}


# --- HLO validator -------------------------------------------------------------

def _staged_fn(plan, reduction="eager", barriers=True):
    def fn(a):
        y, _ = G.staged_transform(a, plan, reduction=reduction,
                                  barriers=barriers)
        return y
    return fn


@pytest.fixture(scope="module")
def dil_plan_512():
    w = NTT.ntt_matrix(512, F.DILITHIUM_Q, negacyclic=True)
    return G.make_channel_plan(w, F.DILITHIUM_Q, data_limbs=3, tw_limbs=3)


def test_validator_accepts_eager(dil_plan_512):
    a = jnp.zeros((8, 512), jnp.uint32)
    rep = V.validate_fn(_staged_fn(dil_plan_512), a, expected_passes=3)
    rep.raise_if_failed()
    assert rep.n_barriers >= 2
    assert rep.zones == set() or all(z.startswith("wzone") for z in rep.zones)


def test_validator_flags_missing_barriers(dil_plan_512):
    a = jnp.zeros((8, 512), jnp.uint32)
    rep = V.validate_fn(_staged_fn(dil_plan_512, barriers=False), a,
                        expected_passes=3)
    assert not rep.ok
    assert any(v[0] == "V2" for v in rep.violations)
    with pytest.raises(V.ValidationError):
        rep.raise_if_failed()


def test_validator_flags_cross_zone_fusion():
    """XLA happily fuses elementwise chains across zones — the class of
    cross-tensor optimisation the validator must catch (paper §6.3)."""
    def fn(x):
        with jax.named_scope("wzone_dilithium"):
            a = x * jnp.float32(2.0) + jnp.float32(1.0)
        with jax.named_scope("wzone_bn254"):
            b = x * jnp.float32(3.0) - jnp.float32(4.0)
        return a + b  # cross-zone combine → fusion mixes zones

    x = jnp.zeros((256, 256), jnp.float32)
    rep = V.validate_fn(fn, x, expect_eager=False)
    assert not rep.ok and any(v[0] == "V3" for v in rep.violations)


def test_validator_accepts_zone_separated_engines():
    """Our co-scheduled program with explicit barriers between zones passes."""
    eng_d = WK.DilithiumEngine(256)

    def fn(a, b):
        y1 = eng_d.evaluate(a)
        y1, b = jax.lax.optimization_barrier((y1, b))
        with jax.named_scope("wzone_bn254"), jax.named_scope("pzone_4limb"):
            y2 = b * jnp.uint32(2)
        return y1, y2

    a = jnp.zeros((4, 256), jnp.uint32)
    b = jnp.zeros((4, 256), jnp.uint32)
    rep = V.validate_fn(fn, a, b, expected_passes=2)
    rep.raise_if_failed()
    assert "wzone_dilithium" in rep.zones and "wzone_bn254" in rep.zones


def test_fold_census_kappa():
    """Static fold census: eager folds per pass vs one lazy fold — the κ
    amortisation object (paper §7.2.1)."""
    m, d = F.DILITHIUM_Q, 512
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    eager_plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3)
    lazy_plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3,
                                    accum="int32_native")
    a = jnp.zeros((4, d), jnp.uint32)
    c_eager = V.fold_census(_staged_fn(eager_plan), a)
    def lazy_fn(x):
        y, _ = G.staged_transform(x, lazy_plan, reduction="lazy", d_max=171)
        return y
    c_lazy = V.fold_census(lazy_fn, a)
    assert c_eager["n_fold_scopes"] > c_lazy["n_fold_scopes"] >= 0
