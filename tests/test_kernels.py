"""Pallas kernels (interpret=True on CPU) vs pure-jnp oracles, shape sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import field as F
from repro.core import limb_gemm as G
from repro.core import ntt as NTT
from repro.core import workloads as WK
from repro.kernels import (limb_matmul, mont_fold, fused_ntt_tile,
                           pallas_tile_fn, pallas_fused_transform,
                           fused_operand_3d)
from repro.kernels.limb_matmul.ref import limb_matmul_ref
from repro.kernels.mont_fold.ref import mont_fold_ref
from repro.kernels.fused_ntt_tile.ref import fused_ntt_tile_ref

RNG = np.random.default_rng(42)


def _rand_u8(shape):
    return jnp.asarray(RNG.integers(0, 256, shape, dtype=np.uint8))


def _rand_s8(shape):
    return jnp.asarray(RNG.integers(-128, 128, shape), jnp.int8)


@pytest.mark.parametrize("n,k,m", [
    (8, 512, 1792),    # BN254 staging pass (dt=128, La=4, d=256, 7 diagonals)
    (16, 513, 1280),   # Dilithium pass 1 (dt=171, La=3, d=256, 5 diagonals)
    (3, 100, 70),      # ragged small
    (128, 256, 128),   # MXU-square
])
def test_limb_matmul_int32_sweep(n, k, m):
    a, b = _rand_u8((n, k)), _rand_s8((k, m))
    got = limb_matmul(a, b, accum="int32_native")
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(limb_matmul_ref(a, b)))


def test_limb_matmul_fp32_model():
    # K bounded so partial sums stay inside the 2^24 window -> exact
    a, b = _rand_u8((8, 256)), _rand_s8((256, 384))
    got = limb_matmul(a, b, accum="fp32_mantissa")
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(limb_matmul_ref(a, b, "fp32_mantissa")))


@pytest.mark.parametrize("n,d,n_diag,m", [
    (8, 256, 7, 2013265921),
    (5, 300, 5, F.DILITHIUM_Q),
    (16, 64, 7, (1 << 31) - 99),
])
def test_mont_fold_sweep(n, d, n_diag, m):
    diags = jnp.asarray(RNG.integers(-(2**24), 2**24, (n, d, n_diag)), jnp.int32)
    got = mont_fold(diags, m)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(mont_fold_ref(diags, m)))


@pytest.mark.parametrize("accum", ["int32_native", "fp32_mantissa"])
def test_fused_tile_vs_ref(accum):
    n, k, d, n_diag = 8, 384, 256, 5
    a = _rand_u8((n, k))
    b3 = _rand_s8((k, d, n_diag))
    m = F.DILITHIUM_Q
    got = fused_ntt_tile(a, b3, modulus=m, accum=accum)
    want = fused_ntt_tile_ref(a, b3, m, accum)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_staged_transform_with_pallas_kernel():
    """Engine path with the Pallas matmul == jnp path == bignum oracle."""
    m, d = F.DILITHIUM_Q, 256
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3)
    a = np.asarray(RNG.integers(0, m, (8, d), dtype=np.uint64), np.uint32)
    y_kernel, _ = G.staged_transform(jnp.asarray(a), plan,
                                     kernel_fn=pallas_tile_fn())
    np.testing.assert_array_equal(np.asarray(y_kernel),
                                  NTT.matrix_ntt_oracle_np(a, w, m))


def test_lazy_kappa_window_with_pallas_kernels():
    """Full-kernel lazy path: Pallas limb matmul per pass + Pallas mont_fold
    once per κ-window == eager jnp path (deferred reduction through the
    kernel ops, paper §7.2.1)."""
    from repro.kernels import mont_fold_window_fn
    m, d = F.DILITHIUM_Q, 256
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3,
                               accum="int32_native")
    a = np.asarray(RNG.integers(0, m, (8, d), dtype=np.uint64), np.uint32)
    eager, _ = G.staged_transform(jnp.asarray(a), plan, d_max=171)
    lazy, stats = G.staged_transform(
        jnp.asarray(a), plan, reduction="lazy", kappa=2, d_max=171,
        kernel_fn=pallas_tile_fn(), fold_fn=mont_fold_window_fn())
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(lazy))
    assert stats["n_folds"] == 1 and stats["n_passes"] == 2


def test_pallas_fused_transform_matches():
    m, d = F.DILITHIUM_Q, 256
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3)
    a = np.asarray(RNG.integers(0, m, (4, d), dtype=np.uint64), np.uint32)
    y = pallas_fused_transform(jnp.asarray(a), plan)
    np.testing.assert_array_equal(np.asarray(y),
                                  NTT.matrix_ntt_oracle_np(a, w, m))


def test_bn254_engine_with_pallas():
    d = 32
    rng = np.random.default_rng(5)
    omega = np.array([[int.from_bytes(rng.bytes(11), "little") for _ in range(d)]
                      for _ in range(d)], object)
    eng = WK.BN254Engine(d, evaluation_matrix=omega)
    coeffs = np.array([[int.from_bytes(rng.bytes(16), "little") for _ in range(d)]
                       for _ in range(2)], object)
    a_res = eng.ingest(coeffs)
    y_plain = np.asarray(eng.evaluate(a_res))
    y_kernel = np.asarray(eng.evaluate(a_res, kernel_fn=pallas_tile_fn()))
    np.testing.assert_array_equal(y_plain, y_kernel)


def test_fused_operand_3d_layout():
    m, d = F.DILITHIUM_Q, 64
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3)
    b3 = fused_operand_3d(plan)
    assert b3.shape == (d * 3, d, 5)
    np.testing.assert_array_equal(
        b3.reshape(d * 3, d * 5), plan.fused_operand)
