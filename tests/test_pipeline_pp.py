"""Pipeline parallelism (GPipe over a mesh axis) == serial stage application."""
import os
import subprocess
import sys

from repro.runtime.pipeline import bubble_fraction

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == 3 / 15
    assert bubble_fraction(1, 8) == 0.0


def test_pipeline_matches_serial_subprocess():
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.runtime.pipeline import pipeline_forward

S, M, MB, D = 4, 8, 2, 16
mesh = jax.make_mesh((S, 2), ("pod", "data"))
rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
x = jnp.asarray(rng.normal(size=(M, MB, D)), jnp.float32)

def stage(p, h):
    return jnp.tanh(h @ p)

w_sharded = jax.device_put(w, NamedSharding(mesh, P("pod")))
out = pipeline_forward(stage, w_sharded, x, mesh=mesh, axis="pod")

# serial reference
ref = x
for i in range(S):
    ref = jnp.tanh(ref @ w[i])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH="src"),
                         cwd=REPO, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
