"""Workload engines + Table-1 accumulator probes."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import accumulator as ACC
from repro.core import field as F
from repro.core import rns as R
from repro.core import wordarith as W
from repro.core import workloads as WK


def test_table1_pattern_matches_paper():
    rows = ACC.table1_rows()
    # v4/FP32: exact through 2^24, rounds at 2^24+1 and beyond.
    assert rows["tpu_v4_fp32_mantissa"] == [True, True, True, False, False, False, False]
    # v5e/v5p int32: exact everywhere probed.
    assert rows["tpu_v5_int32_native"] == [True] * 7


def test_exact_window_bruteforce_matches_formula():
    """The dtype-probed exactness windows equal the analytic W(accum)."""
    assert ACC.exact_window_bruteforce("fp32_mantissa") == 1 << 24
    assert ACC.exact_window_bruteforce("int32_native") == (1 << 31) - 1
    assert ACC.accumulator_window("fp32_mantissa") == 1 << 24
    assert ACC.accumulator_window("int32_native") == (1 << 31) - 1


@pytest.mark.parametrize("d_tile,la,lw", [(1, 2, 2), (2, 2, 2), (2, 3, 2),
                                          (3, 2, 3), (1, 3, 3)])
@pytest.mark.parametrize("accum", ["fp32_mantissa", "int32_native"])
def test_kappa_max_formula_matches_bruteforce(accum, d_tile, la, lw):
    """The derived κ_max formula equals exhaustive search on small word
    sizes, for both accumulator disciplines: brute-force the worst-case
    per-pass diagonal over all extreme operand assignments, brute-force the
    exact window by dtype probing, and divide."""
    got = ACC.kappa_max(accum, d_tile, min(la, lw))
    want = ACC.kappa_max_bruteforce(accum, d_tile, la, lw)
    assert got == want, (accum, d_tile, la, lw, got, want)
    # the analytic per-pass triangle bound is tight, not just an upper bound
    assert ACC.pass_bound(d_tile, min(la, lw)) == \
        ACC.pass_bound_bruteforce(d_tile, la, lw)


def test_kappa_max_paper_values():
    """Paper §7.2.1 anchors: at the fp32-era staging tiles, int32 admits
    κ = 128 deferred passes for both workload classes; fp32 admits none."""
    assert ACC.kappa_max("int32_native", 171, 3) == 128   # Dilithium tile
    assert ACC.kappa_max("int32_native", 128, 4) == 128   # BN254 tile
    assert ACC.kappa_max("fp32_mantissa", 171, 3) == 1
    assert ACC.kappa_max("fp32_mantissa", 128, 4) == 1


def test_window_plan_shapes():
    assert ACC.window_plan(6, 2, 100) == (2, 2, 2)
    assert ACC.window_plan(5, 2, 100) == (2, 2, 1)
    assert ACC.window_plan(3, None, 3) == (3,)
    with pytest.raises(ValueError):
        ACC.window_plan(3, None, 2)      # whole-transform window too deep
    with pytest.raises(ValueError):
        ACC.window_plan(3, 0, 2)


def test_dilithium_engine_exact():
    eng = WK.DilithiumEngine(256)
    assert eng.n_passes == 2  # 171 + 85
    rng = np.random.default_rng(0)
    a = np.asarray(rng.integers(0, F.DILITHIUM_Q, (8, 256), dtype=np.uint64),
                   np.uint32)
    got = np.asarray(eng.evaluate(jnp.asarray(a)))
    np.testing.assert_array_equal(got, eng.oracle_np(a))


def test_bn254_engine_envelope_exact():
    """9-channel engine with a bounded evaluation matrix: exact vs bignum."""
    d = 32
    rng = np.random.default_rng(1)
    omega = np.array([[int.from_bytes(rng.bytes(11), "little") for _ in range(d)]
                      for _ in range(d)], object)  # 88-bit entries
    eng = WK.BN254Engine(d, evaluation_matrix=omega)
    assert eng.n_passes == 1 and eng.plans[0].d_max == 128
    coeffs = np.array([[int.from_bytes(rng.bytes(16), "little") for _ in range(d)]
                       for _ in range(2)], object)  # 128-bit coefficients
    assert eng.in_envelope(coeffs)  # d·2^128·2^88 = 2^221 < M ≈ 2^248
    a_res = eng.ingest(coeffs)
    digits = np.asarray(eng.e2e(a_res))
    want = eng.oracle_eval_np(coeffs) % eng.chain.p
    for idx in np.ndindex(2, d):
        assert W.digits_to_int(digits[idx]) == want[idx]


def test_bn254_full_chain_real_twiddles():
    """18-channel chain with real BN254 NTT twiddles, bounded coefficients."""
    d = 16
    rng = np.random.default_rng(2)
    eng = WK.BN254Engine(d, n_channels=18)
    coeffs = np.array([[int.from_bytes(rng.bytes(32), "little") % F.BN254_FR
                        for _ in range(d)] for _ in range(2)], object)
    assert eng.in_envelope(coeffs)  # d·p² ≈ 2^512 < M₁₇ ≈ 2^526
    a_res = eng.ingest(coeffs)
    digits = np.asarray(eng.e2e(a_res))
    want = eng.oracle_eval_np(coeffs) % F.BN254_FR
    for idx in np.ndindex(2, d):
        assert W.digits_to_int(digits[idx]) == want[idx]


def test_bn254_channel_arithmetic_always_exact():
    """Channel-level arithmetic is exact mod m_i for all inputs, even outside
    the CRT envelope (paper's per-channel guarantee)."""
    d = 64
    rng = np.random.default_rng(3)
    eng = WK.BN254Engine(d)  # real 254-bit twiddles: outside 9-channel envelope
    coeffs = np.array([[int.from_bytes(rng.bytes(32), "little") % F.BN254_FR
                        for _ in range(d)] for _ in range(2)], object)
    assert not eng.in_envelope(coeffs)
    a_res = eng.ingest(coeffs)
    y = np.asarray(eng.evaluate(a_res))
    x_int = eng.oracle_eval_np(coeffs)
    for ci, m in enumerate(eng.chain.moduli):
        np.testing.assert_array_equal(
            y[..., ci], (x_int % m).astype(np.uint32))


def test_engine_cost_structure_counts():
    """The op-count skeleton the paper reports: 144 pointwise cross-products
    per point multiplication and >2,100 base-extension limb-level (u8-
    equivalent) multiplications per BN254 coefficient reduction.

    Our VPU phase uses digit-12 lanes (1 digit-12 product = (12/8)² = 2.25
    u8-equivalents — same int32-window constraint, wider lanes); the paper
    counts at u8 granularity, so we convert.
    """
    eng = WK.BN254Engine(256)
    # pointwise: 9 channels × La·Lw limb cross-products per point mult
    assert eng.n_channels * 4 * 4 == 144
    chain = eng.chain
    n, nd = chain.Ti_digits.shape
    redc_iters = chain.n_red_digits
    base_ext_digit = n * 3 * nd + 3 * nd    # SK conv + α·V accumulation
    redc_digit = redc_iters * (nd + 3 + 1)  # CIOS digit products
    sk_mulmods = n + n + 1                  # ξ, Σ mod m_r, α (mulmod_u32)
    u8_equiv = (base_ext_digit + redc_digit) * 2.25 + sk_mulmods * 16
    assert u8_equiv > 2100, u8_equiv
    # and the two dense base-extension matrix-vector products are present:
    assert base_ext_digit >= 2 * (n * nd)
