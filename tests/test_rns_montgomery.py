"""ERNS chain + Shenoy–Kumaresan + digit-12 Montgomery REDC vs bignum oracles."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import field as F
from repro.core import ntt as NTT
from repro.core import rns as R
from repro.core import wordarith as W

CHAIN = R.make_chain(9)


def test_chain_shape():
    assert CHAIN.n == 8 and len(CHAIN.moduli) == 9
    assert CHAIN.M.bit_length() >= 240
    assert all((m - 1) % (1 << R.TWO_ADICITY) == 0 for m in CHAIN.moduli)
    # redundant channel bound for SK: alpha < n < m_r
    assert CHAIN.redundant > CHAIN.n


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**240))
def test_rns_roundtrip_host(x):
    x = x % CHAIN.M
    res = R.to_rns_np(np.array([x], object), CHAIN)
    back = R.from_rns_np(res, CHAIN)
    assert back[0] == x


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**247))
def test_sk_alpha_exact(x):
    x = x % CHAIN.M
    res = jnp.asarray(R.to_rns_np(np.array([x], object), CHAIN))
    xi, alpha = R.sk_alpha(res, CHAIN)
    # α must equal (Σ ξ_i·(M/m_i) − x) / M exactly
    tot = sum(int(xi[0, i]) * (CHAIN.M // m) for i, m in enumerate(CHAIN.base))
    assert (tot - x) % CHAIN.M == 0
    assert int(alpha[0]) == (tot - x) // CHAIN.M
    assert int(alpha[0]) < CHAIN.n


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**247))
def test_rns_to_field_exact(x):
    x = x % CHAIN.M
    res = jnp.asarray(R.to_rns_np(np.array([x], object), CHAIN))
    digits = R.rns_to_field(res, CHAIN)
    got = W.digits_to_int(np.asarray(digits)[0])
    assert got == x % CHAIN.p


def test_rns_to_field_batch():
    rng = np.random.default_rng(7)
    xs = np.array([int.from_bytes(rng.bytes(30), "little") % CHAIN.M
                   for _ in range(24)], object).reshape(4, 6)
    res = jnp.asarray(R.to_rns_np(xs, CHAIN))
    digits = np.asarray(R.rns_to_field(res, CHAIN))
    for idx in np.ndindex(4, 6):
        assert W.digits_to_int(digits[idx]) == xs[idx] % CHAIN.p


# --- wordarith ---------------------------------------------------------------

def test_normalize_digits_negative_ok():
    # represents 5·β² − 3·β + 7, digits given denormal/negative
    d = jnp.asarray(np.array([[7, -3, 5, 0, 0]], np.int32))
    out = np.asarray(W.normalize_digits(d))
    assert W.digits_to_int(out[0]) == 5 * W.BETA**2 - 3 * W.BETA + 7


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=3, max_size=3))
def test_scalar_conv_accumulate(scalars):
    consts = [123456789012345678901234567890123, 999, 2**200 - 1]
    nd = 20
    cd = np.stack([W.int_to_digits(c, nd) for c in consts])
    sc = jnp.asarray(np.array([scalars], np.uint32))
    acc = W.scalar_conv_accumulate(sc, jnp.asarray(cd), nd + 3)
    out = W.normalize_digits(acc)
    want = sum(s * c for s, c in zip(scalars, consts))
    assert W.digits_to_int(np.asarray(out)[0]) == want


def test_digits_to_words():
    from repro.core import montgomery as MG
    x = 0xDEADBEEF_CAFEBABE_0123456789ABCDEF
    d = W.int_to_digits(x, 12)
    words = np.asarray(MG.digits_to_words_u32(jnp.asarray(d[None, :])))[0]
    got = 0
    for w in range(len(words) - 1, -1, -1):
        got = (got << 32) + int(words[w])
    assert got == x


# --- end-to-end multi-modular polynomial product (the real crypto semantics) --
#
# Negative intermediate values break redundant-channel consistency (c mod m_r
# != (c mod M) mod m_r), so the sound construction is: non-negative CYCLIC
# length-2d convolution per channel (convolution theorem, exact mod m_i),
# SK+REDC per coefficient, then the negacyclic fold c_j = c'_j − c'_{d+j}
# performed **in field space** (digits_submod_p).  See DESIGN.md §2.

def _poly_product_int(a, b):
    """Plain (acyclic) polynomial product over ℤ, length 2d."""
    d = len(a)
    c = [0] * (2 * d)
    for i in range(d):
        for j in range(d):
            c[i + j] += a[i] * b[j]
    return c


@pytest.mark.parametrize("n_channels,p_target", [
    (9, 1267650600228229401496703205653),   # 100-bit prime, paper chain width
    (18, F.BN254_FR),                       # full-range BN254 (extended chain)
])
def test_multimodular_polymul_exact(n_channels, p_target):
    chain = R.make_chain(n_channels, p=p_target)
    d = 16
    rng = np.random.default_rng(3)
    a = [int.from_bytes(rng.bytes(32), "little") % p_target for _ in range(d)]
    b = [int.from_bytes(rng.bytes(32), "little") % p_target for _ in range(d)]
    c_int = _poly_product_int(a, b)
    assert max(c_int) < chain.M, "test must stay in the exactness envelope"
    want_nega = [(c_int[k] - (c_int[k + d] if k + d < 2 * d else 0)) % p_target
                 for k in range(d)]

    d2 = 2 * d
    a_res = R.to_rns_np(np.array(a + [0] * d, object), chain)   # (2d, C)
    b_res = R.to_rns_np(np.array(b + [0] * d, object), chain)
    out_res = np.zeros((d2, len(chain.moduli)), np.uint32)
    for ci, m in enumerate(chain.moduli):
        w = NTT.ntt_matrix(d2, m)
        wi = NTT.intt_matrix(d2, m)
        fa = NTT.matrix_ntt_oracle_np(a_res[None, :, ci], w, m)[0]
        fb = NTT.matrix_ntt_oracle_np(b_res[None, :, ci], w, m)[0]
        prod = (fa.astype(object) * fb.astype(object)) % m
        out_res[:, ci] = NTT.matrix_ntt_oracle_np(prod[None, :], wi, m)[0]
    digits = R.rns_to_field(jnp.asarray(out_res), chain)
    # cyclic length-2d conv of zero-padded inputs == acyclic product (exact):
    for k in range(d2):
        assert W.digits_to_int(np.asarray(digits)[k]) == c_int[k] % p_target
    # negacyclic fold in field space:
    folded = W.digits_submod_p(digits[:d], digits[d:],
                               jnp.asarray(chain.p_digits))
    for k in range(d):
        assert W.digits_to_int(np.asarray(folded)[k]) == want_nega[k]


def test_digits_submod_p():
    chain = CHAIN
    rng = np.random.default_rng(11)
    a = [int.from_bytes(rng.bytes(31), "little") % chain.p for _ in range(8)]
    b = [int.from_bytes(rng.bytes(31), "little") % chain.p for _ in range(8)]
    nd = chain.n_red_digits
    ad = jnp.asarray(np.stack([W.int_to_digits(x, nd) for x in a]))
    bd = jnp.asarray(np.stack([W.int_to_digits(x, nd) for x in b]))
    out = np.asarray(W.digits_submod_p(ad, bd, jnp.asarray(chain.p_digits)))
    for k in range(8):
        assert W.digits_to_int(out[k]) == (a[k] - b[k]) % chain.p
