"""Test-suite bootstrap.

The property tests use a small slice of the ``hypothesis`` API.  When the
real package is unavailable (the pinned accelerator image ships without it)
we register a deterministic miniature implementation under the same module
names so the tier-1 suite runs everywhere.  Draws are seeded per-test, and
interval strategies always emit their boundary values first, so each run
exercises an identical example set.
"""
from __future__ import annotations

import functools
import sys
import zlib


def _install_hypothesis_stub():
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        bounds = [min_value, max_value]

        def draw(rng):
            if bounds:
                return bounds.pop(0)
            return rng.randint(min_value, max_value)

        return _Strategy(draw)

    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)

    def sampled_from(seq):
        seq = list(seq)

        def draw(rng):
            return rng.choice(seq)

        return _Strategy(draw)

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._hyp_settings = {"max_examples": max_examples}
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            # Zero-arg wrapper: the drawn values replace the test's
            # parameters, so pytest must not mistake them for fixtures.
            @functools.wraps(fn)
            def wrapper():
                cfg = getattr(wrapper, "_hyp_settings", None) or {}
                n = cfg.get("max_examples", 20)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(n):
                    vals = [s.example(rng) for s in strategies]
                    fn(*vals)

            del wrapper.__wrapped__
            return wrapper

        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.lists = lists
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__stub__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod


try:  # pragma: no cover - exercised implicitly by every property test
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
