"""Core modular-arithmetic + limb + NTT correctness vs Python-bignum oracles."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import field as F
from repro.core import limbs as L
from repro.core import ntt as NTT
from repro.core import primes as P
from repro.core import limb_gemm as G

RNG = np.random.default_rng(0)
MODULI = [F.DILITHIUM_Q, 2013265921, (1 << 31) - 1 - 2**20 + 1]  # mixed sizes


def _rand_u32(shape, m):
    return np.asarray(RNG.integers(0, m, size=shape, dtype=np.uint64), dtype=np.uint32)


# --- field primitives --------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(0, 2**31 - 2), st.integers(2, 2**31 - 1))
def test_mulmod_u32_matches_python(a, b, m):
    a, b = a % m, b % m
    got = F.mulmod_u32(jnp.uint32(a), jnp.uint32(b), jnp.uint32(m))
    assert int(got) == (a * b) % m


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(0, 2**31 - 2), st.integers(2, 2**31 - 1))
def test_addmod_submod(a, b, m):
    a, b = a % m, b % m
    assert int(F.addmod_u32(jnp.uint32(a), jnp.uint32(b), jnp.uint32(m))) == (a + b) % m
    assert int(F.submod_u32(jnp.uint32(a), jnp.uint32(b), jnp.uint32(m))) == (a - b) % m


def test_fold_diagonals():
    m = 2013265921
    diags = np.asarray(RNG.integers(-(2**24), 2**24, size=(4, 7, 5)), np.int32)
    got = np.asarray(F.fold_diagonals_u32(jnp.asarray(diags), jnp.uint32(m)))
    want = np.zeros((4, 7), np.uint32)
    for idx in np.ndindex(4, 7):
        v = sum(int(diags[idx + (k,)]) << (8 * k) for k in range(5))
        want[idx] = v % m
    np.testing.assert_array_equal(got, want)


# --- limbs -------------------------------------------------------------------

def test_limb_roundtrip():
    x = _rand_u32((64,), 1 << 31)
    limbs = L.decompose_u8(jnp.asarray(x), 4)
    back = L.recompose_u32(limbs)
    np.testing.assert_array_equal(np.asarray(back), x)


@settings(max_examples=30, deadline=None)
@given(st.integers(-(2**30), 2**30))
def test_signed_digits_roundtrip(v):
    d = L.signed_digits(np.asarray([v]), 4)
    assert L.signed_digits_value(d)[0] == v
    assert d.dtype == np.int8


def test_balanced_recode_dilithium_range():
    m = F.DILITHIUM_Q
    w = np.arange(0, m, 9973, dtype=np.uint32)
    bal = L.balanced_residue(w, m)
    d = L.signed_digits(bal, 3)
    np.testing.assert_array_equal(L.signed_digits_value(d), bal)


# --- primes ------------------------------------------------------------------

def test_ntt_friendly_primes():
    primes = P.ntt_friendly_primes(9, 17)
    assert len(set(primes)) == 9
    for m in primes:
        assert P.is_prime(m) and m < 2**31 and (m - 1) % (1 << 17) == 0


def test_primitive_root():
    m = P.ntt_friendly_primes(1, 17)[0]
    w = P.primitive_root_of_unity(m, 256)
    assert pow(w, 256, m) == 1 and pow(w, 128, m) != 1


# --- NTT ---------------------------------------------------------------------

@pytest.mark.parametrize("m,negacyclic", [(F.DILITHIUM_Q, True), (2013265921, False)])
def test_matrix_inverse_roundtrip(m, negacyclic):
    d = 64
    w = NTT.ntt_matrix(d, m, negacyclic=negacyclic)
    winv = NTT.intt_matrix(d, m, negacyclic=negacyclic)
    a = _rand_u32((3, d), m)
    fwd = NTT.matrix_ntt_oracle_np(a, w, m)
    back = NTT.matrix_ntt_oracle_np(fwd, winv, m)
    np.testing.assert_array_equal(back, a)


def test_cooley_tukey_matches_matrix():
    m, d = 2013265921, 128
    a = _rand_u32((2, d), m)
    w = NTT.ntt_matrix(d, m)
    want = NTT.matrix_ntt_oracle_np(a, w, m)
    got = np.asarray(NTT.cooley_tukey_ntt(jnp.asarray(a), m))
    np.testing.assert_array_equal(got, want)


def test_morph_stages_compose_to_ntt():
    m, d = F.DILITHIUM_Q, 32
    mats = NTT.morph_stage_matrices(d, m)
    a = _rand_u32((2, d), m)
    cur = a
    for s in mats:
        cur = NTT.matrix_ntt_oracle_np(cur, s, m)
    want = NTT.matrix_ntt_oracle_np(a, NTT.ntt_matrix(d, m), m)
    np.testing.assert_array_equal(cur, want)


# --- limb GEMM pipeline ------------------------------------------------------

def test_staging_d_max_matches_paper():
    assert G.staging_d_max(4, 4, "fp32_mantissa") == 128   # BN254 residue
    assert G.staging_d_max(3, 3, "fp32_mantissa") == 171   # Dilithium
    assert G.staging_d_max(4, 4, "int32_native") == 16448  # v5p relaxed


@pytest.mark.parametrize("accum", ["fp32_mantissa", "int32_native"])
def test_staged_transform_dilithium(accum):
    m, d = F.DILITHIUM_Q, 256
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3, accum=accum)
    if accum == "fp32_mantissa":
        assert plan.n_passes == 2  # 171 + 85, the paper's staging split
    a = _rand_u32((4, d), m)
    got, stats = G.staged_transform(jnp.asarray(a), plan)
    want = NTT.matrix_ntt_oracle_np(a, w, m)
    np.testing.assert_array_equal(np.asarray(got), want)
    assert stats["n_folds"] == stats["n_passes"]


def test_staged_transform_bn254_channel():
    m = P.ntt_friendly_primes(9, 17)[3]
    d = 256
    w = NTT.ntt_matrix(d, m)
    plan = G.make_channel_plan(w, m, data_limbs=4, tw_limbs=4)
    assert plan.n_passes == 2 and plan.d_max == 128
    a = _rand_u32((2, d), m)
    got, _ = G.staged_transform(jnp.asarray(a), plan)
    want = NTT.matrix_ntt_oracle_np(a, w, m)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_fused_equals_per_plane():
    m, d = F.DILITHIUM_Q, 128
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    fused = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3)
    planes = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3, fuse_below=0)
    assert fused.fused_operand is not None and planes.fused_operand is None
    a = _rand_u32((3, d), m)
    y1, _ = G.staged_transform(jnp.asarray(a), fused)
    y2, _ = G.staged_transform(jnp.asarray(a), planes)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_lazy_reduction_int32_fewer_folds():
    m, d = F.DILITHIUM_Q, 512
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3, accum="int32_native")
    a = _rand_u32((2, d), m)
    eager, st_e = G.staged_transform(jnp.asarray(a), plan, reduction="eager", d_max=171)
    lazy, st_l = G.staged_transform(jnp.asarray(a), plan, reduction="lazy", d_max=171)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(lazy))
    assert st_l["n_folds"] == 1 and st_e["n_folds"] == 3


def test_lazy_fp32_violates_property51():
    m, d = F.DILITHIUM_Q, 512
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3, accum="fp32_mantissa")
    a = jnp.asarray(_rand_u32((1, d), m))
    with pytest.raises(ValueError):
        G.staged_transform(a, plan, reduction="lazy")


def test_ref_transform_matches_oracle():
    m, d = F.DILITHIUM_Q, 64
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    a = _rand_u32((2, d), m)
    got = np.asarray(G.matrix_transform_ref(jnp.asarray(a), jnp.asarray(w), m))
    np.testing.assert_array_equal(got, NTT.matrix_ntt_oracle_np(a, w, m))
