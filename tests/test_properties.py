"""Hypothesis property tests on system invariants."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import field as F
from repro.core import limb_gemm as G
from repro.core import ntt as NTT
from repro.core import primes as P
from repro.core.scheduler import packing_metrics
from repro.core.scheduler.rectangular import (block_diagonal_zero_fraction,
                                              bucket_degree)

MODULI = st.sampled_from(P.ntt_friendly_primes(9, 17) + (F.DILITHIUM_Q,))


@settings(max_examples=20, deadline=None)
@given(MODULI, st.integers(0, 2**62))
def test_shift_fold_consistency(m, x):
    """fold(diagonals of x's limb split) == x mod m for any 62-bit x."""
    diags = np.asarray([(x >> (8 * k)) & 0xFF for k in range(8)],
                       np.int32)[None, None, :]
    got = int(F.fold_diagonals_u32(jnp.asarray(diags), jnp.uint32(m))[0, 0])
    assert got == x % m


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 48), st.integers(0, 3))
def test_staging_pass_count_invariant(d_mult, extra):
    """n_passes == ceil(d / d_max) for every degree and limb config."""
    d = d_mult * 37 + extra + 1
    for la, accum in ((3, "fp32_mantissa"), (4, "fp32_mantissa")):
        dm = G.staging_d_max(la, la, accum)
        tiles = []
        lo = 0
        while lo < d:
            tiles.append(min(lo + dm, d))
            lo = tiles[-1]
        assert len(tiles) == -(-d // dm)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(1, 512), min_size=1, max_size=16))
def test_packing_dominates_block_diagonal(degrees):
    """Rectangular stacking never wastes more than block-diagonal stacking
    (for more than one tenant) and metrics stay in [0, 1]."""
    bucket = bucket_degree(max(degrees))
    m = packing_metrics(degrees, bucket, 128)
    assert 0.0 <= m.batch_fill <= 1.0
    assert 0.0 <= m.padding_waste < 1.0
    assert 0.0 <= m.staging_overhead < 1.0
    if len(degrees) >= 4:
        assert m.padding_waste <= block_diagonal_zero_fraction(degrees) + 0.35


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
def test_linearity_of_transform(seed, n_rows):
    """The staged transform is F_q-linear: T(a+b) == T(a)+T(b) mod q."""
    m, d = F.DILITHIUM_Q, 64
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3)
    rng = np.random.default_rng(seed)
    a = np.asarray(rng.integers(0, m, (n_rows, d), dtype=np.uint64), np.uint32)
    b = np.asarray(rng.integers(0, m, (n_rows, d), dtype=np.uint64), np.uint32)
    ya, _ = G.staged_transform(jnp.asarray(a), plan)
    yb, _ = G.staged_transform(jnp.asarray(b), plan)
    ab = ((a.astype(np.uint64) + b) % m).astype(np.uint32)
    yab, _ = G.staged_transform(jnp.asarray(ab), plan)
    want = (np.asarray(ya).astype(np.uint64) + np.asarray(yb)) % m
    np.testing.assert_array_equal(np.asarray(yab), want.astype(np.uint32))


def test_scan_staging_matches_unrolled():
    """§Perf scan-staging variant is bit-identical to the unrolled eager
    discipline (Invariant 5.1 by loop-carried dataflow)."""
    m, d = F.DILITHIUM_Q, 513  # ragged: forces padding inside the scan
    w = NTT.ntt_matrix(1 << 10, m, negacyclic=True)[:513, :513]
    rng = np.random.default_rng(0)
    a = jnp.asarray(np.asarray(
        rng.integers(0, m, (3, 513), dtype=np.uint64), np.uint32))
    from repro.core.limbs import balanced_residue, signed_digits
    planes = jnp.asarray(signed_digits(balanced_residue(w, m), 3))
    y_unrolled = G.staged_transform_traced(a, planes, modulus=m, data_limbs=3)
    y_scan = G.staged_transform_scan(a, planes, modulus=m, data_limbs=3)
    np.testing.assert_array_equal(np.asarray(y_unrolled), np.asarray(y_scan))
