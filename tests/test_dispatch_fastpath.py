"""Dispatch fast path: super-batching, row-ladder retrace guard, zero-sync
async serving, donation — all proven bit-for-bit neutral.

The parity obligations here are the acceptance criteria of the fast-path PR:
merged/padded dispatch must equal per-batch dispatch row-for-row for mixed
eager/lazy classes, a 200-batch adversarially-heighted trace must trace at
most ladder-size programs per (workload, d_bucket), and V1–V7 HLO validation
must hold on the donated/merged program form actually dispatched.
"""
import numpy as np
import pytest

from repro.core import field as F
from repro.core import workloads as WK
from repro.core.scheduler import TenantRequest
from repro.core.scheduler.coscheduler import (SliceCoScheduler,
                                              default_row_ladder)
from repro.core.scheduler.rectangular import StackedBatch, stack_rows
from repro.launch.serve import serve_crypto, serve_crypto_online
from repro.serve import CryptoServer, ServeConfig

RNG = np.random.default_rng(7)

LADDER = (4, 8, 16)      # small rungs keep CPU compile budget low; the
                         # guard is about the *bound*, not the rung values


def _dil_request(tid, d, t=0.0):
    coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d, dtype=np.uint64),
                        np.uint32)
    return TenantRequest(tid, "dilithium", d, t, coeffs)


def _bn_request(tid, d=64, t=0.0):
    eng = WK.make_engine("bn254", d)
    vals = np.array([int(x) for x in RNG.integers(0, 2**31, d)], object)
    return TenantRequest(tid, "bn254", d, t, np.asarray(eng.ingest(vals)))


def _batch(reqs, d_bucket):
    return StackedBatch(workload=reqs[0].workload, d_bucket=d_bucket,
                        requests=reqs, operand=stack_rows(reqs, d_bucket))


def _mixed_height_batches(n_batches, *, seed, d_buckets=(64, 128),
                          max_rows=16, bn_every=0):
    rng = np.random.default_rng(seed)
    batches = []
    for i in range(n_batches):
        if bn_every and i % bn_every == bn_every - 1:
            rows = int(rng.integers(1, 5))
            reqs = [_bn_request(i * 100 + r) for r in range(rows)]
            batches.append(_batch(reqs, 64))
            continue
        d = int(rng.choice(d_buckets))
        rows = int(rng.integers(1, max_rows + 1))
        reqs = [_dil_request(i * 100 + r, d) for r in range(rows)]
        batches.append(_batch(reqs, d))
    return batches


# --- satellite: κ validated at construction ------------------------------------

def test_kappa_rejected_at_construction_for_all_eager():
    """An all-eager co-scheduler carrying κ>1 used to construct silently and
    only fail (or record a bogus κ) deep in dispatch — now it fails here."""
    with pytest.raises(ValueError, match="reduction='lazy'"):
        SliceCoScheduler(kappa=4)
    with pytest.raises(ValueError, match="reduction='lazy'"):
        SliceCoScheduler(reduction_by_workload={"bn254": "eager"}, kappa=2)
    # κ=1 and κ=None are degenerate-legal everywhere
    SliceCoScheduler(kappa=1)
    SliceCoScheduler(kappa=None)


def test_kappa_scoped_to_lazy_classes_in_mixed_config():
    """κ applies to the lazy classes only: the eager co-tenant's engine must
    not inherit it (its staged_transform would refuse to trace)."""
    cos = SliceCoScheduler(accum="int32_native", d_tile=171,
                           reduction_by_workload={"dilithium": "lazy"},
                           kappa=2)
    eng_lazy = cos.engine_for("dilithium", 256)
    eng_eager = cos.engine_for("bn254", 64)
    assert eng_lazy.kappa == 2 and eng_lazy.reduction == "lazy"
    assert eng_eager.kappa is None and eng_eager.reduction == "eager"
    reqs = [_dil_request(i, 256) for i in range(2)]
    res = cos.dispatch(_batch(reqs, 256))
    for r in reqs:
        np.testing.assert_array_equal(
            res.outputs[r.tenant_id], eng_lazy.oracle_np(r.coeffs[None, :])[0])


# --- row ladder ----------------------------------------------------------------

def test_default_row_ladder_shape():
    assert default_row_ladder(128) == (8, 16, 32, 64, 128)
    assert default_row_ladder(16) == (8, 16)
    assert default_row_ladder(100) == (8, 16, 32, 64, 100)
    assert default_row_ladder(8) == (8,)
    with pytest.raises(ValueError):
        default_row_ladder(0)


def test_launch_rows_snaps_to_rungs():
    cos = SliceCoScheduler(row_ladder=LADDER)
    assert [cos.launch_rows(n) for n in (1, 4, 5, 8, 9, 16)] == \
        [4, 4, 8, 8, 16, 16]
    assert cos.launch_rows(17) == 17      # beyond the top rung: natural size
    plain = SliceCoScheduler()
    assert plain.launch_rows(5) == 5


def test_retrace_guard_200_mixed_height_batches():
    """Acceptance: a 200-batch trace with adversarially varied heights traces
    at most ladder-size programs per (workload, d_bucket)."""
    batches = _mixed_height_batches(200, seed=3)
    cos = SliceCoScheduler(merge=True, row_ladder=LADDER)
    results = []
    for lo in range(0, len(batches), 8):       # pump-loop-sized waves
        results.extend(cos.dispatch_mixed(batches[lo:lo + 8]))
    assert len(cos.trace_counts) == 2          # (dil, 64), (dil, 128)
    for key, n in cos.trace_counts.items():
        assert n <= len(LADDER), (key, n, cos.trace_counts)
    # merging actually happened and every launch fits the top rung
    log = cos.drain_dispatch_log()
    assert any(e["n_batches"] > 1 for e in log)
    assert all(e["launched_rows"] in LADDER or e["launched_rows"] <= LADDER[-1]
               for e in log)
    # row routing survived merging: spot-check tenants against the oracle
    for res in results[::37]:
        eng = cos.engine_for("dilithium", res.batch.d_bucket)
        req = res.batch.requests[0]
        np.testing.assert_array_equal(
            res.outputs[req.tenant_id], eng.oracle_np(req.coeffs[None, :])[0])


def test_merged_padded_dispatch_bitforbit_vs_per_batch_mixed_modes():
    """Acceptance: merged + ladder-padded + donated dispatch is bit-for-bit
    equal to per-batch dispatch for mixed eager/lazy classes."""
    kw = dict(accum="int32_native", d_tile=171,
              reduction_by_workload={"dilithium": "lazy"})
    batches = _mixed_height_batches(24, seed=11, d_buckets=(256,),
                                    max_rows=8, bn_every=4)
    base = SliceCoScheduler(merge=False, **kw)
    fast = SliceCoScheduler(merge=True, row_ladder=LADDER, donate=True, **kw)
    base_res = [base.dispatch(b) for b in batches]
    fast_res = fast.dispatch_mixed(batches)
    for b, r0, r1 in zip(batches, base_res, fast_res):
        assert r1.batch is b
        np.testing.assert_array_equal(np.asarray(r0.rows[:b.n_c]),
                                      np.asarray(r1.rows[:b.n_c]))
    assert any(e["n_batches"] > 1 for e in fast.drain_dispatch_log())


# --- serving integration --------------------------------------------------------

def _serve_kw(seed):
    return dict(duration_s=0.01, rate_hz=1024, seed=seed, d_uniform=256)


def test_async_ladder_serving_matches_offline_bitforbit():
    """Zero-sync pipeline + ladder + merge through the full online runtime:
    per-tenant rows equal the offline replay, the ladder bounds traces, and
    telemetry carries the per-dispatch M-fill records."""
    kw = _serve_kw(23)
    offline_results, n_ops, _ = serve_crypto(validate=False, **kw)
    offline = {}
    for res in offline_results:
        offline.update(res.outputs)

    load, snap, _ = serve_crypto_online(
        max_age_s=0.002, validate=False, merge_dispatch=True,
        row_ladder_max=16, async_pipeline=True, **kw)
    assert set(load.outputs) == set(offline) and n_ops == len(offline)
    for tid, row in offline.items():
        np.testing.assert_array_equal(load.outputs[tid], row)
    disp = snap["dispatch"]
    assert disp["dispatches"] > 0
    assert 0.0 < disp["m_fill_mean"] <= 1.0
    assert disp["launched_rows"] >= disp["live_rows"] > 0
    assert snap["requests_served"] == n_ops


def test_online_ladder_bounds_traces_and_warm_start_covers_rungs():
    """Warm-starting a laddered server precompiles every rung, so live
    dispatches at adversarial heights trigger zero new traces."""
    cfg = ServeConfig(n_c=8, max_age_s=10.0, validate=False,
                      row_ladder_max=16, warm_start=[("dilithium", 64)])
    server = CryptoServer(cfg)
    ladder = server.cos.row_ladder
    assert ladder == default_row_ladder(16)
    assert server.warm_traces == len(ladder)
    assert not server.batcher.pad_rows     # mergeable (live-row) emission
    rng = np.random.default_rng(5)
    now = 0.0
    for i in range(40):                    # heights vary via age closes
        for r in range(int(rng.integers(1, 6))):
            server.submit(_dil_request(i * 10 + r, 64, now), now=now)
        now += 0.02
        server.pump(now)
    server.drain(now + 1.0)
    assert server.telemetry.snapshot()["requests_served"] > 0
    assert server.cos.trace_counts[("dilithium", 64)] == len(ladder)


def test_validator_passes_on_donated_merged_program():
    """Acceptance: V1–V7 hold on the exact dispatched form — ladder-height
    operand, device-resident plane arguments, donated operand buffer — for
    both the eager and the κ-amortised discipline."""
    cfg = ServeConfig(n_c=4, max_age_s=10.0, validate=True, donate=True,
                      row_ladder_max=8, accum="int32_native", d_tile=171,
                      reduction_by_workload={"dilithium": "lazy"}, kappa=2)
    server = CryptoServer(cfg)
    assert server.cos.donate
    handles = [server.submit(_dil_request(i, 256), now=0.0) for i in range(3)]
    handles.append(server.submit(_bn_request(77), now=0.0))
    server.drain(0.001)
    eng = server.cos.engine_for("dilithium", 256)
    for h in handles[:3]:
        np.testing.assert_array_equal(
            h.result(), eng.oracle_np(h.request.coeffs[None, :])[0])
    assert handles[3].done()
    assert {("dilithium", 256), ("bn254", 64)} <= server._validated


def test_async_pipeline_defers_gather_to_next_event():
    """The pump loop's zero-sync contract: a closed batch launches without
    resolving its handles; the next serving event gathers them."""
    cfg = ServeConfig(n_c=2, max_age_s=10.0, validate=False,
                      async_pipeline=True)
    server = CryptoServer(cfg)
    h1 = server.submit(_dil_request(0, 64), now=0.0)
    h2 = server.submit(_dil_request(1, 64), now=0.0)   # closes full → launch
    assert not h1.done() and not h2.done()             # in flight, not gathered
    server.pump(0.005)                                 # gathering edge
    assert h1.done() and h2.done()
    eng = server.cos.engine_for("dilithium", 64)
    iso = np.zeros((1, 64), np.uint32)
    iso[0] = h1.request.coeffs
    np.testing.assert_array_equal(h1.result(), eng.oracle_np(iso)[0])
    # drain finalises anything still in flight
    h3 = server.submit(_dil_request(2, 64), now=0.01)
    h4 = server.submit(_dil_request(3, 64), now=0.01)
    server.drain(0.02)
    assert h3.done() and h4.done()
