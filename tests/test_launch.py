"""Launch layer: sharding rules, hlo_cost correctness, traced transform,
small-mesh dry-run smoke (subprocess with 8 fake devices)."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import field as F
from repro.core import limb_gemm as G
from repro.core import ntt as NTT
from repro.launch import hlo_cost as HC

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_hlo_cost_scan_trip_counts():
    def g(a, ws):
        def body(x, w):
            return x @ w, None
        out, _ = jax.lax.scan(body, a, ws)
        return out

    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    hlo = jax.jit(g).lower(a, ws).compile().as_text()
    got = HC.corrected_cost(hlo)["flops"]
    want = 8 * 2 * 64 * 256 * 256
    assert abs(got - want) / want < 0.01


def test_hlo_cost_matches_xla_unrolled():
    def g(a, b):
        return jax.nn.relu(a @ b)

    a = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 256), jnp.float32)
    compiled = jax.jit(g).lower(a, b).compile()
    got = HC.corrected_cost(compiled.as_text())["flops"]
    want = HC.xla_cost_dict(compiled)["flops"]
    assert abs(got - want) / want < 0.05


def test_staged_transform_traced_matches_plan():
    m, d = F.DILITHIUM_Q, 256
    w = NTT.ntt_matrix(d, m, negacyclic=True)
    plan = G.make_channel_plan(w, m, data_limbs=3, tw_limbs=3)
    rng = np.random.default_rng(0)
    a = jnp.asarray(np.asarray(
        rng.integers(0, m, (4, d), dtype=np.uint64), np.uint32))
    y_plan, _ = G.staged_transform(a, plan)
    y_traced = G.staged_transform_traced(
        a, jnp.asarray(plan.w_planes), modulus=m, data_limbs=3)
    np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_traced))


def test_sharding_rules_fallbacks():
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.launch.shardings import ShardingRules

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules(mesh)
# divisible head dim -> model-sharded
assert rules.param_spec("layers/attn/wq", (32, 1024, 512)) == P(None, None, "model")
# non-divisible vocab (49155 % 4 != 0) -> fallback replicate
assert rules.param_spec("embed", (49155, 64)) == P(None, None)
assert rules.fallbacks
# MoE expert axis divisible -> EP
assert rules.param_spec("layers/moe/wi_gate", (8, 64, 128)) == P("model", None, None)
# MoE expert axis NOT divisible -> d_ff fallback
assert rules.param_spec("layers/moe/wo", (6, 128, 64)) == P(None, "model", None)
assert rules.param_spec("layers/moe/wi_up", (6, 64, 128)) == P(None, None, "model")
# batch spec
assert rules.batch_spec((16, 128)) == P("data", None)
# long-context cache: B=1 -> sequence sharding over data (+ heads over model)
assert rules.cache_spec("k", (4, 1, 1024, 8, 64)) == P(None, None, "data", "model", None)
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH="src"),
                         cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]


@pytest.mark.parametrize("arch,shape", [
    ("olmo_1b", "train_4k"),
    ("mamba2_370m", "long_500k"),
    ("aegis_dilithium", "serve_256"),
])
def test_dryrun_small_mesh_subprocess(arch, shape):
    """Full dry-run path on an 8-device fake mesh (fast CI variant of the
    512-device production run)."""
    script = rf"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import repro.launch.dryrun as DR
import jax
DR.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
    (2, 2, 2) if multi_pod else (4, 2),
    ("pod", "data", "model") if multi_pod else ("data", "model"))
rec = DR.run_cell("{arch}", "{shape}", multi_pod=False)
assert rec["status"] == "ok", rec.get("error") or rec.get("reason")
assert rec["roofline"]["t_compute_s"] >= 0
rec2 = DR.run_cell("{arch}", "{shape}", multi_pod=True)
assert rec2["status"] == "ok", rec2.get("error")
print("OK", rec["roofline"]["dominant"])
"""
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH="src"),
                         cwd=REPO, timeout=900)
    assert out.returncode == 0, (out.stderr[-3000:], out.stdout[-500:])
    assert "OK" in out.stdout


def test_dryrun_skip_rule():
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import repro.launch.dryrun as DR
import jax
DR.make_production_mesh = lambda multi_pod=False: jax.make_mesh((4, 2), ("data", "model"))
rec = DR.run_cell("llama3_405b", "long_500k", multi_pod=False)
assert rec["status"] == "skipped" and "sub-quadratic" in rec["reason"]
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH="src"),
                         cwd=REPO, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
