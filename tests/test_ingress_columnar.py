"""Columnar vectorised ingress: scalar-oracle parity (decisions, reasons,
retry hints, bucket levels), the tenant interner, the submit_many batch
edge, pending-load accounting, and the retry-hint refill bugfix."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import field as F
from repro.core.scheduler import TenantRequest
from repro.core.scheduler.coscheduler import SliceCoScheduler
from repro.serve import CryptoServer, ServeConfig
from repro.serve.admission import (AdmissionController, TenantInterner,
                                   TokenBucket)
from repro.serve.batcher import ContinuousBatcher

RNG = np.random.default_rng(17)

# Shared compiled programs (same reasoning as test_serve_runtime: the serving
# layer exists to reuse them; sharing keeps the suite from recompiling).
COS = SliceCoScheduler()


def _cfg(**kw):
    kw.setdefault("validate", False)
    kw.setdefault("n_c", 4)
    kw.setdefault("max_age_s", 0.01)
    return ServeConfig(**kw)


def _server(**kw):
    return CryptoServer(_cfg(**kw), coscheduler=COS)


def _dil(tid, d=64, t=0.0, coeffs=None):
    if coeffs is None:
        coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d,
                                         dtype=np.uint64), np.uint32)
    return TenantRequest(tid, "dilithium", d, t, coeffs)


# --- satellite bugfix: retry hints must refill to now ---------------------------

def test_time_until_refills_to_now():
    # binary-exact values throughout: rate 8 Hz, instants on 2^-k grids
    tb = TokenBucket(rate_hz=8.0, burst=2.0)
    assert tb.try_take(0.0) and tb.try_take(0.0)      # level -> 0
    assert not tb.try_take(0.0)
    # legacy call (no now): prices the deficit from the stale level
    assert tb.time_until() == 0.125
    # half a token accrues by t = 1/16; the hint must shrink accordingly —
    # the pre-fix code kept quoting 0.125 here (the regression this pins)
    assert tb.time_until(now=0.0625) == 0.0625
    # and the hint is exact: a take at now + hint succeeds, earlier fails
    tb2 = TokenBucket(rate_hz=8.0, burst=2.0)
    tb2.try_take(0.0)
    tb2.try_take(0.0)
    h = tb2.time_until(now=0.0)
    assert h == 0.125
    assert not tb2.try_take(0.109375)                 # 7/64 s: 0.875 tokens
    assert tb2.try_take(0.125)                        # exactly 1.0 token

    # rate 0 quirk is preserved: no accrual ever, hint stays inf
    tb3 = TokenBucket(rate_hz=0.0, burst=1.0)
    assert tb3.try_take(0.0)
    assert tb3.time_until(now=100.0) == float("inf")


# --- tenant interner ------------------------------------------------------------

def test_tenant_interner_dense_and_fallback():
    it = TenantInterner(dense_limit=1 << 10)
    assert it.intern(5) == 0
    assert it.intern(7) == 1
    assert it.intern(5) == 0                          # stable
    assert it.intern(1 << 40) == 2                    # beyond dense range
    assert it.intern(-3) == 3                         # negative
    assert it.intern("tenant-x") == 4                 # non-integer
    assert it.index_of(7) == 1 and it.index_of(8) is None
    assert it.index_of("tenant-x") == 4
    assert len(it) == 5


def test_tenant_interner_vectorised_matches_scalar():
    a = TenantInterner()
    b = TenantInterner()
    rng = np.random.default_rng(0)
    for _ in range(5):
        ids = rng.integers(0, 500, 64)
        va = a.intern_many(ids)
        vb = np.asarray([b.intern(int(t)) for t in ids])
        np.testing.assert_array_equal(va, vb)
    assert len(a) == len(b)
    # growth past the initial dense table, still consistent
    big = np.arange(900, 1100) * 7 % (1 << 18)
    np.testing.assert_array_equal(
        a.intern_many(big), np.asarray([b.intern(int(t)) for t in big]))


# --- scalar vs columnar parity --------------------------------------------------

def _controllers(seed):
    """One random admission config, instantiated in both layouts."""
    rng = np.random.default_rng(seed)
    kw = dict(
        max_pending=int(rng.choice([3, 20, 10_000])),
        tenant_rate_hz=(float(rng.choice([0.0, 0.5, 8.0, 1000.0]))
                        if rng.random() < 0.85 else None),
        tenant_burst=float(rng.integers(1, 5)),
        slo_deadline_s=(float(rng.choice([0.001, 0.1, 1e9]))
                        if rng.random() < 0.7 else None),
        service_rate_init=float(rng.choice([0.0, 10.0, 1024.0, 1e6])))
    return (AdmissionController(columnar=False, **kw),
            AdmissionController(columnar=True, **kw), kw, rng)


def _random_batch(rng, n):
    n_ten = int(rng.integers(1, 30))
    skew = rng.choice(["unique", "zipf", "hot", "mixed"])
    if skew == "unique":
        ids = rng.permutation(10_000)[:n]
    elif skew == "hot":
        ids = np.zeros(n, np.int64)
    elif skew == "zipf":
        ids = np.minimum(rng.zipf(1.5, n), n_ten).astype(np.int64)
    else:
        ids = rng.integers(0, n_ten, n)
    return ids


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_admit_batch_parity(seed):
    """admit_batch on the columnar layout is bit-identical to the scalar
    per-request oracle: decisions, reason codes, retry hints, and the token
    level every touched bucket is left at — over random tenant skews,
    rates, gate configs, and clock jitter, across sequential batches."""
    oracle, fast, _, rng = _controllers(seed)
    n = int(rng.integers(1, 150))
    ids = _random_batch(rng, n)
    pend0 = int(rng.integers(0, 30))
    cp = float(rng.integers(0, 50)) if rng.random() < 0.5 else None
    t0 = float(rng.normal(0, 2))                   # negative clocks too
    for _ in range(3):
        ts = t0 + np.cumsum(rng.exponential(0.01, n))
        if rng.random() < 0.3:                     # non-monotone jitter
            ts = ts + rng.normal(0, 0.005, n)
        t0 = float(ts.max()) + float(rng.exponential(0.05))
        da = oracle.admit_batch(ids, ts, pending=pend0, cluster_pending=cp)
        db = fast.admit_batch(ids, ts, pending=pend0, cluster_pending=cp)
        np.testing.assert_array_equal(da.admitted, db.admitted)
        np.testing.assert_array_equal(da.reason_codes, db.reason_codes)
        # exact — the hints ride the same IEEE ops in both layouts
        np.testing.assert_array_equal(da.retry_after_s, db.retry_after_s)
        assert da.reasons() == db.reasons()
        assert da.counts() == db.counts()
        for tid in set(ids.tolist()):
            la = oracle.bucket_level(tid, t0)
            if la is not None:                     # bucket was reached
                assert fast.bucket_level(tid, t0) == la


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_admit_per_request_parity(seed):
    """The per-request admit() path on columnar state matches the TokenBucket
    dict bit for bit (including the now-refilled retry hints)."""
    oracle, fast, _, rng = _controllers(seed)
    ids = _random_batch(rng, 40)
    ts = np.cumsum(rng.exponential(0.01, 40))
    for tid, t in zip(ids.tolist(), ts.tolist()):
        pend = int(rng.integers(0, 25))
        req = _dil(int(tid), 64, t)
        da = oracle.admit(req, t, pending=pend)
        db = fast.admit(req, t, pending=pend)
        assert (da.admitted, da.reason, da.retry_after_s) == \
               (db.admitted, db.reason, db.retry_after_s)


def test_admit_batch_of_one_equals_admit():
    a = AdmissionController(columnar=True, tenant_rate_hz=4.0,
                            tenant_burst=1.0, slo_deadline_s=0.5)
    b = AdmissionController(columnar=True, tenant_rate_hz=4.0,
                            tenant_burst=1.0, slo_deadline_s=0.5)
    rng = np.random.default_rng(2)
    ids = rng.integers(0, 5, 60)
    ts = np.cumsum(rng.exponential(0.05, 60))
    for tid, t in zip(ids.tolist(), ts.tolist()):
        da = a.admit(_dil(int(tid), 64, t), float(t), pending=0)
        db = b.admit_batch(np.asarray([tid]), np.asarray([t]), pending=0)
        assert (da.admitted, da.reason, da.retry_after_s) == \
               (bool(db.admitted[0]), db.reasons()[0],
                float(db.retry_after_s[0]))


def test_draining_and_duplicate_submit_many():
    server = _server(tenant_rate_hz=100.0)
    r0, r1 = _dil(0), _dil(1)
    hs = server.submit_many([r0, r1, r0], nows=[0.0, 0.0, 0.0])
    assert not hs[0].rejected and not hs[1].rejected
    assert hs[2].rejected and hs[2].decision.reason == "duplicate"
    # still pending from the earlier batch → duplicate across batches too
    h = server.submit_many([r1], nows=[0.001])[0]
    assert h.rejected and h.decision.reason == "duplicate"
    server.drain(0.01)
    assert hs[0].result() is not None and hs[1].result() is not None
    hs2 = server.submit_many([_dil(2), _dil(3)], now=0.02)
    assert all(x.rejected and x.decision.reason == "draining" for x in hs2)
    by_reason = server.telemetry.snapshot()["admission"]["by_reason"]
    assert by_reason["duplicate"] == 2
    assert by_reason["draining"] == 2
    assert by_reason["ok"] == 2


def test_submit_many_matches_per_request_submit():
    """Same trace through the batch edge (columnar) and the per-request
    loop (scalar oracle server): identical decisions and bit-identical
    per-tenant results."""
    kw = dict(n_c=4, max_age_s=10.0, tenant_rate_hz=2.0, tenant_burst=1.0)
    s_batch = _server(**kw)                       # columnar default
    s_loop = _server(columnar_admission=False, **kw)
    reqs = []
    for i in range(24):
        d = 64
        coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d,
                                         dtype=np.uint64), np.uint32)
        t = i * 1e-4
        reqs.append((
            _dil(i % 6, d, t, coeffs), _dil(i % 6, d, t, coeffs.copy())))
    hs_batch = s_batch.submit_many([a for a, _ in reqs],
                                   nows=[a.arrival_time for a, _ in reqs])
    hs_loop = [s_loop.submit(b, now=b.arrival_time) for _, b in reqs]
    s_batch.drain(0.01)
    s_loop.drain(0.01)
    for hb, hl in zip(hs_batch, hs_loop):
        assert hb.rejected == hl.rejected
        if hb.rejected:
            assert hb.decision.reason == hl.decision.reason
            assert hb.decision.retry_after_s == hl.decision.retry_after_s
        else:
            np.testing.assert_array_equal(hb.result(), hl.result())
    assert (s_batch.telemetry.snapshot()["admission"]["by_reason"]
            == s_loop.telemetry.snapshot()["admission"]["by_reason"])


# --- satellite bugfix: failover replay must not re-charge admission -------------

def test_replay_bypasses_admission_and_leaves_bucket_levels_identical():
    """A replayed request was admitted and token-charged once, on the host
    that died — re-entering it on the survivor must not touch the
    survivor's token buckets or SLO gate.  Pinned by comparing the
    survivor's columnar bucket levels bit-for-bit against a scalar oracle
    controller that only ever saw the normal (non-replay) traffic."""
    kw = dict(n_c=4, max_age_s=10.0, tenant_rate_hz=4.0, tenant_burst=2.0)
    survivor = _server(**kw)                       # columnar default
    oracle = AdmissionController(columnar=False, tenant_rate_hz=4.0,
                                 tenant_burst=2.0)
    # normal traffic on the survivor, mirrored into the oracle
    for i, t in enumerate((0.0, 0.125, 0.25)):
        req = _dil(i % 2, 64, t)
        assert not survivor.submit(req, now=t).rejected
        assert oracle.admit(req, t, pending=0).admitted
    # a dead peer's journal: admitted there, never seen here.  The oracle
    # deliberately never sees these — that is the contract under test.
    dead = _server(**kw)
    entries = []
    for i, t in enumerate((0.05, 0.1)):
        req = _dil(i % 2, 64, t, coeffs=np.asarray(
            RNG.integers(0, F.DILITHIUM_Q, 64, dtype=np.uint64), np.uint32))
        req.request_id = 1000 + i
        h = dead.submit(req, now=t)
        assert not h.rejected
        entries.append((req, h))
    replayed, deduped = survivor.replay_admitted(entries, 0.3)
    assert (replayed, deduped) == (2, 0)
    for tid in (0, 1):
        assert survivor.admission.bucket_level(tid, 0.3) == \
            oracle.bucket_level(tid, 0.3)
    # replay is visible in telemetry but not in the token accounting
    by_reason = survivor.telemetry.snapshot()["admission"]["by_reason"]
    assert by_reason["replayed"] == 2
    # later normal traffic is charged normally, still bit-identical
    req = _dil(0, 64, 0.5)
    d_srv = survivor.submit(req, now=0.5)
    d_orc = oracle.admit(req, 0.5, pending=0)
    assert d_srv.rejected == (not d_orc.admitted)
    assert survivor.admission.bucket_level(0, 0.5) == \
        oracle.bucket_level(0, 0.5)
    # idempotence: a second delivery of the same journal dedups entirely
    # and still leaves the buckets untouched
    assert survivor.replay_admitted(entries, 0.6) == (0, 2)
    assert survivor.admission.bucket_level(1, 0.6) == \
        oracle.bucket_level(1, 0.6)
    survivor.drain(1.0)
    dead.drain(1.0)


# --- satellite bugfix: pending_load sees held + in-flight rows ------------------

def test_pending_load_counts_inflight_ring():
    server = _server(n_c=2, async_pipeline=True, slo_deadline_s=0.001,
                     max_age_s=10.0)
    server.admission.service_rate = 1000.0        # pin the wait model
    server.submit(_dil(0), now=0.0)
    server.submit(_dil(1), now=0.0)               # full → async launch
    assert server.batcher.depth == 0
    assert server.inflight_groups == 1
    assert server.pending_load == 2               # launched, not gathered
    # the SLO gate must price those rows: wait = 2/1000 > 1ms deadline.
    # Before the fix it read batcher.depth == 0 and admitted.
    h = server.submit(_dil(2), now=0.0)
    assert h.rejected and h.decision.reason == "slo_miss"
    server.drain(0.01)
    assert server.pending_load == 0


def test_pending_load_counts_held_rows():
    server = _server(n_c=4)
    bt = ContinuousBatcher(n_c=2)
    (cb,) = bt.add(_dil(7), 0.0) + bt.add(_dil(8), 0.0)
    # pending_load is pure accounting — park a closed batch in the pen the
    # way _apply_holdback would: (ClosedBatch, release_at, held_at, hid)
    server._held[("dilithium", 64)] = (cb, 1.0, 0.0, 0)
    assert server.pending_load == 2
    assert server.batcher.depth == 0
