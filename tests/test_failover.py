"""Host-failure recovery: fault injection, rendezvous failover, replay.

The chaos contract under test: kill one of N hosts mid-run and the fleet
(a) loses no admitted request and double-serves none (exactly-once via
journal replay + rid dedup), (b) remaps only the dead host's tenants
(rendezvous hashing), and (c) produces per-tenant results bit-for-bit
equal to the no-failure replay of the same trace.  Everything runs on the
deterministic virtual clock — a FaultPlan applied on tick edges makes
chaos runs exactly reproducible.
"""
import json

import numpy as np
import pytest

from repro.cluster import (ClusterConfig, ClusterServer, FaultEvent,
                           FaultPlan, IntakeJournal, TenantHashRouter,
                           rendezvous_score, stable_tenant_hash,
                           summarize_failover)
from repro.core import field as F
from repro.core import workloads as WK
from repro.core.scheduler import TenantRequest
from repro.core.scheduler.coscheduler import SliceCoScheduler
from repro.launch.serve import serve_crypto_cluster
from repro.obs.validate import validate_chrome_trace
from repro.serve import CryptoServer, ServeConfig

RNG = np.random.default_rng(41)

# One co-scheduler shared by every cluster in this module (and both sides
# of each chaos-parity pair): compiled per-(workload, d_bucket) programs
# are what hosts reuse, and sharing avoids recompiling per host count.
CLUSTER_COS = SliceCoScheduler(accum="int32_native", d_tile=171,
                               reduction_by_workload={"dilithium": "lazy"})

CHAOS_KW = dict(duration_s=0.02, rate_hz=4096, seed=7, d_uniform=256,
                accum="int32_native", validate=False, n_c=8,
                max_age_s=0.002, d_tile=171,
                reduction_by_workload={"dilithium": "lazy"})
# Fractions of the run: kill h1 at 0.35 (7 ms), recover at 0.85 (17 ms).
# Silence crosses the 4 ms staleness bound ~11 ms in, so the fleet cordons
# via gossip_silence well before the recover and the firing alert has a
# full metrics period to be scraped.
CHAOS_PLAN = "kill@0.35:h1,recover@0.85:h1"


def _dil_request(tid, d, t=0.0):
    coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d, dtype=np.uint64),
                        np.uint32)
    return TenantRequest(tid, "dilithium", d, t, coeffs)


def _tenant_on_host(router, host, start=0, skip=()):
    for tid in range(start, start + 100_000):
        if router.host_for(tid) == host and tid not in skip:
            return tid
    raise AssertionError(f"no tenant routes to host {host} "
                         f"(cordoned? live={router.live_hosts})")


# --- fault plans ---------------------------------------------------------------

def test_fault_plan_parse_scale_describe_roundtrip():
    plan = FaultPlan.parse("kill@0.5:h1, recover@0.9:h1,pause@0.25:h0")
    assert plan.describe() == "pause@0.25:h0,kill@0.5:h1,recover@0.9:h1"
    assert len(plan) == 3 and plan.remaining == 3
    abs_plan = plan.scaled(0.02)
    assert [e.t for e in abs_plan.events] == pytest.approx(
        [0.005, 0.01, 0.018])
    assert [e.kind for e in abs_plan.events] == ["pause", "kill", "recover"]
    with pytest.raises(ValueError):
        plan.scaled(0.0)
    for bad in ("kill@0.5", "reboot@0.5:h1", "kill@0.5:1", "kill@-1:h0"):
        with pytest.raises(ValueError):
            FaultPlan.parse(bad)
    with pytest.raises(ValueError):
        FaultEvent(t=0.1, kind="explode", host=0)
    with pytest.raises(ValueError):
        FaultEvent(t=-0.1, kind="kill", host=0)
    with pytest.raises(ValueError):
        FaultEvent(t=0.1, kind="kill", host=-1)
    with pytest.raises(TypeError):
        FaultPlan(["kill@0.5:h1"])


def test_fault_plan_due_is_consumed_once_and_ordered():
    plan = FaultPlan([FaultEvent(0.01, "kill", 1),
                      FaultEvent(0.018, "recover", 1)])
    assert plan.due(0.005) == []
    ev = plan.due(0.01)
    assert [e.kind for e in ev] == ["kill"] and plan.remaining == 1
    # already-popped events never reappear; exclusive form skips t == now
    assert plan.due(0.01) == []
    assert plan.due(0.018, inclusive=False) == []
    assert [e.kind for e in plan.due(0.018)] == ["recover"]
    assert plan.remaining == 0
    # same-instant events keep author order (kill scripted first applies first)
    same = FaultPlan([FaultEvent(0.01, "kill", 0),
                      FaultEvent(0.01, "recover", 0)])
    assert [e.kind for e in same.due(0.01)] == ["kill", "recover"]


# --- rendezvous router ---------------------------------------------------------

def test_rendezvous_minimal_migration_and_restore():
    """Cordoning one host remaps *only* its tenants; restore is the exact
    inverse.  Property-checked over host counts and a mixed tenant id set."""
    tenants = list(range(300)) + [f"tenant-{i}" for i in range(50)]
    for n in (2, 3, 4, 6):
        r = TenantHashRouter(n)
        before = {t: r.host_for(t) for t in tenants}
        for dead in (0, n - 1):
            second = {t: r.choices(t, 2)[1] for t in tenants
                      if before[t] == dead}
            assert r.cordon(dead)
            assert not r.cordon(dead)                    # idempotent
            after = {t: r.host_for(t) for t in tenants}
            for t in tenants:
                if before[t] != dead:
                    assert after[t] == before[t], (n, dead, t)
                else:
                    # the displaced tenant lands on its pre-computed
                    # rendezvous second choice, never back on the dead host
                    assert after[t] == second[t] != dead
            assert r.restore(dead)
            assert not r.restore(dead)
            assert {t: r.host_for(t) for t in tenants} == before


def test_rendezvous_scores_pins_and_successor():
    r = TenantHashRouter(4, pinned={7: 2})
    th = stable_tenant_hash(7)
    assert r.host_for(7) == 2
    # pin to a cordoned host falls back to the rendezvous choice
    r.cordon(2)
    fallback = max({0, 1, 3}, key=lambda h: (rendezvous_score(th, h), h))
    assert r.host_for(7) == fallback != 2
    assert 2 not in r.live_hosts and not r.is_live(2)
    r.restore(2)
    assert r.host_for(7) == 2
    # choices: [owner, failover alternate], both live, stable
    for t in range(50):
        top = r.choices(t, 2)
        if t != 7:
            assert top[0] == r.host_for(t)
        assert len(set(top)) == 2
    # successor: deterministic, live, never the dead host itself
    for dead in range(4):
        s = r.successor(dead)
        assert s != dead and s in r.live_hosts
        assert r.successor(dead) == s
    with pytest.raises(ValueError):
        r.restore(9)
    one = TenantHashRouter(2)
    one.cordon(0)
    with pytest.raises(RuntimeError):
        one.cordon(1)                          # never cordon the last host
    with pytest.raises(RuntimeError):
        one.successor(1)                       # no live successor exists


# --- intake journal & rid dedup ------------------------------------------------

class _Handle:
    def __init__(self, done=False):
        self._done = done

    def done(self):
        return self._done


def test_intake_journal_pending_and_compaction():
    j = IntakeJournal(0)
    live = [j.record(i, f"t{i}", object(), _Handle(), "ok", 0.0)
            for i in range(3)]
    for i in range(70):
        j.record(100 + i, "settled", object(), _Handle(done=True), "ok", 0.0)
    assert j.recorded == 73
    assert [e.rid for e in j.pending()] == [0, 1, 2]
    assert j.pending_tenants() == {"t0", "t1", "t2"}
    j.compact()
    assert j.compacted == 70 and len(j.entries) == 3
    live[0].replayed = True                    # replayed entries stop pending
    assert [e.rid for e in j.pending()] == [1, 2]
    snap = j.snapshot()
    assert snap["pending"] == 2 and snap["compacted"] == 70


def test_submit_edges_dedup_on_request_id():
    server = CryptoServer(ServeConfig(n_c=8, max_age_s=10.0, validate=False),
                          coscheduler=CLUSTER_COS)
    r1 = _dil_request(1, 256)
    r1.request_id = 5
    assert not server.submit(r1, now=0.0).rejected
    # a *different* request object carrying an already-seen rid is a
    # duplicate delivery (LB retry / double replay) — rejected, not served
    r2 = _dil_request(2, 256)
    r2.request_id = 5
    h2 = server.submit(r2, now=0.0)
    assert h2.rejected and h2.decision.reason == "duplicate"
    # batch edge: seen rid, fresh rid, and an intra-batch repeat
    r3, r4, r5 = (_dil_request(t, 256) for t in (3, 4, 5))
    r3.request_id, r4.request_id, r5.request_id = 5, 6, 6
    h3, h4, h5 = server.submit_many([r3, r4, r5], now=0.001)
    assert h3.rejected and h3.decision.reason == "duplicate"
    assert not h4.rejected
    assert h5.rejected and h5.decision.reason == "duplicate"
    by = server.telemetry.snapshot()["admission"]["by_reason"]
    assert by["duplicate"] == 3


def test_replay_admitted_is_idempotent_and_skips_settled():
    dead = CryptoServer(ServeConfig(n_c=8, max_age_s=10.0, validate=False),
                        coscheduler=CLUSTER_COS)
    survivor = CryptoServer(ServeConfig(n_c=8, max_age_s=10.0,
                                        validate=False),
                            coscheduler=CLUSTER_COS)
    reqs = [_dil_request(t, 256) for t in (1, 2, 3)]
    for i, r in enumerate(reqs):
        r.request_id = 100 + i
    handles = [dead.submit(r, now=0.0) for r in reqs]
    entries = list(zip(reqs, handles))
    assert survivor.replay_admitted(entries, 0.01) == (3, 0)
    # second delivery of the same journal slice is fully deduped
    assert survivor.replay_admitted(entries, 0.02) == (0, 3)
    survivor.drain(0.03)
    assert all(h.done() and not h.rejected for h in handles)
    # settled entries are skipped outright on a later (cascade) replay
    third = CryptoServer(ServeConfig(n_c=8, max_age_s=10.0, validate=False),
                         coscheduler=CLUSTER_COS)
    assert third.replay_admitted(entries, 0.04) == (0, 3)


# --- gather-ring rescue --------------------------------------------------------

def test_recover_inflight_rescues_launched_groups():
    """Async-pipeline launches the dead host never gathered are materialised
    at cordon — results recovered, not recomputed."""
    cos = SliceCoScheduler()
    server = CryptoServer(ServeConfig(n_c=1, max_age_s=10.0, validate=False,
                                      async_pipeline=True),
                          coscheduler=cos)
    reqs = [_dil_request(t, 64) for t in (1, 2)]
    handles = [server.submit(r, now=0.0) for r in reqs]
    assert server.inflight_groups > 0          # launched, not yet gathered
    unresolved = [h for h in handles if not h.done()]
    assert unresolved
    assert server.recover_inflight(0.001) == len(unresolved)
    assert server.inflight_groups == 0
    for r, h in zip(reqs, handles):
        assert h.done() and not h.rejected
        iso = np.zeros((1, 64), np.uint32)
        iso[0, : r.degree] = r.coeffs
        np.testing.assert_array_equal(
            h.result(), WK.DilithiumEngine(64).oracle_np(iso)[0])


# --- limbo & pause semantics ---------------------------------------------------

def test_dead_host_limbo_delivers_at_cordon():
    cfg = ClusterConfig(n_hosts=2, fault_plan="kill@0.0005:h1",
                        serve=ServeConfig(n_c=8, max_age_s=10.0,
                                          validate=False))
    cluster = ClusterServer(cfg, coscheduler_factory=lambda h: CLUSTER_COS)
    fo = cluster.failover
    t0 = _tenant_on_host(cluster.router, 0)
    t1 = _tenant_on_host(cluster.router, 1)
    assert not cluster.submit(_dil_request(t0, 256), now=0.0).rejected
    # t=0.001: the kill has applied but silence (1 ms) is inside the 4 ms
    # bound — the owner is dead yet uncordoned, so the request parks in the
    # LB's limbo retry queue instead of being served or rejected.
    h_limbo = cluster.submit(_dil_request(t1, 256), now=0.001)
    assert fo.state[1] == "dead"
    assert not h_limbo.done() and not h_limbo.rejected
    assert len(fo.limbo) == 1 and fo.lost() == 1   # recoverable, unsettled
    # t=0.006: silence crosses the bound on this tick → cordon delivers the
    # limbo queue through normal admission on the post-cordon owner.
    cluster.pump(0.006)
    assert 1 in fo.cordoned
    assert fo.limbo_delivered == 1 and not fo.limbo
    assert fo.lost() == 0
    assert not h_limbo.rejected
    assert cluster.hosts[0].batcher.depth == 2
    cluster.drain(0.01)
    assert h_limbo.done() and not h_limbo.rejected
    ev = [e for e in fo.events if e["kind"] == "cordon"]
    assert len(ev) == 1 and ev[0]["cause"] == "gossip_silence"
    assert ev[0]["limbo_delivered"] == 1


def test_pause_cordons_reroute_only_and_keeps_serving():
    cfg = ClusterConfig(n_hosts=2,
                        fault_plan="pause@0.0005:h1,recover@0.008:h1",
                        serve=ServeConfig(n_c=8, max_age_s=10.0,
                                          validate=False))
    cluster = ClusterServer(cfg, coscheduler_factory=lambda h: CLUSTER_COS)
    fo = cluster.failover
    t1 = _tenant_on_host(cluster.router, 1)
    t1b = _tenant_on_host(cluster.router, 1, skip={t1})   # pre-cordon pick
    held = cluster.submit(_dil_request(t1, 256), now=0.0)
    cluster.pump(0.001)                       # applies the pause
    assert fo.state[1] == "paused"
    cluster.pump(0.006)                       # silence crosses → cordon
    ev = [e for e in fo.events if e["kind"] == "cordon"]
    assert len(ev) == 1 and ev[0]["mode"] == "reroute_only"
    assert ev[0]["replayed"] == 0 and fo.replayed == 0
    # a paused host keeps its rows (no replay), new arrivals re-route
    assert cluster.hosts[1].batcher.depth == 1
    rerouted = cluster.submit(_dil_request(t1b, 256), now=0.0065)
    assert not rerouted.rejected
    assert cluster.hosts[0].batcher.depth == 1
    cluster.pump(0.009)                       # recover: rejoin, state intact
    assert fo.state[1] == "serving" and not fo.cordoned
    assert cluster.router.live_hosts == (0, 1)
    cluster.drain(0.01)
    assert held.done() and rerouted.done() and fo.lost() == 0


# --- transient load shedding ---------------------------------------------------

def test_shed_watermark_sticky_sheds_and_p2c_diverts():
    probe = TenantHashRouter(3)
    owner = probe.host_for(0)
    cfg = ClusterConfig(n_hosts=3, pinned={999: owner}, shed_watermark=0.5,
                        serve=ServeConfig(n_c=16, max_age_s=10.0,
                                          validate=False, max_pending=20))
    cluster = ClusterServer(cfg, coscheduler_factory=lambda h: CLUSTER_COS)
    fo = cluster.failover
    # 12 pending rows on the owner (> watermark 0.5 × 20 = 10), from the
    # sticky tenant; the t=0.01 tick republishes that depth as the digest.
    for _ in range(12):
        assert not cluster.submit(_dil_request(0, 256), now=0.0).rejected
    fo._transient_until = 1.0                 # as _cordon would have set it
    shed = cluster.submit(_dil_request(0, 256), now=0.01)
    assert shed.rejected and shed.decision.reason == "shed"
    assert shed.decision.retry_after_s == pytest.approx(1.0 - 0.01)
    # pinned tenants are sticky too — never split across hosts mid-transient
    pinned = cluster.submit(_dil_request(999, 256), now=0.0101)
    assert pinned.rejected and pinned.decision.reason == "shed"
    # a non-sticky tenant of the saturated owner diverts power-of-two to
    # its rendezvous alternate (shallow digest) instead of shedding
    t_b = _tenant_on_host(cluster.router, owner, skip={0, 999})
    second = [h for h in cluster.router.choices(t_b, 2) if h != owner][0]
    diverted = cluster.submit(_dil_request(t_b, 256), now=0.0102)
    assert not diverted.rejected
    assert cluster.hosts[second].batcher.depth == 1
    assert fo.sheds == 2 and fo.diverted == 1
    by = cluster.hosts[owner].telemetry.snapshot()["admission"]["by_reason"]
    assert by["shed"] == 2
    snap = cluster.snapshot()["failover"]
    assert snap["sheds"] == 2 and snap["diverted"] == 1
    assert snap["transient_until"] == 1.0
    # outside the transient window the watermark is inert
    late = cluster.submit(_dil_request(0, 256), now=2.0)
    assert not late.rejected


# --- chaos parity ---------------------------------------------------------------

@pytest.mark.parametrize("n_hosts", [2, 4])
def test_kill_recover_chaos_matches_no_failure_replay(n_hosts):
    """Acceptance: kill 1 of N hosts mid-trace (recover later); per-tenant
    results are bit-for-bit those of the identical no-failure run, nothing
    is lost or double-served, and the cordon was silence-driven."""
    base, _, _ = serve_crypto_cluster(
        hosts=n_hosts, coscheduler_factory=lambda h: CLUSTER_COS, **CHAOS_KW)
    chaos, snap, _ = serve_crypto_cluster(
        hosts=n_hosts, coscheduler_factory=lambda h: CLUSTER_COS,
        fault_plan=CHAOS_PLAN, **CHAOS_KW)
    assert set(chaos.outputs) == set(base.outputs)
    for tid, row in base.outputs.items():
        np.testing.assert_array_equal(chaos.outputs[tid], row)
    fo = snap["failover"]
    s = fo["summary"]
    assert s["kills"] == 1 and s["recovers"] == 1
    assert s["cordons_by_cause"].get("gossip_silence", 0) >= 1
    assert s["replayed"] > 0 and s["deduped"] == 0
    assert fo["lost"] == 0 and fo["limbo_pending"] == 0
    assert fo["host_states"] == {h: "serving" for h in range(n_hosts)}
    assert snap["routing"]["live_hosts"] == list(range(n_hosts))
    assert snap["drain_barrier"]["complete"]
    assert snap["drain_barrier"]["serving_hosts"] == n_hosts
    assert summarize_failover(fo["events"]) == s


def test_chaos_trace_validates_and_silence_alert_fires_and_resolves(tmp_path):
    """The traced chaos run exports a causally-valid Perfetto trace in which
    gossip_silence fires during the outage and resolves after rejoin, and
    the fleet metrics carry the failover series."""
    trace_path = tmp_path / "chaos_trace.json"
    metrics_path = tmp_path / "chaos_metrics.prom"
    _, snap, _ = serve_crypto_cluster(
        hosts=2, coscheduler_factory=lambda h: CLUSTER_COS,
        fault_plan=CHAOS_PLAN, trace_out=str(trace_path),
        metrics_out=str(metrics_path),
        telemetry_out=str(tmp_path / "chaos_telemetry.json"), **CHAOS_KW)
    assert snap["failover"]["lost"] == 0
    report = validate_chrome_trace(str(trace_path))
    assert report["requests"] > 0
    with open(trace_path) as f:
        names = [ev["name"] for ev in json.load(f)["traceEvents"]]
    assert "fault:kill" in names and "fault:recover" in names
    assert "failover:h1" in names
    assert "alert_firing:gossip_silence" in names
    assert "alert_resolved:gossip_silence" in names
    text = metrics_path.read_text()
    assert "repro_cluster_replayed_total" in text
    assert "repro_cluster_sheds_total" in text


# --- mid-drain failure ----------------------------------------------------------

@pytest.mark.parametrize("n_hosts", [2, 4])
def test_drain_barrier_completes_with_mid_barrier_kill(n_hosts):
    """A kill scripted at exactly the drain instant lands between quiesce
    and flush; the dead host's journal replays onto the already-draining
    survivors and the barrier still resolves every admitted request."""
    cfg = ClusterConfig(
        n_hosts=n_hosts,
        fault_plan=FaultPlan([FaultEvent(0.001, "kill", 1)]),
        serve=ServeConfig(n_c=8, max_age_s=10.0, validate=False))
    cluster = ClusterServer(cfg, coscheduler_factory=lambda h: CLUSTER_COS)
    handles, victims = [], 0
    seen = set()
    for host in range(n_hosts):
        for _ in range(2):
            tid = _tenant_on_host(cluster.router, host, skip=seen)
            seen.add(tid)
            handles.append(cluster.submit(_dil_request(tid, 256), now=0.0))
            victims += host == 1
    assert all(not h.rejected for h in handles)
    flushed = cluster.drain(0.001)
    assert flushed > 0 and cluster.drained
    assert all(h.done() and not h.rejected for h in handles)
    fo = cluster.failover
    ev = [e for e in fo.events if e["kind"] == "cordon"]
    assert len(ev) == 1 and ev[0]["cause"] == "drain_probe"
    assert fo.replayed == victims and fo.lost() == 0
    bar = cluster.snapshot()["drain_barrier"]
    assert bar["complete"] and bar["hosts"] == n_hosts
    assert bar["serving_hosts"] == n_hosts - 1
    assert bar["inflight_groups"] == 0
