"""Multi-host sharded serving: routing, gossip, drain barrier, parity, merge.

Everything here runs on the deterministic virtual clock — host counts,
gossip staleness scenarios, and drain barriers are all exercised on one
machine with no wall-clock sensitivity.
"""
import numpy as np
import pytest

from repro.cluster import (ClusterConfig, ClusterServer, GossipBus,
                           TenantHashRouter, load_imbalance, merge_snapshots,
                           stable_tenant_hash)
from repro.core import field as F
from repro.core.scheduler import TenantRequest
from repro.core.scheduler.coscheduler import SliceCoScheduler
from repro.launch.serve import serve_crypto, serve_crypto_cluster
from repro.serve import CryptoServer, ServeConfig
from repro.serve.telemetry import BatchRecord, Telemetry

RNG = np.random.default_rng(17)

# One co-scheduler shared by every host of every cluster in this module (and
# by the offline replays): per-(workload, d_bucket) compiled programs are
# exactly what hosts reuse, and sharing keeps the suite from recompiling the
# mixed eager/lazy engine set once per host count.
LAZY_COS = SliceCoScheduler(accum="int32_native", d_tile=171,
                            reduction_by_workload={"dilithium": "lazy"})
PLAIN_COS = SliceCoScheduler()


def _dil_request(tid, d, t=0.0):
    coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d, dtype=np.uint64),
                        np.uint32)
    return TenantRequest(tid, "dilithium", d, t, coeffs)


def _tenant_on_host(router, host, start=0):
    tid = start
    while router.host_for(tid) != host:
        tid += 1
    return tid


# --- ingress router ------------------------------------------------------------

def test_router_stable_and_pinned():
    r = TenantHashRouter(4, pinned={7: 2})
    # process-independent: CRC32, not salted hash()
    assert stable_tenant_hash(123) == 0x884863D2           # crc32(b"123")
    assert stable_tenant_hash("123") == stable_tenant_hash(123)
    assert all(r.host_for(t) == r.host_for(t) for t in range(100))
    assert r.host_for(7) == 2                       # pin overrides the hash
    parts = r.partition(range(1000))
    assert sorted(sum(parts.values(), [])) == list(range(1000))
    assert all(len(v) > 150 for v in parts.values())   # near-uniform spread
    with pytest.raises(ValueError):
        TenantHashRouter(2, pinned={0: 5})
    with pytest.raises(ValueError):
        TenantHashRouter(0)


def test_cluster_routes_by_tenant_hash_and_pinning():
    cfg = ClusterConfig(n_hosts=3, pinned={99: 1},
                        serve=ServeConfig(n_c=64, max_age_s=10.0,
                                          validate=False))
    cluster = ClusterServer(cfg, coscheduler_factory=lambda h: PLAIN_COS)
    for tid in (0, 1, 2, 3, 99):
        cluster.submit(_dil_request(tid, 64), now=0.0)
    expect = [0, 0, 0]
    for tid in (0, 1, 2, 3):
        expect[cluster.router.host_for(tid)] += 1
    expect[1] += 1                                   # the pinned tenant
    assert [h.batcher.depth for h in cluster.hosts] == expect
    assert cluster.snapshot()["routing"]["per_host_submissions"] == expect


# --- gossip --------------------------------------------------------------------

def test_gossip_period_gating_and_staleness_bound():
    g = GossipBus(2, period_s=0.01, staleness_factor=2.0)
    assert g.staleness_bound_s == pytest.approx(0.02)
    assert g.maybe_publish(1, 10, now=0.0)
    assert not g.maybe_publish(1, 20, now=0.005)     # inside the period
    assert g.maybe_publish(1, 20, now=0.01)
    # fresh digest is used and its staleness recorded
    v = g.cluster_view(0, local_depth=3, now=0.025)
    assert v.peer_depth == 20 and v.contributing_hosts == 2
    assert v.max_staleness_s == pytest.approx(0.015)
    assert v.total_depth == 23 and v.per_host_equiv == pytest.approx(11.5)
    # past the bound the digest is dropped, never consumed
    v2 = g.cluster_view(0, local_depth=3, now=0.031)
    assert v2.peer_depth == 0 and v2.stale_dropped == 1
    assert v2.per_host_equiv == pytest.approx(3.0)
    snap = g.snapshot()
    assert snap["stale_drops"] == 1
    assert snap["used_staleness_max_s"] <= snap["staleness_bound_s"]


def test_gossip_dead_host_pruned_after_one_drop():
    """A host that stops publishing costs exactly one stale drop, ever: the
    first view that ages its digest past the bound also prunes it, so later
    views neither consume nor re-drop it.  Republishing revives the host."""
    g = GossipBus(3, period_s=0.01, staleness_factor=2.0)
    g.publish(1, 5, now=0.0)
    g.publish(2, 7, now=0.0)
    v = g.cluster_view(0, local_depth=0, now=0.01)
    assert v.peer_depth == 12 and v.stale_dropped == 0
    # host 1 dies; host 2 keeps publishing; views every period for 1 s
    for i in range(2, 102):
        now = 0.01 * i
        g.maybe_publish(2, 7, now=now)
        v = g.cluster_view(0, local_depth=0, now=now)
    assert v.peer_depth == 7 and v.contributing_hosts == 2
    snap = g.snapshot()
    assert snap["stale_drops"] == 1          # pre-fix: one drop per view
    assert snap["pruned_digests"] == 1
    # a pruned host that publishes again is simply fresh
    g.publish(1, 3, now=1.02)
    v = g.cluster_view(0, local_depth=0, now=1.025)
    assert v.peer_depth == 10 and v.stale_dropped == 0


def test_gossip_gated_admission_rejects_on_cluster_depth():
    """Acceptance: the SLO gate rejects on cluster-wide depth that
    local-only state would admit, and never consumes a digest older than
    period × 2."""
    period = 0.01
    cfg = ClusterConfig(
        n_hosts=2, gossip_period_s=period,
        serve=ServeConfig(n_c=64, max_age_s=10.0, validate=False,
                          slo_deadline_s=0.1))
    cluster = ClusterServer(cfg, coscheduler_factory=lambda h: PLAIN_COS)
    for srv in cluster.hosts:
        srv.admission.service_rate = 100.0           # pin the EWMA: 100 ops/s
        srv.admission.ewma_alpha = 0.0
    # host 1 is the victim we overload; its *local* SLO gate would reject the
    # pile-up itself, so disable it there — the point is host 0's gate acting
    # on gossiped cluster state.
    cluster.hosts[1].admission.slo_deadline_s = None
    t_cold = _tenant_on_host(cluster.router, 0)
    # pile 30 pending rows onto host 1 (nothing dispatches: n_c=64, age=10s)
    tid = 0
    for _ in range(30):
        tid = _tenant_on_host(cluster.router, 1, start=tid)
        h = cluster.submit(_dil_request(tid, 64), now=0.0)
        assert not h.rejected
        tid += 1
    assert cluster.hosts[1].batcher.depth == 30

    # t=0.02: the tick publishes host 1's depth, then host 0 sees cluster
    # state: local 0 (wait 0s — local-only admits), cluster 30/2 = 15 rows
    # → 0.15s predicted wait > 0.1s SLO → cluster rejection.
    h = cluster.submit(_dil_request(t_cold, 64), now=0.02)
    assert h.rejected and h.decision.reason == "cluster_slo_miss"
    assert h.decision.retry_after_s == pytest.approx(0.15)
    # local-only state would have admitted this request
    local = cluster.hosts[0].admission.admit(
        _dil_request(t_cold + 10, 64), 0.02,
        pending=cluster.hosts[0].batcher.depth)
    assert local.admitted

    # digest aged inside the bound (period < age ≤ 2×period) is still used:
    # submit directly to host 0 so no tick refreshes host 1's digest
    h2 = cluster.hosts[0].submit(_dil_request(t_cold + 20, 64), now=0.035)
    assert h2.rejected and h2.decision.reason == "cluster_slo_miss"
    # digest aged past the bound is dropped — local-only state decides,
    # which admits (a quiet host's stale depth must not gate admission)
    h3 = cluster.hosts[0].submit(_dil_request(t_cold + 30, 64), now=0.045)
    assert not h3.rejected
    g = cluster.snapshot()["gossip"]
    assert g["stale_drops"] >= 1
    assert g["used_staleness_max_s"] == pytest.approx(0.015)
    assert g["used_staleness_max_s"] <= g["staleness_bound_s"]
    by = cluster.hosts[0].telemetry.snapshot()["admission"]["by_reason"]
    assert by["cluster_slo_miss"] == 2


# --- distributed drain barrier -------------------------------------------------

def test_drain_barrier_quiesces_fleet_then_flushes():
    cfg = ClusterConfig(n_hosts=3,
                        serve=ServeConfig(n_c=64, max_age_s=10.0,
                                          validate=False))
    cluster = ClusterServer(cfg, coscheduler_factory=lambda h: PLAIN_COS)
    handles = []
    for host in range(3):
        tid = _tenant_on_host(cluster.router, host)
        handles.append(cluster.submit(_dil_request(tid, 64), now=0.0))
    assert not cluster.drained
    flushed = cluster.drain(0.001)
    assert flushed == 3 and cluster.drained
    assert all(h.done() and not h.rejected for h in handles)
    # post-barrier ingress is rejected on *every* host, not just one
    for host in range(3):
        tid = _tenant_on_host(cluster.router, host, start=1000)
        h = cluster.submit(_dil_request(tid, 64), now=0.002)
        assert h.rejected and h.decision.reason == "draining"
    bar = cluster.snapshot()["drain_barrier"]
    assert bar["complete"] and bar["hosts"] == 3
    assert bar["batches_flushed"] == 3
    assert bar["quiesced_at"] <= bar["drained_at"]


# --- cluster vs single-host parity ---------------------------------------------

@pytest.mark.parametrize("n_hosts", [1, 2, 4])
def test_cluster_drain_matches_single_host_replay(n_hosts):
    """Acceptance: draining an N-host cluster yields bit-for-bit identical
    per-tenant results to the single-host offline replay of the same trace,
    with mixed eager/lazy reduction classes."""
    kw = dict(duration_s=0.01, rate_hz=1024, seed=5, d_uniform=256,
              accum="int32_native", validate=False)
    offline_results, n_ops, _ = serve_crypto(coscheduler=LAZY_COS, **kw)
    offline = {}
    for res in offline_results:
        offline.update(res.outputs)

    load, snap, _ = serve_crypto_cluster(
        hosts=n_hosts, n_c=8, max_age_s=0.002, d_tile=171,
        reduction_by_workload={"dilithium": "lazy"},
        coscheduler_factory=lambda h: LAZY_COS, **kw)
    assert set(load.outputs) == set(offline) and n_ops == len(offline)
    for tid, row in offline.items():
        np.testing.assert_array_equal(load.outputs[tid], row)
    m = snap["merged"]
    assert m["requests_served"] == n_ops
    assert set(m["per_workload"]) == {"dilithium", "bn254"}
    assert m["per_workload"]["dilithium"]["reduction"] == "lazy"
    assert m["per_workload"]["bn254"]["reduction"] == "eager"
    assert snap["n_hosts"] == n_hosts and len(snap["per_host"]) == n_hosts
    assert snap["drain_barrier"]["complete"]
    if n_hosts > 1:
        # the trace actually spread across hosts (hash ingress works)
        assert sum(1 for s in snap["per_host"]
                   if s["requests_served"] > 0) > 1


# --- warm-start compile cache --------------------------------------------------

def test_warm_start_first_dispatch_triggers_zero_new_traces():
    cos = SliceCoScheduler()
    programs = [("dilithium", 64), ("dilithium", 128)]
    server = CryptoServer(
        ServeConfig(n_c=4, max_age_s=10.0, validate=False,
                    warm_start=programs),
        coscheduler=cos)
    assert server.warm_traces == 2
    assert all(cos.trace_counts[key] == 1 for key in programs)
    reqs = [_dil_request(i, d) for i, d in enumerate((64, 60, 100, 128))]
    handles = [server.submit(r, now=0.0) for r in reqs]
    server.drain(0.001)
    # first live dispatch of both warmed programs: zero new XLA traces
    assert all(cos.trace_counts[key] == 1 for key in programs)
    from repro.core import workloads as WK
    for r, h in zip(reqs, handles):
        d = server.batcher.bucket_for(r.degree)
        iso = np.zeros((1, d), np.uint32)
        iso[0, : r.degree] = r.coeffs
        np.testing.assert_array_equal(h.result(),
                                      WK.DilithiumEngine(d).oracle_np(iso)[0])
    # without row padding the warmed shapes could never be reused — reject
    with pytest.raises(ValueError, match="pad_rows"):
        CryptoServer(ServeConfig(pad_rows=False, warm_start=programs),
                     coscheduler=cos)


# --- telemetry merge -----------------------------------------------------------

def _random_telemetry(rng, n_batches, reason_pool=("full", "age", "drain")):
    t = Telemetry()
    for _ in range(n_batches):
        workload = rng.choice(["dilithium", "bn254"])
        lazy = workload == "dilithium"
        t.record_batch(BatchRecord(
            workload=str(workload), d_bucket=int(rng.choice([64, 256])),
            n_c=int(rng.integers(1, 9)),
            close_reason=str(rng.choice(reason_pool)),
            m_occupancy=float(rng.uniform(0, 1)),
            k_occupancy=float(rng.uniform(0, 1)),
            queue_depth=int(rng.integers(0, 50)),
            service_s=float(rng.uniform(0, 1e-2)),
            age_s=float(rng.uniform(0, 1e-2)),
            reduction="lazy" if lazy else "eager",
            n_folds=1 if lazy else 9))
        t.record_admission(str(rng.choice(["ok", "ok", "queue_full"])))
    for _ in range(4 * n_batches):
        t.observe_latency(float(rng.uniform(0, 0.1)),
                          queue_wait_s=float(rng.uniform(0, 0.05)))
    return t


def test_merge_snapshots_matches_concatenated_records():
    """Satellite acceptance: merging K per-host snapshots reproduces the
    quantiles/counters of the concatenated batch records within the
    documented tolerance (exact samples path: 1e-9 relative)."""
    rng = np.random.default_rng(23)
    parts = [_random_telemetry(rng, n) for n in (7, 13, 5)]
    combined = Telemetry()
    for t in parts:
        for rec in t.batches:
            combined.record_batch(rec)
        for reason, n in t.admission_counts.items():
            for _ in range(n):
                combined.record_admission(reason)
        for lat, qw in zip(t.latency.samples, t.queue_wait.samples):
            combined.observe_latency(lat, queue_wait_s=qw)
    merged = merge_snapshots([t.snapshot(include_samples=True)
                              for t in parts])
    want = combined.snapshot()
    rel = 1e-9
    for key in ("batches", "requests_served", "queue_depth_max"):
        assert merged[key] == want[key], key
    for key in ("k_occupancy_mean", "m_occupancy_mean", "queue_depth_mean",
                "service_s_total"):
        assert merged[key] == pytest.approx(want[key], rel=rel), key
    assert merged["close_reasons"] == want["close_reasons"]
    assert merged["reduction_stalls"] == want["reduction_stalls"]
    assert merged["admission"] == want["admission"]
    for w, stats in want["per_workload"].items():
        got = merged["per_workload"][w]
        for k, v in stats.items():
            if isinstance(v, float):
                assert got[k] == pytest.approx(v, rel=rel), (w, k)
            else:
                assert got[k] == v, (w, k)
    for hist in ("latency", "queue_wait"):
        assert merged[hist]["merged_exact"] is True
        for q in ("count", "mean_s", "p50_s", "p95_s", "p99_s", "max_s"):
            assert merged[hist][q] == pytest.approx(want[hist][q], rel=rel), \
                (hist, q)
    imb = merged["load_imbalance"]
    assert imb["per_host_requests"] == [t.snapshot()["requests_served"]
                                        for t in parts]
    assert imb["max_over_mean"] >= 1.0


def test_merge_without_samples_is_flagged_approximate():
    rng = np.random.default_rng(29)
    parts = [_random_telemetry(rng, 4) for _ in range(2)]
    merged = merge_snapshots([t.snapshot() for t in parts])   # no samples
    assert merged["latency"]["merged_exact"] is False
    # max of maxes stays exact even on the approximate path
    assert merged["latency"]["max_s"] == pytest.approx(
        max(t.latency.percentile(100) for t in parts))
    assert merged["latency"]["count"] == sum(len(t.latency) for t in parts)


def test_merge_mixed_cross_host_reduction_modes():
    """Hosts running the same workload under different fold disciplines
    merge per-mode batch counts; the derived label reads "mixed" (a single
    agreeing mode keeps its own name)."""
    a, b = Telemetry(), Telemetry()
    rec = dict(workload="dilithium", d_bucket=64, n_c=1, close_reason="full",
               m_occupancy=0.5, k_occupancy=0.5, queue_depth=0,
               service_s=1e-3, age_s=1e-3)
    a.record_batch(BatchRecord(reduction="lazy", n_folds=1, **rec))
    a.record_batch(BatchRecord(reduction="lazy", n_folds=1, **rec))
    b.record_batch(BatchRecord(reduction="eager", n_folds=9, **rec))
    merged = merge_snapshots([a.snapshot(), b.snapshot()])
    w = merged["per_workload"]["dilithium"]
    assert w["reduction_batches"] == {"lazy": 2, "eager": 1}
    assert w["reduction"] == "mixed"
    agree = merge_snapshots([a.snapshot(), a.snapshot()])
    assert agree["per_workload"]["dilithium"]["reduction"] == "lazy"


def test_merge_degenerate_hosts():
    """The fleet merge must survive hosts that served nothing: zero batches,
    empty histograms, and snapshots missing whole sections."""
    busy, idle = Telemetry(), Telemetry()
    busy.record_batch(BatchRecord(
        workload="dilithium", d_bucket=64, n_c=2, close_reason="full",
        m_occupancy=0.5, k_occupancy=0.75, queue_depth=1,
        service_s=1e-3, age_s=1e-3, reduction="eager", n_folds=9))
    busy.observe_latency(0.01, queue_wait_s=0.002)
    merged = merge_snapshots([busy.snapshot(include_samples=True),
                              idle.snapshot(include_samples=True)])
    assert merged["batches"] == 1
    assert merged["requests_served"] == 2
    assert merged["latency"]["count"] == 1
    assert merged["latency"]["merged_exact"] is True
    assert merged["k_occupancy_mean"] == pytest.approx(0.75)
    w = merged["per_workload"]["dilithium"]
    assert w["batches"] == 1 and w["reduction"] == "eager"
    # an all-idle fleet merges to zeros, not a crash
    empty = merge_snapshots([idle.snapshot(), idle.snapshot()])
    assert empty["batches"] == 0 and empty["per_workload"] == {}
    assert empty["latency"]["count"] == 0
    assert empty["penalty"] == {}


def test_merge_legacy_host_sections():
    """Hosts predating a section (no penalty ledger, scalar ``reduction``
    label instead of per-mode counts) contribute what they have."""
    busy = Telemetry()
    busy.record_batch(BatchRecord(
        workload="dilithium", d_bucket=64, n_c=1, close_reason="full",
        m_occupancy=0.5, k_occupancy=0.5, queue_depth=0,
        service_s=1e-3, age_s=1e-3, reduction="eager", n_folds=9))
    legacy = busy.snapshot(include_samples=True)
    legacy.pop("penalty", None)
    legacy["per_workload"]["dilithium"].pop("reduction_batches", None)
    merged = merge_snapshots([busy.snapshot(include_samples=True), legacy])
    w = merged["per_workload"]["dilithium"]
    assert w["reduction_batches"] == {"eager": 2}
    assert w["reduction"] == "eager"
    assert merged["batches"] == 2


def test_load_imbalance_metrics():
    even = load_imbalance([10, 10, 10])
    assert even["max_over_mean"] == pytest.approx(1.0)
    assert even["cv"] == pytest.approx(0.0)
    hot = load_imbalance([30, 0, 0])
    assert hot["max_over_mean"] == pytest.approx(3.0)
    assert load_imbalance([0, 0])["max_over_mean"] == 1.0
