"""Device-parallel fleet: env bootstrap, device pinning, partition shapes,
dispatch-overlap audit, and device-mode ≡ simulated-oracle bit parity.

The module asks for 4 forced host devices *before* jax initialises; when
another test module already initialised jax (tier-1 runs collect this file
after ``test_cluster``), the multi-device cases skip and the parity /
validation / audit cases still run on whatever device count the process
has.  The ``tier2-devices`` CI job sets ``XLA_FLAGS`` in the environment so
every case runs under a real 4-device topology.
"""
from repro.launch.xla_env import (HOST_DEVICE_FLAG, force_host_device_count,
                                  maybe_force_host_device_count,
                                  with_host_device_count)

maybe_force_host_device_count(4)   # must precede any jax-importing line

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import ClusterConfig, ClusterServer
from repro.core.scheduler.coscheduler import (SliceCoScheduler,
                                              partition_devices,
                                              resolve_devices)
from repro.launch.serve import serve_crypto, serve_crypto_cluster
from repro.serve.telemetry import DispatchOverlapAuditor

N_DEV = jax.device_count()
multi = pytest.mark.skipif(N_DEV < 2, reason="needs >= 2 JAX devices")
quad = pytest.mark.skipif(N_DEV < 4, reason="needs >= 4 JAX devices")

# The parity cells mirror test_cluster's acceptance config (mixed eager/lazy
# reduction classes).  One shared oracle co-scheduler, and one pinned
# co-scheduler per *device* (hosts pinned to the same device share a
# compiled-program cache — bit-neutral, rows are what they are), keep the
# module from recompiling the engine set once per host count.
LAZY_KW = dict(accum="int32_native", d_tile=171,
               reduction_by_workload={"dilithium": "lazy"})
LAZY_COS = SliceCoScheduler(**LAZY_KW)
_PINNED_LAZY: dict = {}


def _pinned_lazy_factory(host: int) -> SliceCoScheduler:
    dev = host % N_DEV
    if dev not in _PINNED_LAZY:
        _PINNED_LAZY[dev] = SliceCoScheduler(devices=[dev], **LAZY_KW)
    return _PINNED_LAZY[dev]


# --- xla_env bootstrap ---------------------------------------------------------

def test_with_host_device_count_pure_edit():
    assert with_host_device_count(None, 4) == f"{HOST_DEVICE_FLAG}=4"
    # user flags survive; an existing count token is replaced, not stacked
    out = with_host_device_count(
        f"--xla_cpu_foo=1 {HOST_DEVICE_FLAG}=2 --xla_bar=x", 8)
    assert out.split() == ["--xla_cpu_foo=1", "--xla_bar=x",
                           f"{HOST_DEVICE_FLAG}=8"]
    with pytest.raises(ValueError):
        with_host_device_count("", 0)


def test_force_host_device_count_after_jax_init():
    jax.devices()   # ensure the backend is live
    env = {"XLA_FLAGS": "--xla_something=1"}
    # matching count: a no-op that must NOT clobber the caller's env
    force_host_device_count(N_DEV, env=env)
    assert env == {"XLA_FLAGS": "--xla_something=1"}
    with pytest.raises(RuntimeError):
        force_host_device_count(N_DEV + 1, env=env)
    # best-effort variant degrades to False instead of raising
    assert maybe_force_host_device_count(N_DEV + 1, env=env) is False
    assert env == {"XLA_FLAGS": "--xla_something=1"}


# --- devices= validation -------------------------------------------------------

def test_resolve_devices_rejects_bad_specs():
    assert resolve_devices(None) == list(jax.devices())
    assert resolve_devices([0]) == [jax.devices()[0]]
    assert resolve_devices([jax.devices()[0]]) == [jax.devices()[0]]
    with pytest.raises(ValueError, match="twice"):
        resolve_devices([0, 0])
    with pytest.raises(ValueError, match="out of range"):
        resolve_devices([N_DEV])
    with pytest.raises(ValueError, match="at least one"):
        resolve_devices([])


def test_coscheduler_devices_validation_at_construction():
    with pytest.raises(ValueError, match="twice"):
        SliceCoScheduler(devices=[0, 0])
    with pytest.raises(ValueError, match="out of range"):
        SliceCoScheduler(devices=[N_DEV + 7])


def test_default_coscheduler_is_unpinned():
    cos = SliceCoScheduler()
    assert not cos._pinned
    assert cos.devices == list(jax.devices())
    assert set(cos.device_ids()) == {d.id for d in jax.devices()}


# --- device partitioning -------------------------------------------------------

def test_partition_devices_shapes():
    with pytest.raises(ValueError):
        partition_devices(0)
    ids = [d.id for d in jax.devices()]
    # D >= n_parts: contiguous near-even chunks covering every device once
    parts = partition_devices(1)
    assert [[d.id for d in p] for p in parts] == [ids]
    if N_DEV >= 2:
        parts = partition_devices(2)
        flat = [d.id for p in parts for d in p]
        assert flat == ids and abs(len(parts[0]) - len(parts[1])) <= 1
    # D < n_parts: round-robin singletons (hosts share device queues)
    parts = partition_devices(2 * N_DEV + 1)
    assert all(len(p) == 1 for p in parts)
    assert [p[0].id for p in parts] == [ids[i % N_DEV]
                                        for i in range(2 * N_DEV + 1)]


@quad
def test_partition_four_devices_distinct():
    parts = partition_devices(4)
    assert [len(p) for p in parts] == [1, 1, 1, 1]
    assert len({p[0].id for p in parts}) == 4


# --- pinned placement ----------------------------------------------------------

@multi
def test_pinned_placement_operand_planes_and_log():
    target = jax.devices()[N_DEV - 1]
    cos = SliceCoScheduler(devices=[target.id])
    assert cos._pinned and cos.devices == [target]
    assert cos.device_ids() == (target.id,)
    # operands commit to the pinned device
    op = cos._shard("dilithium", jnp.zeros((8, 64), jnp.uint32))
    assert op.devices() == {target}
    # the engine's twiddle planes re-home onto the pin (the process-wide
    # engine cache uploads to the default device)
    planes = cos.device_planes_for("dilithium", 64)
    for leaf in jax.tree_util.tree_leaves(planes):
        assert leaf.devices() == {target}
    # and the cache returns the same re-homed pytree, not a fresh upload
    assert cos.device_planes_for("dilithium", 64) is planes
    # both workload-class meshes stay inside the pin
    for workload in ("dilithium", "bn254"):
        assert set(cos.device_ids(workload)) <= {target.id}


@multi
def test_unpinned_planes_passthrough():
    cos = SliceCoScheduler()
    planes = cos.device_planes_for("dilithium", 64)
    engine_planes = cos.engine_for("dilithium", 64).device_planes()
    for a, b in zip(jax.tree_util.tree_leaves(planes),
                    jax.tree_util.tree_leaves(engine_planes)):
        assert a is b   # no re-upload, no extra device memory


# --- cluster-layer partitioning ------------------------------------------------

def test_cluster_partitions_devices_and_reports_them():
    cluster = ClusterServer(ClusterConfig(n_hosts=4, device_parallel=True))
    snap = cluster.snapshot()
    dv = snap["devices"]
    assert dv["device_parallel"] and len(dv["per_host"]) == 4
    expect = [[d.id for d in p] for p in partition_devices(4)]
    assert dv["per_host"] == expect
    assert dv["distinct"] == min(4, N_DEV)
    assert "dispatch_overlap" in snap
    # off by default: every host sees the whole process, nothing pinned
    plain = ClusterServer(ClusterConfig(n_hosts=2)).snapshot()["devices"]
    assert not plain["device_parallel"]
    assert plain["distinct"] == N_DEV


# --- dispatch-overlap audit (pure event-order unit test) -----------------------

def test_overlap_auditor_event_order():
    aud = DispatchOverlapAuditor()
    f0, f1, f2 = object(), object(), object()
    aud.on_launch(0, f0, [{"devices": (0,)}])
    aud.on_launch(1, f1, [{"devices": (1,)}])       # disjoint device: clean
    snap = aud.snapshot()
    assert snap["cross_host_shared_launches"] == 0
    assert snap["launch_concurrency_max"] == 2      # two devices busy
    aud.on_launch(2, f2, [{"devices": (0,)}])       # host 0 still in flight
    assert aud.snapshot()["cross_host_shared_launches"] == 1
    aud.on_gather(f0)
    aud.on_gather(f1)
    aud.on_gather(f2)
    snap = aud.snapshot()
    assert snap["inflight_launches"] == 0
    assert snap["launches"] == 3 and snap["flights"] == 3
    assert snap["cross_host_queue_share"] == pytest.approx(1 / 3)
    assert snap["per_host_devices"] == {"0": [0], "1": [1], "2": [0]}


def test_overlap_auditor_reset_drops_dead_host():
    aud = DispatchOverlapAuditor()
    f0, f1 = object(), object()
    aud.on_launch(0, f0, [{"devices": (0,)}])
    aud.on_launch(1, f1, [{"devices": (1,)}])
    aud.on_reset(0)   # host 0 died mid-flight
    assert aud.snapshot()["inflight_launches"] == 1
    # a later same-device launch by another host is clean — the dead
    # host's queue entry is gone, not leaked as permanently busy
    aud.on_launch(2, object(), [{"devices": (0,)}])
    assert aud.snapshot()["cross_host_shared_launches"] == 0


# --- device mode ≡ simulated oracle (bit parity) -------------------------------

@pytest.mark.parametrize("n_hosts", [1, 2, 4])
def test_device_mode_matches_simulated_oracle(n_hosts):
    """Acceptance: pinning each host slice to its own device changes
    *where* programs run, never *what* they compute — per-tenant outputs
    are bit-for-bit the single-host offline replay's, with mixed
    eager/lazy reduction classes, for N ∈ {1, 2, 4}."""
    kw = dict(duration_s=0.01, rate_hz=1024, seed=5, d_uniform=256,
              accum="int32_native", validate=False)
    offline_results, n_ops, _ = serve_crypto(coscheduler=LAZY_COS, **kw)
    offline = {}
    for res in offline_results:
        offline.update(res.outputs)

    load, snap, _ = serve_crypto_cluster(
        hosts=n_hosts, n_c=8, max_age_s=0.002, device_parallel=True,
        coscheduler_factory=_pinned_lazy_factory, **kw)
    assert set(load.outputs) == set(offline) and n_ops == len(offline)
    for tid, row in offline.items():
        np.testing.assert_array_equal(load.outputs[tid], row)
    assert snap["drain_barrier"]["complete"]
    ov = snap["dispatch_overlap"]
    assert ov["launches"] > 0 and ov["inflight_launches"] == 0
    if n_hosts <= N_DEV:
        # hosts on distinct devices → no cross-host queue gaps, ever
        assert snap["devices"]["distinct"] == n_hosts
        assert ov["cross_host_queue_share"] == 0.0
    if n_hosts > 1 and N_DEV > 1:
        assert ov["launch_concurrency_max"] >= 1


def test_device_mode_parity_under_kill_recover():
    """PR 9's chaos plan composed with device pinning: killing a host whose
    in-flight arrays live on its *own* device must still replay losslessly
    and converge to the oracle's bits."""
    kw = dict(duration_s=0.01, rate_hz=4096, seed=0, d_uniform=64,
              validate=False)
    shared = SliceCoScheduler()
    load_sim, _, _ = serve_crypto_cluster(
        hosts=4, n_c=8, max_age_s=0.002,
        coscheduler_factory=lambda h: shared, **kw)
    load_f, snap_f, _ = serve_crypto_cluster(
        hosts=4, n_c=8, max_age_s=0.002, device_parallel=True,
        fault_plan="kill@0.5:h1,recover@0.9:h1", **kw)
    fo = snap_f["failover"]
    assert fo["lost"] == 0 and fo["limbo_pending"] == 0, fo
    assert fo["summary"]["cordons"] >= 1
    assert all(h.done() for h in load_f.handles)
    assert set(load_f.outputs) == set(load_sim.outputs)
    for tid, row in load_sim.outputs.items():
        np.testing.assert_array_equal(load_f.outputs[tid], row)
