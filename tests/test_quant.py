"""W8A8 AQT path: quantisation error bounds + exact-K guarantee transfer."""
import numpy as np
import jax.numpy as jnp

from repro.quant import QuantizedLinear, quantize_symmetric, quantized_matmul
from repro.quant.aqt import exact_k_bound


def test_quantize_roundtrip():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    codes, scale = quantize_symmetric(w, axis=0)
    err = np.abs(np.asarray(codes, np.float32) * np.asarray(scale) - np.asarray(w))
    assert err.max() <= float(np.asarray(scale).max()) * 0.51


def test_quantized_linear_close_to_fp():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(128, 64)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    layer = QuantizedLinear(w)
    got = np.asarray(layer(x))
    want = np.asarray(x @ w)
    rel = np.abs(got - want).max() / np.abs(want).max()
    assert rel < 0.05


def test_quantized_matmul_int32_exact_within_window():
    """Integer-valued inputs inside the Prop-5.1 window are bit-exact."""
    rng = np.random.default_rng(2)
    k = 256
    assert k < exact_k_bound("int32_native")
    # integer tensors already on the int8 grid -> quantisation is lossless
    xi = rng.integers(-127, 128, (4, k))
    wi = rng.integers(-127, 128, (k, 16))
    x = jnp.asarray(xi, jnp.float32) / 127.0
    w_codes = jnp.asarray(wi, jnp.int8)
    w_scale = jnp.full((1, 16), 1.0 / 127.0, jnp.float32)
    out = np.asarray(quantized_matmul(x, w_codes, w_scale))
    want = (xi @ wi).astype(np.float64) / (127.0 * 127.0)
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_exact_k_bounds_match_paper():
    assert exact_k_bound("fp32_mantissa") == (1 << 24) // (255 * 128)  # 514
    assert exact_k_bound("int32_native") == ((1 << 31) - 1) // (255 * 128)
